"""Baseline algorithms (FedAvg / WRWGD / Hier-Local-QSGD) run + learn +
meter the hop types the paper's Fig. 2 compares."""

from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    WRWGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
    run_wrwgd,
)


def test_fedavg_learns_and_uses_ps(small_task):
    res = run_fedavg(small_task, FedAvgConfig(rounds=8, local_steps=8, eval_every=7))
    assert res.final_acc() > 0.8
    assert res.ledger.bits["client_to_ps"] > 0
    assert res.ledger.bits["es_to_es"] == 0


def test_wrwgd_learns_with_single_hop_rounds(small_task):
    # Diagnosis of the 0.667 < 0.75 failure: bisecting every PR back to the
    # seed commit reproduced the IDENTICAL 0.6666 accuracy at each one — no
    # regression from the dither swap or the global-slot key fold (both kept
    # bit parity); the walk was red from day one.  Root cause: the B.1 decay
    # eta_k = 1/(K sqrt(k+1)) was indexed by the LOCAL step k, restarting at
    # eta_0 on every visit, so the step size never annealed across the walk
    # and the model rattled between client optima.  Fixed by indexing the
    # schedule with the global walk round t (constant over one visit's K
    # steps): final_acc 0.91-0.96 across seeds 0-4 on this task.
    res = run_wrwgd(small_task, WRWGDConfig(rounds=30, local_steps=8, eval_every=29))
    assert res.final_acc() > 0.75
    # exactly one client->client model hop per round
    assert res.ledger.messages["client_to_client"] == 30


def test_hier_local_qsgd_learns_and_compresses(small_task):
    res = run_hier_local_qsgd(
        small_task, HierLocalQSGDConfig(rounds=3, local_steps=10, local_epochs=5,
                                        qsgd_levels=16, eval_every=2)
    )
    assert res.final_acc() > 0.5
    assert res.ledger.bits["es_to_ps"] > 0  # still offloads to the PS
    # quantized uplinks are smaller than the dense broadcasts
    per_up = res.ledger.bits["client_to_es"] / res.ledger.messages["client_to_es"]
    per_down = res.ledger.bits["es_to_client"] / res.ledger.messages["es_to_client"]
    assert per_up < per_down / 4


def test_fedchs_beats_baselines_on_es_to_ps_traffic(small_task):
    """The structural claim: Fed-CHS has zero PS traffic; HFL does not."""
    from repro.core import FedCHSConfig, run_fed_chs

    chs = run_fed_chs(small_task, FedCHSConfig(rounds=4, local_steps=10, eval_every=100))
    hlq = run_hier_local_qsgd(
        small_task, HierLocalQSGDConfig(rounds=1, local_steps=10, local_epochs=5,
                                        eval_every=100)
    )
    assert chs.ledger.bits["es_to_ps"] + chs.ledger.bits["ps_to_es"] == 0
    assert hlq.ledger.bits["es_to_ps"] + hlq.ledger.bits["ps_to_es"] > 0
