"""repro.part — availability traces, samplers, masked engine rounds, and the
pass-through/availability-aware protocol behaviors.

The seed-parity contract (FullParticipation == no sampler, bit-identical) is
pinned in tests/test_engine_parity.py; the closed-form ledger contract in
tests/test_ledger.py; deadline-dropout replay in tests/test_netsim.py.  This
module covers the subsystem itself.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import DenseChannel, QSGDChannel
from repro.core import AvailabilityAwareScheduler, FedCHSConfig, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
)
from repro.core.engine import RoundEngine
from repro.core.topology import make_topology
from repro.optim.local import MomentumSGD
from repro.part import (
    AlwaysOn,
    AvailabilityAware,
    BernoulliTrace,
    FullParticipation,
    GilbertElliottTrace,
    UniformK,
    is_full_participation,
    participation_mask,
)

# -- traces ------------------------------------------------------------------


def test_bernoulli_trace_is_deterministic_and_rate_correct():
    a = BernoulliTrace(p=0.7, seed=3)
    b = BernoulliTrace(p=0.7, seed=3)
    draws = [a.available(c, t) for c in range(10) for t in range(50)]
    assert draws == [b.available(c, t) for c in range(10) for t in range(50)]
    rate = np.mean(draws)
    assert 0.6 < rate < 0.8
    # a different seed gives a different trace
    c = BernoulliTrace(p=0.7, seed=4)
    assert draws != [c.available(cl, t) for cl in range(10) for t in range(50)]


def test_gilbert_elliott_is_query_order_independent():
    fwd = GilbertElliottTrace(p_fail=0.2, p_recover=0.3, seed=1)
    bwd = GilbertElliottTrace(p_fail=0.2, p_recover=0.3, seed=1)
    rounds = list(range(40))
    a = [fwd.available(2, t) for t in rounds]
    b = [bwd.available(2, t) for t in reversed(rounds)][::-1]
    assert a == b


def test_gilbert_elliott_produces_bursts_not_blips():
    """Outages under GE are runs with mean length ~1/p_recover, so the number
    of distinct outage *spells* is far below the number of down rounds."""
    tr = GilbertElliottTrace(p_fail=0.3, p_recover=0.25, seed=0)
    T = 400
    states = [tr.available(0, t) for t in range(T)]
    down = states.count(False)
    spells = sum(1 for t in range(1, T) if not states[t] and states[t - 1])
    assert down > 0.2 * T                      # it does go down
    assert spells < down                       # ...in multi-round bursts
    up_frac = states.count(True) / T
    assert abs(up_frac - tr.steady_state_up()) < 0.15


# -- samplers ----------------------------------------------------------------


def test_sampler_contracts():
    clients = [3, 1, 4, 1, 5, 9, 2, 6]
    assert FullParticipation().participants(0, clients) == clients
    assert is_full_participation(None) and is_full_participation(FullParticipation())
    assert not is_full_participation(AvailabilityAware(AlwaysOn()))

    aa = AvailabilityAware(AlwaysOn())
    assert aa.participants(7, clients) == clients

    uk = UniformK(k=3, seed=0)
    picks = uk.participants(5, list(range(10)))
    assert picks == uk.participants(5, list(range(10)))  # pure
    assert len(picks) == 3 and len(set(picks)) == 3
    assert set(picks) <= set(range(10))
    assert uk.participants(6, list(range(10))) != picks or \
           uk.participants(7, list(range(10))) != picks  # varies across rounds
    assert uk.participants(0, [1, 2]) == [1, 2]  # fewer candidates than k

    # UniformK respects its trace: never picks an unavailable client
    tr = BernoulliTrace(p=0.5, seed=2)
    uk_tr = UniformK(k=4, seed=0, trace=tr)
    for t in range(20):
        picked = uk_tr.participants(t, list(range(12)))
        assert all(tr.available(c, t) for c in picked)
        assert len(picked) <= 4


def test_uniform_k_draws_independently_per_candidate_set():
    """Distinct candidate sets queried in the same round (e.g. every cluster
    of a hierarchical round) must not pick correlated positions."""
    uk = UniformK(k=3, seed=0)
    positions_differ = any(
        [c for c in uk.participants(t, list(range(7)))]
        != [c - 10 for c in uk.participants(t, list(range(10, 17)))]
        for t in range(10)
    )
    assert positions_differ


def test_participation_mask():
    m = participation_mask([10, 11, 12, 13], [11, 13])
    np.testing.assert_array_equal(m, np.array([0.0, 1.0, 0.0, 1.0], np.float32))


# -- availability-aware scheduler --------------------------------------------


def test_availability_scheduler_skips_dead_clusters():
    topo = make_topology("full", 4)
    dead = {1}  # cluster 1 is never reachable
    sched = AvailabilityAwareScheduler(
        topo, [10, 40, 20, 30], lambda m, r: m not in dead, initial=0)
    order = [sched.advance() for _ in range(8)]
    assert 1 not in order
    assert set(order) == {0, 2, 3}


def test_availability_scheduler_falls_back_when_all_dead():
    topo = make_topology("ring", 3)
    sched = AvailabilityAwareScheduler(
        topo, [10, 20, 30], lambda m, r: False, initial=0)
    nxt = sched.advance()  # nothing reachable: the paper's plain rule applies
    assert nxt in (1, 2)


def test_availability_scheduler_probes_next_round():
    """m(t+1) is chosen with reachability evaluated at round t+1, not t."""
    topo = make_topology("full", 3)
    seen = []

    def reachable(m, r):
        seen.append(r)
        return True

    sched = AvailabilityAwareScheduler(topo, [1, 2, 3], reachable, initial=0)
    sched.advance()   # during round 0 -> picks m(1)
    assert set(seen) == {1}


# -- masked engine rounds ----------------------------------------------------


def _warm_engine_state(small_task, local_opt=None, channel=None):
    engine = RoundEngine(small_task.model, channel or DenseChannel(),
                         local_opt=local_opt)
    small_task.reset_loaders(0)
    members = small_task.cluster_members[0]
    n = len(members)
    params = small_task.init_params()
    gammas = jnp.asarray(small_task.cluster_weights(0))
    lrs = jnp.full((2, 2), 0.05, jnp.float32)
    batch = small_task.sample_round_batches(0, 4, 2)
    opt0 = engine.init_opt_state(params, n)
    # one full round so the optimizer state is nonzero before masking
    params, opt1, _ = engine.cluster_round(params, batch, gammas, lrs, None, opt0)
    return engine, params, opt1, gammas, lrs, n


def test_masked_round_freezes_dropped_opt_state(small_task):
    engine, params, opt1, gammas, lrs, n = _warm_engine_state(
        small_task, local_opt=MomentumSGD())
    mask = np.zeros(n, np.float32)
    mask[[0, 2]] = 1.0
    w = np.asarray(gammas) * mask
    gammas_r = jnp.asarray(w / w.sum())
    batch = small_task.sample_round_batches(0, 4, 2)
    _, opt2, _ = engine.cluster_round(params, batch, gammas_r, lrs, None, opt1,
                                      mask=mask)
    for before, after in zip(jax.tree.leaves(opt1), jax.tree.leaves(opt2)):
        for i in range(n):
            if mask[i]:
                assert not np.array_equal(np.asarray(after[i]), np.asarray(before[i]))
            else:
                np.testing.assert_array_equal(np.asarray(after[i]),
                                              np.asarray(before[i]))


def test_all_zero_mask_is_a_no_op_on_params(small_task):
    engine, params, opt1, gammas, lrs, n = _warm_engine_state(small_task)
    batch = small_task.sample_round_batches(0, 4, 2)
    mask = np.zeros(n, np.float32)
    new_params, _, losses = engine.cluster_round(
        params, batch, jnp.zeros_like(gammas), lrs, None, opt1, mask=mask)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(losses), np.zeros_like(losses))


# -- driver-level churn behavior ---------------------------------------------


class _Blackout:
    """Everyone is down in `dark` rounds; full participation otherwise."""

    def __init__(self, dark):
        self.dark = set(dark)

    def participants(self, round_idx, clients):
        return [] if round_idx in self.dark else list(clients)


def test_fed_chs_pass_through_round_forwards_model(small_task):
    cfg = FedCHSConfig(rounds=4, local_steps=4, local_epochs=2, eval_every=1,
                       seed=0, sampler=_Blackout({1}))
    res = run_fed_chs(small_task, cfg)
    evs = res.ledger.round_events()
    assert {e.hop for e in evs[1]} == {"es_to_es"}          # forwarded, no traffic
    assert res.ledger.round_bits("client_to_es").get(1, 0) == 0
    assert len([e for e in evs[1] if e.hop == "es_to_es"]) == 1
    # eval after the dark round still works (params simply unchanged by it)
    assert len(res.test_acc) == 4


def test_fed_chs_partial_round_drops_exactly_the_absent(small_task):
    tr = BernoulliTrace(p=0.5, seed=11)
    sampler = AvailabilityAware(tr)
    cfg = FedCHSConfig(rounds=5, local_steps=4, local_epochs=2, eval_every=10,
                       seed=1, initial_cluster=0, sampler=sampler)
    res = run_fed_chs(small_task, cfg)
    # round 0 is cluster 0: the uplink sender set is exactly the available set
    members = small_task.cluster_members[0]
    expect = {f"client:{i}" for i in sampler.participants(0, members)}
    assert res.ledger.round_senders(0, "client_to_es") == expect


def test_fed_chs_availability_scheduler_avoids_dark_clusters(small_task):
    class OneClusterDark:
        """Cluster `dark`'s clients are always down; everyone else is up."""

        def __init__(self, members):
            self.members = set(members)

        def participants(self, round_idx, clients):
            return [c for c in clients if c not in self.members]

    dark = 2
    sampler = OneClusterDark(small_task.cluster_members[dark])
    cfg = FedCHSConfig(rounds=8, local_steps=2, local_epochs=1, eval_every=10,
                       seed=0, initial_cluster=0, topology="full",
                       sampler=sampler, availability_scheduler=True)
    res = run_fed_chs(small_task, cfg)
    senders = {e.sender for e in res.ledger.events if e.hop == "es_to_es"}
    receivers = {e.receiver for e in res.ledger.events if e.hop == "es_to_es"}
    assert f"es:{dark}" not in senders | receivers
    # and no round was a pass-through: the walk only visited live clusters
    for t in range(8):
        assert res.ledger.round_bits("client_to_es")[t] > 0


def test_fedavg_empty_round_is_skipped(small_task):
    cfg = FedAvgConfig(rounds=3, local_steps=2, eval_every=1, seed=0,
                       sampler=_Blackout({1}))
    res = run_fedavg(small_task, cfg)
    assert 1 not in {e.round for e in res.ledger.events}
    n = small_task.num_clients
    assert res.ledger.messages["client_to_ps"] == 2 * n
    # the ledger still snapshots every round
    assert [r for r, _ in res.ledger.history] == [0, 1, 2]


def test_hier_dark_cluster_is_pass_through(small_task):
    class ClusterDark:
        def __init__(self, members):
            self.members = set(members)

        def participants(self, round_idx, clients):
            return [c for c in clients if c not in self.members]

    dark = 1
    sampler = ClusterDark(small_task.cluster_members[dark])
    cfg = HierLocalQSGDConfig(rounds=2, local_steps=4, local_epochs=2,
                              qsgd_levels=None, eval_every=1, seed=0,
                              sampler=sampler)
    res = run_hier_local_qsgd(small_task, cfg)
    ups = {e.sender for e in res.ledger.events if e.hop == "es_to_ps"}
    downs = {e.receiver for e in res.ledger.events if e.hop == "ps_to_es"}
    assert f"es:{dark}" not in ups          # nothing to upload
    assert f"es:{dark}" in downs            # but it stays in sync
    client_ups = {e.sender for e in res.ledger.events if e.hop == "client_to_es"}
    assert not client_ups & {f"client:{i}" for i in small_task.cluster_members[dark]}


def test_hier_dark_cluster_keeps_trajectory_of_reweighted_rest(small_task):
    """A dark cluster must not drag the global average toward the broadcast
    model: ES weights renormalize over the clusters that trained."""

    class ClusterDark:
        def __init__(self, members):
            self.members = set(members)

        def participants(self, round_idx, clients):
            return [c for c in clients if c not in self.members]

    sampler = ClusterDark(small_task.cluster_members[0])
    cfg = HierLocalQSGDConfig(rounds=1, local_steps=2, local_epochs=2,
                              qsgd_levels=None, eval_every=1, seed=3,
                              sampler=sampler)
    res = run_hier_local_qsgd(small_task, cfg)
    base = small_task.init_params()
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(res.final_params), jax.tree.leaves(base))
    )
    assert moved


def test_stochastic_channel_churn_is_reproducible(small_task):
    tr = GilbertElliottTrace(p_fail=0.3, p_recover=0.4, seed=6)
    cfg = FedCHSConfig(rounds=4, local_steps=4, local_epochs=2, eval_every=2,
                       seed=2, channel=QSGDChannel(8),
                       sampler=AvailabilityAware(tr))
    a = run_fed_chs(small_task, cfg)
    # fresh trace object: the cached-chain state must not leak across runs
    cfg2 = FedCHSConfig(rounds=4, local_steps=4, local_epochs=2, eval_every=2,
                        seed=2, channel=QSGDChannel(8),
                        sampler=AvailabilityAware(
                            GilbertElliottTrace(p_fail=0.3, p_recover=0.4, seed=6)))
    b = run_fed_chs(small_task, cfg2)
    assert a.ledger.events == b.ledger.events
    assert a.test_acc == b.test_acc and a.train_loss == b.train_loss


def test_channel_message_bits_unchanged_by_masking(small_task):
    """Dropped clients save bits by sending nothing; the messages that ARE
    sent cost exactly the channel's per-message bits."""
    tr = BernoulliTrace(p=0.6, seed=0)
    cfg = FedCHSConfig(rounds=3, local_steps=4, local_epochs=2, eval_every=10,
                       seed=0, qsgd_levels=16, sampler=AvailabilityAware(tr))
    res = run_fed_chs(small_task, cfg)
    from repro.comm.channels import channel_wire_bits

    q = channel_wire_bits(QSGDChannel(16), small_task.num_params(),
                          small_task.param_leaf_sizes())
    up_events = [e for e in res.ledger.events if e.hop == "client_to_es"]
    assert up_events and all(e.n_bits == q for e in up_events)
