"""Seed-parity regression: the engine-driven algorithms must reproduce the
pre-refactor (host-level, per-interaction) loop implementations at fixed seed.

The reference implementations below are verbatim copies of the pre-engine
driver loops: eager per-interaction staging, per-interaction `float()` host
syncs, Python loops over clusters, `key, sub = jax.random.split(key)` chains.
Two intentional deviations: (1) the Hier-Local-QSGD ES->PS hop splits its
PRNG key per leaf (the historical implementation reused one subkey for every
layer — the bug class the Channel abstraction removes); (2) stacked client
uplinks compress per-sender with `fold_in(sub, slot)` keys and per-leaf
packed-wire block boundaries (the packed-QSGD refactor: a sender's encoding
is independent of how many senders share the stacked uplink, which is what
lets ragged clusters run under the whole-run scan).  The references mirror
both via `qsgd_compress_tree` under an explicit per-sender vmap.

Tolerance: losses within 1e-5, accuracies within 1e-5 (test-set accuracy is
quantized in steps of 1/test_size, so this effectively requires identical
predictions). QSGD cases run short horizons: stochastic rounding (`floor`)
can amplify sub-ulp compiler-fusion differences into level flips over long
runs, but short fixed-seed trajectories are stable.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedCHSConfig, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    WRWGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
    run_wrwgd,
)
from repro.core.oracles import cluster_sgd, local_sgd, multi_client_local_sgd
from repro.core.scheduler import FedCHSScheduler
from repro.core.simulation import evaluate
from repro.core.topology import make_topology
from repro.kernels.ops import qsgd_compress_tree
from repro.optim.schedules import paper_sqrt_schedule
from repro.utils import tree_add


def _compress_stacked(deltas, sub, levels):
    """The engine's stacked-uplink keying (see `engine.compress_uplinks`):
    sender slot i compresses under fold_in(sub, i), so its message is
    independent of the stacked width."""
    n = jax.tree.leaves(deltas)[0].shape[0]
    return jax.vmap(
        lambda d, i: qsgd_compress_tree(d, jax.random.fold_in(sub, i), s=levels)
    )(deltas, jnp.arange(n))


def _assert_trajectories_match(ref, new, atol=1e-5):
    ref_rounds, ref_acc, ref_loss = ref
    assert ref_rounds == new.rounds
    np.testing.assert_allclose(new.train_loss, ref_loss, atol=atol, rtol=0)
    np.testing.assert_allclose(new.test_acc, ref_acc, atol=atol, rtol=0)


# --------------------------------------------------------------------------
# reference implementations (pre-refactor loop structure)
# --------------------------------------------------------------------------


def ref_fed_chs(task, config):
    task.reset_loaders(config.seed)
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.array([sched_fn(k) for k in range(K)], dtype=np.float32)

    topo = make_topology(config.topology, task.num_clusters, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    m0 = (
        int(rng.integers(task.num_clusters))
        if config.initial_cluster is None
        else config.initial_cluster
    )
    scheduler = FedCHSScheduler(topo, task.cluster_sizes, initial=m0)

    params = task.init_params()
    cluster_phase = cluster_sgd(task.model)
    multi_local = multi_client_local_sgd(task.model)
    key = jax.random.PRNGKey(config.seed + 1)

    rounds_log, acc_log, loss_log = [], [], []
    m = scheduler.state.current
    for t in range(config.rounds):
        gammas = jnp.asarray(task.cluster_weights(m))
        if E == 1 and config.qsgd_levels is None:
            b = task.sample_cluster_batches(m, K)
            params, loss = cluster_phase(params, b["x"], b["y"], gammas, jnp.asarray(lrs))
        else:
            loss_acc = 0.0
            for j in range(interactions):
                lr_slice = jnp.asarray(lrs[j * E : (j + 1) * E])
                b = task.sample_cluster_batches(m, E)
                xs = jnp.swapaxes(b["x"], 0, 1)
                ys = jnp.swapaxes(b["y"], 0, 1)
                new_p, losses = multi_local(params, xs, ys, lr_slice)
                deltas = jax.tree.map(lambda np_, op: np_ - op[None], new_p, params)
                if config.qsgd_levels is not None:
                    key, sub = jax.random.split(key)
                    deltas = _compress_stacked(deltas, sub, config.qsgd_levels)
                agg = jax.tree.map(lambda dl: jnp.einsum("n,n...->...", gammas, dl), deltas)
                params = tree_add(params, agg)
                loss_acc += float(jnp.mean(losses))
            loss = loss_acc / interactions

        m = scheduler.advance()
        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(evaluate(task.model, params, task.dataset))
            loss_log.append(float(loss))
    return rounds_log, acc_log, loss_log


def ref_fedavg(task, config):
    task.reset_loaders(config.seed)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = jnp.asarray([sched_fn(k) for k in range(K)], dtype=jnp.float32)

    params = task.init_params()
    multi_local = multi_client_local_sgd(task.model)
    gammas = jnp.asarray(task.global_weights())
    key = jax.random.PRNGKey(config.seed + 1)

    rounds_log, acc_log, loss_log = [], [], []
    n = task.num_clients
    for t in range(config.rounds):
        per_client = [task.sample_client_batches(i, K) for i in range(n)]
        xs = jnp.stack([b["x"] for b in per_client])
        ys = jnp.stack([b["y"] for b in per_client])
        new_p, losses = multi_local(params, xs, ys, lrs)
        deltas = jax.tree.map(lambda np_, op: np_ - op[None], new_p, params)
        if config.qsgd_levels is not None:
            key, sub = jax.random.split(key)
            deltas = _compress_stacked(deltas, sub, config.qsgd_levels)
        agg = jax.tree.map(lambda dl: jnp.einsum("n,n...->...", gammas, dl), deltas)
        params = tree_add(params, agg)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(evaluate(task.model, params, task.dataset))
            loss_log.append(float(jnp.mean(losses)))
    return rounds_log, acc_log, loss_log


def ref_wrwgd(task, config):
    task.reset_loaders(config.seed)
    K = config.local_steps
    # the walk's decaying schedule is indexed by the GLOBAL round t (constant
    # over the K local steps of one visit) — see wrwgd._walk_round_lrs
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)

    topo = make_topology(config.topology, task.num_clients, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    current = int(rng.integers(task.num_clients))

    params = task.init_params()
    local = local_sgd(task.model)

    rounds_log, acc_log, loss_log = [], [], []
    for t in range(config.rounds):
        b = task.sample_client_batches(current, K)
        lrs_t = jnp.full((K,), sched_fn(t), dtype=jnp.float32)
        params, loss = local(params, b["x"], b["y"], lrs_t)

        nbrs = list(topo.neighbors(current))
        if config.weighting == "data_size":
            w = task.client_sizes[nbrs]
            w = w / w.sum()
        else:
            w = np.full(len(nbrs), 1.0 / len(nbrs))
        current = int(rng.choice(nbrs, p=w))

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(evaluate(task.model, params, task.dataset))
            loss_log.append(float(loss))
    return rounds_log, acc_log, loss_log


def ref_hier_local_qsgd(task, config):
    task.reset_loaders(config.seed)
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)

    params = task.init_params()
    multi_local = multi_client_local_sgd(task.model)
    key = jax.random.PRNGKey(config.seed + 1)

    M = task.num_clusters
    cluster_gammas = [jnp.asarray(task.cluster_weights(m)) for m in range(M)]
    es_weights = jnp.asarray(
        np.array(task.cluster_sizes, dtype=np.float32) / sum(task.cluster_sizes)
    )

    rounds_log, acc_log, loss_log = [], [], []
    for t in range(config.rounds):
        cluster_params = [params] * M
        loss_acc = 0.0
        for j in range(interactions):
            lr_slice = jnp.asarray(lrs[j * E : (j + 1) * E])
            for m in range(M):
                b = task.sample_cluster_batches(m, E)
                xs = jnp.swapaxes(b["x"], 0, 1)
                ys = jnp.swapaxes(b["y"], 0, 1)
                new_p, losses = multi_local(cluster_params[m], xs, ys, lr_slice)
                deltas = jax.tree.map(
                    lambda np_, op: np_ - op[None], new_p, cluster_params[m]
                )
                if config.qsgd_levels is not None:
                    key, sub = jax.random.split(key)
                    deltas = _compress_stacked(deltas, sub, config.qsgd_levels)
                agg = jax.tree.map(
                    lambda dl, g=cluster_gammas[m]: jnp.einsum("n,n...->...", g, dl),
                    deltas,
                )
                cluster_params[m] = tree_add(cluster_params[m], agg)
                loss_acc += float(jnp.mean(losses))

        es_deltas = []
        for m in range(M):
            delta = jax.tree.map(lambda a, b: a - b, cluster_params[m], params)
            if config.qsgd_levels is not None:
                key, sub = jax.random.split(key)
                # per-leaf key split (the fixed ES->PS behavior)
                delta = qsgd_compress_tree(delta, sub, s=config.qsgd_levels)
            es_deltas.append(delta)
        stacked = jax.tree.map(lambda *xs_: jnp.stack(xs_), *es_deltas)
        agg = jax.tree.map(lambda x: jnp.einsum("m,m...->...", es_weights, x), stacked)
        params = tree_add(params, agg)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(evaluate(task.model, params, task.dataset))
            loss_log.append(loss_acc / (interactions * M))
    return rounds_log, acc_log, loss_log


# --------------------------------------------------------------------------
# parity assertions
# --------------------------------------------------------------------------


def test_fed_chs_grad_mode_parity(small_task):
    cfg = FedCHSConfig(rounds=5, local_steps=6, eval_every=2, seed=3)
    _assert_trajectories_match(ref_fed_chs(small_task, cfg), run_fed_chs(small_task, cfg))


def test_fed_chs_local_epochs_parity(small_task):
    cfg = FedCHSConfig(rounds=4, local_steps=6, local_epochs=3, eval_every=2, seed=1)
    _assert_trajectories_match(ref_fed_chs(small_task, cfg), run_fed_chs(small_task, cfg))


def test_fed_chs_qsgd_parity(small_task):
    cfg = FedCHSConfig(rounds=3, local_steps=4, local_epochs=2, qsgd_levels=16,
                       eval_every=1, seed=0)
    _assert_trajectories_match(ref_fed_chs(small_task, cfg), run_fed_chs(small_task, cfg))


def test_fedavg_parity(small_task):
    cfg = FedAvgConfig(rounds=3, local_steps=5, qsgd_levels=8, eval_every=1, seed=2)
    _assert_trajectories_match(ref_fedavg(small_task, cfg), run_fedavg(small_task, cfg))


def test_fedavg_dense_parity(small_task):
    cfg = FedAvgConfig(rounds=3, local_steps=5, eval_every=1, seed=0)
    _assert_trajectories_match(ref_fedavg(small_task, cfg), run_fedavg(small_task, cfg))


def test_wrwgd_parity(small_task):
    cfg = WRWGDConfig(rounds=8, local_steps=5, eval_every=3, seed=4)
    _assert_trajectories_match(ref_wrwgd(small_task, cfg), run_wrwgd(small_task, cfg))


def test_hier_local_qsgd_parity(small_task):
    # small_task has equal-size clusters, so the padded/masked vmapped round
    # is sample-for-sample identical to the sequential per-cluster loop
    cfg = HierLocalQSGDConfig(rounds=2, local_steps=4, local_epochs=2,
                              qsgd_levels=16, eval_every=1, seed=0)
    _assert_trajectories_match(
        ref_hier_local_qsgd(small_task, cfg), run_hier_local_qsgd(small_task, cfg)
    )


def test_hier_local_dense_parity(small_task):
    cfg = HierLocalQSGDConfig(rounds=2, local_steps=4, local_epochs=2,
                              qsgd_levels=None, eval_every=1, seed=5)
    _assert_trajectories_match(
        ref_hier_local_qsgd(small_task, cfg), run_hier_local_qsgd(small_task, cfg)
    )


def test_hier_parity_with_ragged_clusters():
    """Ragged cluster sizes exercise the padding/masking path. Dense channel:
    padded slots must contribute exactly nothing."""
    from repro.core.simulation import FLTask
    from repro.data import dirichlet_partition, make_dataset
    from repro.models.classifier import make_classifier

    ds = make_dataset("mnist", train_size=1200, test_size=300, seed=1)
    clients = dirichlet_partition(ds.train_y, 7, 0.6, seed=1)
    clusters = [[0, 1, 2], [3, 4], [5, 6]]  # ragged: 3/2/2
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    task = FLTask(model, ds, clients, clusters, batch_size=16, seed=1)

    cfg = HierLocalQSGDConfig(rounds=2, local_steps=4, local_epochs=2,
                              qsgd_levels=None, eval_every=1, seed=0)
    _assert_trajectories_match(
        ref_hier_local_qsgd(task, cfg), run_hier_local_qsgd(task, cfg)
    )


# --------------------------------------------------------------------------
# participation parity: FullParticipation must be *bit-identical* to the
# no-sampler path (params, losses, ledger totals) for all four drivers
# --------------------------------------------------------------------------

from repro.part import FullParticipation  # noqa: E402


def _assert_bit_identical(a, b):
    assert a.rounds == b.rounds
    assert a.train_loss == b.train_loss      # float() of the same arrays
    assert a.test_acc == b.test_acc
    assert a.ledger.bits == b.ledger.bits
    assert a.ledger.messages == b.ledger.messages
    assert a.ledger.events == b.ledger.events
    for la, lb in zip(jax.tree.leaves(a.final_params), jax.tree.leaves(b.final_params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _full_participation_cases(seed=0, qsgd=None):
    return [
        (run_fed_chs, FedCHSConfig, dict(rounds=3, local_steps=4, local_epochs=2,
                                         eval_every=1, seed=seed, qsgd_levels=qsgd)),
        (run_fedavg, FedAvgConfig, dict(rounds=2, local_steps=3, eval_every=1,
                                        seed=seed, qsgd_levels=qsgd)),
        (run_wrwgd, WRWGDConfig, dict(rounds=4, local_steps=3, eval_every=2,
                                      seed=seed)),
        (run_hier_local_qsgd, HierLocalQSGDConfig,
         dict(rounds=2, local_steps=4, local_epochs=2, eval_every=1, seed=seed,
              qsgd_levels=qsgd)),
    ]


def _assert_full_participation_parity(task, seed=0, qsgd=None):
    for run, cfg_cls, kwargs in _full_participation_cases(seed, qsgd):
        base = run(task, cfg_cls(**kwargs))
        sampled = run(task, cfg_cls(**kwargs, sampler=FullParticipation()))
        _assert_bit_identical(base, sampled)


def test_full_participation_is_bit_identical_all_drivers(small_task):
    _assert_full_participation_parity(small_task, seed=0, qsgd=None)


def test_full_participation_is_bit_identical_with_qsgd(small_task):
    _assert_full_participation_parity(small_task, seed=3, qsgd=8)


# hypothesis-randomized versions (cluster shapes x channels x seeds); the
# deterministic cases above always run, so the parity contract is pinned even
# where hypothesis is absent — CI passes --require-hypothesis to guarantee
# these actually execute there (see tests/conftest.py)
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    import functools

    # drawn from small fixed menus (and tasks cached per shape, sharing one
    # model instance) so jit re-compiles stay bounded across examples
    _SHAPES = (
        ((0, 1, 2), (3, 4), (5, 6)),          # ragged 3/2/2
        ((0, 1), (2, 3), (4, 5), (6,)),       # ragged with a singleton
        ((0, 1, 2, 3), (4, 5, 6)),            # two fat clusters
    )
    _CHANNELS = [None, 8, 16]  # qsgd_levels (None = dense)

    @functools.lru_cache(maxsize=None)
    def _prop_task(shape):
        from repro.core.simulation import FLTask
        from repro.data import dirichlet_partition, make_dataset
        from repro.models.classifier import make_classifier

        ds = make_dataset("mnist", train_size=700, test_size=150, seed=1)
        clients = dirichlet_partition(ds.train_y, 7, 0.6, seed=1)
        model = _prop_task.model  # one model instance -> one engine cache entry
        if model is None:
            model = _prop_task.model = make_classifier(
                "mlp", "mnist", ds.spec.image_shape, 10)
        return FLTask(model, ds, clients, [list(c) for c in shape],
                      batch_size=8, seed=1)

    _prop_task.model = None

    @given(shape=st.sampled_from(_SHAPES), seed=st.integers(0, 20),
           qsgd=st.sampled_from(_CHANNELS))
    @settings(max_examples=5, deadline=None)
    def test_property_full_participation_parity(shape, seed, qsgd):
        _assert_full_participation_parity(_prop_task(shape), seed=seed, qsgd=qsgd)

    @given(seed=st.integers(0, 50),
           mask_bits=st.lists(st.booleans(), min_size=5, max_size=5))
    @settings(max_examples=10, deadline=None)
    def test_property_mask_freezes_dropped_opt_state(small_task, seed, mask_bits):
        """Any mask pattern leaves dropped clients' LocalOpt state unchanged."""
        from repro.comm.channels import DenseChannel
        from repro.core.engine import RoundEngine
        from repro.optim.local import MomentumSGD

        engine = RoundEngine(small_task.model, DenseChannel(),
                             local_opt=MomentumSGD())
        small_task.reset_loaders(seed)
        members = small_task.cluster_members[0]
        n = len(members)
        mask = np.asarray(mask_bits[:n], np.float32)
        params = small_task.init_params()
        gammas = np.asarray(small_task.cluster_weights(0))
        lrs = jnp.full((2, 2), 0.05, jnp.float32)
        batch = small_task.sample_round_batches(0, 4, 2)
        opt0 = engine.init_opt_state(params, n)
        # warm round so the momentum state is nonzero
        params, opt1, _ = engine.cluster_round(params, batch, jnp.asarray(gammas),
                                               lrs, None, opt0)
        w = gammas * mask
        gammas_r = jnp.asarray(w / w.sum() if w.sum() > 0 else w)
        batch2 = small_task.sample_round_batches(0, 4, 2)
        _, opt2, _ = engine.cluster_round(params, batch2, gammas_r, lrs, None,
                                          opt1, mask=mask)
        for before, after in zip(jax.tree.leaves(opt1), jax.tree.leaves(opt2)):
            for i in range(n):
                if not mask[i]:
                    np.testing.assert_array_equal(np.asarray(after[i]),
                                                  np.asarray(before[i]))


# --------------------------------------------------------------------------
# whole-run scan parity: the scanned executor (scan_rounds=True, the default)
# must reproduce the looped path at fixed seed — params bit-identical,
# eval metrics exactly equal, ledger (aggregates + event stream + history)
# identical; reported loss scalars may differ by reduction-fusion ulps
# across the scan boundary, hence the 1e-5 tolerance
# --------------------------------------------------------------------------

import dataclasses  # noqa: E402

from repro.comm.channels import TopKChannel  # noqa: E402
from repro.part import AvailabilityAware, BernoulliTrace, UniformK  # noqa: E402


def _assert_scan_matches_loop(run, task, cfg):
    a = run(task, dataclasses.replace(cfg, scan_rounds=True))
    b = run(task, dataclasses.replace(cfg, scan_rounds=False))
    assert a.rounds == b.rounds
    assert a.test_acc == b.test_acc
    np.testing.assert_allclose(a.train_loss, b.train_loss, atol=1e-5, rtol=0)
    for la, lb in zip(jax.tree.leaves(a.final_params), jax.tree.leaves(b.final_params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert a.ledger.bits == b.ledger.bits
    assert a.ledger.messages == b.ledger.messages
    assert a.ledger.events == b.ledger.events
    assert a.ledger.history == b.ledger.history


_CHURN = AvailabilityAware(BernoulliTrace(p=0.4, seed=9))       # pass-through rounds
_DARK = AvailabilityAware(BernoulliTrace(p=0.15, seed=3))       # mostly-dark rounds


def test_scan_parity_fed_chs_grad_mode(small_task):
    _assert_scan_matches_loop(run_fed_chs, small_task,
                              FedCHSConfig(rounds=6, local_steps=6, eval_every=2,
                                           seed=3, chunk_rounds=2))


def test_scan_parity_fed_chs_channels(small_task):
    base = dict(rounds=4, local_steps=4, local_epochs=2, eval_every=1, seed=0)
    _assert_scan_matches_loop(run_fed_chs, small_task, FedCHSConfig(**base))
    _assert_scan_matches_loop(run_fed_chs, small_task,
                              FedCHSConfig(**base, qsgd_levels=16))
    _assert_scan_matches_loop(run_fed_chs, small_task,
                              FedCHSConfig(**base, channel=TopKChannel(0.1)))


def test_scan_parity_fed_chs_samplers(small_task):
    base = dict(rounds=8, local_steps=4, local_epochs=2, eval_every=3, seed=2)
    _assert_scan_matches_loop(run_fed_chs, small_task,
                              FedCHSConfig(**base, sampler=UniformK(k=2, seed=5)))
    _assert_scan_matches_loop(run_fed_chs, small_task,
                              FedCHSConfig(**base, sampler=_CHURN))
    _assert_scan_matches_loop(run_fed_chs, small_task,
                              FedCHSConfig(**base, sampler=_DARK, qsgd_levels=8))
    _assert_scan_matches_loop(run_fed_chs, small_task,
                              FedCHSConfig(**base, sampler=_CHURN,
                                           availability_scheduler=True))


def test_scan_parity_fed_chs_dynamic_topologies(small_task):
    """Dynamic networks (IoV rewiring, LEO visibility windows) were the last
    scan_rounds=False fallback.  The graph sequence is a seed-deterministic
    function of the round index, so `Scheduler.precompute(dynamic=...)`
    replays the whole visit order host-side — swapping in `dynamic(t)`
    exactly where the looped driver calls `set_topology` — and the scanned
    executor runs dynamic cells like any static topology."""
    for dyn in ("iov", "leo"):
        _assert_scan_matches_loop(run_fed_chs, small_task,
                                  FedCHSConfig(rounds=6, local_steps=6,
                                               eval_every=2, seed=1,
                                               dynamic=dyn))
        _assert_scan_matches_loop(run_fed_chs, small_task,
                                  FedCHSConfig(rounds=4, local_steps=4,
                                               local_epochs=2, eval_every=2,
                                               seed=0, dynamic=dyn,
                                               qsgd_levels=16))


def test_scan_parity_fedavg(small_task):
    _assert_scan_matches_loop(run_fedavg, small_task,
                              FedAvgConfig(rounds=3, local_steps=5, qsgd_levels=8,
                                           eval_every=1, seed=2))
    _assert_scan_matches_loop(run_fedavg, small_task,
                              FedAvgConfig(rounds=3, local_steps=5, eval_every=1,
                                           seed=0, channel=TopKChannel(0.05)))
    _assert_scan_matches_loop(run_fedavg, small_task,
                              FedAvgConfig(rounds=8, local_steps=3, eval_every=3,
                                           seed=2, sampler=_DARK))


def test_scan_parity_wrwgd(small_task):
    _assert_scan_matches_loop(run_wrwgd, small_task,
                              WRWGDConfig(rounds=8, local_steps=5, eval_every=3, seed=4))
    _assert_scan_matches_loop(run_wrwgd, small_task,
                              WRWGDConfig(rounds=10, local_steps=4, eval_every=3,
                                          seed=4, sampler=_DARK, chunk_rounds=3))


def test_scan_parity_hier(small_task):
    _assert_scan_matches_loop(run_hier_local_qsgd, small_task,
                              HierLocalQSGDConfig(rounds=2, local_steps=4,
                                                  local_epochs=2, qsgd_levels=16,
                                                  eval_every=1, seed=0))
    _assert_scan_matches_loop(run_hier_local_qsgd, small_task,
                              HierLocalQSGDConfig(rounds=6, local_steps=4,
                                                  local_epochs=2, qsgd_levels=16,
                                                  eval_every=2, seed=2,
                                                  sampler=_CHURN, chunk_rounds=2))
    _assert_scan_matches_loop(run_hier_local_qsgd, small_task,
                              HierLocalQSGDConfig(rounds=3, local_steps=4,
                                                  local_epochs=2, qsgd_levels=16,
                                                  es_channel=TopKChannel(0.1),
                                                  eval_every=1, seed=1))


def test_scan_parity_ragged_clusters_padding_exact():
    """Ragged clusters exercise the scanned path's padded slots.  Every
    channel is padding-invariant now — Dense (identity), per-message Top-K,
    and packed-wire QSGD/sign-SGD (per-leaf block boundaries + per-sender
    fold_in keys), so the PR-5-era QSGD fall-back-to-looped gate is gone:
    Fed-CHS+QSGD on ragged clusters runs scanned, bit-identically."""
    from repro.comm.channels import SignSGDChannel
    from repro.core.fed_chs import _fed_chs_scannable
    from repro.core.simulation import FLTask
    from repro.data import dirichlet_partition, make_dataset
    from repro.models.classifier import make_classifier

    ds = make_dataset("mnist", train_size=1200, test_size=300, seed=1)
    clients = dirichlet_partition(ds.train_y, 7, 0.6, seed=1)
    clusters = [[0, 1, 2], [3, 4], [5, 6]]  # ragged: 3/2/2
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    task = FLTask(model, ds, clients, clusters, batch_size=16, seed=1)

    _assert_scan_matches_loop(run_fed_chs, task,
                              FedCHSConfig(rounds=5, local_steps=6, local_epochs=3,
                                           eval_every=2, seed=1))
    _assert_scan_matches_loop(run_fed_chs, task,
                              FedCHSConfig(rounds=4, local_steps=4, local_epochs=2,
                                           channel=TopKChannel(0.1), eval_every=1,
                                           seed=0))
    # the cell PR 5 had to gate out: stochastic QSGD on ragged clusters
    _assert_scan_matches_loop(run_fed_chs, task,
                              FedCHSConfig(rounds=4, local_steps=4, local_epochs=2,
                                           qsgd_levels=16, eval_every=1, seed=2))
    _assert_scan_matches_loop(run_fed_chs, task,
                              FedCHSConfig(rounds=3, local_steps=4, local_epochs=2,
                                           channel=SignSGDChannel(), eval_every=1,
                                           seed=3))
    assert _fed_chs_scannable(task, FedCHSConfig(qsgd_levels=16))
    assert _fed_chs_scannable(task, FedCHSConfig())


def test_scanned_hot_loop_zero_host_transfers(small_task):
    """Between eval points the scanned executor's hot loop is ONE compiled
    chunk call on pre-staged device inputs: with jax.transfer_guard
    ("disallow") active, executing a chunk performs zero implicit
    host<->device transfers."""
    from repro.core.engine import scan_chunk_fn
    from repro.core.fed_chs import _fed_chs_scan_plan

    cfg = FedCHSConfig(rounds=6, local_steps=4, local_epochs=2, eval_every=10,
                       chunk_rounds=6, seed=0)
    plan, _params_of, _traffic = _fed_chs_scan_plan(small_task, small_task.source, cfg)
    idxs = np.flatnonzero(np.asarray(plan.trained))
    xs = jax.device_put(plan.stage(idxs))
    carry = jax.device_put(plan.carry)
    consts = jax.device_put(plan.consts)
    chunk = scan_chunk_fn(plan.body)
    # compile outside the guard (compilation may stage constants); warm on a
    # copy so backends with buffer donation don't invalidate `carry`
    warm = chunk(jax.tree.map(jnp.array, carry), xs, consts)
    jax.block_until_ready(jax.tree.leaves(warm))
    with jax.transfer_guard("disallow"):
        out_carry, losses = chunk(carry, xs, consts)
        jax.block_until_ready(jax.tree.leaves((out_carry, losses)))


# --------------------------------------------------------------------------
# telemetry parity: the in-graph taps are READ-ONLY — a run with
# obs=RunTelemetry() must be bit-identical (params, metrics, losses, ledger)
# to the same run with obs=None, for all four drivers, scanned and looped
# --------------------------------------------------------------------------

from repro.obs import RunTelemetry  # noqa: E402


def _assert_telemetry_neutral(task, run, cfg_cls, kwargs):
    base = run(task, cfg_cls(**kwargs))
    obs = RunTelemetry()
    tapped = run(task, cfg_cls(**kwargs, obs=obs))
    _assert_bit_identical(base, tapped)
    assert base.telemetry is None and tapped.telemetry is obs
    # full participation: every round trains, so every round is tapped
    assert obs.rounds == list(range(kwargs["rounds"]))
    for k in ("update_norm", "drift", "comp_err", "mass"):
        assert len(obs.metrics[k]) == kwargs["rounds"]
    assert obs.tracer.events  # spans were recorded


def test_telemetry_is_bit_neutral_all_drivers_scanned(small_task):
    for run, cfg_cls, kwargs in _full_participation_cases(seed=1, qsgd=8):
        _assert_telemetry_neutral(small_task, run, cfg_cls, kwargs)


def test_telemetry_is_bit_neutral_all_drivers_looped(small_task):
    for run, cfg_cls, kwargs in _full_participation_cases(seed=2, qsgd=None):
        _assert_telemetry_neutral(small_task, run, cfg_cls,
                                  dict(kwargs, scan_rounds=False))


def test_tapped_scanned_hot_loop_zero_host_transfers(small_task):
    """The tapped chunk accumulates telemetry ON DEVICE: with
    jax.transfer_guard("disallow") active, executing a tapped chunk still
    performs zero implicit host<->device transfers (materialization happens
    at the chunk boundary via RunTelemetry.record_stacked, outside the
    guard)."""
    from repro.core.engine import scan_chunk_fn
    from repro.core.fed_chs import _fed_chs_scan_plan

    cfg = FedCHSConfig(rounds=6, local_steps=4, local_epochs=2, eval_every=10,
                       chunk_rounds=6, seed=0, obs=RunTelemetry())
    plan, _params_of, _traffic = _fed_chs_scan_plan(small_task, small_task.source, cfg)
    idxs = np.flatnonzero(np.asarray(plan.trained))
    xs = jax.device_put(plan.stage(idxs))
    carry = jax.device_put(plan.carry)
    consts = jax.device_put(plan.consts)
    chunk = scan_chunk_fn(plan.body)
    warm = chunk(jax.tree.map(jnp.array, carry), xs, consts)
    jax.block_until_ready(jax.tree.leaves(warm))
    with jax.transfer_guard("disallow"):
        out_carry, (losses, tele) = chunk(carry, xs, consts)
        jax.block_until_ready(jax.tree.leaves((out_carry, losses, tele)))
    assert set(tele) == {"update_norm", "drift", "comp_err", "mass"}


if HAS_HYPOTHESIS:

    @given(seed=st.integers(0, 30), qsgd=st.sampled_from([None, 8]),
           p=st.sampled_from([None, 0.7, 0.3]))
    @settings(max_examples=5, deadline=None)
    def test_property_scan_loop_parity(seed, qsgd, p):
        """Random (seed, channel, churn) — scanned == looped for Fed-CHS and
        FedAvg on a cached ragged-cluster task (QSGD on ragged clusters now
        runs the real scanned path: packed-wire blocks are per-leaf and keys
        per-sender, so padding to n_max cannot change any message)."""
        task = _prop_task(_SHAPES[seed % len(_SHAPES)])
        sampler = None if p is None else AvailabilityAware(BernoulliTrace(p=p, seed=seed))
        _assert_scan_matches_loop(
            run_fed_chs, task,
            FedCHSConfig(rounds=4, local_steps=4, local_epochs=2, eval_every=2,
                         seed=seed, qsgd_levels=qsgd, sampler=sampler))
        _assert_scan_matches_loop(
            run_fedavg, task,
            FedAvgConfig(rounds=3, local_steps=3, eval_every=1, seed=seed,
                         qsgd_levels=qsgd, sampler=sampler))


# --------------------------------------------------------------------------
# client_microbatch parity: the group-scanned engine vs the all-clients vmap.
# Grad mode is BIT-identical for every microbatch width (the per-step grad
# stack feeds the same einsum); delta mode re-associates the gamma-weighted
# aggregation (acc += einsum per group), so params/opt-state agree to <=1 ulp
# of the aggregate (atol 3e-6 at MLP scale) and exactly at microbatch == n.
# --------------------------------------------------------------------------

from repro.comm.channels import DenseChannel, QSGDChannel, SignSGDChannel  # noqa: E402
from repro.core.engine import RoundEngine, split_chain  # noqa: E402
from repro.optim.local import MomentumSGD  # noqa: E402


def test_microbatch_grad_mode_bit_parity(small_task):
    task = small_task
    n = len(task.cluster_members[0])
    params = task.init_params()
    gammas = jnp.asarray(task.cluster_weights(0))
    lrs = jnp.full((6,), 0.05, jnp.float32)
    task.reset_loaders(0)
    batch = task.sample_cluster_batches(0, 6)
    p_ref, l_ref = RoundEngine(task.model).grad_round(params, batch, gammas, lrs)
    for mb in (1, 2, 3, n):
        eng = RoundEngine(task.model, client_microbatch=mb)
        p_mb, l_mb = eng.grad_round(params, batch, gammas, lrs)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_mb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_mb))


def test_microbatch_delta_mode_one_ulp(small_task):
    task = small_task
    n = len(task.cluster_members[0])
    params = task.init_params()
    gammas = jnp.asarray(task.cluster_weights(0))
    lrs = jnp.full((3, 2), 0.05, jnp.float32)
    for channel in (DenseChannel(), QSGDChannel(8), SignSGDChannel()):
        task.reset_loaders(0)
        batch = task.sample_round_batches(0, 6, 2)
        _, subs = split_chain(jax.random.PRNGKey(7), 3)
        base = RoundEngine(task.model, channel, local_opt=MomentumSGD())
        opt0 = base.init_opt_state(params, n)
        p_ref, s_ref, l_ref = base.cluster_round(params, batch, gammas, lrs,
                                                 subs, opt0)
        for mb in (1, 2, n):
            eng = RoundEngine(task.model, channel, local_opt=MomentumSGD(),
                              client_microbatch=mb)
            p_mb, s_mb, l_mb = eng.cluster_round(params, batch, gammas, lrs,
                                                 subs, opt0)
            for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_mb)):
                if mb == n:  # one group: the accumulator adds exactly once
                    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
                else:
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               rtol=0, atol=3e-6)
            for a, b in zip(jax.tree.leaves(s_ref), jax.tree.leaves(s_mb)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=0, atol=3e-6)
            np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_mb),
                                       rtol=0, atol=1e-6)


if HAS_HYPOTHESIS:
    _MB_CHANNELS = {"dense": DenseChannel(), "qsgd8": QSGDChannel(8),
                    "sign": SignSGDChannel()}

    @given(shape=st.sampled_from(_SHAPES), seed=st.integers(0, 10),
           kind=st.sampled_from(sorted(_MB_CHANNELS)),
           mb=st.sampled_from((1, 2, None)))
    @settings(max_examples=8, deadline=None)
    def test_property_microbatch_delta_parity(shape, seed, kind, mb):
        """Ragged clusters x {Dense, QSGD, SignSGD} x microbatch {1, 2, n}:
        the microbatched cluster round tracks the vmapped one to <=1 ulp of
        the aggregate (slot-keyed uplink rng makes QSGD messages identical
        across group widths)."""
        task = _prop_task(shape)
        n = len(task.cluster_members[0])
        mb_val = n if mb is None else mb
        channel = _MB_CHANNELS[kind]
        params = task.init_params()
        gammas = jnp.asarray(task.cluster_weights(0))
        lrs = jnp.full((2, 2), 0.05, jnp.float32)
        task.reset_loaders(seed)
        batch = task.sample_round_batches(0, 4, 2)
        _, subs = split_chain(jax.random.PRNGKey(seed), 2)
        base = RoundEngine(task.model, channel, local_opt=MomentumSGD())
        opt0 = base.init_opt_state(params, n)
        p_ref, s_ref, l_ref = base.cluster_round(params, batch, gammas, lrs,
                                                 subs, opt0)
        eng = RoundEngine(task.model, channel, local_opt=MomentumSGD(),
                          client_microbatch=mb_val)
        p_mb, s_mb, l_mb = eng.cluster_round(params, batch, gammas, lrs,
                                             subs, opt0)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_mb)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=3e-6)
        for a, b in zip(jax.tree.leaves(s_ref), jax.tree.leaves(s_mb)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=3e-6)
        np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_mb),
                                   rtol=0, atol=1e-6)
