"""Sharding rules + a miniature dry-run on the real (1-device) CPU mesh.

The full 256/512-chip dry-run is the dedicated entry point
(src/repro/launch/dryrun.py — it must own XLA_FLAGS); here we verify the
machinery end-to-end on a 1x1 (and, when available, wider) mesh, plus the
pure rule functions.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import build_lowering, lower_spec
from repro.models import transformer as tf
from repro.sharding.specs import batch_pspec, cache_pspecs, param_pspecs


def _mesh11():
    return make_debug_mesh(1, 1)


def test_param_pspecs_structure_matches():
    cfg = smoke_config("qwen3-0.6b")
    params = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_pspecs(params, num_experts=cfg.num_experts)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    # every spec rank matches its leaf rank
    for leaf, spec in zip(
        jax.tree.leaves(params),
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        assert len(spec) <= leaf.ndim


def test_param_pspecs_expert_parallel_only_for_moe():
    moe_cfg = smoke_config("dbrx-132b")
    params = jax.eval_shape(lambda: tf.init_params(moe_cfg, jax.random.PRNGKey(0)))
    specs = param_pspecs(params, num_experts=moe_cfg.num_experts)
    leaf = specs["super"][0]["ffn"]["w_gate"]
    assert leaf[-3] == "model"  # expert dim sharded
    dense_cfg = smoke_config("qwen3-0.6b")
    dparams = jax.eval_shape(lambda: tf.init_params(dense_cfg, jax.random.PRNGKey(0)))
    dspecs = param_pspecs(dparams, num_experts=0)
    assert dspecs["super"][0]["ffn"]["w_gate"][-1] == "model"  # column parallel


def test_divisibility_guard_replicates_odd_dims():
    import dataclasses

    cfg = dataclasses.replace(smoke_config("whisper-tiny"), vocab_size=51865)
    params = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    specs16 = param_pspecs(params, mesh=FakeMesh())
    assert specs16["lm_head"] == P(None, None)  # 51865 % 16 != 0 -> replicated
    assert all(a is None for a in specs16["final_norm"])  # 1-D: replicated


def test_batch_pspec_divisibility():
    mesh = _mesh11()

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")

    def axes(spec):
        a = spec[0]
        if a is None:
            return ()
        return (a,) if isinstance(a, str) else tuple(a)

    assert axes(batch_pspec(256, FakeMesh())) == ("pod", "data")
    assert axes(batch_pspec(2, FakeMesh())) == ("pod",)
    assert axes(batch_pspec(1, FakeMesh())) == ()


def test_cache_pspecs_seq_fallback():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = smoke_config("qwen1.5-32b")  # kv == heads == 4 (smoke) -> divisible case
    caches = jax.eval_shape(lambda: tf.init_caches(cfg, 128, 32768))
    specs = cache_pspecs(caches, 128, FakeMesh())
    kspec = specs["super"][0]["self"]["k"]
    # either kv heads sharded or sequence sharded on model
    assert "model" in [a for a in kspec if isinstance(a, str)] or any(
        isinstance(a, tuple) and "model" in a for a in kspec if a
    )


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m", "dbrx-132b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_mini_dryrun_lowers_on_debug_mesh(arch, shape):
    """Reduced config + tiny shapes through the SAME build/lower path."""
    import dataclasses

    cfg = smoke_config(arch)
    mesh = _mesh11()
    import repro.launch.steps as steps

    tiny = dict(steps.SHAPES)
    tiny[shape] = dict(tiny[shape])
    tiny[shape]["seq_len"] = 64
    tiny[shape]["global_batch"] = 2
    orig = steps.SHAPES
    steps.SHAPES = tiny
    try:
        spec = build_lowering(cfg, shape, mesh)
        lowered = lower_spec(spec, mesh)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        txt = compiled.as_text()
        assert "while" in txt or cfg.num_layers <= 2
    finally:
        steps.SHAPES = orig


def test_roofline_hlo_parser_trip_scaling():
    """The analyzer must multiply while-body flops by known_trip_count."""
    from repro.roofline.analysis import analyze_hlo_text

    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((12, 128, 128), jnp.float32),
    )
    rec = analyze_hlo_text(lowered.compile().as_text())
    analytic = 12 * 2 * 64 * 128 * 128
    assert rec["dot_flops_per_device"] == pytest.approx(analytic, rel=0.01)


def test_roofline_collective_parser():
    from repro.roofline.analysis import analyze_hlo_text

    txt = """
HloModule m

ENTRY %main (a: f32[256,128]) -> f32[256,128] {
  %a = f32[256,128]{1,0} parameter(0)
  ROOT %all-reduce.1 = f32[256,128]{1,0} all-reduce(%a), replica_groups={}, to_apply=%add
}
"""
    rec = analyze_hlo_text(txt)
    assert rec["collective_total_bytes"] == 2 * 256 * 128 * 4  # 2x for all-reduce
