"""Checkpoint round-trips: pytrees + resumable FL state.

The hardening cells pin the failure modes `load_pytree` must catch loudly:
a checkpoint written for one structure can never be silently mis-mapped
onto another — structure drift raises naming the leaves, never truncates.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    load_fl_state,
    load_pytree,
    load_run_state,
    run_state_exists,
    save_fl_state,
    save_pytree,
    save_run_state,
)


def test_pytree_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": [jnp.zeros(5), jnp.ones(1)]},
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree)
    back = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_dual_dtype_pytree_roundtrip_bit_exact(tmp_path):
    """A mixed-precision run state holds bf16 compute leaves AND f32 master
    leaves in one pytree; every leaf must come back at its true dtype with
    its exact bit pattern (ml_dtypes leaves are stored as same-width ints,
    not widened to f32)."""
    tree = {
        "master": jnp.linspace(-1, 1, 33, dtype=jnp.float32),
        "opt": {"mom": jnp.linspace(-2, 2, 33).astype(jnp.bfloat16)},
        "wire": jnp.linspace(-1, 1, 9).astype(jnp.float8_e4m3fn),
        "steps": jnp.arange(4, dtype=jnp.int32),
    }
    path = os.path.join(tmp_path, "dual.npz")
    save_pytree(path, tree)
    back = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8)
        )
    # native width on disk: the bf16 leaf is half the f32 leaf of equal length
    import zipfile

    sizes = {i.filename: i.file_size for i in zipfile.ZipFile(path).infolist()}
    assert sizes["opt/mom.npy"] < sizes["master.npy"]


def test_fl_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros(4)}
    counts = np.array([3, 1, 2, 0], dtype=np.int64)
    base = os.path.join(tmp_path, "state")
    save_fl_state(base, params, round_idx=17, visit_counts=counts, current=2)
    p, r, c, cur = load_fl_state(base, params)
    assert r == 17 and cur == 2
    np.testing.assert_array_equal(c, counts)
    np.testing.assert_array_equal(np.asarray(p["w"]), np.ones((4, 4)))


def test_resume_continues_identically(small_task, tmp_path):
    """Fed-CHS(10 rounds) == Fed-CHS(5) -> checkpoint -> Fed-CHS(5 more) for
    the scheduler state (params equality needs identical batch draws, which
    the loaders' per-client rngs guarantee only within one process run —
    scheduler state is the FL-protocol-critical part)."""
    from repro.core.scheduler import FedCHSScheduler
    from repro.core.topology import make_topology

    topo = make_topology("random_sparse", 6, seed=0)
    s1 = FedCHSScheduler(topo, [5, 6, 7, 8, 9, 10], initial=0)
    for _ in range(5):
        s1.advance()
    base = os.path.join(tmp_path, "s")
    save_fl_state(base, {"w": jnp.zeros(1)}, round_idx=5,
                  visit_counts=s1.state.visit_counts, current=s1.state.current)
    _, r, counts, cur = load_fl_state(base, {"w": jnp.zeros(1)})
    s2 = FedCHSScheduler(topo, [5, 6, 7, 8, 9, 10], initial=0)
    s2.state.visit_counts = counts
    s2.state.current = cur
    assert [s1.advance() for _ in range(10)] == [s2.advance() for _ in range(10)]


# --------------------------------------------------------------------------
# hardening: structure drift must raise loudly, naming the leaf + file
# --------------------------------------------------------------------------


def test_load_pytree_structure_mismatch_names_leaves(tmp_path):
    path = os.path.join(tmp_path, "a.npz")
    save_pytree(path, {"w": jnp.ones((2, 2)), "b": jnp.zeros(3)})
    with pytest.raises(ValueError, match=r"missing=\['extra'\]"):
        load_pytree(path, {"w": jnp.ones((2, 2)), "b": jnp.zeros(3),
                           "extra": jnp.zeros(1)})
    with pytest.raises(ValueError, match=r"unexpected=\['b'\]"):
        load_pytree(path, {"w": jnp.ones((2, 2))})


def test_load_pytree_treedef_mismatch(tmp_path):
    path = os.path.join(tmp_path, "t.npz")
    save_pytree(path, {"x": [jnp.zeros(2), jnp.zeros(2)]})
    # same leaf order strings but a different container structure
    with pytest.raises(ValueError, match="treedef mismatch"):
        load_pytree(path, {"x": {"0": jnp.zeros(2), "1": jnp.zeros(2)}})


def test_load_pytree_shape_mismatch_names_leaf(tmp_path):
    path = os.path.join(tmp_path, "s.npz")
    save_pytree(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError, match=r"leaf 'w' has shape \(2, 2\)"):
        load_pytree(path, {"w": jnp.ones((4, 4))})


def test_load_pytree_missing_leaf_names_it(tmp_path):
    """A legacy npz without the meta record falls back to key lookup — a
    missing key must raise KeyError naming the leaf, not truncate."""
    path = os.path.join(tmp_path, "legacy.npz")
    np.savez(path, w=np.ones((2, 2)))  # no __pytree_meta__ at all
    with pytest.raises(KeyError, match="no leaf 'b'"):
        load_pytree(path, {"w": jnp.ones((2, 2)), "b": jnp.zeros(3)})


def test_run_state_roundtrip_and_atomicity(tmp_path):
    base = os.path.join(tmp_path, "run")
    assert not run_state_exists(base)
    arrays = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
              "key": jax.random.PRNGKey(7),
              "pending": {"u0": {"w": jnp.ones((2, 3))}}}
    meta = {"round": 5, "sim_time": 12.5, "draw_counts": [3, 1, 4]}
    save_run_state(base, arrays, meta)
    assert run_state_exists(base)
    like = jax.tree.map(jnp.zeros_like, arrays)
    back, meta2 = load_run_state(base, like)
    assert meta2 == meta
    for a, b in zip(jax.tree.leaves(arrays), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # no stray .tmp files survive an atomic save
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_load_run_state_missing_meta_is_incomplete(tmp_path):
    base = os.path.join(tmp_path, "torn")
    # arrays landed but the meta sidecar (written LAST) did not: the
    # checkpoint must read as absent, not half-present
    save_pytree(base + ".arrays.npz", {"w": jnp.zeros(2)})
    assert not run_state_exists(base)
    with pytest.raises(FileNotFoundError, match="meta sidecar missing"):
        load_run_state(base, {"w": jnp.zeros(2)})


def test_ledger_state_roundtrip():
    from repro.core.ledger import CommLedger

    led = CommLedger(track_events=True)
    led.record("client_to_es", 100, round=0, phase=1, sender="client:1",
               receiver="es:0", staleness=0)
    led.record("client_to_es", 100, round=1, phase=1, sender="client:2",
               receiver="es:0", staleness=3)
    led.record("es_to_es", 50, round=1, phase=2, sender="es:0", receiver="es:1")
    led.snapshot(1)

    led2 = CommLedger(track_events=True)
    led2.load_state(led.state_dict())
    assert led2.bits == led.bits and led2.messages == led.messages
    assert led2.history == led.history and led2.events == led.events
    assert led2.staleness_histogram() == {0: 1, 3: 1}


def test_array_source_fast_forward_parity(small_task):
    """Draw-and-discard fast-forward reproduces the stream position exactly:
    the next batch after fast_forward equals the next batch of an
    uninterrupted source with the same draw history."""
    src = small_task.source
    src.reset(0)
    for c, n in [(0, 3), (1, 1), (5, 2)]:
        for _ in range(n):
            src.next_batch(c)
    counts = list(src.draw_counts)
    nxt = {c: src.next_batch(c) for c in (0, 1, 5)}

    src.reset(0)
    src.fast_forward(counts)
    assert src.draw_counts == counts
    for c in (0, 1, 5):
        a, b = nxt[c], src.next_batch(c)
        np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
    # rewinding is impossible by construction
    src.reset(0)
    src.next_batch(0)
    with pytest.raises(AssertionError, match="rewind"):
        src.fast_forward([0] * src.num_clients)
