"""Checkpoint round-trips: pytrees + resumable FL state."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_fl_state, load_pytree, save_fl_state, save_pytree


def test_pytree_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": [jnp.zeros(5), jnp.ones(1)]},
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree)
    back = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_fl_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros(4)}
    counts = np.array([3, 1, 2, 0], dtype=np.int64)
    base = os.path.join(tmp_path, "state")
    save_fl_state(base, params, round_idx=17, visit_counts=counts, current=2)
    p, r, c, cur = load_fl_state(base, params)
    assert r == 17 and cur == 2
    np.testing.assert_array_equal(c, counts)
    np.testing.assert_array_equal(np.asarray(p["w"]), np.ones((4, 4)))


def test_resume_continues_identically(small_task, tmp_path):
    """Fed-CHS(10 rounds) == Fed-CHS(5) -> checkpoint -> Fed-CHS(5 more) for
    the scheduler state (params equality needs identical batch draws, which
    the loaders' per-client rngs guarantee only within one process run —
    scheduler state is the FL-protocol-critical part)."""
    from repro.core.scheduler import FedCHSScheduler
    from repro.core.topology import make_topology

    topo = make_topology("random_sparse", 6, seed=0)
    s1 = FedCHSScheduler(topo, [5, 6, 7, 8, 9, 10], initial=0)
    for _ in range(5):
        s1.advance()
    base = os.path.join(tmp_path, "s")
    save_fl_state(base, {"w": jnp.zeros(1)}, round_idx=5,
                  visit_counts=s1.state.visit_counts, current=s1.state.current)
    _, r, counts, cur = load_fl_state(base, {"w": jnp.zeros(1)})
    s2 = FedCHSScheduler(topo, [5, 6, 7, 8, 9, 10], initial=0)
    s2.state.visit_counts = counts
    s2.state.current = cur
    assert [s1.advance() for _ in range(10)] == [s2.advance() for _ in range(10)]
