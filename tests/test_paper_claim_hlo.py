"""The paper's communication claim, asserted in lowered XLA (subprocess with
8 host devices -> a (2,2,2) pod/data/model mesh):

  * Fed-CHS sequential ES->ES pass == ONE collective-permute over `pod`;
  * HFL star aggregation == a pod all-reduce and NO collective-permute.

This is the §5.3 comm-saving argument made structural: a permute moves the
parameter bytes once, the all-reduce moves them twice.
"""
import os
import subprocess
import sys
import textwrap

_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import repro.launch.steps as steps
    from repro.configs.registry import smoke_config
    from repro.launch.mesh import make_debug_mesh

    cfg = smoke_config("qwen3-0.6b")
    mesh = make_debug_mesh(data=2, model=2, pod=2)
    tiny = dict(steps.SHAPES)
    tiny["train_4k"] = dict(tiny["train_4k"], seq_len=64, global_batch=8)
    steps.SHAPES = tiny

    hlo = {}
    for variant in ("fedchs", "hfl"):
        spec = steps.build_lowering(cfg, "train_4k", mesh, variant=variant)
        hlo[variant] = steps.lower_spec(spec, mesh).compile().as_text()

    assert "collective-permute" in hlo["fedchs"], "sequential pass must lower to collective-permute"
    assert "collective-permute" not in hlo["hfl"], "star aggregation must not permute"
    assert "all-reduce" in hlo["hfl"]

    # the permute must actually cross the pod axis: with 8 devices in a
    # (pod, data, model) = (2,2,2) mesh, pod partners differ by 4
    import re
    pairs = []
    for m in re.finditer(r"collective-permute[^\\n]*source_target_pairs=\\{([^}]*)\\}",
                         hlo["fedchs"]):
        pairs += [tuple(map(int, p.split(",")))
                  for p in m.group(1).replace("{", "").split("},") if p.strip()]
    assert pairs, "no source_target_pairs parsed"
    assert any(abs(a - b) == 4 for a, b in pairs), pairs
    print("OK")
    """
)


def test_fedchs_pass_is_pod_permute_hfl_is_allreduce():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env, cwd=root,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
