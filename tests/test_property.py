"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import FedCHSScheduler
from repro.core.topology import make_topology, random_sparse
from repro.kernels.ops import qsgd_roundtrip
from repro.utils import tree_weighted_sum


@given(seed=st.integers(0, 1000), n=st.integers(3, 16))
@settings(max_examples=20, deadline=None)
def test_scheduler_no_starvation(seed, n):
    """Invariant of the 2-step rule: every ES is visited regularly (no
    starvation). Note a line/star graph forces hub nodes to be visited ~2x
    more often than leaves, so counts are NOT balanced in general — the
    guarantee is a lower bound on every node's visit rate."""
    topo = random_sparse(n, max_degree=3, seed=seed)
    sizes = list(np.random.default_rng(seed).integers(1, 100, size=n))
    sched = FedCHSScheduler(topo, sizes, initial=0)
    T = 30 * n
    for _ in range(T):
        sched.advance()
    counts = sched.state.visit_counts
    assert counts.min() >= max(1, T // (10 * n))  # visited at a steady rate


@given(
    weights=st.lists(st.floats(0.01, 1.0), min_size=2, max_size=6),
    seed=st.integers(0, 100),
)
@settings(max_examples=20, deadline=None)
def test_weighted_sum_linearity(weights, seed):
    """Eq.(5) aggregation is linear: agg(a*x) == a*agg(x)."""
    key = jax.random.PRNGKey(seed)
    n = len(weights)
    trees = [
        {"w": jax.random.normal(jax.random.fold_in(key, i), (4, 3))} for i in range(n)
    ]
    w = np.asarray(weights, np.float32)
    agg = tree_weighted_sum(trees, w)
    agg2 = tree_weighted_sum([jax.tree.map(lambda x: 2.0 * x, t) for t in trees], w)
    np.testing.assert_allclose(np.asarray(agg2["w"]), 2 * np.asarray(agg["w"]), rtol=1e-5)


@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_qsgd_scale_equivariance(scale, seed):
    """QSGD is positively homogeneous: Q(a*v) == a*Q(v) for a>0 (same draw)."""
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (2048,))
    q1 = qsgd_roundtrip(v, jax.random.PRNGKey(seed + 1), s=16)
    q2 = qsgd_roundtrip(v * scale, jax.random.PRNGKey(seed + 1), s=16)
    np.testing.assert_allclose(np.asarray(q2), np.asarray(q1) * scale, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_qsgd_never_increases_block_norm_by_more_than_bound(seed):
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (4096,))
    out = qsgd_roundtrip(v, jax.random.fold_in(key, 1), s=16)
    # each reconstructed entry is at most the block norm
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.linalg.norm(v)) + 1e-5


@given(
    kind=st.sampled_from(["ring", "line", "star", "full", "random_sparse"]),
    n=st.integers(2, 12),
    seed=st.integers(0, 20),
)
@settings(max_examples=30, deadline=None)
def test_all_topologies_connected_and_symmetric(kind, n, seed):
    topo = make_topology(kind, n, seed=seed)
    topo.validate()
    assert topo.is_connected()


@given(b=st.integers(1, 4), t=st.integers(1, 32), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_blockwise_attention_rowsums(b, t, seed):
    """Softmax invariance: with v == ones, attention output is exactly ones."""
    from repro.models.attention import blockwise_attention

    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, t, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, 2, 8))
    v = jnp.ones((b, t, 2, 8))
    out = blockwise_attention(q, k, v, causal=True, kv_block=16)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)
