"""CommLedger: aggregate accounting, snapshots, and the CommEvent stream."""
from repro.core import CommLedger, FedCHSConfig, run_fed_chs
from repro.core.baselines import WRWGDConfig, run_wrwgd
from repro.core.ledger import dense_message_bits


def test_bits_until_empty_history_falls_back_to_total():
    led = CommLedger()
    led.record("client_to_es", 100, 3)
    assert led.history == []
    assert led.bits_until(0) == 300
    assert led.bits_until(10**9) == 300


def test_bits_until_exact_round_hit_and_gaps():
    led = CommLedger()
    led.record("client_to_es", 10)
    led.snapshot(0)
    led.record("client_to_es", 10)
    led.snapshot(2)  # rounds may be sparse
    led.record("client_to_es", 10)
    assert led.bits_until(0) == 10   # exact hit
    assert led.bits_until(1) == 20   # first snapshot with round >= 1 is round 2
    assert led.bits_until(2) == 20
    assert led.bits_until(3) == 30   # past the last snapshot -> running total


def test_metadata_does_not_change_aggregates():
    plain, tagged = CommLedger(), CommLedger()
    for i in range(4):
        plain.record("client_to_es", 77)
        tagged.record("client_to_es", 77, round=0, phase=i,
                      sender=f"client:{i}", receiver="es:0")
    assert plain.bits == tagged.bits
    assert plain.messages == tagged.messages
    assert plain.events == [] and len(tagged.events) == 4


def test_track_events_off_drops_metadata_but_not_bits():
    led = CommLedger(track_events=False)
    led.record("es_to_es", 50, round=3, sender="es:0", receiver="es:1")
    assert led.bits["es_to_es"] == 50
    assert led.events == []


def test_count_expansion_produces_one_event_per_message():
    led = CommLedger()
    led.record("ps_to_es", 9, 3, round=1, phase=2, sender="ps", receiver="es:0")
    assert led.messages["ps_to_es"] == 3
    assert len(led.events) == 3
    assert all(e.n_bits == 9 and e.round == 1 for e in led.events)


def test_round_events_groups_and_orders():
    led = CommLedger()
    led.record("client_to_es", 1, round=1, phase=1, sender="client:2", receiver="es:0")
    led.record("client_to_es", 1, round=0, phase=0, sender="client:9", receiver="es:0")
    led.record("es_to_client", 1, round=1, phase=0, sender="es:0", receiver="client:2")
    grouped = led.round_events()
    assert sorted(grouped) == [0, 1]
    assert [e.phase for e in grouped[1]] == [0, 1]


def test_every_driver_snapshots_every_round(small_task):
    """engine.end_round gives a uniform per-round history: one snapshot per
    round, rounds contiguous from 0."""
    res = run_fed_chs(small_task, FedCHSConfig(rounds=5, local_steps=2, eval_every=10))
    assert [r for r, _ in res.ledger.history] == list(range(5))
    res = run_wrwgd(small_task, WRWGDConfig(rounds=4, local_steps=2, eval_every=10))
    assert [r for r, _ in res.ledger.history] == list(range(4))


def test_fed_chs_event_stream_matches_aggregates(small_task):
    T, K = 3, 4
    res = run_fed_chs(small_task, FedCHSConfig(rounds=T, local_steps=K, eval_every=10))
    led = res.ledger
    assert sum(e.n_bits for e in led.events) == led.total_bits()
    assert len([e for e in led.events if e.hop == "es_to_es"]) == T
    q = dense_message_bits(small_task.num_params())
    assert all(e.n_bits == q for e in led.events if e.hop == "es_to_es")
    # every uplink has a matching broadcast in the same (round, phase)
    ups = {(e.round, e.phase, e.sender) for e in led.events if e.hop == "client_to_es"}
    downs = {(e.round, e.phase, e.receiver) for e in led.events if e.hop == "es_to_client"}
    assert ups == downs
