"""CommLedger: aggregate accounting, snapshots, and the CommEvent stream."""
import jax
import pytest

from repro.comm.channels import (
    DenseChannel,
    QSGDChannel,
    SignSGDChannel,
    TopKChannel,
    channel_wire_bits,
)
from repro.core import CommLedger, FedCHSConfig, FedCHSScheduler, run_fed_chs
from repro.core.baselines import FedAvgConfig, WRWGDConfig, run_fedavg, run_wrwgd
from repro.core.ledger import dense_message_bits
from repro.core.topology import make_topology
from repro.part import UniformK


def test_bits_until_empty_history_falls_back_to_total():
    led = CommLedger()
    led.record("client_to_es", 100, 3)
    assert led.history == []
    assert led.bits_until(0) == 300
    assert led.bits_until(10**9) == 300


def test_bits_until_exact_round_hit_and_gaps():
    led = CommLedger()
    led.record("client_to_es", 10)
    led.snapshot(0)
    led.record("client_to_es", 10)
    led.snapshot(2)  # rounds may be sparse
    led.record("client_to_es", 10)
    assert led.bits_until(0) == 10   # exact hit
    assert led.bits_until(1) == 20   # first snapshot with round >= 1 is round 2
    assert led.bits_until(2) == 20
    assert led.bits_until(3) == 30   # past the last snapshot -> running total


def test_metadata_does_not_change_aggregates():
    plain, tagged = CommLedger(), CommLedger()
    for i in range(4):
        plain.record("client_to_es", 77)
        tagged.record("client_to_es", 77, round=0, phase=i,
                      sender=f"client:{i}", receiver="es:0")
    assert plain.bits == tagged.bits
    assert plain.messages == tagged.messages
    assert plain.events == [] and len(tagged.events) == 4


def test_track_events_off_drops_metadata_but_not_bits():
    led = CommLedger(track_events=False)
    led.record("es_to_es", 50, round=3, sender="es:0", receiver="es:1")
    assert led.bits["es_to_es"] == 50
    assert led.events == []


def test_count_expansion_produces_one_event_per_message():
    led = CommLedger()
    led.record("ps_to_es", 9, 3, round=1, phase=2, sender="ps", receiver="es:0")
    assert led.messages["ps_to_es"] == 3
    assert len(led.events) == 3
    assert all(e.n_bits == 9 and e.round == 1 for e in led.events)


def test_round_events_groups_and_orders():
    led = CommLedger()
    led.record("client_to_es", 1, round=1, phase=1, sender="client:2", receiver="es:0")
    led.record("client_to_es", 1, round=0, phase=0, sender="client:9", receiver="es:0")
    led.record("es_to_client", 1, round=1, phase=0, sender="es:0", receiver="client:2")
    grouped = led.round_events()
    assert sorted(grouped) == [0, 1]
    assert [e.phase for e in grouped[1]] == [0, 1]


def test_every_driver_snapshots_every_round(small_task):
    """engine.end_round gives a uniform per-round history: one snapshot per
    round, rounds contiguous from 0."""
    res = run_fed_chs(small_task, FedCHSConfig(rounds=5, local_steps=2, eval_every=10))
    assert [r for r, _ in res.ledger.history] == list(range(5))
    res = run_wrwgd(small_task, WRWGDConfig(rounds=4, local_steps=2, eval_every=10))
    assert [r for r, _ in res.ledger.history] == list(range(4))


def test_round_bits_and_senders_require_events():
    led = CommLedger()
    led.record("client_to_es", 10, round=0, phase=0, sender="client:1", receiver="es:0")
    led.record("client_to_es", 10, round=0, phase=1, sender="client:1", receiver="es:0")
    led.record("client_to_es", 10, round=1, phase=0, sender="client:2", receiver="es:0")
    led.record("es_to_es", 99, round=1, phase=1, sender="es:0", receiver="es:1")
    assert led.round_bits("client_to_es") == {0: 20, 1: 10}
    assert led.round_bits() == {0: 20, 1: 109}
    assert led.round_senders(0, "client_to_es") == {"client:1"}
    assert led.round_senders(1, "es_to_es") == {"es:0"}


# -- closed-form participation accounting ------------------------------------


@pytest.mark.parametrize("channel", [DenseChannel(), QSGDChannel(8),
                                     TopKChannel(0.25)],
                         ids=["dense", "qsgd", "topk"])
def test_uniform_k_uplink_bits_closed_form(small_task, channel):
    """Under UniformK sampling the per-round uplink is exactly
    |sampled| * interactions * bits_per_message, and the event-stream sender
    set is exactly the sampled set — for Dense, QSGD, and Top-K channels."""
    T, K, E = 4, 4, 2
    interactions = K // E
    sampler = UniformK(k=3, seed=9)
    cfg = FedCHSConfig(rounds=T, local_steps=K, local_epochs=E, eval_every=10,
                       seed=1, initial_cluster=0, channel=channel,
                       sampler=sampler)
    res = run_fed_chs(small_task, cfg)
    d = small_task.num_params()
    # wire channels are priced on the exact multi-leaf payload (per-leaf
    # block padding), not the flat-vector approximation
    up = channel_wire_bits(channel, d, small_task.param_leaf_sizes())
    down = dense_message_bits(d)

    # replay the deterministic 2-step schedule to know each round's cluster
    topo = make_topology(cfg.topology, small_task.num_clusters,
                         seed=cfg.topology_seed)
    order = FedCHSScheduler(topo, small_task.cluster_sizes, initial=0).schedule(T)

    up_bits = res.ledger.round_bits("client_to_es")
    down_bits = res.ledger.round_bits("es_to_client")
    total = 0
    for t in range(T):
        sampled = sampler.participants(t, small_task.cluster_members[order[t]])
        assert len(sampled) == 3
        assert res.ledger.round_senders(t, "client_to_es") == \
            {f"client:{i}" for i in sampled}
        assert up_bits[t] == len(sampled) * interactions * up
        assert down_bits[t] == len(sampled) * interactions * down
        total += up_bits[t]
    assert res.ledger.bits["client_to_es"] == total


def test_uniform_k_fedavg_uplink_bits_closed_form(small_task):
    T, K, k = 3, 2, 5
    sampler = UniformK(k=k, seed=4)
    res = run_fedavg(small_task, FedAvgConfig(rounds=T, local_steps=K,
                                              eval_every=10, seed=0,
                                              sampler=sampler))
    d = small_task.num_params()
    q = dense_message_bits(d)
    clients = list(range(small_task.num_clients))
    for t in range(T):
        sampled = sampler.participants(t, clients)
        assert res.ledger.round_senders(t, "client_to_ps") == \
            {f"client:{i}" for i in sampled}
        assert res.ledger.round_bits("client_to_ps")[t] == len(sampled) * q
    assert res.ledger.bits["client_to_ps"] == T * k * q


# -- wire honesty: the ledger charges what the payload actually weighs -------


@pytest.mark.parametrize("channel", [QSGDChannel(16), QSGDChannel(7),
                                     QSGDChannel(1), SignSGDChannel()],
                         ids=["qsgd16", "qsgd4bit", "qsgd2bit", "signsgd"])
def test_ledger_matches_transmitted_payload_bytes(small_task, channel):
    """The honesty check the packed wire format exists for: the byte size of
    the *transmitted* in-graph value (uint32 payload words + f32 norm sidecar,
    per leaf) equals the CommLedger's per-message accounting — within one
    32-bit word of padding per block row, and in fact exactly."""
    params = small_task.init_params()
    wires = channel.encode(params, jax.random.PRNGKey(0))
    measured = sum(
        w["payload"].size * w["payload"].dtype.itemsize
        + w["norms"].size * w["norms"].dtype.itemsize
        for w in wires
    )
    d = small_task.num_params()
    priced = channel_wire_bits(channel, d, small_task.param_leaf_sizes())
    assert priced % 8 == 0
    assert measured == priced // 8
    # the flat-d formula may differ only by tail padding: strictly less than
    # one block row (payload words + norm word) per extra leaf
    n_leaves = len(wires)
    per_block_bits = channel.message_bits(1)
    assert 0 <= priced - channel.message_bits(d) < n_leaves * per_block_bits
    # and a run's recorded uplink bits are an integer multiple of the payload
    res = run_fed_chs(small_task, FedCHSConfig(rounds=2, local_steps=2,
                                               eval_every=10, channel=channel))
    ups = [e.n_bits for e in res.ledger.events if e.hop == "client_to_es"]
    assert ups and all(b == measured * 8 for b in ups)


def test_ledger_matches_bf16_dense_wire_payload_bytes(small_task):
    """Honesty for the mixed-precision dense wire: the bf16 payload
    DenseChannel(wire_dtype="bfloat16") actually emits weighs exactly what the
    ledger records — half the f32 dense message, with the downlink priced at
    wire width too (the ES ships the compute-dtype model)."""
    from repro.core.precision import Precision

    channel = DenseChannel(wire_dtype="bfloat16")
    params = small_task.init_params()
    wires = channel.encode(params)
    measured = sum(w["payload"].size * w["payload"].dtype.itemsize
                   for w in wires)
    d = small_task.num_params()
    priced = channel_wire_bits(channel, d, small_task.param_leaf_sizes())
    assert measured == priced // 8
    assert priced * 2 == dense_message_bits(d)  # exactly half of f32 dense

    res = run_fed_chs(small_task, FedCHSConfig(rounds=2, local_steps=2,
                                               eval_every=10,
                                               precision=Precision()))
    ups = [e.n_bits for e in res.ledger.events if e.hop == "client_to_es"]
    assert ups and all(b == measured * 8 for b in ups)
    downs = [e.n_bits for e in res.ledger.events if e.hop == "es_to_client"]
    assert downs and all(b == measured * 8 for b in downs)


def test_fed_chs_event_stream_matches_aggregates(small_task):
    T, K = 3, 4
    res = run_fed_chs(small_task, FedCHSConfig(rounds=T, local_steps=K, eval_every=10))
    led = res.ledger
    assert sum(e.n_bits for e in led.events) == led.total_bits()
    assert len([e for e in led.events if e.hop == "es_to_es"]) == T
    q = dense_message_bits(small_task.num_params())
    assert all(e.n_bits == q for e in led.events if e.hop == "es_to_es")
    # every uplink has a matching broadcast in the same (round, phase)
    ups = {(e.round, e.phase, e.sender) for e in led.events if e.hop == "client_to_es"}
    downs = {(e.round, e.phase, e.receiver) for e in led.events if e.hop == "es_to_client"}
    assert ups == downs
