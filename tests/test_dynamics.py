"""Dynamic (time-varying) ES topologies — the paper's Appendix-D scenarios
and the §1 claim that the 2-step rule is robust to them."""
import numpy as np
import pytest

from repro.core.dynamics import iov_gilbert, leo_constellation, make_dynamic
from repro.core.scheduler import FedCHSScheduler
from repro.core.topology import make_topology

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip; the deterministic ones still run
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @given(n=st.integers(5, 16), t=st.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_leo_graphs_valid_connected_and_rotating(n, t):
        dyn = leo_constellation(n, window=2, period=1)
        g = dyn(t)
        g.validate()
        assert g.is_connected()
        # the band rotates: after n periods it returns to the start
        assert dyn(t).adjacency == dyn(t + n).adjacency

    @given(n=st.integers(3, 16), t=st.integers(0, 100), p=st.sampled_from([0.1, 0.3, 0.6]))
    @settings(max_examples=25, deadline=None)
    def test_iov_graphs_valid_connected_and_replayable(n, t, p):
        dyn = iov_gilbert(n, p_drop=p, seed=3)
        g = dyn(t)
        g.validate()
        assert g.is_connected()
        assert dyn(t).adjacency == iov_gilbert(n, p_drop=p, seed=3)(t).adjacency
        assert iov_gilbert(n, p_drop=0.9, seed=3)(t).is_connected()  # repair works


def test_iov_stays_connected_after_repair_many_seeds_and_rounds():
    """The repair step must hold across seeds, sizes, rounds, and drop rates
    — a disconnected round would silently stall the sequential pass."""
    for seed in range(6):
        for n, p in [(4, 0.5), (9, 0.7), (13, 0.9)]:
            dyn = iov_gilbert(n, p_drop=p, seed=seed)
            for t in range(25):
                g = dyn(t)
                g.validate()
                assert g.is_connected(), (seed, n, p, t)


def test_iov_dropped_set_is_replayable_and_consistent():
    dyn = iov_gilbert(8, p_drop=0.5, seed=4)
    for t in range(20):
        dropped = dyn.dropped(t)
        assert dropped == iov_gilbert(8, p_drop=0.5, seed=4).dropped(t)
        # drops are a subset of the base line + skip links
        base = {(m, m + 1) for m in range(7)} | {(m, m + 2) for m in range(6)}
        assert dropped <= base
        # links that never faded are always present in the repaired graph
        for a, b in base - dropped:
            assert b in dyn(t).neighbors(a)


def test_leo_rotation_invariants():
    """The visibility graph is a circulant: every node has the same degree,
    the graph is invariant under label rotation, and it returns to the
    initial band after num_nodes periods."""
    for n, window, period in [(6, 2, 1), (9, 2, 3), (11, 3, 2)]:
        dyn = leo_constellation(n, window=window, period=period)
        for t in range(2 * n):
            g = dyn(t)
            # vertex-transitive: every node sees the same number of links
            # (2*window in general; fewer when a band distance hits n/2 or
            # wraps to 0 and is skipped — never below the connecting ring)
            degs = {g.degree(m) for m in range(n)}
            assert len(degs) == 1 and 2 <= degs.pop() <= 2 * window
            for m in range(n):  # rotation symmetry of the banded ring
                rotated = tuple(sorted((v + 1) % n for v in g.neighbors(m)))
                assert rotated == g.neighbors((m + 1) % n)
        assert dyn(0).adjacency == dyn(n * period).adjacency
        # the band actually moves between periods
        assert dyn(0).adjacency != dyn(period).adjacency


def test_set_topology_determinism_across_swaps():
    """Two schedulers fed the same swap sequence walk identical paths, and
    swapping a graph out and back leaves the scheduler state untouched."""
    n = 8
    dyn = make_dynamic("iov", n, seed=5)
    sizes = list(range(10, 10 + n))
    a = FedCHSScheduler(dyn(0), sizes, initial=2)
    b = FedCHSScheduler(dyn(0), sizes, initial=2)
    walk_a, walk_b = [], []
    for t in range(60):
        a.set_topology(dyn(t))
        b.set_topology(dyn(t))
        walk_a.append(a.advance())
        walk_b.append(b.advance())
    assert walk_a == walk_b
    assert np.array_equal(a.state.visit_counts, b.state.visit_counts)

    # swap away and back: peek is a pure function of (state, topology)
    before = a.peek()
    a.set_topology(make_topology("ring", n))
    a.set_topology(dyn(59))
    assert a.peek() == before


@pytest.mark.parametrize("kind", ["leo", "iov"])
def test_scheduler_no_starvation_under_dynamics(kind):
    """The 2-step rule must keep covering every cluster while the graph
    changes under it (the paper's robustness claim)."""
    n = 8
    dyn = make_dynamic(kind, n, seed=1)
    sched = FedCHSScheduler(dyn(0), list(range(10, 10 + n)), initial=0)
    T = 40 * n
    for t in range(T):
        sched.set_topology(dyn(t))
        sched.advance()
    counts = sched.state.visit_counts
    assert counts.min() >= T // (10 * n), counts


def test_fed_chs_converges_on_dynamic_topology(small_task):
    """End-to-end: Fed-CHS trains through a rotating LEO constellation
    exactly as well as through a static sparse graph."""
    from repro.core import FedCHSConfig, run_fed_chs

    res = run_fed_chs(small_task, FedCHSConfig(
        rounds=16, local_steps=10, eval_every=8, dynamic="leo", seed=0))
    # measured 0.998 at this config; 0.9 leaves margin for backend drift
    assert res.final_acc() > 0.9, res.test_acc
    # ledger: still exactly one ES->ES hop per round, no PS traffic
    assert res.ledger.messages["es_to_es"] == 16
    assert res.ledger.bits["es_to_ps"] == 0 and res.ledger.bits["client_to_ps"] == 0
