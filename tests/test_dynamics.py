"""Dynamic (time-varying) ES topologies — the paper's Appendix-D scenarios
and the §1 claim that the 2-step rule is robust to them."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dynamics import iov_gilbert, leo_constellation, make_dynamic
from repro.core.scheduler import FedCHSScheduler


@given(n=st.integers(5, 16), t=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_leo_graphs_valid_connected_and_rotating(n, t):
    dyn = leo_constellation(n, window=2, period=1)
    g = dyn(t)
    g.validate()
    assert g.is_connected()
    # the band rotates: after n periods it returns to the start
    assert dyn(t).adjacency == dyn(t + n).adjacency


@given(n=st.integers(3, 16), t=st.integers(0, 100), p=st.sampled_from([0.1, 0.3, 0.6]))
@settings(max_examples=25, deadline=None)
def test_iov_graphs_valid_connected_and_replayable(n, t, p):
    dyn = iov_gilbert(n, p_drop=p, seed=3)
    g = dyn(t)
    g.validate()
    assert g.is_connected()
    assert dyn(t).adjacency == iov_gilbert(n, p_drop=p, seed=3)(t).adjacency  # replayable
    assert iov_gilbert(n, p_drop=0.9, seed=3)(t).is_connected()  # repair works


@pytest.mark.parametrize("kind", ["leo", "iov"])
def test_scheduler_no_starvation_under_dynamics(kind):
    """The 2-step rule must keep covering every cluster while the graph
    changes under it (the paper's robustness claim)."""
    n = 8
    dyn = make_dynamic(kind, n, seed=1)
    sched = FedCHSScheduler(dyn(0), list(range(10, 10 + n)), initial=0)
    T = 40 * n
    for t in range(T):
        sched.set_topology(dyn(t))
        sched.advance()
    counts = sched.state.visit_counts
    assert counts.min() >= T // (10 * n), counts


def test_fed_chs_converges_on_dynamic_topology(small_task):
    """End-to-end: Fed-CHS trains through a rotating LEO constellation
    exactly as well as through a static sparse graph."""
    from repro.core import FedCHSConfig, run_fed_chs

    res = run_fed_chs(small_task, FedCHSConfig(
        rounds=16, local_steps=5, eval_every=8, dynamic="leo", seed=0))
    assert res.final_acc() > 0.7, res.test_acc
    # ledger: still exactly one ES->ES hop per round, no PS traffic
    assert res.ledger.messages["es_to_es"] == 16
    assert res.ledger.bits["es_to_ps"] == 0 and res.ledger.bits["client_to_ps"] == 0
