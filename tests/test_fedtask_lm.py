"""The transformer-LM FedTask runs through the SAME metered stack as the
paper's classifiers: engine rounds, compressed channels, bit-exact ledger
events, and a netsim replay to simulated wall-clock time-to-perplexity."""
import numpy as np
import pytest

from repro.comm.channels import QSGDChannel, channel_wire_bits
from repro.comm.bits import dense_message_bits
from repro.configs.base import ArchConfig
from repro.core import FedCHSConfig, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    WRWGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
    run_wrwgd,
)
from repro.core.simulation import FLTask
from repro.data.sources import TokenSource
from repro.models.fed import LMFedModel
from repro.netsim.adapters import simulate_run, time_to_accuracy
from repro.netsim.links import NetworkModel

VOCAB, SEQ, BATCH = 64, 16, 2
T, K, E = 3, 4, 2  # rounds, local steps, steps per upload
J = K // E


@pytest.fixture(scope="module")
def lm_task():
    cfg = ArchConfig(
        name="toy-lm", family="dense", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, d_ff=64, vocab_size=VOCAB, dtype="float32",
    )
    source = TokenSource(VOCAB, num_clients=4, batch_size=BATCH, seq_len=SEQ,
                         topics=4, seed=0)
    # two equal clusters of two clients -> closed-form message counts
    return FLTask.from_source(LMFedModel(cfg), source, [[0, 1], [2, 3]], seed=0)


def _chs_config(**kw):
    return FedCHSConfig(rounds=T, local_steps=K, local_epochs=E, eval_every=1,
                        seed=0, channel=QSGDChannel(16), schedule=lambda k: 0.3, **kw)


def test_fed_chs_lm_loss_decreases_and_ledger_closed_form(lm_task):
    res = run_fed_chs(lm_task, _chs_config())

    # training moves: loss below the uniform-vocab ceiling and decreasing
    assert res.train_loss[0] < np.log(VOCAB) + 0.5
    assert res.train_loss[-1] < res.train_loss[0]
    assert res.metric_mode == "min"  # perplexity
    assert all(p > 0 for p in res.test_acc)

    # closed-form §3.2 bit accounting for this config: every round one
    # 2-client cluster runs J interactions (broadcast down, QSGD up), then
    # one dense ES->ES pass
    d = lm_task.num_params()
    # wire channels are priced on the exact per-leaf packed payload
    up = channel_wire_bits(QSGDChannel(16), d, lm_task.param_leaf_sizes())
    down = dense_message_bits(d)
    assert res.ledger.bits["client_to_es"] == T * J * 2 * up
    assert res.ledger.bits["es_to_client"] == T * J * 2 * down
    assert res.ledger.bits["es_to_es"] == T * down
    assert res.ledger.bits["es_to_ps"] == 0  # no PS anywhere
    assert res.ledger.total_bits() == T * (J * 2 * (up + down) + down)


def test_fedavg_lm_loss_decreases_and_ledger_closed_form(lm_task):
    res = run_fedavg(lm_task, FedAvgConfig(
        rounds=T, local_steps=K, eval_every=1, seed=0, channel=QSGDChannel(16),
        schedule=lambda k: 0.3))
    assert res.train_loss[-1] < res.train_loss[0]

    d = lm_task.num_params()
    n = lm_task.num_clients
    up = channel_wire_bits(QSGDChannel(16), d, lm_task.param_leaf_sizes())
    assert res.ledger.bits["client_to_ps"] == T * n * up
    assert res.ledger.bits["ps_to_client"] == T * n * dense_message_bits(d)


def test_lm_event_stream_replays_through_netsim(lm_task):
    res = run_fed_chs(lm_task, _chs_config())
    assert len(res.ledger.events) == T * (J * 2 * 2 + 1)  # per-message metadata
    timeline = simulate_run(lm_task, res, NetworkModel(), local_steps=K)
    assert timeline.makespan > 0
    # time-to-loss: a generous perplexity target must be reached and priced
    tta = time_to_accuracy(res, timeline, VOCAB * 2.0)
    assert tta is not None and 0 < tta <= timeline.makespan
    # an unreachable target prices to None, not an error
    assert time_to_accuracy(res, timeline, 1.0) is None


def test_remaining_baselines_run_lm_end_to_end(lm_task):
    """WRWGD (client-level walk) and Hier-Local-QSGD (3-tier, vmapped over
    clusters) execute the transformer FedTask and their event streams
    schedule through netsim."""
    wr = run_wrwgd(lm_task, WRWGDConfig(rounds=2, local_steps=2, eval_every=1,
                                        seed=0, schedule=lambda k: 0.3))
    assert np.isfinite(wr.train_loss).all()
    tl = simulate_run(lm_task, wr, NetworkModel(), local_steps=2)
    assert tl.makespan > 0

    hi = run_hier_local_qsgd(lm_task, HierLocalQSGDConfig(
        rounds=2, local_steps=K, local_epochs=E, eval_every=1, seed=0,
        qsgd_levels=16, schedule=lambda k: 0.3))
    assert np.isfinite(hi.train_loss).all()
    tl = simulate_run(lm_task, hi, NetworkModel(), local_steps=K)
    assert tl.makespan > 0


def test_token_source_draws_are_position_keyed():
    """Draws are a pure function of (seed, client, draw index): a reset source
    replays the exact stream, and fast_forward resumes mid-stream without
    replaying (the old example's batch_for(round_idx) ignored its argument)."""
    src = TokenSource(VOCAB, num_clients=2, batch_size=2, seq_len=8, seed=3)
    first = [src.next_batch(0) for _ in range(4)]
    src.reset(3)
    replay = [src.next_batch(0) for _ in range(4)]
    for a, b in zip(first, replay):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    src.reset(3)
    src.fast_forward([2, 0])
    resumed = src.next_batch(0)
    np.testing.assert_array_equal(resumed["tokens"], first[2]["tokens"])

    # different seed -> different stream; eval set is seed-independent
    e1 = src.eval_data()
    src.reset(4)
    assert not np.array_equal(src.next_batch(0)["tokens"], first[0]["tokens"])
    np.testing.assert_array_equal(e1["tokens"], src.eval_data()["tokens"])


def test_token_source_is_non_iid_across_clients():
    """Clients emphasize different topics: bigram statistics differ more
    across clients than across two draws of the same client."""
    src = TokenSource(VOCAB, num_clients=2, batch_size=8, seq_len=64,
                      topics=2, dominance=1.0, seed=0)

    def bigram_hist(batch):
        toks = batch["tokens"]
        h = np.zeros((VOCAB, VOCAB))
        for row in toks:
            h[row[:-1], row[1:]] += 1
        return h / h.sum()

    a1, a2 = bigram_hist(src.next_batch(0)), bigram_hist(src.next_batch(0))
    b1 = bigram_hist(src.next_batch(1))
    within = np.abs(a1 - a2).sum()
    across = np.abs(a1 - b1).sum()
    assert across > within
