"""roofline.attribution on a hand-written post-optimization HLO module:
trip scaling through while bodies, the 2x all-reduce factor, skip-list,
and op_name-phase grouping (phase_bytes)."""

from repro.roofline.attribution import (
    collective_breakdown,
    phase_bytes,
    top_output_bytes,
)

# 8*4*4 = 128 B all-reduce inside a 48-trip while; 16*4 = 64 B permute outside
HLO = """\
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %p = (s32[], f32[8,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,4]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,4]{1,0} all-reduce(%x), to_apply=%add, metadata={op_name="jit(f)/psum"}
  %big = f32[64,64]{1,0} multiply(%ar, %ar)
  ROOT %t = (s32[], f32[8,4]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,4])) -> pred[] {
  %p = (s32[], f32[8,4]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (arg: f32[8,4]) -> f32[8,4] {
  %arg = f32[8,4]{1,0} parameter(0)
  %init = (s32[], f32[8,4]) tuple(%arg)
  %w = (s32[], f32[8,4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"48"}}
  %cp = f32[16]{0} collective-permute(%arg), source_target_pairs={{0,1}}, metadata={op_name="jit(f)/ppermute"}
  ROOT %out = f32[8,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_breakdown_trip_and_factor():
    rows = collective_breakdown(HLO)
    by_op = {r["op"]: r for r in rows}
    # all-reduce: 128 B * 2 (reduce+broadcast) * 48 trips
    assert by_op["all-reduce"]["bytes"] == 128 * 2 * 48
    assert "psum" in by_op["all-reduce"]["source"]
    # collective-permute: 64 B, once, factor 1
    assert by_op["collective-permute"]["bytes"] == 64
    # sorted descending
    assert rows[0]["op"] == "all-reduce"


def test_top_output_bytes_scaling_and_skips():
    rows = top_output_bytes(HLO)
    names = [r["name"] for r in rows]
    # bookkeeping excluded
    assert all(r["op"] not in ("parameter", "tuple", "get-tuple-element")
               for r in rows)
    # the in-loop 16 KiB multiply dominates (x48)
    assert rows[0]["name"] == "big"
    assert rows[0]["bytes"] == 64 * 64 * 4 * 48
    # the all-reduce output inside the loop is also trip-scaled
    ar = next(r for r in rows if r["name"] == "ar")
    assert ar["bytes"] == 128 * 48


def test_phase_bytes_groups_by_op_name():
    got = phase_bytes(HLO, {"comm": r"psum|ppermute"})
    # tagged: in-loop all-reduce output (128 B x 48) + permute (64 B)
    assert got["comm"] == 128 * 48 + 64
    # untagged non-bookkeeping: the 16 KiB multiply x 48 (+ tiny cond pred)
    assert got["other"] >= 64 * 64 * 4 * 48
    # first-match-wins: a pattern hitting everything leaves nothing behind
    all_in = phase_bytes(HLO, {"everything": r""})
    assert "other" not in all_in or all_in["other"] == 0.0


def test_phase_bytes_attributes_qsgd_wire_cost_end_to_end():
    """The named_scope tags in kernels/ops.py survive jit into compiled HLO:
    phase_bytes on a real encode→decode roundtrip bills nonzero bytes to both
    phases. This is the hook benchmarks use to attribute quantize/pack cost."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import qsgd_decode, qsgd_encode

    def roundtrip(v, key):
        wire = qsgd_encode(v, key, s=16)
        return qsgd_decode(wire, s=16, shape=(4096,))

    hlo = (
        jax.jit(roundtrip)
        .lower(jnp.zeros((4096,)), jax.random.PRNGKey(0))
        .compile()
        .as_text()
    )
    got = phase_bytes(hlo, {"encode": r"qsgd_encode", "decode": r"qsgd_decode"})
    assert got.get("encode", 0.0) > 0.0
    assert got.get("decode", 0.0) > 0.0
    # the payload itself (4 blocks x 6-bit planes x 32 words x 4 B) plus the
    # uniform draw and intermediates: encode moves at least the payload bytes
    assert got["encode"] >= 4 * 6 * 32 * 4
