"""roofline.attribution on a hand-written post-optimization HLO module:
trip scaling through while bodies, the 2x all-reduce factor, skip-list,
and op_name-phase grouping (phase_bytes)."""

from repro.roofline.attribution import (
    collective_breakdown,
    phase_bytes,
    top_output_bytes,
)

# 8*4*4 = 128 B all-reduce inside a 48-trip while; 16*4 = 64 B permute outside
HLO = """\
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %p = (s32[], f32[8,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,4]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,4]{1,0} all-reduce(%x), to_apply=%add, metadata={op_name="jit(f)/psum"}
  %big = f32[64,64]{1,0} multiply(%ar, %ar)
  ROOT %t = (s32[], f32[8,4]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,4])) -> pred[] {
  %p = (s32[], f32[8,4]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (arg: f32[8,4]) -> f32[8,4] {
  %arg = f32[8,4]{1,0} parameter(0)
  %init = (s32[], f32[8,4]) tuple(%arg)
  %w = (s32[], f32[8,4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"48"}}
  %cp = f32[16]{0} collective-permute(%arg), source_target_pairs={{0,1}}, metadata={op_name="jit(f)/ppermute"}
  ROOT %out = f32[8,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_breakdown_trip_and_factor():
    rows = collective_breakdown(HLO)
    by_op = {r["op"]: r for r in rows}
    # all-reduce: 128 B * 2 (reduce+broadcast) * 48 trips
    assert by_op["all-reduce"]["bytes"] == 128 * 2 * 48
    assert "psum" in by_op["all-reduce"]["source"]
    # collective-permute: 64 B, once, factor 1
    assert by_op["collective-permute"]["bytes"] == 64
    # sorted descending
    assert rows[0]["op"] == "all-reduce"


def test_top_output_bytes_scaling_and_skips():
    rows = top_output_bytes(HLO)
    names = [r["name"] for r in rows]
    # bookkeeping excluded
    assert all(r["op"] not in ("parameter", "tuple", "get-tuple-element")
               for r in rows)
    # the in-loop 16 KiB multiply dominates (x48)
    assert rows[0]["name"] == "big"
    assert rows[0]["bytes"] == 64 * 64 * 4 * 48
    # the all-reduce output inside the loop is also trip-scaled
    ar = next(r for r in rows if r["name"] == "ar")
    assert ar["bytes"] == 128 * 48


def test_phase_bytes_groups_by_op_name():
    got = phase_bytes(HLO, {"comm": r"psum|ppermute"})
    # tagged: in-loop all-reduce output (128 B x 48) + permute (64 B)
    assert got["comm"] == 128 * 48 + 64
    # untagged non-bookkeeping: the 16 KiB multiply x 48 (+ tiny cond pred)
    assert got["other"] >= 64 * 64 * 4 * 48
    # first-match-wins: a pattern hitting everything leaves nothing behind
    all_in = phase_bytes(HLO, {"everything": r""})
    assert "other" not in all_in or all_in["other"] == 0.0


def test_phase_bytes_attributes_qsgd_wire_cost_end_to_end():
    """The named_scope tags in kernels/ops.py survive jit into compiled HLO:
    phase_bytes on a real encode→decode roundtrip bills nonzero bytes to both
    phases. This is the hook benchmarks use to attribute quantize/pack cost."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import qsgd_decode, qsgd_encode

    def roundtrip(v, key):
        wire = qsgd_encode(v, key, s=16)
        return qsgd_decode(wire, s=16, shape=(4096,))

    hlo = (
        jax.jit(roundtrip)
        .lower(jnp.zeros((4096,)), jax.random.PRNGKey(0))
        .compile()
        .as_text()
    )
    got = phase_bytes(hlo, {"encode": r"qsgd_encode", "decode": r"qsgd_decode"})
    assert got.get("encode", 0.0) > 0.0
    assert got.get("decode", 0.0) > 0.0
    # the payload itself (4 blocks x 6-bit planes x 32 words x 4 B) plus the
    # uniform draw and intermediates: encode moves at least the payload bytes
    assert got["encode"] >= 4 * 6 * 32 * 4


def test_phase_bytes_pins_bf16_dense_wire_roundtrip():
    """DenseChannel(wire_dtype="bfloat16") encode/decode: the wire scopes
    survive jit and the billed bytes are EXACTLY the payload widths — encode
    emits the bf16 payload (2 B/param), decode rebuilds f32 (4 B/param)."""
    import jax
    import jax.numpy as jnp

    from repro.comm.channels import DenseChannel

    ch = DenseChannel(wire_dtype="bfloat16")
    leaf = jnp.zeros((1024,), jnp.float32)

    enc_hlo = (
        jax.jit(lambda t: ch.encode(t)).lower({"w": leaf}).compile().as_text()
    )
    got = phase_bytes(enc_hlo, {"encode": r"wire_encode"})
    assert got["encode"] == 1024 * 2  # bf16 payload: 2 bytes per param

    def dec(wires):
        return ch.decode(wires, {"w": leaf})

    dec_hlo = (
        jax.jit(dec)
        .lower([{"payload": leaf.astype(jnp.bfloat16)}])
        .compile()
        .as_text()
    )
    got = phase_bytes(dec_hlo, {"decode": r"wire_decode"})
    assert got["decode"] == 1024 * 4  # rebuilt at f32: 4 bytes per param


def test_phase_bytes_attributes_mixed_precision_round(small_task):
    """A bf16 round bills nonzero bytes to precision_cast (the params/batch/lr
    down-casts survive jit as tagged converts) on BOTH engine paths (vmapped
    and microbatched).  The master up-cast of the deltas fuses into the
    gamma-weighted einsum, so its bytes land under intra_agg — which must
    therefore also be nonzero and larger than the bare f32 aggregate of the
    no-precision round (the fused accumulate now reads bf16 and writes f32)."""
    import jax.numpy as jnp

    from repro.comm.channels import DenseChannel
    from repro.core.engine import RoundEngine, _delta_round_fn
    from repro.core.precision import Precision, dense_wire_channel

    prec = Precision()
    channel = dense_wire_channel(prec)
    assert channel == DenseChannel(wire_dtype="bfloat16")
    engine = RoundEngine(small_task.model, channel, precision=prec)
    params = small_task.init_params()
    n = len(small_task.cluster_members[0])
    opt_state = engine.init_opt_state(params, n)
    batch = small_task.sample_round_batches(0, 4, 2)
    gammas = jnp.asarray(small_task.cluster_weights(0))
    lrs = jnp.full((2, 2), 0.05, jnp.float32)
    for mb in (None, 2):
        fn = _delta_round_fn(engine.model, channel, engine.local_opt, False,
                             mb, prec)
        hlo = fn.lower(params, opt_state, batch, gammas, lrs,
                       None).compile().as_text()
        got = phase_bytes(hlo, {"cast": r"precision_cast",
                                "agg": r"intra_agg|master_accumulate",
                                "train": r"local_train"})
        assert got.get("cast", 0.0) > 0.0, mb
        assert got.get("agg", 0.0) > 0.0, mb
        assert got.get("train", 0.0) > 0.0, mb
        # training (fwd+bwd over E steps per client) still dominates
        assert got["train"] > got["agg"], mb


def test_compute_seconds_prices_f32_dots_at_half_rate():
    from repro.roofline.analysis import HW, arithmetic_intensity, compute_seconds

    hw = HW(peak_flops=200e12, peak_flops_f32=100e12)
    rec = {"dot_flops_per_device": 3e12,
           "dot_flops_by_dtype": {"bf16": 2e12, "f32": 1e12},
           "scaled_bytes_per_device": 1.5e12}
    assert compute_seconds(rec, hw=hw) == 2e12 / 200e12 + 1e12 / 100e12
    assert arithmetic_intensity(rec) == 2.0
    # records without the breakdown (older artifacts) use the flat bf16 rate
    flat = {"dot_flops_per_device": 3e12}
    assert compute_seconds(flat, hw=hw) == 3e12 / 200e12


def test_analyze_hlo_dtype_breakdown():
    """dot flops are split by output dtype so mixed-precision graphs can be
    priced per MXU rate; a bf16 matmul lands under a low-precision key."""
    import jax
    import jax.numpy as jnp

    from repro.roofline.analysis import analyze_hlo_text

    def f(a, b):
        return (a @ b).astype(jnp.float32)

    a = jnp.zeros((64, 128), jnp.bfloat16)
    b = jnp.zeros((128, 32), jnp.bfloat16)
    rec = analyze_hlo_text(jax.jit(f).lower(a, b).compile().as_text())
    want = 2.0 * 64 * 32 * 128
    assert rec["dot_flops_per_device"] == want
    assert sum(rec["dot_flops_by_dtype"].values()) == want
