"""Unit coverage for the whole-run scan machinery: eval segmentation,
scheduler precompute, deferred ledger materialization, bulk batch staging,
chunk-size invariance, and the vmapped multi-seed sweep."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import FedCHSConfig, run_fed_chs, run_sweep
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    WRWGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
    run_wrwgd,
)
from repro.core.engine import eval_rounds
from repro.core.ledger import CommLedger
from repro.core.scheduler import AvailabilityAwareScheduler, FedCHSScheduler
from repro.core.topology import make_topology
from repro.data.sources import ArraySource, bulk_batches
from repro.part import AvailabilityAware, BernoulliTrace, UniformK, schedule_participants, stack_masks


# --------------------------------------------------------------------------
# eval segmentation
# --------------------------------------------------------------------------


@pytest.mark.parametrize("rounds,eval_every", [(1, 1), (5, 2), (10, 3), (200, 10),
                                               (7, 100), (8, 4)])
def test_eval_rounds_matches_looped_cadence(rounds, eval_every):
    looped = [t for t in range(rounds) if t % eval_every == 0 or t == rounds - 1]
    assert eval_rounds(rounds, eval_every) == looped


# --------------------------------------------------------------------------
# scheduler precompute
# --------------------------------------------------------------------------


def test_precompute_matches_sequential_advance():
    topo = make_topology("random_sparse", 6, seed=2)
    sizes = [5, 9, 3, 7, 4, 6]
    a = FedCHSScheduler(topo, sizes, initial=1)
    order = a.precompute(25)
    b = FedCHSScheduler(topo, sizes, initial=1)
    seq = [b.state.current] + [b.advance() for _ in range(24)]
    assert list(order) == seq
    # precompute does not mutate: the scheduler still replays the same walk
    assert a.state.current == 1 and a.state.step == 0
    assert list(a.precompute(25)) == seq


def test_precompute_availability_scheduler_probes_next_round():
    """The availability variant probes reachability at state.step + 1 — the
    precomputed order must agree with live advances (same probe indices)."""
    topo = make_topology("ring", 5, seed=0)
    sizes = [4, 4, 4, 4, 4]
    trace = BernoulliTrace(p=0.5, seed=7)

    def reachable(m, r):
        return trace.available(m, r)

    a = AvailabilityAwareScheduler(topo, sizes, reachable, initial=0)
    order = a.precompute(20)
    b = AvailabilityAwareScheduler(topo, sizes, reachable, initial=0)
    seq = [b.state.current] + [b.advance() for _ in range(19)]
    assert list(order) == seq


# --------------------------------------------------------------------------
# deferred ledger
# --------------------------------------------------------------------------


def test_materialize_replays_record_stream():
    live = CommLedger()
    for t in range(3):
        for i in (4, 7):
            live.record("client_to_es", 100, round=t, phase=0,
                        sender=f"client:{i}", receiver="es:0")
        live.record("es_to_es", 320, round=t, phase=1, sender="es:0", receiver="es:1")
        live.snapshot(t)

    deferred = CommLedger()
    deferred.materialize(
        (t, [("client_to_es", 100, 1, 0, "client:4", "es:0"),
             ("client_to_es", 100, 1, 0, "client:7", "es:0"),
             ("es_to_es", 320, 1, 1, "es:0", "es:1")])
        for t in range(3)
    )
    assert deferred.bits == live.bits
    assert deferred.messages == live.messages
    assert deferred.events == live.events
    assert deferred.history == live.history


def test_materialize_aggregate_mode():
    live = CommLedger(track_events=False)
    live.record("client_to_ps", 64, 5)
    live.snapshot(0)
    deferred = CommLedger(track_events=False)
    deferred.materialize([(0, [("client_to_ps", 64, 5, 0, None, None)])])
    assert deferred.bits == live.bits and deferred.messages == live.messages
    assert deferred.events == [] and deferred.history == live.history


# --------------------------------------------------------------------------
# participation precompute helpers
# --------------------------------------------------------------------------


def test_schedule_participants_matches_pointwise_queries():
    sampler = UniformK(k=3, seed=2, trace=BernoulliTrace(p=0.7, seed=1))
    clients = [2, 5, 6, 9, 11]
    sched = schedule_participants(sampler, 12, clients)
    assert sched == [sampler.participants(t, clients) for t in range(12)]
    full = schedule_participants(None, 4, clients)
    assert full == [clients] * 4


def test_stack_masks_pads_to_width():
    members = [3, 8, 5]
    parts = [[3, 5], [], [3, 8, 5]]
    masks = stack_masks(members, parts, width=5)
    np.testing.assert_array_equal(
        masks,
        np.array([[1, 0, 1, 0, 0], [0, 0, 0, 0, 0], [1, 1, 1, 0, 0]], np.float32))


# --------------------------------------------------------------------------
# bulk staging
# --------------------------------------------------------------------------


def test_next_batches_bit_identical_to_sequential_draws(small_task):
    src = small_task.source
    assert isinstance(src, ArraySource)
    src.reset(5)
    seq = [src.next_batch(3) for _ in range(6)]
    src.reset(5)
    bulk = bulk_batches(src, 3, 6)
    for j in range(6):
        np.testing.assert_array_equal(bulk["x"][j], seq[j]["x"])
        np.testing.assert_array_equal(bulk["y"][j], seq[j]["y"])
    # the stream position after a bulk read equals six sequential reads
    a = src.next_batch(3)
    src.reset(5)
    for _ in range(6):
        src.next_batch(3)
    b = src.next_batch(3)
    np.testing.assert_array_equal(a["x"], b["x"])


def test_bulk_batches_generic_fallback():
    class Minimal:
        batch_size = 2
        num_clients = 1
        client_sizes = np.ones(1)

        def __init__(self):
            self.n = 0

        def reset(self, seed):
            self.n = 0

        def next_batch(self, client):
            self.n += 1
            return {"x": np.full((2, 3), self.n)}

        def eval_data(self):
            return None

    src = Minimal()
    out = bulk_batches(src, 0, 3)
    np.testing.assert_array_equal(out["x"][:, 0, 0], [1, 2, 3])


# --------------------------------------------------------------------------
# chunking invariance: the chunk_rounds knob is a memory bound, never a
# semantics change
# --------------------------------------------------------------------------


def test_chunk_rounds_invariance(small_task):
    base = FedCHSConfig(rounds=7, local_steps=4, local_epochs=2, qsgd_levels=8,
                        eval_every=3, seed=1)
    ref = run_fed_chs(small_task, dataclasses.replace(base, chunk_rounds=1))
    for chunk in (2, 3, 64):
        res = run_fed_chs(small_task, dataclasses.replace(base, chunk_rounds=chunk))
        assert res.test_acc == ref.test_acc
        np.testing.assert_allclose(res.train_loss, ref.train_loss, atol=1e-5, rtol=0)
        for la, lb in zip(jax.tree.leaves(res.final_params),
                          jax.tree.leaves(ref.final_params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        assert res.ledger.events == ref.ledger.events


# --------------------------------------------------------------------------
# vmapped multi-seed sweep
# --------------------------------------------------------------------------


def _assert_sweep_matches_solo(task, run, cfg, seeds, exact):
    swept = run_sweep(task, cfg, seeds)
    for s, res in zip(seeds, swept):
        solo = run(task, dataclasses.replace(cfg, seed=s))
        assert res.name == solo.name and res.rounds == solo.rounds
        assert res.ledger.bits == solo.ledger.bits
        assert res.ledger.events == solo.ledger.events
        if exact:  # grad mode: bit-identical to the solo scanned run
            assert res.test_acc == solo.test_acc
            for la, lb in zip(jax.tree.leaves(res.final_params),
                              jax.tree.leaves(solo.final_params)):
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        else:  # delta mode: vmap reassociates small reductions by ~1 ulp
            np.testing.assert_allclose(res.test_acc, solo.test_acc, atol=0.02)
            for la, lb in zip(jax.tree.leaves(res.final_params),
                              jax.tree.leaves(solo.final_params)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           atol=1e-5, rtol=0)


def test_sweep_fed_chs_grad_mode_bit_identical(small_task):
    _assert_sweep_matches_solo(
        small_task, run_fed_chs,
        FedCHSConfig(rounds=5, local_steps=4, eval_every=2), (0, 3, 7), exact=True)


def test_sweep_wrwgd_bit_identical(small_task):
    _assert_sweep_matches_solo(
        small_task, run_wrwgd,
        WRWGDConfig(rounds=6, local_steps=4, eval_every=2), (0, 9), exact=True)


def test_sweep_delta_mode_numerically_identical(small_task):
    _assert_sweep_matches_solo(
        small_task, run_fedavg,
        FedAvgConfig(rounds=3, local_steps=4, eval_every=1), (0, 5), exact=False)
    _assert_sweep_matches_solo(
        small_task, run_hier_local_qsgd,
        HierLocalQSGDConfig(rounds=2, local_steps=4, local_epochs=2,
                            qsgd_levels=16, eval_every=1), (0, 4), exact=False)


def test_sweep_rejects_sampler_configs(small_task):
    cfg = FedCHSConfig(rounds=3, local_steps=4, local_epochs=2,
                       sampler=AvailabilityAware(BernoulliTrace(p=0.5)))
    with pytest.raises(AssertionError):
        run_sweep(small_task, cfg, (0, 1))


def test_sweep_leaves_task_source_untouched(small_task):
    """Sweeps stage from per-seed shallow copies; the task's own source must
    keep its position so interleaved solo runs stay deterministic."""
    small_task.reset_loaders(123)
    before = small_task.source.next_batch(0)
    small_task.reset_loaders(123)
    run_sweep(small_task, FedCHSConfig(rounds=3, local_steps=4, eval_every=2), (0, 1))
    after = small_task.source.next_batch(0)
    np.testing.assert_array_equal(before["x"], after["x"])
