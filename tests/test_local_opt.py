"""The LocalOpt plug point: client-held optimizer state changes trajectories
but never the wire — and the default plain-SGD path is the seed-parity path."""
import numpy as np

from repro.core import FedCHSConfig, run_fed_chs
from repro.core.baselines import FedAvgConfig, run_fedavg
from repro.optim.local import AdamWOpt, MomentumSGD, PlainSGD


def _cfg(**kw):
    # delta mode (E=2) so the local-opt plug point is actually exercised
    return FedCHSConfig(rounds=3, local_steps=4, local_epochs=2, qsgd_levels=16,
                        eval_every=1, seed=0, **kw)


def test_adamw_state_stays_local_uplink_bits_unchanged(small_task):
    """Switching SGD -> client-held AdamW changes zero bits on any hop: the
    moments never traverse a channel."""
    sgd = run_fed_chs(small_task, _cfg())
    adam = run_fed_chs(small_task, _cfg(local_opt=AdamWOpt(weight_decay=0.0)))
    assert dict(adam.ledger.bits) == dict(sgd.ledger.bits)
    assert dict(adam.ledger.messages) == dict(sgd.ledger.messages)
    # ... but the plug point is real: the trajectory differs
    assert adam.train_loss != sgd.train_loss


def test_explicit_plain_sgd_is_bit_identical_to_default(small_task):
    """`local_opt=PlainSGD()` must reproduce the default path exactly — the
    fixed-seed trajectory contract of tests/test_engine_parity.py extends to
    the explicit opt plug point."""
    default = run_fed_chs(small_task, _cfg())
    explicit = run_fed_chs(small_task, _cfg(local_opt=PlainSGD()))
    assert explicit.train_loss == default.train_loss
    assert explicit.test_acc == default.test_acc
    assert explicit.ledger.total_bits() == default.ledger.total_bits()

    # E=1 dense as well: explicit PlainSGD must still take the fused
    # grad-mode path, not silently switch to delta mode
    g_cfg = FedCHSConfig(rounds=2, local_steps=3, eval_every=1, seed=0)
    g_default = run_fed_chs(small_task, g_cfg)
    g_explicit = run_fed_chs(small_task, FedCHSConfig(
        rounds=2, local_steps=3, eval_every=1, seed=0, local_opt=PlainSGD()))
    assert g_explicit.train_loss == g_default.train_loss
    assert g_explicit.test_acc == g_default.test_acc


def test_momentum_state_persists_across_rounds(small_task):
    """A client-held velocity must carry across rounds: two 1-round runs from
    scratch differ from one 2-round run at the second round's loss."""
    cfg = FedAvgConfig(rounds=2, local_steps=4, eval_every=1, seed=0,
                       local_opt=MomentumSGD(momentum=0.9))
    two = run_fedavg(small_task, cfg)
    plain = run_fedavg(small_task, FedAvgConfig(rounds=2, local_steps=4,
                                                eval_every=1, seed=0))
    assert two.train_loss != plain.train_loss
    assert np.isfinite(two.train_loss).all()


def test_fedavg_adamw_runs_and_learns(small_task):
    res = run_fedavg(small_task, FedAvgConfig(rounds=6, local_steps=5, eval_every=5,
                                              seed=0, local_opt=AdamWOpt(weight_decay=0.0)))
    assert np.isfinite(res.train_loss).all()
    assert res.train_loss[-1] < res.train_loss[0]
