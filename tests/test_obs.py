"""Observability stack: span tracer, telemetry carrier, merged Chrome-trace
export + validation, netsim drop surfacing, named_scope round attribution,
and the RunResult empty-log metric-direction fix."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedCHSConfig, run_fed_chs
from repro.core.ledger import CommLedger
from repro.core.simulation import RunResult
from repro.netsim import Timeline, edge_cloud_network, replay_run
from repro.netsim.events import JobTimes
from repro.obs import (
    RunTelemetry,
    SpanTracer,
    build_chrome_trace,
    validate_chrome_trace,
    write_metrics_jsonl,
)

# --------------------------------------------------------------------------
# RunResult: empty logs must read as WORST, respecting metric direction
# --------------------------------------------------------------------------


def test_empty_run_result_reads_worst_for_both_metric_modes():
    for mode, worst in (("max", 0.0), ("min", float("inf"))):
        r = RunResult("x", [], [], [], CommLedger(), None, metric_mode=mode)
        assert r.best_acc() == worst
        assert r.final_acc() == worst


def test_min_mode_best_and_final_are_consistent():
    r = RunResult("lm", [0, 1, 2], [9.0, 3.5, 4.0], [0.0, 0.0, 0.0],
                  CommLedger(), None, metric_mode="min")
    assert r.best_acc() == 3.5
    assert r.final_acc() == 4.0
    assert r.rounds_to_accuracy(4.0) == 1  # min mode: first eval <= gamma


# --------------------------------------------------------------------------
# SpanTracer
# --------------------------------------------------------------------------


def test_span_tracer_nesting_and_wall():
    tr = SpanTracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
        with tr.span("inner"):
            pass
    assert [(k, n) for k, n, _ in tr.events] == [
        ("B", "outer"), ("B", "inner"), ("E", "inner"),
        ("B", "inner"), ("E", "inner"), ("E", "outer")]
    ts = [t for _, _, t in tr.events]
    assert ts == sorted(ts) and ts[0] == 0.0
    assert tr.wall("outer") >= tr.wall("inner") >= 0.0


def test_run_telemetry_rows_and_jsonl(tmp_path):
    obs = RunTelemetry()
    obs.record_round(0, {"update_norm": jnp.float32(1.5), "mass": jnp.float32(3)})
    obs.record_stacked([1, 2], {"update_norm": jnp.asarray([2.0, 2.5]),
                                "mass": jnp.asarray([3.0, 2.0])})
    rows = obs.metrics_rows()
    assert [r["round"] for r in rows] == [0, 1, 2]
    assert rows[1]["update_norm"] == 2.0
    path = tmp_path / "m.jsonl"
    assert write_metrics_jsonl(obs, path) == 3
    back = [json.loads(line) for line in path.read_text().splitlines()]
    assert back == rows
    s = obs.summary()
    assert s["rounds"] == 3
    assert s["metrics"]["mass"]["max"] == 3.0


# --------------------------------------------------------------------------
# export + validation
# --------------------------------------------------------------------------


def test_validate_catches_malformed_traces():
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]
    mismatched = {"traceEvents": [
        {"ph": "B", "pid": 1, "tid": "t", "name": "a", "ts": 1.0},
        {"ph": "E", "pid": 1, "tid": "t", "name": "b", "ts": 2.0}]}
    assert any("closes" in p for p in validate_chrome_trace(mismatched))
    unclosed = {"traceEvents": [
        {"ph": "B", "pid": 1, "tid": "t", "name": "a", "ts": 1.0}]}
    assert any("unclosed" in p for p in validate_chrome_trace(unclosed))
    backwards = {"traceEvents": [
        {"ph": "i", "pid": 2, "tid": "h", "name": "x", "ts": 5.0},
        {"ph": "i", "pid": 2, "tid": "h", "name": "y", "ts": 1.0}]}
    assert any("<" in p for p in validate_chrome_trace(backwards))
    ok = {"traceEvents": [
        {"ph": "i", "pid": 2, "tid": "h", "cat": "comm", "name": "x", "ts": 1.0}]}
    assert validate_chrome_trace(ok, expected_comm_events=2)  # count mismatch
    assert validate_chrome_trace(ok, expected_comm_events=1) == []


def test_ledger_event_index_groups_in_stream_order():
    led = CommLedger()
    led.record("client_to_es", 100, round=0, phase=0, sender="client:1",
               receiver="es:0")
    led.record("client_to_es", 100, round=0, phase=1, sender="client:1",
               receiver="es:0")
    led.record("es_to_es", 200, round=0, phase=2, sender="es:0", receiver="es:1")
    idx = led.event_index()
    assert idx[(0, "client_to_es", "client:1->es:0")] == [0, 1]
    assert idx[(0, "es_to_es", "es:0->es:1")] == [2]


def test_timeline_drop_counts():
    tl = Timeline(JobTimes(), {0: 1.0, 1: 2.0}, 2.0,
                  dropped={0: frozenset({"client:1", "client:2"}),
                           1: frozenset()})
    assert tl.drop_counts() == {0: 2}


def test_merged_trace_end_to_end(small_task):
    """One instrumented Fed-CHS run -> replay -> merged trace: valid, with
    every ledger event present as a comm instant and every netsim job as an
    X slice; drop bookkeeping rides along in otherData."""
    obs = RunTelemetry()
    cfg = FedCHSConfig(rounds=4, local_steps=4, local_epochs=2, eval_every=2,
                       seed=0, track_events=True, obs=obs)
    res = run_fed_chs(small_task, cfg)
    net = edge_cloud_network(seed=0)
    jobs, tl = replay_run(res, net, local_steps=cfg.local_steps,
                          batch_size=small_task.batch_size,
                          num_params=small_task.num_params())
    trace = build_chrome_trace(obs, res.ledger, jobs, tl)
    assert validate_chrome_trace(
        trace, expected_comm_events=len(res.ledger.events)) == []
    evs = trace["traceEvents"]
    assert sum(e.get("ph") == "X" for e in evs) == len(jobs)
    assert {e["pid"] for e in evs} == {1, 2, 3}
    assert trace["otherData"]["makespan_s"] == tl.makespan
    # comm instants sit at their carrying job's finish time, so none can
    # land after the simulated makespan
    comm_ts = [e["ts"] for e in evs if e.get("cat") == "comm"]
    assert comm_ts and max(comm_ts) <= tl.makespan * 1e6 + 1e-6


def test_trace_without_replay_uses_stream_order_clock(small_task):
    obs = RunTelemetry(taps=False)
    cfg = FedCHSConfig(rounds=2, local_steps=4, local_epochs=2, eval_every=1,
                       seed=1, track_events=True, obs=obs)
    res = run_fed_chs(small_task, cfg)
    trace = build_chrome_trace(obs, res.ledger)
    assert validate_chrome_trace(
        trace, expected_comm_events=len(res.ledger.events)) == []
    assert not obs.metrics  # taps=False: spans only, no tele


def test_sweep_rejects_telemetry(small_task):
    from repro.core import run_sweep

    cfg = FedCHSConfig(rounds=2, local_steps=2, eval_every=1,
                       obs=RunTelemetry())
    with pytest.raises(AssertionError, match="telemetry"):
        run_sweep(small_task, cfg, (0, 1))


# --------------------------------------------------------------------------
# named_scope round attribution: the engine's phase tags survive jit, so
# roofline.attribution.phase_bytes can bill a WHOLE Fed-CHS round by phase
# --------------------------------------------------------------------------


def test_phase_bytes_attributes_delta_round(small_task):
    from repro.core.engine import RoundEngine, _delta_round_fn, dummy_subs
    from repro.roofline.attribution import phase_bytes

    engine = RoundEngine(small_task.model)
    params = small_task.init_params()
    n = len(small_task.cluster_members[0])
    opt_state = engine.init_opt_state(params, n)
    batch = small_task.sample_round_batches(0, 4, 2)
    gammas = jnp.asarray(small_task.cluster_weights(0))
    lrs = jnp.full((2, 2), 0.05, jnp.float32)
    fn = _delta_round_fn(engine.model, engine.channel, engine.local_opt, False)
    hlo = fn.lower(params, opt_state, batch, gammas, lrs,
                   dummy_subs(2)).compile().as_text()
    got = phase_bytes(hlo, {"local_train": r"local_train",
                            "uplink": r"uplink",
                            "intra_agg": r"intra_agg"})
    assert got.get("local_train", 0.0) > 0.0
    assert got.get("uplink", 0.0) > 0.0
    assert got.get("intra_agg", 0.0) > 0.0
    # local training (per-client fwd+bwd over E steps) dominates the round
    assert got["local_train"] > got["intra_agg"]


def test_phase_bytes_attributes_multi_round_es_hop(small_task):
    from repro.core.engine import RoundEngine, _multi_round_fn, dummy_subs
    from repro.roofline.attribution import phase_bytes

    engine = RoundEngine(small_task.model)
    params = small_task.init_params()
    gammas, mask = small_task.padded_cluster_weights()
    M = small_task.num_clusters
    opt_state = engine.init_opt_state(params, M, mask.shape[1])
    batch = small_task.sample_all_cluster_batches(4, 2)
    es_weights = jnp.asarray(
        np.array(small_task.cluster_sizes, np.float32)
        / sum(small_task.cluster_sizes))
    lrs = jnp.full((2, 2), 0.05, jnp.float32)
    fn = _multi_round_fn(engine.model, engine.channel, engine.channel,
                         engine.local_opt, False)
    hlo = fn.lower(params, opt_state, batch, gammas, mask, es_weights, lrs,
                   dummy_subs(2, M), dummy_subs(M)).compile().as_text()
    got = phase_bytes(hlo, {"local_train": r"local_train",
                            "uplink": r"uplink",
                            "intra_agg": r"intra_agg",
                            "es_hop": r"es_hop"})
    for phase in ("local_train", "uplink", "intra_agg", "es_hop"):
        assert got.get(phase, 0.0) > 0.0, phase
