"""Kill-and-resume bit parity, across REAL process boundaries.

The in-process cells in tests/test_async_fl.py already pin resume parity;
these subprocess cells close the remaining gap — a checkpoint written by one
process and read by a *fresh* process (new PRNG objects, new jit caches, new
data-loader rng streams) must still continue bit-identically.  The crashed
leg dies via ``os._exit`` immediately after a checkpoint lands (the serve
--federation hidden --kill-after-activation switch), so nothing is flushed
gracefully: exactly the hard-kill the atomic tmp+rename writes are for.

Also covers the synchronous Fed-CHS looped driver's checkpoint/resume
(FedCHSConfig.checkpoint/resume), compared against the UNINTERRUPTED
scanned default — resume parity composes with scan/loop parity.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_service(tmp_path, extra, *, expect_fail=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [
        sys.executable, "-m", "repro.launch.serve", "--federation",
        "--rounds", "6", "--clients", "8", "--clusters", "2",
        "--local-steps", "2", "--quorum-frac", "0.6", "--deadline-s", "2.0",
        "--churn-p", "0.75", "--seed", "0", *extra,
    ]
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=ROOT, timeout=600)
    if expect_fail:
        assert p.returncode != 0, f"expected the kill leg to die:\n{p.stdout}"
        return None
    assert p.returncode == 0, f"{p.stdout}\n{p.stderr}"
    return json.loads(p.stdout.strip().splitlines()[-1])


def test_federation_service_kill_and_resume(tmp_path):
    ck = os.path.join(tmp_path, "ck")
    full = _run_service(tmp_path, [])
    _run_service(tmp_path, ["--checkpoint", ck, "--kill-after-activation", "3"],
                 expect_fail=True)
    resumed = _run_service(tmp_path, ["--checkpoint", ck, "--resume"])
    for k in ("test_acc", "sim_times", "total_bits", "staleness", "rounds"):
        assert full[k] == resumed[k], f"{k}: {full[k]} != {resumed[k]}"


def test_sync_fed_chs_resume_matches_scanned(small_task, tmp_path):
    """Looped-with-checkpoint -> kill -> resume equals the uninterrupted
    SCANNED run (checkpointing forces the looped path; loop/scan parity is
    pinned elsewhere, so this composes the two)."""
    from repro.core.fed_chs import FedCHSConfig, run_fed_chs

    kw = dict(rounds=8, local_steps=4, local_epochs=2, eval_every=2,
              initial_cluster=0, qsgd_levels=8)
    base = run_fed_chs(small_task, FedCHSConfig(**kw))  # scanned default

    ck = os.path.join(tmp_path, "sync")
    # the shortened leg's final-round eval (t=4) must sit ON the eval cadence
    # or its recorder log would carry an extra entry the full run never takes
    run_fed_chs(small_task, FedCHSConfig(**{**kw, "rounds": 5}, checkpoint=ck))
    resumed = run_fed_chs(small_task,
                          FedCHSConfig(**kw, checkpoint=ck, resume=True))

    la, lb = jax.tree.leaves(base.final_params), jax.tree.leaves(resumed.final_params)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(la, lb))
    assert base.test_acc == resumed.test_acc
    assert base.ledger.bits == resumed.ledger.bits


def test_mixed_precision_resume_bit_parity(small_task, tmp_path):
    """Kill/resume under the full memory-lean configuration: bf16 compute +
    f32 master (dual-dtype run state — bf16 momentum leaves and f32 params in
    ONE checkpoint pytree) with the client-microbatched engine.  The resumed
    run must be bit-identical to an uninterrupted one: the checkpoint stores
    every leaf's exact bit pattern at its true dtype."""
    from repro.core.fed_chs import FedCHSConfig, run_fed_chs
    from repro.core.precision import Precision
    from repro.optim.local import MomentumSGD

    kw = dict(rounds=6, local_steps=4, local_epochs=2, eval_every=2,
              initial_cluster=0, precision=Precision(), client_microbatch=2,
              local_opt=MomentumSGD(), scan_rounds=False)
    base = run_fed_chs(small_task, FedCHSConfig(**kw))

    ck = os.path.join(tmp_path, "mp")
    run_fed_chs(small_task, FedCHSConfig(**{**kw, "rounds": 3}, checkpoint=ck))
    resumed = run_fed_chs(small_task,
                          FedCHSConfig(**kw, checkpoint=ck, resume=True))

    la, lb = jax.tree.leaves(base.final_params), jax.tree.leaves(resumed.final_params)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(la, lb))
    assert base.test_acc == resumed.test_acc
    assert base.ledger.bits == resumed.ledger.bits
