"""Sanity checks on the paper's convergence theory (Thm 4.1 / 4.3) using a
strongly-convex quadratic where every quantity is analytic.

Setup: f_n(w) = 0.5 ||w - c_n||^2 (L = mu = 1), cluster weights uniform.
F(w) = 0.5||w||^2 - <w, c_bar> + const, minimiser w* = c_bar.
"""
import numpy as np
import pytest

from repro.core.scheduler import FedCHSScheduler
from repro.core.topology import make_topology
from repro.optim.schedules import (
    nonconvex_schedule,
    paper_power_schedule,
    paper_sqrt_schedule,
    schedule_satisfies_theorem,
)


def _run_quadratic(centers_per_cluster, T, K, eta_fn, d=8, seed=0):
    """Simulate Fed-CHS on the quadratic with exact gradients."""
    M = len(centers_per_cluster)
    topo = make_topology("full", M)
    sched = FedCHSScheduler(topo, [len(c) for c in centers_per_cluster], initial=0)
    w = np.zeros(d)
    m = 0
    gaps = []
    w_star = np.mean([c for cl in centers_per_cluster for c in cl], axis=0)
    for t in range(T):
        centers = centers_per_cluster[m]
        for k in range(K):
            grad = np.mean([w - c for c in centers], axis=0)  # Eq.(5) aggregate
            w = w - eta_fn(k) * grad
        m = sched.advance()
        gaps.append(0.5 * np.linalg.norm(w - w_star) ** 2)
    return np.array(gaps)


def _clusters(M, n_per, hetero, d=8, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(M, d)) * hetero
    return [[base[m] + rng.normal(size=d) * 0.5 for _ in range(n_per)] for m in range(M)]


def test_linear_rate_in_T_strongly_convex():
    """Thm 4.1: optimality gap contracts geometrically in T (up to the
    heterogeneity floor)."""
    clusters = _clusters(4, 5, hetero=0.3)
    K = 20
    gaps = _run_quadratic(clusters, T=60, K=K, eta_fn=paper_sqrt_schedule(K, L=1.0))
    # geometric decrease in the early phase, then bounded by the mu*Delta_max
    # heterogeneity floor (Remark 4.2) — not divergence
    assert gaps[5] < 0.5 * gaps[0]
    assert gaps[10] < 0.25 * gaps[0]
    assert gaps[-1] < 0.05


def test_zero_gap_when_clusters_iid():
    """Remark 4.2: identical cluster distributions => Delta_m == 0 => the gap
    floor vanishes."""
    d = 8
    rng = np.random.default_rng(1)
    shared = [rng.normal(size=d) for _ in range(6)]
    clusters_iid = [list(shared) for _ in range(4)]  # same data in every cluster
    K = 20
    gaps = _run_quadratic(clusters_iid, T=80, K=K, eta_fn=paper_sqrt_schedule(K, L=1.0))
    assert gaps[-1] < 1e-8, gaps[-1]


def test_heterogeneity_raises_the_floor():
    K = 10
    g_small = _run_quadratic(_clusters(4, 5, hetero=0.1), 80, K, paper_sqrt_schedule(K))
    g_large = _run_quadratic(_clusters(4, 5, hetero=2.0), 80, K, paper_sqrt_schedule(K))
    assert np.mean(g_large[-20:]) > np.mean(g_small[-20:])


def test_power_schedule_converges_faster_in_K():
    """Remark 4.2 second bullet: eta_k = 1/(2LK^q), q>=2 shrinks the K-dependent
    residual terms faster. Proxy: the within-round drift is smaller."""
    clusters = _clusters(4, 5, hetero=1.0)
    gaps_q2 = _run_quadratic(clusters, 40, 20, paper_power_schedule(20, q=2.0))
    # with q=2, per-round steps are tiny -> near-zero drift; gap stays near init
    # while sqrt schedule moves it: we just verify stability (no divergence)
    assert np.all(np.isfinite(gaps_q2))
    assert gaps_q2[-1] <= gaps_q2[0] * 1.01


def test_schedule_premises():
    for K in (5, 20, 100):
        assert schedule_satisfies_theorem(K, paper_sqrt_schedule(K), 1.0, strongly_convex=True)
        assert schedule_satisfies_theorem(K, paper_power_schedule(K, 2.0), 1.0,
                                          strongly_convex=True)
    with pytest.raises(AssertionError):
        nonconvex_schedule(100, q1=0.5, q2=1.8)  # violates 1+q1>q2


def test_nonconvex_schedule_valid_region():
    s = nonconvex_schedule(400, q1=0.5, q2=0.5, L=1.0)
    assert s(0) == pytest.approx(1.0 / 20.0)
