"""Integration tests for the Fed-CHS protocol (Algorithm 1)."""
import numpy as np

from repro.core import FedCHSConfig, run_fed_chs
from repro.core.ledger import dense_message_bits, qsgd_message_bits
from repro.optim.schedules import paper_sqrt_schedule, schedule_satisfies_theorem


def test_fed_chs_learns(small_task):
    res = run_fed_chs(small_task, FedCHSConfig(rounds=25, local_steps=10, eval_every=8, seed=0))
    assert res.test_acc[0] < 0.5
    assert res.final_acc() > 0.9, res.test_acc
    assert not np.isnan(res.train_loss).any()


def test_communication_accounting_matches_paper_formula(small_task):
    """§3.2: <= T*K*Q*N_max uplink bits, exactly T*Q bits ES->ES."""
    T, K = 12, 8
    res = run_fed_chs(small_task, FedCHSConfig(rounds=T, local_steps=K, eval_every=100))
    d = small_task.num_params()
    Q = dense_message_bits(d)
    n_max = max(len(m) for m in small_task.cluster_members)
    assert res.ledger.bits["es_to_es"] == T * Q
    assert res.ledger.bits["client_to_es"] <= T * K * Q * n_max
    assert res.ledger.bits["es_to_ps"] == 0  # no PS anywhere
    assert res.ledger.bits["client_to_ps"] == 0


def test_qsgd_compression_reduces_bits_and_still_learns(small_task):
    """12 rounds x 6 steps was too little SGD for the old 0.6 bar (measured
    0.48); at 20 rounds x 10 steps QSGD s=16 reaches 0.997, so 0.9 guards
    the full claim with margin instead of xfailing an under-trained run."""
    T, K = 20, 10
    dense = run_fed_chs(small_task, FedCHSConfig(rounds=T, local_steps=K, eval_every=100))
    comp = run_fed_chs(
        small_task,
        FedCHSConfig(rounds=T, local_steps=K, qsgd_levels=16, eval_every=T - 1),
    )
    assert comp.ledger.bits["client_to_es"] < 0.25 * dense.ledger.bits["client_to_es"]
    assert comp.final_acc() > 0.9


def test_local_epochs_reduce_interactions(small_task):
    """Fig. 2: E=5 means K/E interactions instead of K."""
    r1 = run_fed_chs(small_task, FedCHSConfig(rounds=5, local_steps=10, local_epochs=1,
                                              eval_every=100))
    r5 = run_fed_chs(small_task, FedCHSConfig(rounds=5, local_steps=10, local_epochs=5,
                                              eval_every=100))
    assert r5.ledger.messages["client_to_es"] * 5 == r1.ledger.messages["client_to_es"]


def test_deterministic_given_seed(small_task):
    cfg = FedCHSConfig(rounds=6, local_steps=5, eval_every=5, seed=3)
    a = run_fed_chs(small_task, cfg)
    b = run_fed_chs(small_task, cfg)
    assert a.test_acc == b.test_acc


def test_theorem_step_size_premises():
    K = 20
    assert schedule_satisfies_theorem(K, paper_sqrt_schedule(K, L=1.0), 1.0,
                                      strongly_convex=True)
    assert schedule_satisfies_theorem(K, paper_sqrt_schedule(K, L=2.0), 2.0,
                                      strongly_convex=True)


def test_qsgd_message_bits_formula():
    d = 100_000
    assert qsgd_message_bits(d, levels=1) < qsgd_message_bits(d, levels=127)
    assert qsgd_message_bits(d, levels=15) < dense_message_bits(d) / 5
