"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch is instantiated as its REDUCED variant (<=2 layers /
one pattern period, d_model<=512, <=4 experts) and runs a real forward +
train step + decode step on CPU, asserting shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.data.tokens import synthetic_lm_batch
from repro.models import transformer as tf

B, T = 2, 16


def _batch(cfg, seed=0):
    batch = synthetic_lm_batch(cfg.vocab_size, B, T, seed=seed)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    if cfg.is_encoder_decoder:
        batch["frames"] = (
            jax.random.normal(jax.random.PRNGKey(1), (B, cfg.num_audio_frames, cfg.d_model))
            * 0.1
        )
    if cfg.num_patches:
        batch["patches"] = (
            jax.random.normal(jax.random.PRNGKey(2), (B, cfg.num_patches, 1024)) * 0.05
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    table = {
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "mamba2-370m": (48, 1024, None, None, 0, 50280),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-v3-671b": (61, 7168, 128, None, 2048, 129280),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
    }
    L, d, h, kv, ff, v = table[arch]
    assert cfg.num_layers == L and cfg.d_model == d and cfg.d_ff == ff
    assert cfg.vocab_size == v
    if h is not None:
        assert cfg.num_heads == h
    if kv is not None:
        assert cfg.num_kv_heads == kv


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_bounds(arch):
    cfg = smoke_config(arch)
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = tf.forward(cfg, params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    step = jax.jit(tf.make_train_step(cfg, remat=True))
    new_params, loss = step(params, batch, 1e-2)
    assert float(loss) > 0 and not jnp.isnan(loss)
    # at least one parameter moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_reduces_loss(arch):
    cfg = smoke_config(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    step = jax.jit(tf.make_train_step(cfg, remat=False))
    lr = 5e-2 if cfg.family not in ("moe",) else 2e-2
    losses = []
    for _ in range(8):
        params, loss = step(params, batch, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    enc_len = cfg.num_audio_frames if cfg.is_encoder_decoder else 0
    caches = tf.init_caches(cfg, B, capacity=8, enc_len=enc_len)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_caches = jax.jit(
        lambda p, c, t: tf.decode_step(cfg, p, c, t)
    )(params, caches, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


def test_param_count_close_to_model_scale():
    """Analytic param counts should be in the ballpark of the models' names."""
    expect = {
        "qwen1.5-32b": 32e9,
        "dbrx-132b": 132e9,
        "mamba2-370m": 370e6,
        "qwen3-0.6b": 0.6e9,
        "phi-3-vision-4.2b": 3.8e9,   # LM backbone only (vision tower stubbed)
        "starcoder2-3b": 3e9,
        "recurrentgemma-9b": 9e9,
        "deepseek-v3-671b": 671e9,
        "mistral-nemo-12b": 12e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 1.7 * n, f"{arch}: {got:.3e} vs {n:.3e}"


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert active < 0.15 * cfg.param_count()  # ~37B of 671B
