"""shard_map MoE interior (models/moe_shardmap.py) vs the GSPMD oracle.

* mesh (1,1): bit-close to global expert choice (the paper-faithful path).
* mesh (2,2) [subprocess, 4 host devices]: equals group-limited expert
  choice with one batch-row group per data shard.
* gradients flow through the manual-collective interior.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.models import ffn as F
from repro.models.moe_shardmap import moe_routed_shardmap, shardmap_supported

B, T = 2, 8


def _setup(seed=0):
    cfg = smoke_config("dbrx-132b")  # 4 experts top-2, no shared experts
    p = F.init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, cfg.d_model)) * 0.3
    return cfg, p, x


def test_equals_global_expert_choice_on_1x1():
    cfg, p, x = _setup()
    mesh = make_debug_mesh(1, 1)
    assert shardmap_supported(cfg, mesh, B)
    y_ref, aux_ref = F.moe_forward(cfg, p, x, method="expert_choice")
    y_sm, aux_sm = moe_routed_shardmap(cfg, p, x, mesh)
    aux_sm = aux_sm * cfg.router_aux_coef
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(float(aux_sm), float(aux_ref), rtol=1e-5)


def test_dispatch_via_moe_forward_flag():
    """cfg.moe_shardmap + ambient mesh routes through the interior."""
    import dataclasses

    from repro.sharding.ctx import model_mesh

    cfg, p, x = _setup()
    cfg2 = dataclasses.replace(cfg, moe_shardmap=True)
    mesh = make_debug_mesh(1, 1)
    y_ref, aux_ref = F.moe_forward(cfg, p, x, method="expert_choice")
    with model_mesh(mesh):
        y_sm, aux_sm = F.moe_forward(cfg2, p, x, method="expert_choice")
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(float(aux_sm), float(aux_ref), rtol=1e-5)
    # without an ambient mesh the flag is inert (falls back to GSPMD path)
    y_fb, _ = F.moe_forward(cfg2, p, x, method="expert_choice")
    np.testing.assert_allclose(np.asarray(y_fb), np.asarray(y_ref), atol=1e-5)


def test_gradients_flow():
    cfg, p, x = _setup()
    mesh = make_debug_mesh(1, 1)

    def loss(p, x):
        y, aux = moe_routed_shardmap(cfg, p, x, mesh)
        return jnp.mean(y * y) + aux

    val, grads = jax.value_and_grad(loss)(p, x)
    assert np.isfinite(float(val))
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves)
    # expert weights and router both receive signal
    assert float(jnp.max(jnp.abs(grads["w_out"]))) > 0
    assert float(jnp.max(jnp.abs(grads["router"]))) > 0


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import smoke_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models import ffn as F
    from repro.models.moe_shardmap import moe_routed_shardmap

    B, T = 2, 8
    cfg = smoke_config("dbrx-132b")
    p = F.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.3

    # oracle: group-limited expert choice, one group per batch row
    cfg_g = dataclasses.replace(cfg, moe_groups=2)
    y_ref, aux_ref = F.moe_forward(cfg_g, p, x, method="expert_choice")

    mesh = make_debug_mesh(2, 2)  # 2 data shards (1 row each) x 2 expert shards
    y_sm, aux_sm = moe_routed_shardmap(cfg, p, x, mesh)
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(
        float(aux_sm * cfg.router_aux_coef), float(aux_ref), rtol=1e-5)
    print("OK")
    """
)


def test_matches_grouped_oracle_on_2x2_mesh():
    """4 host devices in a subprocess (device count locks at jax init)."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
