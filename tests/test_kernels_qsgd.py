"""Per-kernel validation: Pallas QSGD vs the pure-jnp oracle.

Sweeps shapes / dtypes / levels; checks bit-exact oracle agreement (the
stochastic rounding shares the same uniform draw), unbiasedness, and the
QSGD variance bound.  Off-TPU every `pl.pallas_call` here runs under
`interpret=True` (see `qsgd._interpret`), so CI exercises the actual kernel
bodies, not just the fallback.

The packed-wire tests pin integer bit-parity: codes and packed uint32
payloads must match the `ref.py` oracles exactly; dequantized *floats* are
compared at rtol=1e-6 (jit fusion of the norm/s divide moves the last ulp,
exactly as for the pre-existing dense-code kernels).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    qsgd_decode,
    qsgd_dequantize,
    qsgd_encode,
    qsgd_quantize,
    qsgd_roundtrip,
    signsgd_decode,
    signsgd_encode,
)
from repro.kernels.qsgd import (
    _pack_words,
    _unpack_words,
    qsgd_dequantize_blocks,
    qsgd_quantize_blocks,
    qsgd_quantize_pack_blocks,
    qsgd_unpack_dequantize_blocks,
)
from repro.kernels.ref import (
    pack_codes_ref,
    qsgd_code_bits,
    qsgd_dequantize_blocks_ref,
    qsgd_dequantize_codes_ref,
    qsgd_quantize_blocks_ref,
    qsgd_quantize_codes_ref,
    signsgd_dequantize_codes_ref,
    signsgd_quantize_codes_ref,
    unpack_codes_ref,
)

PACK_LEVELS = [1, 3, 7, 15, 127]  # 2, 3, 4, 5, 8-bit codes


@pytest.mark.parametrize("n_blocks", [8, 16, 64])
@pytest.mark.parametrize("block", [128, 256, 1024])
@pytest.mark.parametrize("s", [1, 4, 16, 127])
def test_kernel_matches_oracle(n_blocks, block, s):
    key = jax.random.PRNGKey(n_blocks * 1000 + block + s)
    v = jax.random.normal(key, (n_blocks, block), jnp.float32) * 3.0
    u = jax.random.uniform(jax.random.fold_in(key, 1), v.shape)
    qk, nk = qsgd_quantize_blocks(v, u, s=s)
    qr, nr = qsgd_quantize_blocks_ref(v, u, s)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(nk), np.asarray(nr), rtol=1e-6)
    dk = qsgd_dequantize_blocks(qk, nk, s=s)
    dr = qsgd_dequantize_blocks_ref(qr, nr, s)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("shape", [(100,), (33, 17), (5, 7, 11)])
def test_roundtrip_shapes_dtypes(dtype, shape):
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    out = qsgd_roundtrip(v.astype(jnp.float32), key, s=64)
    assert out.shape == shape
    assert not bool(jnp.isnan(out).any())


def test_zero_vector_is_fixed_point():
    v = jnp.zeros((4096,))
    out = qsgd_roundtrip(v, jax.random.PRNGKey(0), s=16)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_unbiasedness():
    """E[Q(v)] == v (QSGD's defining property).

    The sample mean of `reps` draws has expected deviation
    sqrt(E||Q(v) - v||^2 / reps); we bound the observed deviation against the
    *measured* per-rep variance (3x margin -> far outside noise if biased)
    rather than a magic constant, so the test is insensitive to s/reps.
    """
    key = jax.random.PRNGKey(42)
    v = np.asarray(jax.random.normal(key, (2048,), jnp.float32))
    reps = 300
    acc = np.zeros_like(v)
    sq_dev = 0.0
    for i in range(reps):
        out = np.asarray(qsgd_roundtrip(jnp.asarray(v), jax.random.PRNGKey(100 + i), s=8))
        acc += out
        sq_dev += float(np.sum((out - v) ** 2))
    mean = acc / reps
    err = np.linalg.norm(mean - v)
    # std of the mean's norm-deviation, from the measured per-rep second moment
    expected = np.sqrt(sq_dev / reps / reps)
    assert err < 3.0 * expected, (err, expected)
    # and the mean must be a strictly better estimate than any single draw
    assert err < np.sqrt(sq_dev / reps) * 0.2, (err, np.sqrt(sq_dev / reps))


def test_variance_bound():
    """E||Q(v) - v||^2 <= min(n/s^2, sqrt(n)/s) ||v||^2 per block."""
    key = jax.random.PRNGKey(7)
    block = 1024
    v = jax.random.normal(key, (8, block), jnp.float32)
    s = 16
    bound = min(block / s**2, np.sqrt(block) / s)
    errs = []
    for i in range(50):
        u = jax.random.uniform(jax.random.PRNGKey(i), v.shape)
        q, n = qsgd_quantize_blocks(v, u, s=s)
        back = qsgd_dequantize_blocks(q, n, s=s)
        errs.append(float(jnp.sum((back - v) ** 2) / jnp.sum(v * v)))
    assert np.mean(errs) <= bound * 1.1, (np.mean(errs), bound)


def test_quantize_padding_roundtrip():
    """Non-tile-multiple sizes are padded and exactly truncated back.

    QSGD per-coordinate error std is (||v_block|| / s) * sqrt(frac(1-frac));
    with frac ~ U[0,1) the expected squared relative error per block is
    ~ B / (6 s^2), so the expected rel error is sqrt(B/6)/s (~0.10 for
    B=1024, s=127). We assert within 1.5x of theory, not a magic constant.
    """
    v = jnp.arange(10_000, dtype=jnp.float32) / 100.0
    block = 1024
    q, norms, n = qsgd_quantize(v, jax.random.PRNGKey(0), s=127, block=block)
    assert n == 10_000
    back = qsgd_dequantize(q, norms, s=127, shape=(10_000,), block=block)
    assert back.shape == (10_000,)
    rel = float(jnp.linalg.norm(back - v) / jnp.linalg.norm(v))
    expected = np.sqrt(block / 6.0) / 127
    assert rel < 1.5 * expected, (rel, expected)


# -- packed wire format: fused quantize->pack / unpack->dequantize -----------


def _codes_and_blocks(key, n_blocks, block, s):
    v = jax.random.normal(key, (n_blocks, block), jnp.float32) * 2.0
    u = jax.random.uniform(jax.random.fold_in(key, 1), v.shape)
    codes, norms = qsgd_quantize_codes_ref(v, u, s)
    return v, u, codes, norms


@pytest.mark.parametrize("s", PACK_LEVELS)
@pytest.mark.parametrize("n_blocks,block", [(3, 128), (8, 1024), (5, 1024)])
def test_pack_unpack_identity_on_codes(s, n_blocks, block):
    """pack o unpack == identity, and the vectorized packer used inside the
    Pallas kernels is word-for-word the naive bit-plane oracle."""
    key = jax.random.PRNGKey(s * 1000 + n_blocks)
    _, _, codes, _ = _codes_and_blocks(key, n_blocks, block, s)
    bits = qsgd_code_bits(s)
    ref_payload = pack_codes_ref(np.asarray(codes), bits)
    vec_payload = np.asarray(_pack_words(codes, bits))
    np.testing.assert_array_equal(vec_payload, ref_payload)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes_ref(ref_payload, bits)), np.asarray(codes))
    np.testing.assert_array_equal(
        np.asarray(_unpack_words(jnp.asarray(ref_payload), bits)),
        np.asarray(codes))


@pytest.mark.parametrize("s", PACK_LEVELS)
def test_fused_kernels_match_oracles_bit_exactly(s):
    """The fused Pallas pair (interpret=True off-TPU) must agree with the
    ref.py oracles: payload and norms bit-exact, dequantized floats rtol."""
    key = jax.random.PRNGKey(17 + s)
    n_blocks, block = 5, 1024  # 5 rows: exercises the tail-tile pad path
    v, u, codes, norms_ref = _codes_and_blocks(key, n_blocks, block, s)
    bits = qsgd_code_bits(s)
    payload_k, norms_k = qsgd_quantize_pack_blocks(v, u, s=s)
    np.testing.assert_array_equal(
        np.asarray(payload_k), pack_codes_ref(np.asarray(codes), bits))
    np.testing.assert_allclose(np.asarray(norms_k), np.asarray(norms_ref),
                               rtol=1e-6)
    deq_k = qsgd_unpack_dequantize_blocks(payload_k, norms_k, s=s, block=block)
    deq_ref = qsgd_dequantize_codes_ref(codes, norms_ref, s)
    np.testing.assert_allclose(np.asarray(deq_k), np.asarray(deq_ref),
                               rtol=1e-6)


@pytest.mark.parametrize("s", [1, 7, 16])
def test_encode_decode_tail_and_shape(s):
    """Non-multiple-of-block leaves round-trip through the wire dict with the
    tail zero-padded (decode slices it back off) and exact payload shape."""
    shape = (33, 17)  # 561 params -> one 1024-block with a 463-entry tail
    key = jax.random.PRNGKey(3)
    v = jax.random.normal(key, shape, jnp.float32)
    wire = qsgd_encode(v, jax.random.fold_in(key, 1), s=s)
    bits = qsgd_code_bits(s)
    assert wire["payload"].dtype == jnp.uint32
    assert wire["payload"].shape == (1, bits * (1024 // 32))
    assert wire["norms"].shape == (1,)
    back = qsgd_decode(wire, s=s, shape=shape)
    assert back.shape == shape
    # tail codes come from zero padding -> code == s -> decode to exactly 0,
    # so the error obeys the QSGD bound on the real entries alone:
    # E||Q(v)-v||^2 <= min(B/s^2, sqrt(B)/s) ||v||^2  (B = 1024 here)
    bound = np.sqrt(min(1024 / s**2, np.sqrt(1024) / s))
    err = float(jnp.linalg.norm(back - v)) / float(jnp.linalg.norm(v))
    assert err <= 2.0 * bound, (err, bound)


def test_zero_norm_blocks_decode_to_exact_zero():
    v = jnp.zeros((4096,))
    wire = qsgd_encode(v, jax.random.PRNGKey(0), s=16)
    np.testing.assert_array_equal(np.asarray(wire["norms"]), 0.0)
    back = qsgd_decode(wire, s=16, shape=(4096,))
    np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_signsgd_wire_roundtrip():
    key = jax.random.PRNGKey(5)
    v = jax.random.normal(key, (2048,), jnp.float32)
    wire = signsgd_encode(v)
    codes_ref, scales_ref = signsgd_quantize_codes_ref(
        jnp.reshape(v, (2, 1024)))
    np.testing.assert_array_equal(
        np.asarray(wire["payload"]),
        pack_codes_ref(np.asarray(codes_ref), 1))
    np.testing.assert_allclose(np.asarray(wire["norms"]),
                               np.asarray(scales_ref), rtol=1e-6)
    back = signsgd_decode(wire, shape=(2048,))
    ref = signsgd_dequantize_codes_ref(codes_ref, scales_ref).reshape(2048)
    np.testing.assert_allclose(np.asarray(back), np.asarray(ref), rtol=1e-6)
    # every decoded entry is +/- its block scale; signs match the input's
    np.testing.assert_array_equal(np.sign(np.asarray(back)),
                                  np.sign(np.asarray(v)))


def test_signsgd_zero_block_decodes_to_zero():
    wire = signsgd_encode(jnp.zeros((1024,)))
    np.testing.assert_array_equal(np.asarray(wire["norms"]), 0.0)
    np.testing.assert_array_equal(
        np.asarray(signsgd_decode(wire, shape=(1024,))), 0.0)


def test_code_bits_formula_matches_comm_bits():
    """comm.bits duplicates qsgd_code_bits to stay jax-free; pin them."""
    from repro.comm.bits import qsgd_code_bits as comm_code_bits
    for s in range(1, 260):
        assert comm_code_bits(s) == qsgd_code_bits(s), s


def test_pack_unpack_identity_property():
    """Hypothesis property: pack o unpack == identity on arbitrary code
    tensors (any values representable in `bits`, not just QSGD outputs)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(1, 8), st.integers(1, 4), st.integers(0, 2**32 - 1))
    @hyp.settings(deadline=None, max_examples=25)
    def check(bits, n_blocks, seed):
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, 2**bits, size=(n_blocks, 1024),
                             dtype=np.uint32)
        payload = pack_codes_ref(codes, bits)
        assert payload.shape == (n_blocks, bits * 32)
        np.testing.assert_array_equal(unpack_codes_ref(payload, bits), codes)
        np.testing.assert_array_equal(
            np.asarray(_unpack_words(_pack_words(jnp.asarray(codes), bits),
                                     bits)), codes)

    check()
