"""Per-kernel validation: Pallas QSGD vs the pure-jnp oracle.

Sweeps shapes / dtypes / levels; checks bit-exact oracle agreement (the
stochastic rounding shares the same uniform draw), unbiasedness, and the
QSGD variance bound.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import qsgd_dequantize, qsgd_quantize, qsgd_roundtrip
from repro.kernels.qsgd import qsgd_dequantize_blocks, qsgd_quantize_blocks
from repro.kernels.ref import qsgd_dequantize_blocks_ref, qsgd_quantize_blocks_ref


@pytest.mark.parametrize("n_blocks", [8, 16, 64])
@pytest.mark.parametrize("block", [128, 256, 1024])
@pytest.mark.parametrize("s", [1, 4, 16, 127])
def test_kernel_matches_oracle(n_blocks, block, s):
    key = jax.random.PRNGKey(n_blocks * 1000 + block + s)
    v = jax.random.normal(key, (n_blocks, block), jnp.float32) * 3.0
    u = jax.random.uniform(jax.random.fold_in(key, 1), v.shape)
    qk, nk = qsgd_quantize_blocks(v, u, s=s)
    qr, nr = qsgd_quantize_blocks_ref(v, u, s)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(nk), np.asarray(nr), rtol=1e-6)
    dk = qsgd_dequantize_blocks(qk, nk, s=s)
    dr = qsgd_dequantize_blocks_ref(qr, nr, s)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("shape", [(100,), (33, 17), (5, 7, 11)])
def test_roundtrip_shapes_dtypes(dtype, shape):
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    out = qsgd_roundtrip(v.astype(jnp.float32), key, s=64)
    assert out.shape == shape
    assert not bool(jnp.isnan(out).any())


def test_zero_vector_is_fixed_point():
    v = jnp.zeros((4096,))
    out = qsgd_roundtrip(v, jax.random.PRNGKey(0), s=16)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_unbiasedness():
    """E[Q(v)] == v (QSGD's defining property).

    The sample mean of `reps` draws has expected deviation
    sqrt(E||Q(v) - v||^2 / reps); we bound the observed deviation against the
    *measured* per-rep variance (3x margin -> far outside noise if biased)
    rather than a magic constant, so the test is insensitive to s/reps.
    """
    key = jax.random.PRNGKey(42)
    v = np.asarray(jax.random.normal(key, (2048,), jnp.float32))
    reps = 300
    acc = np.zeros_like(v)
    sq_dev = 0.0
    for i in range(reps):
        out = np.asarray(qsgd_roundtrip(jnp.asarray(v), jax.random.PRNGKey(100 + i), s=8))
        acc += out
        sq_dev += float(np.sum((out - v) ** 2))
    mean = acc / reps
    err = np.linalg.norm(mean - v)
    # std of the mean's norm-deviation, from the measured per-rep second moment
    expected = np.sqrt(sq_dev / reps / reps)
    assert err < 3.0 * expected, (err, expected)
    # and the mean must be a strictly better estimate than any single draw
    assert err < np.sqrt(sq_dev / reps) * 0.2, (err, np.sqrt(sq_dev / reps))


def test_variance_bound():
    """E||Q(v) - v||^2 <= min(n/s^2, sqrt(n)/s) ||v||^2 per block."""
    key = jax.random.PRNGKey(7)
    block = 1024
    v = jax.random.normal(key, (8, block), jnp.float32)
    s = 16
    bound = min(block / s**2, np.sqrt(block) / s)
    errs = []
    for i in range(50):
        u = jax.random.uniform(jax.random.PRNGKey(i), v.shape)
        q, n = qsgd_quantize_blocks(v, u, s=s)
        back = qsgd_dequantize_blocks(q, n, s=s)
        errs.append(float(jnp.sum((back - v) ** 2) / jnp.sum(v * v)))
    assert np.mean(errs) <= bound * 1.1, (np.mean(errs), bound)


def test_quantize_padding_roundtrip():
    """Non-tile-multiple sizes are padded and exactly truncated back.

    QSGD per-coordinate error std is (||v_block|| / s) * sqrt(frac(1-frac));
    with frac ~ U[0,1) the expected squared relative error per block is
    ~ B / (6 s^2), so the expected rel error is sqrt(B/6)/s (~0.10 for
    B=1024, s=127). We assert within 1.5x of theory, not a magic constant.
    """
    v = jnp.arange(10_000, dtype=jnp.float32) / 100.0
    block = 1024
    q, norms, n = qsgd_quantize(v, jax.random.PRNGKey(0), s=127, block=block)
    assert n == 10_000
    back = qsgd_dequantize(q, norms, s=127, shape=(10_000,), block=block)
    assert back.shape == (10_000,)
    rel = float(jnp.linalg.norm(back - v) / jnp.linalg.norm(v))
    expected = np.sqrt(block / 6.0) / 127
    assert rel < 1.5 * expected, (rel, expected)
