"""Unit tests: ES topologies + the paper's 2-step next-cluster rule."""
import pytest

from repro.core.scheduler import FedCHSScheduler, RandomWalkScheduler, RingScheduler
from repro.core.topology import make_topology, random_sparse


@pytest.mark.parametrize("kind", ["ring", "line", "star", "full", "random_sparse"])
@pytest.mark.parametrize("n", [2, 3, 10, 17])
def test_topologies_valid_and_connected(kind, n):
    topo = make_topology(kind, n)
    topo.validate()
    assert topo.is_connected()


@pytest.mark.parametrize("seed", range(5))
def test_random_sparse_degree_cap(seed):
    topo = random_sparse(12, max_degree=3, seed=seed)
    assert max(topo.degree(m) for m in range(12)) <= 3
    assert topo.is_connected()


def test_two_step_rule_least_traversed():
    """Step 1: the scheduler must always pick among least-visited neighbors."""
    topo = make_topology("full", 5)
    sched = FedCHSScheduler(topo, [10, 20, 30, 40, 50], initial=0)
    for _ in range(25):
        cur = sched.state.current
        counts = sched.state.visit_counts.copy()  # pre-advance snapshot
        nxt = sched.advance()
        nbrs = topo.neighbors(cur)
        assert counts[nxt] == min(counts[m] for m in nbrs)


def test_two_step_rule_tie_break_by_dataset_size():
    """Step 2: ties broken by largest cluster dataset."""
    topo = make_topology("full", 4)
    sizes = [10, 99, 50, 70]
    sched = FedCHSScheduler(topo, sizes, initial=0)
    # all neighbors (1,2,3) have count 0 -> pick the largest dataset: node 1
    assert sched.peek() == 1


def test_scheduler_covers_all_clusters():
    """The visit-count rule drives the walk to cover every ES (paper's goal:
    'cover a broader range of dataset')."""
    for seed in range(4):
        topo = make_topology("random_sparse", 10, seed=seed)
        sched = FedCHSScheduler(topo, list(range(1, 11)), initial=0)
        order = sched.schedule(60)
        assert set(order) == set(range(10)), f"seed {seed}: {sorted(set(order))}"


def test_schedule_replay_is_pure():
    topo = make_topology("ring", 6)
    sched = FedCHSScheduler(topo, [1] * 6, initial=2)
    a = sched.schedule(20)
    b = sched.schedule(20)
    assert a == b


def test_ring_scheduler_fixed_order():
    s = RingScheduler(4, initial=0)
    assert [s.advance() for _ in range(6)] == [1, 2, 3, 0, 1, 2]


def test_random_walk_stays_on_graph():
    topo = make_topology("random_sparse", 8, seed=1)
    s = RandomWalkScheduler(topo, initial=0, seed=0)
    prev = 0
    for _ in range(50):
        nxt = s.advance()
        assert nxt in topo.neighbors(prev)
        prev = nxt
