"""Hypothesis property tests on the MoE dispatch invariants (GSPMD and
shard_map interiors share these)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.models import ffn as F
from repro.models.moe_shardmap import moe_routed_shardmap


def _cfg_params_x(seed, B, T):
    cfg = smoke_config("dbrx-132b")  # E=4, k=2
    p = F.init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, cfg.d_model)) * 0.5
    return cfg, p, x


@given(seed=st.integers(0, 50), B=st.integers(1, 3), T=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_moe_output_finite_and_shaped(seed, B, T):
    cfg, p, x = _cfg_params_x(seed, B, T)
    for method in ("expert_choice", "dense_topk"):
        y, aux = F.moe_forward(cfg, p, x, method=method)
        assert y.shape == x.shape
        assert np.all(np.isfinite(np.asarray(y)))
        assert float(aux) >= 0  # Switch load-balance loss is a sum of squares


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_moe_zero_input_fixed_point(seed):
    """Zero tokens -> zero routed output (router is linear, no biases in
    expert MLPs), for every dispatch method."""
    cfg, p, _ = _cfg_params_x(seed, 2, 8)
    x = jnp.zeros((2, 8, cfg.d_model))
    for method in ("expert_choice", "dense_topk"):
        y, _ = F.moe_forward(cfg, p, x, method=method)
        np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)
    y, _ = moe_routed_shardmap(cfg, p, x, make_debug_mesh(1, 1))
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


@given(seed=st.integers(0, 30), G=st.sampled_from([1, 2, 4]))
@settings(max_examples=8, deadline=None)
def test_group_limited_equals_global_on_uniform_groups(seed, G):
    """Group-limited routing with G groups == global routing applied to each
    group independently (the decomposition the data-sharding relies on)."""
    cfg, p, x = _cfg_params_x(seed, G, 8)
    cfg_g = dataclasses.replace(cfg, moe_groups=G if G > 1 else 1)
    y_g, _ = F.moe_forward(cfg_g, p, x, method="expert_choice")
    # reference: run each batch row (=group) through global expert choice
    rows = [F.moe_forward(cfg, p, x[i:i + 1], method="expert_choice")[0]
            for i in range(G)]
    y_ref = jnp.concatenate(rows, axis=0)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_ref), atol=1e-5)


@given(seed=st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_shardmap_gate_mass_normalisation(seed):
    """The combine divides by the summed gate mass: scaling the router
    weights (hence all gates, pre-normalisation) must not blow up outputs."""
    cfg, p, x = _cfg_params_x(seed, 2, 8)
    mesh = make_debug_mesh(1, 1)
    y1, _ = moe_routed_shardmap(cfg, p, x, mesh)
    assert np.all(np.isfinite(np.asarray(y1)))
    # outputs are convex-ish combinations of expert outputs; bound vs inputs
    assert float(jnp.max(jnp.abs(y1))) < 1e3
