"""Serving correctness: teacher-forced decode must reproduce the training
forward's logits (cache paths == full paths), per architecture family.

MoE archs use the exact dense_topk routing in both paths (expert-choice
routing is batch-context dependent by construction, so only dense_topk admits
a step-wise parity check). VLM parity runs without the patch prefix (the
prefix is prefill state, exercised in test_models_smoke + dry-run).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.data.tokens import synthetic_lm_batch
from repro.models import transformer as tf

PARITY_ARCHS = [
    "qwen1.5-32b",        # dense MHA + qkv bias
    "qwen3-0.6b",         # GQA + qk_norm
    "starcoder2-3b",      # GQA kv=2, gelu
    "mistral-nemo-12b",   # GQA
    "mamba2-370m",        # SSD state decode
    "recurrentgemma-9b",  # RG-LRU + local attention ring buffer
    "whisper-tiny",       # enc-dec with cross-attention caches
    "dbrx-132b",          # MoE (dense_topk routing)
    "deepseek-v3-671b",   # MLA absorbed decode + MoE
]


def _teacher_forced_decode(cfg, params, batch, moe_method):
    B, T = batch["tokens"].shape
    enc_len = cfg.num_audio_frames if cfg.is_encoder_decoder else 0
    caches = tf.init_caches(cfg, B, capacity=T, enc_len=enc_len)
    if cfg.is_encoder_decoder:
        caches = tf._fill_cross_caches(cfg, params, batch, caches)
    outs = []
    for t in range(T):
        logits, caches = tf.decode_step(
            cfg, params, caches, batch["tokens"][:, t : t + 1], moe_method=moe_method
        )
        outs.append(logits)
    return jnp.stack(outs, axis=1)  # (B, T, V)


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    moe_method = "dense_topk" if cfg.is_moe else "expert_choice"
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 12
    batch = synthetic_lm_batch(cfg.vocab_size, B, T, seed=0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    if cfg.is_encoder_decoder:
        batch["frames"] = (
            jax.random.normal(jax.random.PRNGKey(1), (B, cfg.num_audio_frames, cfg.d_model))
            * 0.1
        )
    fwd, _ = tf.forward(cfg, params, batch, moe_method=moe_method)
    dec = _teacher_forced_decode(cfg, params, batch, moe_method)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(fwd, np.float32), atol=2e-3, rtol=2e-3
    )


def test_sliding_window_ring_buffer_parity():
    """mistral long-context variant: ring-buffer decode == windowed forward."""
    import dataclasses

    cfg = dataclasses.replace(
        smoke_config("mistral-nemo-12b"), block_pattern=("local",), sliding_window=6
    )
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 20
    batch = synthetic_lm_batch(cfg.vocab_size, B, T, seed=3)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    fwd, _ = tf.forward(cfg, params, batch)
    # ring buffer capacity == window
    caches = tf.init_caches(cfg, B, capacity=cfg.sliding_window)
    outs = []
    for t in range(T):
        logits, caches = tf.decode_step(cfg, params, caches, batch["tokens"][:, t : t + 1])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(fwd, np.float32), atol=2e-3, rtol=2e-3
    )
