"""Async federation drivers (repro.async_fl).

The load-bearing cells:
  * sync anchor — full-quorum AlwaysOn async Fed-CHS is BIT-identical to
    the synchronous `run_fed_chs(local_epochs=K)` (the async event loop
    degenerates to barrier rounds when every update arrives on time);
  * in-process kill-and-resume — under churn + stragglers + partial
    quorum, params/metrics/ledger/staleness of a checkpointed-and-resumed
    run equal an uninterrupted one bit-for-bit;
  * the buffer/arrival units that make the event loop deterministic.
"""
import os

import jax
import numpy as np
import pytest

from repro.async_fl import (
    AsyncFedCHSConfig,
    AsyncPSConfig,
    Dispatch,
    StalenessBuffer,
    Update,
    fire_time,
    run_async_fed_chs,
    run_async_fedavg,
    run_async_hier,
    staleness_weight,
)
from repro.core.fed_chs import FedCHSConfig, run_fed_chs
from repro.netsim.links import edge_cloud_network
from repro.part import BernoulliTrace


def _params_equal(a, b) -> float:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return max(
        float(np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64)).max())
        for x, y in zip(la, lb)
    )


# --------------------------------------------------------------------------
# units: buffer + staleness discount + fire time
# --------------------------------------------------------------------------


def test_staleness_weight():
    assert staleness_weight(0.5, 0, 0.7) == 0.5  # tau=0: discount is exactly 1
    assert staleness_weight(1.0, 3, 0.5) == pytest.approx(0.5)
    assert staleness_weight(1.0, 1, 0.0) == 1.0  # alpha=0: undiscounted FedBuff


def _u(client, version, arrival):
    return Update(client=client, cluster=0, version=version, arrival=arrival,
                  gamma=1.0, delta=None)


def test_buffer_take_is_totally_ordered():
    buf = StalenessBuffer(max_staleness=None)
    for u in [_u(3, 2, 5.0), _u(1, 1, 9.0), _u(2, 1, 9.0), _u(0, 1, 2.0)]:
        buf.add(u)
    out = buf.take()
    assert [(u.version, u.arrival, u.client) for u in out] == [
        (1, 2.0, 0), (1, 9.0, 1), (1, 9.0, 2), (2, 5.0, 3)]
    assert len(buf) == 0


def test_buffer_eviction_bound():
    buf = StalenessBuffer(max_staleness=2)
    buf.add(_u(0, 0, 1.0))
    buf.add(_u(1, 3, 1.0))
    evicted = buf.evict_stale(current_version=4)  # tau=4 > 2 for version 0
    assert [u.client for u in evicted] == [0]
    assert buf.dropped == 1 and [u.client for u in buf.updates] == [1]


def test_take_arrived_splits_on_time():
    buf = StalenessBuffer()
    buf.add(_u(0, 0, 1.0))
    buf.add(_u(1, 0, 5.0))
    ready = buf.take_arrived(now=2.0)
    assert [u.client for u in ready] == [0]
    assert [u.client for u in buf.updates] == [1]


def _d(client, arrival):
    return Dispatch(client=client, cluster=0, version=0, start=0.0,
                    arrival=arrival)


def test_fire_time_quorum_and_deadline():
    ds = [_d(0, 1.0), _d(1, 2.0), _d(2, 10.0)]
    assert fire_time(ds, quorum_frac=1.0, deadline_s=None, start=0.0) == 10.0
    # ceil(3 * 0.5) = 2nd arrival
    assert fire_time(ds, quorum_frac=0.5, deadline_s=None, start=0.0) == 2.0
    # deadline caps the wait for the straggler
    assert fire_time(ds, quorum_frac=1.0, deadline_s=4.0, start=0.0) == 4.0
    # empty cohort: pass-through fires at the deadline (or immediately)
    assert fire_time([], quorum_frac=1.0, deadline_s=3.0, start=7.0) == 10.0
    assert fire_time([], quorum_frac=1.0, deadline_s=None, start=7.0) == 7.0


# --------------------------------------------------------------------------
# the sync anchor: async degenerates to the synchronous chain
# --------------------------------------------------------------------------


def test_async_fed_chs_matches_sync_at_full_quorum(small_task):
    """AlwaysOn + quorum 1.0 + no deadline: every activation folds its full
    cohort at staleness 0, so the fold arithmetic must reproduce the
    synchronous driver's J=1 delta round BIT-exactly."""
    R, K = 8, 4
    ra = run_async_fed_chs(small_task, AsyncFedCHSConfig(
        rounds=R, local_steps=K, eval_every=2, initial_cluster=0,
        quorum_frac=1.0, deadline_s=None, renormalize=False))
    rs = run_fed_chs(small_task, FedCHSConfig(
        rounds=R, local_steps=K, local_epochs=K, eval_every=2,
        initial_cluster=0))
    assert _params_equal(ra.final_params, rs.final_params) == 0.0
    assert ra.test_acc == rs.test_acc
    # simulated time exists and advances (the sync run has no sim_times)
    assert ra.sim_times is not None and len(ra.sim_times) == len(ra.test_acc)
    assert all(b > a for a, b in zip(ra.sim_times, ra.sim_times[1:]))
    assert rs.sim_times is None


def _churn_config(**over):
    kw = dict(
        rounds=10, local_steps=4, eval_every=2, initial_cluster=0,
        quorum_frac=0.6, deadline_s=2.0, staleness_alpha=0.5, max_staleness=3,
        trace=BernoulliTrace(p=0.7, seed=3),
        network=edge_cloud_network(straggler_frac=0.25, straggler_slowdown=6.0,
                                   heterogeneity=0.5, seed=1),
    )
    kw.update(over)
    return AsyncFedCHSConfig(**kw)


def test_async_fed_chs_deterministic(small_task):
    r1 = run_async_fed_chs(small_task, _churn_config())
    r2 = run_async_fed_chs(small_task, _churn_config())
    assert _params_equal(r1.final_params, r2.final_params) == 0.0
    assert r1.test_acc == r2.test_acc and r1.sim_times == r2.sim_times
    assert r1.ledger.bits == r2.ledger.bits


def test_async_fed_chs_staleness_is_recorded(small_task):
    res = run_async_fed_chs(small_task, _churn_config())
    hist = res.ledger.staleness_histogram()
    assert hist and 0 in hist  # on-time folds dominate
    assert sum(hist.values()) > 0
    # under partial quorum + churn some updates fold (or evict) late
    assert any(tau > 0 for tau in hist)


def test_async_kill_and_resume_in_process(small_task, tmp_path):
    """The continuous checkpoint carries EVERYTHING: a run restarted from the
    mid-run checkpoint finishes bit-identical to one never interrupted —
    params, metrics, sim clock, comm bits, and the staleness histogram."""
    full = run_async_fed_chs(small_task, _churn_config())

    ck = os.path.join(tmp_path, "state")
    run_async_fed_chs(small_task, _churn_config(rounds=5, checkpoint=ck))
    resumed = run_async_fed_chs(
        small_task, _churn_config(checkpoint=ck, resume=True))

    assert _params_equal(full.final_params, resumed.final_params) == 0.0
    assert full.test_acc == resumed.test_acc
    assert full.sim_times == resumed.sim_times
    assert full.ledger.bits == resumed.ledger.bits
    assert (full.ledger.staleness_histogram()
            == resumed.ledger.staleness_histogram())


def test_async_checkpoint_hook_fires(small_task, tmp_path):
    seen = []
    cfg = _churn_config(rounds=4, checkpoint=os.path.join(tmp_path, "s"),
                        checkpoint_every=2, on_checkpoint=seen.append)
    run_async_fed_chs(small_task, cfg)
    assert seen == [2, 4]


# --------------------------------------------------------------------------
# async PS baselines
# --------------------------------------------------------------------------


def _ps_config(**over):
    kw = dict(rounds=8, local_steps=4, quorum_k=4, eval_every=2,
              trace=BernoulliTrace(p=0.8, seed=3),
              network=edge_cloud_network(straggler_frac=0.25, seed=1))
    kw.update(over)
    return AsyncPSConfig(**kw)


@pytest.mark.parametrize("run", [run_async_fedavg, run_async_hier])
def test_async_ps_drivers_run_and_meter(small_task, run):
    res = run(small_task, _ps_config())
    assert len(res.test_acc) == len(res.sim_times)
    assert all(np.isfinite(a) for a in res.test_acc)
    assert all(b >= a for a, b in zip(res.sim_times, res.sim_times[1:]))
    hist = res.ledger.staleness_histogram()
    assert sum(hist.values()) > 0
    assert res.ledger.total_bits() > 0


@pytest.mark.parametrize("run", [run_async_fedavg, run_async_hier])
def test_async_ps_deterministic(small_task, run):
    r1 = run(small_task, _ps_config(rounds=5))
    r2 = run(small_task, _ps_config(rounds=5))
    assert _params_equal(r1.final_params, r2.final_params) == 0.0
    assert r1.sim_times == r2.sim_times


def test_sim_time_to_accuracy(small_task):
    res = run_async_fed_chs(small_task, AsyncFedCHSConfig(
        rounds=6, local_steps=4, eval_every=2, initial_cluster=0))
    gamma = res.test_acc[-1]
    t = res.sim_time_to_accuracy(gamma)
    assert t is not None and t in res.sim_times
    assert res.sim_time_to_accuracy(2.0) is None  # unreachable target
