"""Flash-attention Pallas kernel vs the pure-jnp oracles (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import blockwise_attention


@pytest.mark.parametrize("T,S", [(128, 128), (64, 256), (200, 200)])
@pytest.mark.parametrize("H,Hkv", [(4, 4), (8, 2)])
def test_flash_matches_blockwise(T, S, H, Hkv):
    key = jax.random.PRNGKey(T + S + H)
    B, hd = 2, 32
    q = jax.random.normal(key, (B, T, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64)
    ref = blockwise_attention(q, k, v, causal=True, kv_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    key = jax.random.PRNGKey(7)
    B, T, H, hd = 1, 192, 2, 32
    q = jax.random.normal(key, (B, T, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=64)
    ref = blockwise_attention(q, k, v, causal=True, window=window, kv_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    key = jax.random.PRNGKey(1)
    B, T, H, hd = 1, 64, 2, 64
    q = jax.random.normal(key, (B, T, H, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64)
    assert out.dtype == dtype and out.shape == q.shape
    ref = blockwise_attention(q, k, v, causal=True)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_nonaligned_shapes_padded():
    key = jax.random.PRNGKey(2)
    B, T, S, H, hd = 1, 50, 77, 2, 32  # neither T nor S aligned
    q = jax.random.normal(key, (B, T, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64)
    ref = blockwise_attention(q, k, v, causal=True, kv_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
