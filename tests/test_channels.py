"""Channel protocol: bit accounting against the ledger formulas + the
in-graph lossy transforms."""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import (
    Channel,
    DenseChannel,
    QSGDChannel,
    SignSGDChannel,
    TopKChannel,
    channel_wire_bits,
    low_bit_channel,
    make_channel,
)
from repro.core.ledger import dense_message_bits, qsgd_message_bits
from repro.kernels.ops import qsgd_compress_tree, topk_sparsify


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "w": jax.random.normal(k1, (37, 11), jnp.float32),
        "b": jax.random.normal(k2, (11,), jnp.float32),
    }


def test_channels_satisfy_protocol():
    for ch in (DenseChannel(), QSGDChannel(16), TopKChannel(0.1)):
        assert isinstance(ch, Channel)


def test_dense_bits_match_ledger_formula():
    for d in (1, 1000, 123_457):
        assert DenseChannel().message_bits(d) == dense_message_bits(d)
        assert DenseChannel(16).message_bits(d) == dense_message_bits(d, 16)


def test_qsgd_bits_match_ledger_formula():
    for d in (1, 1000, 123_457):
        for s in (4, 16, 127):
            assert QSGDChannel(s).message_bits(d) == qsgd_message_bits(d, s)


def test_topk_bits_scale_with_fraction():
    d = 100_000
    small = TopKChannel(0.01).message_bits(d)
    large = TopKChannel(0.1).message_bits(d)
    assert small < large < dense_message_bits(d)
    # k (value+index) pairs
    k = math.ceil(0.01 * d)
    assert small == k * (32 + math.ceil(math.log2(d)))


def test_dense_compress_is_identity():
    tree = _tree()
    out = DenseChannel().compress(tree, jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert bool(jnp.all(a == b))


def test_qsgd_compress_matches_kernel_wrapper():
    tree = _tree()
    key = jax.random.PRNGKey(7)
    out = QSGDChannel(16).compress(tree, key)
    ref = qsgd_compress_tree(tree, key, s=16)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert bool(jnp.all(a == b))


def test_topk_compress_keeps_largest_across_whole_message():
    tree = _tree()
    frac = 0.25
    out = TopKChannel(frac).compress(tree, jax.random.PRNGKey(0))
    flat = np.concatenate([np.asarray(l).ravel() for l in jax.tree.leaves(tree)])
    sflat = np.concatenate([np.asarray(l).ravel() for l in jax.tree.leaves(out)])
    # exactly k survivors over the WHOLE message — matching message_bits exactly
    k = max(1, math.ceil(frac * flat.size))
    nz = np.nonzero(sflat)[0]
    assert len(nz) == k
    top_idx = np.argsort(-np.abs(flat))[:k]
    assert set(nz) == set(top_idx)
    np.testing.assert_array_equal(sflat[nz], flat[nz])


def test_topk_is_per_sender_in_the_engine():
    """A sender with uniformly small deltas must still get its own top-k
    budget — Top-K over the stacked client axis would zero it out entirely."""
    from repro.core.engine import compress_uplinks

    big = np.arange(1.0, 9.0, dtype=np.float32).reshape(8)
    small = big / 1000.0
    deltas = {"w": jnp.stack([big, small])}  # client 0 dominates magnitudes
    out = compress_uplinks(TopKChannel(0.25), deltas, jax.random.PRNGKey(0))
    w = np.asarray(out["w"])
    assert np.count_nonzero(w[0]) == 2  # ceil(0.25 * 8) per sender
    assert np.count_nonzero(w[1]) == 2  # NOT starved by client 0


def test_topk_sparsify_k_larger_than_size():
    v = jnp.arange(5.0)
    out = topk_sparsify(v, k=100)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v))


def test_stochastic_flags():
    assert not DenseChannel().stochastic
    assert QSGDChannel(16).stochastic
    assert not TopKChannel(0.1).stochastic


def test_make_channel_back_compat():
    assert make_channel(None, 32) == DenseChannel(32)
    assert make_channel(16) == QSGDChannel(16)


def test_channels_are_hashable_cache_keys():
    assert hash(QSGDChannel(16)) == hash(QSGDChannel(16))
    assert QSGDChannel(16) != QSGDChannel(8)
    assert len({DenseChannel(), DenseChannel(), QSGDChannel(4)}) == 2


def test_split_chain_matches_eager_chain():
    from repro.core.engine import split_chain

    key = jax.random.PRNGKey(42)
    k_eager = key
    subs_eager = []
    for _ in range(5):
        k_eager, sub = jax.random.split(k_eager)
        subs_eager.append(sub)
    k_chain, subs = split_chain(key, 5)
    assert bool(jnp.all(k_chain == k_eager))
    assert bool(jnp.all(subs == jnp.stack(subs_eager)))


def test_low_bit_channel_table():
    """The wire width (code bits per entry) is exactly the advertised budget."""
    from repro.comm.bits import qsgd_code_bits

    for bits, ch in [(8, low_bit_channel(8)), (4, low_bit_channel(4)),
                     (2, low_bit_channel(2))]:
        assert isinstance(ch, QSGDChannel)
        assert qsgd_code_bits(ch.levels) == bits
    assert isinstance(low_bit_channel(1), SignSGDChannel)
    try:
        low_bit_channel(3)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass


def test_wire_bits_matches_actual_payload():
    """channel_wire_bits prices exactly what encode() emits (also pinned
    end-to-end in test_ledger.py against a real run's events)."""
    tree = _tree()
    sizes = tuple(leaf.size for leaf in jax.tree.leaves(tree))
    d = sum(sizes)
    for ch in (QSGDChannel(16), QSGDChannel(1), SignSGDChannel()):
        wires = ch.encode(tree, jax.random.PRNGKey(0))
        measured_bits = 8 * sum(
            w["payload"].size * 4 + w["norms"].size * 4 for w in wires)
        assert channel_wire_bits(ch, d, sizes) == measured_bits
    # f32 dense: wire_bits and the flat formula agree (no block padding)
    assert channel_wire_bits(DenseChannel(), d, sizes) == dense_message_bits(d)
    # a bf16 wire halves every dense message exactly
    bf = DenseChannel(wire_dtype="bfloat16")
    assert channel_wire_bits(bf, d, sizes) * 2 == dense_message_bits(d)
    wires = bf.encode(tree)
    assert 8 * sum(w["payload"].size * w["payload"].dtype.itemsize
                   for w in wires) == channel_wire_bits(bf, d, sizes)


def test_precision_dtype_table_sync():
    """Every dtype a Precision policy names must be priceable by the wire
    width table — the ledger can never meet a dtype it cannot price."""
    from repro.comm.bits import dtype_bits
    from repro.core.precision import _SUPPORTED

    assert {dt: dtype_bits(dt) for dt in _SUPPORTED} == {
        "float32": 32, "bfloat16": 16, "float16": 16, "float8_e4m3fn": 8}


def test_signsgd_channel_properties():
    ch = SignSGDChannel()
    assert isinstance(ch, Channel)
    assert not ch.stochastic  # deterministic: no rounding noise
    assert ch.per_message
    tree = _tree()
    out = ch.compress(tree, jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        a, b = np.asarray(a), np.asarray(b)
        # decode is +/- (per-block mean |.|): signs preserved everywhere
        np.testing.assert_array_equal(np.sign(a) != 0,
                                      np.abs(b) > 0)
        np.testing.assert_array_equal(np.sign(a), np.sign(b))


def test_qsgd_channel_compress_is_decode_of_encode():
    tree = _tree()
    key = jax.random.PRNGKey(11)
    ch = QSGDChannel(7)
    via_wire = ch.decode(ch.encode(tree, key), tree)
    direct = ch.compress(tree, key)
    for a, b in zip(jax.tree.leaves(via_wire), jax.tree.leaves(direct)):
        assert bool(jnp.all(a == b))


def test_topk_channel_drives_fed_chs_end_to_end(small_task):
    """Extensibility proof: a channel the original drivers never knew about
    plugs into the engine and both compresses and learns."""
    from repro.core import FedCHSConfig, run_fed_chs

    cfg = FedCHSConfig(rounds=10, local_steps=6, local_epochs=2, eval_every=9,
                       channel=TopKChannel(0.05), seed=0)
    res = run_fed_chs(small_task, cfg)
    dense_cfg = FedCHSConfig(rounds=10, local_steps=6, local_epochs=2, eval_every=9, seed=0)
    dense = run_fed_chs(small_task, dense_cfg)
    assert res.ledger.bits["client_to_es"] < 0.1 * dense.ledger.bits["client_to_es"]
    assert res.final_acc() > 0.5
    assert not np.isnan(res.train_loss).any()
