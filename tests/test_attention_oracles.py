"""Numeric oracles: blockwise attention, SSD chunking, RG-LRU scan, MoE paths.

Each optimised implementation is checked against a naive reference.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models.attention import blockwise_attention, decode_attention
from repro.models.ffn import init_moe, moe_forward
from repro.models.rglru import _lru_scan
from repro.models.ssd import ssd_chunked, ssd_decode_step


def naive_attention(q, k, v, *, causal=True, window=None):
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, T, Hkv, g, hd) / math.sqrt(hd)
    s = jnp.einsum("bthgd,bshd->bthgs", qf, k.astype(jnp.float32))
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bthgs,bshd->bthgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, v.shape[-1])


@pytest.mark.parametrize("T,kv_block", [(64, 16), (100, 32), (128, 128), (37, 64)])
@pytest.mark.parametrize("H,Hkv", [(4, 4), (8, 2), (6, 1)])
def test_blockwise_matches_naive(T, kv_block, H, Hkv):
    key = jax.random.PRNGKey(T * H)
    B, hd = 2, 16
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, hd))
    out = blockwise_attention(q, k, v, causal=True, kv_block=kv_block)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [4, 16, 33])
def test_blockwise_sliding_window(window):
    key = jax.random.PRNGKey(0)
    B, T, H, hd = 2, 80, 4, 16
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, hd))
    out = blockwise_attention(q, k, v, causal=True, window=window, kv_block=32)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_mla_distinct_v_dim():
    key = jax.random.PRNGKey(3)
    B, T, H, hd, hdv = 2, 48, 4, 24, 12
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, hdv))
    out = blockwise_attention(q, k, v, causal=True, kv_block=16)
    ref = naive_attention(q, k, v, causal=True)
    assert out.shape == (B, T, H, hdv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_matches_last_row_of_prefill():
    """decode_attention(q_T) == full attention's last query row."""
    key = jax.random.PRNGKey(5)
    B, S, H, hd = 2, 40, 4, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    full = blockwise_attention(q, k, v, causal=True, kv_block=16)
    dec = decode_attention(q[:, -1:], k, v, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]), atol=2e-5)


# ---------------------------- SSD ----------------------------------------


def naive_ssd(x, log_a, Bm, Cm):
    """Sequential recurrence oracle. x (B,T,H,P), log_a (B,T,H), Bm/Cm (B,T,N)."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    S = np.zeros((Bsz, H, P, N), np.float64)
    ys = []
    for t in range(T):
        a = np.exp(np.asarray(log_a[:, t], np.float64))[:, :, None, None]
        S = a * S + np.einsum(
            "bhp,bn->bhpn", np.asarray(x[:, t], np.float64), np.asarray(Bm[:, t], np.float64)
        )
        ys.append(np.einsum("bhpn,bn->bhp", S, np.asarray(Cm[:, t], np.float64)))
    return np.stack(ys, axis=1), S


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (64, 64), (48, 16)])
def test_ssd_chunked_matches_recurrence(T, chunk):
    key = jax.random.PRNGKey(T + chunk)
    B, H, P, N = 2, 3, 8, 4
    x = jax.random.normal(key, (B, T, H, P))
    log_a = -jax.random.uniform(jax.random.fold_in(key, 1), (B, T, H), minval=0.01, maxval=1.0)
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, T, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, T, N))
    y, S = ssd_chunked(x, log_a, Bm, Cm, chunk=chunk)
    y_ref, S_ref = naive_ssd(x, log_a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(S), S_ref, atol=1e-3, rtol=1e-3)


def test_ssd_decode_continues_prefill_state():
    key = jax.random.PRNGKey(9)
    B, T, H, P, N = 1, 16, 2, 4, 4
    x = jax.random.normal(key, (B, T + 1, H, P))
    log_a = -jax.random.uniform(jax.random.fold_in(key, 1), (B, T + 1, H), minval=0.1, maxval=1.0)
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, T + 1, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, T + 1, N))
    _, S = ssd_chunked(x[:, :T], log_a[:, :T], Bm[:, :T], Cm[:, :T], chunk=8)
    y_dec, _ = ssd_decode_step(x[:, T], log_a[:, T], Bm[:, T], Cm[:, T], S)
    y_ref, _ = naive_ssd(x, log_a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_dec), y_ref[:, T], atol=1e-3, rtol=1e-3)


# ---------------------------- RG-LRU -------------------------------------


def test_lru_scan_matches_loop():
    key = jax.random.PRNGKey(11)
    B, T, W = 2, 33, 8
    a = jax.random.uniform(key, (B, T, W), minval=0.5, maxval=0.99)
    u = jax.random.normal(jax.random.fold_in(key, 1), (B, T, W))
    h_scan = _lru_scan(a, u)
    h = np.zeros((B, W))
    for t in range(T):
        h = np.asarray(a[:, t]) * h + np.asarray(u[:, t])
        np.testing.assert_allclose(np.asarray(h_scan[:, t]), h, atol=1e-5)


# ---------------------------- MoE ----------------------------------------


def _moe_cfg(E=4, k=2):
    return ArchConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=128, num_experts=E, experts_per_token=k, dtype="float32",
    )


def test_moe_dense_topk_only_uses_topk_experts():
    cfg = _moe_cfg()
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y, aux = moe_forward(cfg, p, x, method="dense_topk")
    assert y.shape == x.shape and float(aux) >= 0
    assert not bool(jnp.isnan(y).any())


def test_moe_expert_choice_shapes_and_capacity():
    cfg = _moe_cfg(E=4, k=2)
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe_forward(cfg, p, x, method="expert_choice")
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())


def test_moe_methods_agree_when_capacity_covers_everything():
    """With E=1 expert and k=1, both dispatch methods are exact and equal."""
    cfg = _moe_cfg(E=1, k=1)
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y1, _ = moe_forward(cfg, p, x, method="dense_topk")
    y2, _ = moe_forward(cfg, p, x, method="expert_choice")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)


def test_moe_load_balance_loss_penalises_collapse():
    cfg = _moe_cfg(E=4, k=1)
    from repro.models.ffn import _load_balance_loss

    uniform = jnp.full((64, 4), 0.25)
    collapsed = jnp.zeros((64, 4)).at[:, 0].set(1.0)
    assert float(_load_balance_loss(collapsed, 4)) > float(_load_balance_loss(uniform, 4)) * 3
