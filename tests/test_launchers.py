"""Production launchers (launch/train.py, launch/serve.py) — execute-mode
smoke tests in subprocesses (the launchers set XLA_FLAGS before jax init)."""
import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=240):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m", *args], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


def test_train_execute_smoke():
    r = _run(["repro.launch.train", "--arch", "qwen3-0.6b", "--execute",
              "--rounds", "4", "--batch", "2", "--seq", "32"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "loss" in r.stdout and "done" in r.stdout


def test_serve_execute_smoke():
    r = _run(["repro.launch.serve", "--arch", "qwen3-0.6b", "--execute",
              "--requests", "4", "--slots", "2", "--prompt-len", "4",
              "--max-new", "6"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "tok/s" in r.stdout
    # every request produced output
    assert "4 requests over 2 slots" in r.stdout
