"""repro.netsim — the event-driven network/time simulator.

Pins the acceptance contract: deterministic timelines given (seed, config);
Fed-CHS's per-round wall-clock is the *serial* chain (every round pays its
ES->ES hop on the critical path), FedAvg's is the *max over parallel client
uploads* plus the PS round trip; and the bits-winner and time-winner of a
comparison can differ once link speeds enter the picture — the claim class
§3.2's bit counting cannot express.
"""
import numpy as np
import pytest

from repro.core import CommLedger, FedCHSConfig, LatencyAwareScheduler, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
)
from repro.core.ledger import dense_message_bits
from repro.core.simulation import RunResult
from repro.core.topology import make_topology
from repro.netsim import (
    Job,
    edge_cloud_network,
    sgd_step_flops,
    simulate,
    simulate_run,
    time_to_accuracy,
    timeline_for,
)

# -- the raw simulator -------------------------------------------------------


def test_simulator_resolves_deps_and_resource_contention():
    jobs = [
        Job(0, "compute", 2.0, "a"),
        Job(1, "compute", 3.0, "a"),            # same resource: serializes
        Job(2, "transfer", 1.0, "a->b", (0, 1)),
        Job(3, "compute", 5.0, "b"),            # independent, parallel
    ]
    tl = simulate(jobs)
    assert tl.job_times[0] == (0.0, 2.0)
    assert tl.job_times[1] == (2.0, 5.0)
    assert tl.job_times[2] == (5.0, 6.0)
    assert tl.job_times[3] == (0.0, 5.0)
    assert tl.makespan == 6.0


def test_simulator_is_deterministic():
    rng = np.random.default_rng(0)
    jobs = []
    for i in range(200):
        n_deps = int(rng.integers(0, 3)) if i else 0
        deps = tuple(int(d) for d in rng.integers(0, i, size=n_deps))
        jobs.append(Job(i, "compute", float(rng.random()), f"r{int(rng.integers(6))}", deps))
    a, b = simulate(jobs), simulate(jobs)
    assert a.job_times == b.job_times and a.makespan == b.makespan


def test_timeline_time_until():
    tl = simulate([Job(0, "compute", 1.0, "a", (), 0), Job(1, "compute", 1.0, "a", (0,), 2)])
    assert tl.time_until(0) == 1.0
    assert tl.time_until(1) == 2.0   # first recorded round >= 1 is round 2
    assert tl.time_until(99) == tl.makespan


# -- link/compute models -----------------------------------------------------


def test_network_model_determinism_and_straggler_effects():
    net = edge_cloud_network(seed=7, heterogeneity=0.4, straggler_frac=0.5,
                             straggler_slowdown=8.0, jitter=0.2)
    net2 = edge_cloud_network(seed=7, heterogeneity=0.4, straggler_frac=0.5,
                              straggler_slowdown=8.0, jitter=0.2)
    for node in [f"client:{i}" for i in range(20)]:
        assert net.node_speed(node) == net2.node_speed(node)
        assert net.is_straggler(node) == net2.is_straggler(node)
    assert any(net.is_straggler(f"client:{i}") for i in range(20))
    strag = next(f"client:{i}" for i in range(20) if net.is_straggler(f"client:{i}"))
    fast = next(f"client:{i}" for i in range(20) if not net.is_straggler(f"client:{i}"))
    # a straggler's radio is slower too
    t_s = net.transfer_time("client_to_es", strag, "es:0", 1e6, 0)
    t_f = net.transfer_time("client_to_es", fast, "es:0", 1e6, 0)
    assert t_s > t_f
    assert net.transfer_time("es_to_es", "es:0", "es:1", 1e6, 3) == \
           net2.transfer_time("es_to_es", "es:0", "es:1", 1e6, 3)


def test_dynamic_topology_degrades_flaky_backhaul():
    from repro.core.dynamics import iov_gilbert

    dyn = iov_gilbert(6, p_drop=0.6, seed=2)
    net = edge_cloud_network(seed=0, dynamics=dyn)
    base = net.backhaul.base_time(1e6)
    # find a round where a base-graph link was dropped by fading
    t = next(t for t in range(50) if dyn.dropped(t))
    a, b = sorted(next(iter(dyn.dropped(t))))
    degraded = net.transfer_time("es_to_es", f"es:{a}", f"es:{b}", 1e6, t)
    assert degraded > base  # flaky link costs time, not bits
    # an intact link that round is at nominal speed
    intact = next(e for e in [(m, m + 1) for m in range(5)]
                  if e not in dyn.dropped(t) and e[1] in dyn(t).neighbors(e[0]))
    assert net.transfer_time("es_to_es", f"es:{intact[0]}", f"es:{intact[1]}", 1e6, t) \
           == pytest.approx(base)


# -- pinned protocol timing (the acceptance contract) ------------------------


def _flat_net():
    """No jitter, no heterogeneity, no stragglers: analytically predictable."""
    return edge_cloud_network(seed=0)


def test_fed_chs_round_time_is_the_serial_chain(small_task):
    K, T = 4, 3
    res = run_fed_chs(small_task, FedCHSConfig(rounds=T, local_steps=K, eval_every=10, seed=0))
    net = _flat_net()
    tl = simulate_run(small_task, res, net, local_steps=K)

    d = small_task.num_params()
    q = dense_message_bits(d)
    t_down = net.wireless.base_time(q)
    t_up = net.wireless.base_time(q)
    t_comp = sgd_step_flops(d, small_task.batch_size) / net.compute.flops_per_second
    t_hop = net.backhaul.base_time(q)
    # E=1 dense => K interactions, each broadcast -> 1 step -> upload, then
    # ONE ES->ES pass whose latency the next round serially waits for
    per_round = K * (t_down + t_comp + t_up) + t_hop
    for t in range(T):
        assert tl.round_duration(t) == pytest.approx(per_round, rel=1e-9)
    assert tl.makespan == pytest.approx(T * per_round, rel=1e-9)


def test_fedavg_round_time_is_max_over_parallel_clients(small_task):
    K, T = 4, 2
    res = run_fedavg(small_task, FedAvgConfig(rounds=T, local_steps=K, eval_every=10, seed=0))
    net = edge_cloud_network(seed=1, heterogeneity=0.5)  # unequal client speeds
    tl = simulate_run(small_task, res, net, local_steps=K)

    d = small_task.num_params()
    q = dense_message_bits(d)
    flops = K * sgd_step_flops(d, small_task.batch_size)
    per_client = [
        net.transfer_time("ps_to_client", "ps", f"client:{i}", q)
        + net.compute_time(f"client:{i}", flops)
        + net.transfer_time("client_to_ps", f"client:{i}", "ps", q)
        for i in range(small_task.num_clients)
    ]
    per_round = max(per_client)  # parallel clients: slowest gates the round
    for t in range(T):
        assert tl.round_duration(t) == pytest.approx(per_round, rel=1e-9)


def test_hier_round_time_honors_two_level_barriers(small_task):
    K, E = 4, 2
    res = run_hier_local_qsgd(small_task, HierLocalQSGDConfig(
        rounds=1, local_steps=K, local_epochs=E, eval_every=10,
        qsgd_levels=None, seed=0))
    net = _flat_net()
    tl = simulate_run(small_task, res, net, local_steps=K)

    d = small_task.num_params()
    q = dense_message_bits(d)
    t_edge = net.wireless.base_time(q) * 2 + \
        E * sgd_step_flops(d, small_task.batch_size) / net.compute.flops_per_second
    t_wan = net.wan.base_time(q)
    # all clusters in parallel (uniform nodes -> identical chains), then the
    # PS barrier: every ES upload must land before any broadcast leaves
    per_round = (K // E) * t_edge + 2 * t_wan
    assert tl.round_duration(0) == pytest.approx(per_round, rel=1e-9)


def test_shared_ingress_scales_star_round_with_fan_in(small_task):
    """Default: dedicated links, star round = max over parallel clients
    (n-independent). shared_ingress: the PS's bandwidth splits across the
    fan-in, so the same round slows down ~n-fold at scale."""
    K = 2
    res = run_fedavg(small_task, FedAvgConfig(rounds=1, local_steps=K, eval_every=10))
    n = small_task.num_clients
    dedicated = _flat_net()
    shared = edge_cloud_network(seed=0)
    shared.shared_ingress = True
    t_ded = simulate_run(small_task, res, dedicated, local_steps=K).makespan
    t_shared = simulate_run(small_task, res, shared, local_steps=K).makespan
    assert t_shared > t_ded
    d = small_task.num_params()
    q = dense_message_bits(d)
    # only the uplink leg is contended: it alone stretches by the fan-in
    extra = (n - 1) * (q / shared.wan.bandwidth_bps)
    assert t_shared == pytest.approx(t_ded + extra, rel=1e-9)


def test_timeline_identical_across_reruns(small_task):
    cfg = FedCHSConfig(rounds=4, local_steps=4, eval_every=2, seed=5)
    net = edge_cloud_network(seed=3, heterogeneity=0.3, straggler_frac=0.25, jitter=0.15)
    runs = [run_fed_chs(small_task, cfg) for _ in range(2)]
    assert runs[0].ledger.events == runs[1].ledger.events
    tls = [simulate_run(small_task, r, net, local_steps=4) for r in runs]
    assert tls[0].job_times == tls[1].job_times
    assert tls[0].round_end == tls[1].round_end


# -- participation: deadline dropouts + pass-through replay ------------------


def _nominal_chain_s(net, task, steps, link_class="wan"):
    """A non-straggler client's download -> compute -> upload chain."""
    q = dense_message_bits(task.num_params())
    return net.nominal_chain_s(
        link_class, q, steps * sgd_step_flops(task.num_params(), task.batch_size))


def test_deadline_converts_stragglers_into_dropouts(small_task):
    """Bits saved, wall-clock wasted: stragglers miss the reporting deadline,
    their uploads never happen, and the aggregator waits out the deadline."""
    K, T = 2, 2
    res = run_fedavg(small_task, FedAvgConfig(rounds=T, local_steps=K,
                                              eval_every=10, seed=0))
    net = edge_cloud_network(seed=1, straggler_frac=0.3, straggler_slowdown=32.0)
    stragglers = {f"client:{i}" for i in range(small_task.num_clients)
                  if net.is_straggler(f"client:{i}")}
    assert stragglers and len(stragglers) < small_task.num_clients
    deadline = 2.0 * _nominal_chain_s(net, small_task, K)

    plain = simulate_run(small_task, res, net, local_steps=K)
    tl = simulate_run(small_task, res, net, local_steps=K, deadline_s=deadline)
    # exactly the stragglers are dropped, every round
    assert tl.dropped == {t: frozenset(stragglers) for t in range(T)}
    q = dense_message_bits(small_task.num_params())
    assert tl.dropped_bits == len(stragglers) * T * q
    # bits saved, but each round waits out EXACTLY the full deadline: the
    # kept (nominal) chains land inside it, and the dropped stragglers'
    # abandoned compute is untracked — it must not stretch the round
    for t in range(T):
        assert tl.round_duration(t) == pytest.approx(deadline)
    # ...which beats waiting for a 32x straggler
    assert tl.makespan == pytest.approx(T * deadline)
    assert tl.makespan < plain.makespan
    # the deadline can also ride on the NetworkModel itself
    net_dl = edge_cloud_network(seed=1, straggler_frac=0.3,
                                straggler_slowdown=32.0, deadline_s=deadline)
    tl2 = simulate_run(small_task, res, net_dl, local_steps=K)
    assert tl2.dropped == tl.dropped and tl2.makespan == tl.makespan


def test_deadline_bounds_multi_phase_rounds(small_task):
    """Abandoned straggler compute (64x nominal, overhanging every phase)
    must never stretch a later phase: each phase with a drop closes at
    exactly the deadline, so a dropped round costs J*deadline + the hop."""
    K, E, T = 4, 2, 3
    res = run_fed_chs(small_task, FedCHSConfig(rounds=T, local_steps=K,
                                               local_epochs=E, eval_every=10,
                                               seed=0))
    net = edge_cloud_network(seed=1, straggler_frac=0.3, straggler_slowdown=64.0)
    deadline = 2.0 * _nominal_chain_s(net, small_task, E, link_class="wireless")
    tl = simulate_run(small_task, res, net, local_steps=K, deadline_s=deadline)
    assert any(tl.dropped.values())
    J = K // E
    hop = net.backhaul.base_time(dense_message_bits(small_task.num_params()))
    for t, dropped in tl.dropped.items():
        if dropped:
            assert tl.round_duration(t) == pytest.approx(J * deadline + hop)


def test_deadline_dropout_replay_is_deterministic(small_task):
    """Same (seed, config) -> same trained events, same dropped-client sets,
    same makespan — across training reruns AND across timeline_for calls."""
    from repro.part import AvailabilityAware, GilbertElliottTrace

    def make_cfg():
        return FedCHSConfig(
            rounds=6, local_steps=4, local_epochs=2, eval_every=10, seed=2,
            sampler=AvailabilityAware(
                GilbertElliottTrace(p_fail=0.3, p_recover=0.4, seed=5)))

    runs = [run_fed_chs(small_task, make_cfg()) for _ in range(2)]
    assert runs[0].ledger.events == runs[1].ledger.events
    net = edge_cloud_network(seed=4, heterogeneity=0.3, straggler_frac=0.3,
                             straggler_slowdown=12.0, jitter=0.1)
    deadline = 3.0 * _nominal_chain_s(net, small_task, 2, link_class="wireless")
    tls = [simulate_run(small_task, r, net, local_steps=4, deadline_s=deadline)
           for r in runs + [runs[0]]]  # second run + repeated invocation
    for tl in tls[1:]:
        assert tl.job_times == tls[0].job_times
        assert tl.round_end == tls[0].round_end
        assert tl.dropped == tls[0].dropped
        assert tl.dropped_bits == tls[0].dropped_bits
        assert tl.makespan == tls[0].makespan
    assert any(tls[0].dropped.values())  # the deadline actually bites


def test_fed_chs_pass_through_round_replays_as_a_bare_hop(small_task):
    """A round whose whole cluster is dark carries only the ES->ES model pass
    — its replay cost is one backhaul hop, deterministically."""

    class Blackout:
        def participants(self, round_idx, clients):
            return [] if round_idx == 2 else list(clients)

    cfg = FedCHSConfig(rounds=4, local_steps=4, local_epochs=2, eval_every=10,
                       seed=0, sampler=Blackout())
    runs = [run_fed_chs(small_task, cfg) for _ in range(2)]
    assert runs[0].ledger.events == runs[1].ledger.events
    net = _flat_net()
    tls = [simulate_run(small_task, r, net, local_steps=4) for r in runs]
    assert tls[0].job_times == tls[1].job_times
    assert tls[0].makespan == tls[1].makespan
    q = dense_message_bits(small_task.num_params())
    assert tls[0].round_duration(2) == pytest.approx(net.backhaul.base_time(q))
    assert tls[0].round_duration(2) < tls[0].round_duration(1) / 10


# -- bits-winner vs time-winner ---------------------------------------------


def _fabricated_pair(d=1000):
    """Two synthetic runs with hand-built ledgers: a Fed-CHS-style serial
    pass (bits-frugal) and a FedAvg-style parallel star that reaches the
    target in a quarter of the rounds by training 4x the clients per round."""
    q = dense_message_bits(d)
    chs = CommLedger()
    T_chs = 9  # reaches gamma at round 8
    for t in range(T_chs):
        for i in (0, 1):
            chs.record("es_to_client", q, round=t, phase=0, sender="es:0",
                       receiver=f"client:{i}")
            chs.record("client_to_es", q, round=t, phase=0, sender=f"client:{i}",
                       receiver="es:0")
        chs.record("es_to_es", q, round=t, phase=1, sender="es:0", receiver="es:1")
        chs.snapshot(t)
    acc = [0.5] * (T_chs - 1) + [0.9]
    fed_chs = RunResult("fed_chs", list(range(T_chs)), acc, [0.0] * T_chs, chs, None)

    avg = CommLedger()
    T_avg = 3  # reaches gamma at round 2
    for t in range(T_avg):
        for i in range(8):
            avg.record("ps_to_client", q, round=t, phase=0, sender="ps",
                       receiver=f"client:{i}")
            avg.record("client_to_ps", q, round=t, phase=0, sender=f"client:{i}",
                       receiver="ps")
        avg.snapshot(t)
    acc = [0.5] * (T_avg - 1) + [0.9]
    fedavg = RunResult("fedavg", list(range(T_avg)), acc, [0.0] * T_avg, avg, None)
    return d, fed_chs, fedavg


def test_bits_winner_and_time_winner_can_differ():
    d, fed_chs, fedavg = _fabricated_pair()
    gamma = 0.9
    bits = {r.name: r.bits_to_accuracy(gamma) for r in (fed_chs, fedavg)}
    assert bits["fed_chs"] < bits["fedavg"]  # Fed-CHS is the bits-winner

    def t2a(res, net):
        tl = timeline_for(res, net, local_steps=1, batch_size=32, num_params=d)
        return time_to_accuracy(res, tl, gamma)

    # compute-bound net (fat links): FedAvg's 4x per-round parallelism wins
    compute_bound = edge_cloud_network(seed=0, wireless_mbps=1e5, backhaul_mbps=1e5,
                                       wan_mbps=1e5, wan_latency_ms=0.0,
                                       flops_per_second=1e6)
    assert t2a(fedavg, compute_bound) < t2a(fed_chs, compute_bound)

    # WAN-starved net (the paper's deployment): the PS hop dominates and the
    # serial edge-only pass wins wall-clock too
    wan_starved = edge_cloud_network(seed=0, wireless_mbps=1000.0, backhaul_mbps=1000.0,
                                     wan_mbps=0.05, flops_per_second=1e12)
    assert t2a(fed_chs, wan_starved) < t2a(fedavg, wan_starved)


# -- latency-aware scheduling ------------------------------------------------


def test_latency_aware_scheduler_breaks_ties_by_link_delay():
    topo = make_topology("full", 4)
    delays = {(0, 1): 5.0, (0, 2): 1.0, (0, 3): 3.0,
              (1, 2): 2.0, (1, 3): 9.0, (2, 3): 4.0}

    def delay(a, b):
        return delays[(min(a, b), max(a, b))]

    sched = LatencyAwareScheduler(topo, [10, 20, 30, 40], delay, initial=0)
    # all neighbors unvisited: tie on counts, 0->2 is the cheapest link
    assert sched.advance() == 2
    # from 2, unvisited are {1, 3}: delay(2,1)=2 < delay(2,3)=4
    assert sched.advance() == 1
    assert sched.advance() == 3  # only unvisited left


def test_latency_aware_scheduler_via_fed_chs_config(small_task):
    net = edge_cloud_network(seed=0, backhaul_spread=1.0)
    q = dense_message_bits(small_task.num_params())
    cfg = FedCHSConfig(rounds=6, local_steps=2, eval_every=10, seed=0,
                       link_delay=net.link_delay_fn(q))
    a = run_fed_chs(small_task, cfg)
    b = run_fed_chs(small_task, cfg)
    assert a.ledger.events == b.ledger.events  # deterministic path choice
    # still exactly one ES->ES pass per round, zero PS traffic
    assert a.ledger.messages["es_to_es"] == 6
    assert a.ledger.bits["es_to_ps"] == 0
