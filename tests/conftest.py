import pytest


@pytest.fixture(scope="session")
def small_task():
    """Shared small FL task: synthetic MNIST, 20 clients, 4 clusters."""
    from repro.core.simulation import FLTask
    from repro.data import assign_clusters, dirichlet_partition, make_dataset
    from repro.models.classifier import make_classifier

    ds = make_dataset("mnist", train_size=3000, test_size=600, seed=0)
    clients = dirichlet_partition(ds.train_y, 20, 0.6, seed=0)
    clusters = assign_clusters(20, 4, seed=0)
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    return FLTask(model, ds, clients, clusters, batch_size=32, seed=0)
