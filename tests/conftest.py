import os

import pytest

# test files whose contents are hypothesis-guarded (module-level
# `pytest.importorskip("hypothesis")` or try-import guards): without
# hypothesis they skip/vanish SILENTLY, so CI passes --require-hypothesis to
# turn that silence into a hard failure
HYPOTHESIS_GUARDED = ("test_property.py", "test_property_moe.py",
                      "test_partition.py")

# mixed files: mostly deterministic tests plus `if HAS_HYPOTHESIS:` property
# suites — file-level collection always succeeds, so the guard must check
# that at least one test with the given name prefix was actually collected
HYPOTHESIS_GUARDED_PREFIXES = (("test_engine_parity.py", "test_property_"),)


def pytest_addoption(parser):
    parser.addoption(
        "--require-hypothesis", action="store_true", default=False,
        help="fail (instead of silently skipping) when hypothesis is missing "
             "or the hypothesis-guarded property tests collected nothing",
    )


def pytest_collection_finish(session):
    if not session.config.getoption("--require-hypothesis"):
        return
    try:
        import hypothesis  # noqa: F401
    except ImportError:
        raise pytest.UsageError(
            "--require-hypothesis: hypothesis is not importable — the "
            "property tests in tests/test_property*.py / test_partition.py "
            "would silently skip. Install requirements-dev.txt.")
    collected = {os.path.basename(item.nodeid.split("::")[0])
                 for item in session.items}
    missing = [f for f in HYPOTHESIS_GUARDED if f not in collected]
    if missing:
        raise pytest.UsageError(
            f"--require-hypothesis: no tests collected from {missing} — "
            "the property suites did not run.")
    by_file: dict[str, set[str]] = {}
    for item in session.items:
        by_file.setdefault(
            os.path.basename(item.nodeid.split("::")[0]), set()
        ).add(item.name.split("[")[0])
    missing_props = [
        f"{f}::{prefix}*" for f, prefix in HYPOTHESIS_GUARDED_PREFIXES
        if f in by_file and not any(n.startswith(prefix) for n in by_file[f])
    ]
    if missing_props:
        raise pytest.UsageError(
            f"--require-hypothesis: no property tests collected for "
            f"{missing_props} — the embedded hypothesis suites did not run.")


@pytest.fixture(scope="session")
def small_task():
    """Shared small FL task: synthetic MNIST, 20 clients, 4 clusters."""
    from repro.core.simulation import FLTask
    from repro.data import assign_clusters, dirichlet_partition, make_dataset
    from repro.models.classifier import make_classifier

    ds = make_dataset("mnist", train_size=3000, test_size=600, seed=0)
    clients = dirichlet_partition(ds.train_y, 20, 0.6, seed=0)
    clusters = assign_clusters(20, 4, seed=0)
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    return FLTask(model, ds, clients, clusters, batch_size=32, seed=0)
