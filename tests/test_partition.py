"""Dirichlet non-IID partitioner: correctness + hypothesis properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.partition import (
    assign_clusters,
    dirichlet_partition,
    iid_partition,
    label_histogram,
    partial_heterogeneity_partition,
)


def _labels(n=1000, classes=10, seed=0):
    return np.random.default_rng(seed).integers(0, classes, size=n).astype(np.int64)


@given(
    n_clients=st.integers(2, 20),
    alpha=st.floats(0.05, 10.0),
    seed=st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
def test_partition_is_exact_cover(n_clients, alpha, seed):
    labels = _labels(seed=seed % 7)
    clients = dirichlet_partition(labels, n_clients, alpha, seed=seed)
    all_idx = np.concatenate([c.indices for c in clients])
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)  # disjoint + complete


def test_smaller_alpha_is_more_heterogeneous():
    labels = _labels(n=20_000)
    h_low = label_histogram(labels, dirichlet_partition(labels, 10, 0.1, seed=0), 10)
    h_high = label_histogram(labels, dirichlet_partition(labels, 10, 100.0, seed=0), 10)

    def skew(h):
        p = h / np.maximum(h.sum(axis=1, keepdims=True), 1)
        return np.mean(np.std(p, axis=1))

    assert skew(h_low) > 2 * skew(h_high)


def test_iid_partition_balanced():
    labels = _labels()
    clients = iid_partition(labels, 10)
    sizes = [c.size for c in clients]
    assert max(sizes) - min(sizes) <= 1


def test_assign_clusters_covers_all_clients():
    members = assign_clusters(100, 10, seed=0)
    flat = sorted(c for m in members for c in m)
    assert flat == list(range(100))
    assert all(8 <= len(m) <= 12 for m in members)


def test_partial_heterogeneity_clusters_are_iid():
    """Fig. 4 mode: cluster-level label dists must be near-uniform even though
    client-level dists are skewed."""
    labels = _labels(n=40_000)
    clients, members = partial_heterogeneity_partition(labels, 40, 4, alpha=0.1, seed=0)
    hist = label_histogram(labels, clients, 10)
    cluster_hist = np.stack([hist[m].sum(axis=0) for m in members])
    p = cluster_hist / cluster_hist.sum(axis=1, keepdims=True)
    assert np.abs(p - 0.1).max() < 0.02  # clusters ~ global distribution
    client_p = hist / np.maximum(hist.sum(axis=1, keepdims=True), 1)
    assert np.std(client_p, axis=1).mean() > 0.05  # clients still skewed
