"""Continuous-batching serving loop (launch/serve.py serve_loop).

Pins the two properties the per-slot prefill splice restored:
  * every request yields EXACTLY max_new tokens (one from the prefill's
    last-position argmax + max_new-1 batched decode steps);
  * a request decodes the SAME tokens whether it runs alone in a 1-slot
    server or concurrently with others in a multi-slot server with slot
    recycling — i.e. admission prefill no longer corrupts the other
    in-flight slots' KV caches, and a recycled slot restarts at position 0.
"""
import functools

import jax
import pytest

from repro.launch.serve import serve_loop


@functools.lru_cache(maxsize=None)
def _model():
    from repro.configs.registry import smoke_config
    from repro.models import transformer as tf

    cfg = smoke_config("qwen3-0.6b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_exact_max_new_tokens():
    cfg, params = _model()
    done, steps = serve_loop(cfg, params, requests=5, slots=2,
                             prompt_len=6, max_new=9)
    assert sorted(done) == list(range(5))
    assert all(len(toks) == 9 for toks in done.values())
    # with S slots the batched loop needs >= ceil(total decode tokens / S)
    assert steps >= (5 * 8) // 2


@pytest.mark.parametrize("slots", [3, 4])
def test_batched_equals_solo(slots):
    """Cross-slot isolation: concurrent decode with slot recycling produces
    token-for-token what each request produces alone."""
    cfg, params = _model()
    batched, _ = serve_loop(cfg, params, requests=6, slots=slots,
                            prompt_len=6, max_new=8)
    # a 1-slot server decodes the same ids strictly one at a time (and
    # recycles its single slot between them — position counters must reset)
    solo, _ = serve_loop(cfg, params, requests=6, slots=1,
                         prompt_len=6, max_new=8)
    assert batched == solo
