"""Sharded == unsharded parity for the population-scale device-mesh engine.

Every driver accepts `config.mesh` (a ("clusters", "clients") federation
mesh, `launch.mesh.make_federation_mesh`); `sharding.fed.shard_plan` rewrites
the driver's ScanPlan so the compiled chunk runs under shard_map with the
client/cluster axes mapped to devices.  The contract (sharding/fed.py module
docstring): params, eval metrics and ledger aggregates BIT-identical to the
single-device run; loss log scalars bit-identical in grad mode, within 1 ulp
in delta modes.

The XLA device count locks at backend init, so the multi-device cells are
guarded by `jax.device_count() >= 8` and a meta-test re-invokes pytest on
this file in a subprocess with --xla_force_host_platform_device_count=8.
Under the CI sharding-smoke job (XLA_FLAGS exported) the cells run directly
and the meta-test skips.

Bit-exactness regime: XLA:CPU's batched GEMM is per-lane width-DEPENDENT for
large layers under forced host devices (fed.py docstring), so the bit-exact
end-to-end cells use a tiny 16->32->4 model whose GEMMs sit in the
width-invariant regime; an MNIST-MLP cell pins params at tight allclose plus
exact ledger aggregates instead.
"""
import dataclasses
import functools
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import FedCHSConfig, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    WRWGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
    run_wrwgd,
)
from repro.core.sweep import run_sweep
from repro.launch.mesh import make_federation_mesh
from repro.sharding.fed import FED_AXES, resolve_mesh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices (runs via test_forced_8_devices_subprocess)")


def _mesh():
    m = make_federation_mesh(2, 4)
    assert m.size == 8 and m.axis_names == FED_AXES
    return m


@functools.lru_cache(maxsize=None)
def tiny_task(ragged: bool = False):
    """Tiny task whose GEMMs sit in XLA:CPU's width-invariant regime, so the
    sharded parity checks are BIT-exact end to end (see module docstring)."""
    from repro.core.simulation import FLTask
    from repro.data import assign_clusters, dirichlet_partition
    from repro.data.synthetic import Dataset, DatasetSpec
    from repro.models.classifier import Classifier, _dense_init

    spec = DatasetSpec("tiny", (4, 4, 1), 4, 400, 80)
    rng = np.random.default_rng(0)
    train_y = rng.integers(0, 4, 400).astype(np.int32)
    test_y = rng.integers(0, 4, 80).astype(np.int32)
    protos = rng.normal(size=(4, 4, 4, 1)).astype(np.float32)
    train_x = (protos[train_y]
               + 0.3 * rng.normal(size=(400, 4, 4, 1))).astype(np.float32)
    test_x = (protos[test_y]
              + 0.3 * rng.normal(size=(80, 4, 4, 1))).astype(np.float32)
    ds = Dataset(spec, train_x, train_y, test_x, test_y)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"fc1": _dense_init(k1, 16, 32), "out": _dense_init(k2, 32, 4)}

    def apply(p, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["b"])
        return x @ p["out"]["w"] + p["out"]["b"]

    model = Classifier("tiny-mlp", init, apply, 4)
    clients = dirichlet_partition(train_y, 20, 0.6, seed=0)
    if ragged:  # 7/5/4/4: exercises padded client slots on every shard
        clusters = [list(range(0, 7)), list(range(7, 12)),
                    list(range(12, 16)), list(range(16, 20))]
    else:
        clusters = assign_clusters(20, 4, seed=0)
    return FLTask(model, ds, clients, clusters, batch_size=8, seed=0)


def _check(r0, r1, exact_loss=False):
    """The fidelity contract: params/metrics/ledger bit-identical; loss log
    scalars exact in grad mode, within 1 ulp (rtol 1e-6) in delta modes."""
    for a, b in zip(jax.tree.leaves(r0.final_params),
                    jax.tree.leaves(r1.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert r0.test_acc == r1.test_acc
    if exact_loss:
        assert r0.train_loss == r1.train_loss
    else:
        np.testing.assert_allclose(r0.train_loss, r1.train_loss,
                                   rtol=1e-6, atol=0)
    assert r0.ledger.total_bits() == r1.ledger.total_bits()
    assert r0.ledger.history == r1.ledger.history


def _run_pair(run, task, cfg, exact_loss=False):
    r0 = run(task, cfg)
    r1 = run(task, dataclasses.replace(cfg, mesh=_mesh()))
    _check(r0, r1, exact_loss=exact_loss)


# --------------------------------------------------------------------------
# bit-exact parity cells: 4 drivers x {dense, QSGD} on the 2x4 mesh
# --------------------------------------------------------------------------


@needs8
def test_fed_chs_sharded_bit_parity():
    _run_pair(run_fed_chs, tiny_task(),
              FedCHSConfig(rounds=6, eval_every=3, seed=0), exact_loss=True)
    _run_pair(run_fed_chs, tiny_task(),
              FedCHSConfig(rounds=6, local_steps=4, local_epochs=2,
                           qsgd_levels=16, eval_every=3, seed=0))


@needs8
def test_fedavg_sharded_bit_parity():
    base = dict(rounds=4, local_steps=4, eval_every=2, seed=0)
    _run_pair(run_fedavg, tiny_task(), FedAvgConfig(**base))
    _run_pair(run_fedavg, tiny_task(), FedAvgConfig(**base, qsgd_levels=16))


@needs8
def test_wrwgd_sharded_bit_parity():
    """n=1 walk: degrades to replicated compute on the mesh, still exact."""
    _run_pair(run_wrwgd, tiny_task(),
              WRWGDConfig(rounds=6, local_steps=4, eval_every=3, seed=0),
              exact_loss=True)


@needs8
def test_hier_sharded_bit_parity():
    base = dict(rounds=4, local_steps=4, local_epochs=2, eval_every=2, seed=0)
    _run_pair(run_hier_local_qsgd, tiny_task(),
              HierLocalQSGDConfig(**base, qsgd_levels=16))
    _run_pair(run_hier_local_qsgd, tiny_task(),
              HierLocalQSGDConfig(**base, qsgd_levels=None))


@needs8
def test_ragged_clusters_sharded_bit_parity():
    """Ragged 7/5/4/4 clusters: every shard carries padded client slots whose
    zero gammas/masks must contribute exactly nothing."""
    _run_pair(run_fed_chs, tiny_task(ragged=True),
              FedCHSConfig(rounds=4, local_steps=4, local_epochs=2,
                           qsgd_levels=16, eval_every=2, seed=1))
    _run_pair(run_hier_local_qsgd, tiny_task(ragged=True),
              HierLocalQSGDConfig(rounds=2, local_steps=4, local_epochs=2,
                                  qsgd_levels=16, eval_every=1, seed=1))


@needs8
def test_sweep_seed_axis_sharded_bit_parity():
    """run_sweep(mesh=...) shards the leading SEED axis (pure GSPMD put):
    every per-seed trajectory is bit-identical to the unsharded sweep."""
    cfg = FedAvgConfig(rounds=4, local_steps=4, eval_every=2)
    rs0 = run_sweep(tiny_task(), cfg, range(8))
    rs1 = run_sweep(tiny_task(), cfg, range(8), mesh=_mesh())
    for a, b in zip(rs0, rs1):
        _check(a, b)


@needs8
def test_mlp_scale_tolerance_parity():
    """MNIST-MLP scale: the 784x200 GEMM is in XLA:CPU's width-dependent
    regime under forced host devices, so params are pinned at tight allclose
    (the divergence is lane-math, not sharding); ledger stays exact."""
    from repro.core.simulation import FLTask
    from repro.data import dirichlet_partition, make_dataset
    from repro.models.classifier import make_classifier

    ds = make_dataset("mnist", train_size=600, test_size=150, seed=0)
    clients = dirichlet_partition(ds.train_y, 8, 0.6, seed=0)
    clusters = [[0, 1, 2], [3, 4, 5], [6, 7]]
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    task = FLTask(model, ds, clients, clusters, batch_size=8, seed=0)

    cfg = FedAvgConfig(rounds=3, local_steps=3, eval_every=1, seed=0)
    r0 = run_fedavg(task, cfg)
    r1 = run_fedavg(task, dataclasses.replace(cfg, mesh=_mesh()))
    for a, b in zip(jax.tree.leaves(r0.final_params),
                    jax.tree.leaves(r1.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(r0.train_loss, r1.train_loss, rtol=1e-4, atol=0)
    assert r0.ledger.total_bits() == r1.ledger.total_bits()


# --------------------------------------------------------------------------
# structural properties of the sharded path
# --------------------------------------------------------------------------


@needs8
def test_sharded_chunk_zero_host_transfers():
    """The sharded hot loop stays on-device: executing a shard_map-wrapped
    chunk on pre-staged per-shard inputs performs zero implicit host<->device
    transfers under jax.transfer_guard("disallow")."""
    from repro.core.baselines.fedavg import _fedavg_scan_plan

    task = tiny_task()
    cfg = FedAvgConfig(rounds=4, local_steps=4, eval_every=10, chunk_rounds=4,
                       seed=0, mesh=_mesh())
    plan, _params_of, _traffic = _fedavg_scan_plan(task, task.source, cfg)
    assert plan.chunk_fn is not None and plan.xs_put is not None
    idxs = np.flatnonzero(np.asarray(plan.trained))
    xs = plan.xs_put(plan.stage(idxs))
    carry, consts = plan.carry, plan.consts
    # compile + warm outside the guard, on a sharding-preserving copy so
    # backends with buffer donation don't invalidate `carry`
    warm_carry = jax.tree.map(
        lambda leaf: jax.device_put(np.asarray(leaf), leaf.sharding), carry)
    warm = plan.chunk_fn(warm_carry, xs, consts)
    jax.block_until_ready(jax.tree.leaves(warm))
    with jax.transfer_guard("disallow"):
        out_carry, ys = plan.chunk_fn(carry, xs, consts)
        jax.block_until_ready(jax.tree.leaves((out_carry, ys)))


@needs8
def test_ambient_mesh_adoption():
    """mesh=None configs adopt an ambient ("clusters","clients") mesh via
    sharding.ctx; meshes with other axis names are never adopted."""
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding.ctx import model_mesh

    fed = _mesh()
    assert resolve_mesh(None) is None
    with model_mesh(fed):
        assert resolve_mesh(None) is fed
    with model_mesh(make_debug_mesh(2, 4)):  # ("data","model"): not a fed mesh
        assert resolve_mesh(None) is None


@needs8
def test_mesh_with_telemetry_rejected():
    """Telemetry taps materialize at host chunk boundaries — incompatible
    with the device-sharded chunk; the combination must fail loudly."""
    from repro.obs import RunTelemetry

    cfg = FedAvgConfig(rounds=2, local_steps=2, eval_every=1, seed=0,
                       mesh=_mesh(), obs=RunTelemetry())
    with pytest.raises(AssertionError):
        run_fedavg(tiny_task(), cfg)


# --------------------------------------------------------------------------
# single-device behavior (any device count)
# --------------------------------------------------------------------------


def test_run_sweep_rejects_config_mesh():
    cfg = FedAvgConfig(rounds=2, local_steps=2, eval_every=1,
                       mesh=object())  # any non-None config.mesh
    with pytest.raises(AssertionError, match="run_sweep shards the seed axis"):
        run_sweep(tiny_task(), cfg, range(2))


def test_single_device_federation_mesh_is_inert():
    """A size-1 mesh resolves to None: the run takes the byte-for-byte
    single-device path (same jit cache entries, same results)."""
    m = make_federation_mesh(1, 1)
    assert m.axis_names == FED_AXES and resolve_mesh(m) is None
    cfg = FedAvgConfig(rounds=2, local_steps=2, eval_every=1, seed=0)
    r0 = run_fedavg(tiny_task(), cfg)
    r1 = run_fedavg(tiny_task(), dataclasses.replace(cfg, mesh=m))
    _check(r0, r1, exact_loss=True)


@pytest.mark.skipif(jax.device_count() >= 8, reason="enough devices exist")
def test_federation_mesh_falls_back_with_warning(caplog):
    with caplog.at_level("WARNING", logger="repro.launch.mesh"):
        m = make_federation_mesh(2, 4)
    assert m.size == 1
    assert any("falling back to a single-device mesh" in r.message
               for r in caplog.records)
    assert resolve_mesh(m) is None


def test_forced_8_devices_subprocess():
    """Re-run this file's multi-device cells under 8 forced host devices (the
    device count locks at backend init, so this needs a fresh process)."""
    if jax.device_count() >= 8:
        pytest.skip("cells ran directly")
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join("tests", "test_sharding_fed.py")],
        env=env, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
