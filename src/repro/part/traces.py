"""Seeded per-client availability processes + participation samplers.

Real hierarchical edge deployments are defined by intermittent client
availability: devices sleep, radios fade in bursts, operators sample a
subset per round to bound tail latency.  This module is the *process* half
of the participation subsystem — who could report this round, and who is
asked to:

  * `AvailabilityTrace` — a deterministic per-(client, round) on/off
    process.  `AlwaysOn`, `BernoulliTrace` (IID coins) and
    `GilbertElliottTrace` (two-state Markov on/off bursts — the classic
    wireless fading model) all key every draw by ``(seed, client, round)``
    through the same crc32-hashed scheme as `repro.netsim.links`, so traces
    are platform-stable and query-order independent.
  * `Sampler` — which of a round's *candidate* clients actually
    participate.  `FullParticipation` is the default everywhere and is the
    seed-parity path: drivers treat it exactly like "no sampler", so fixed
    -seed trajectories are bit-identical to the pre-participation stack.
    `AvailabilityAware` takes everyone the trace reports up;
    `UniformK` additionally subsamples k of them uniformly (the FedAvg
    -style participation cap).

Samplers are pure functions of ``(round_idx, clients)`` — the drivers, the
schedulers' reachability probes, and the closed-form ledger tests can all
re-evaluate them and see the same participant sets.  The *mechanics* half
(masked engine rounds, pass-through hops, deadline dropouts) lives in
`core/engine.py`, the drivers, and `netsim/adapters.py`.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

__all__ = [
    "AvailabilityTrace",
    "AlwaysOn",
    "BernoulliTrace",
    "GilbertElliottTrace",
    "Sampler",
    "FullParticipation",
    "AvailabilityAware",
    "UniformK",
    "is_full_participation",
    "participation_mask",
    "schedule_participants",
    "stack_masks",
]


def _uniform(*key) -> float:
    """One deterministic U[0,1) draw from a structured key (crc32-hashed,
    platform-stable — the same scheme as `repro.netsim.links._rng`)."""
    return float(np.random.default_rng(zlib.crc32(repr(key).encode())).random())


# --------------------------------------------------------------------------
# availability traces
# --------------------------------------------------------------------------


@runtime_checkable
class AvailabilityTrace(Protocol):
    """Deterministic per-(client, round) on/off availability process."""

    def available(self, client: int, round_idx: int) -> bool:
        ...


@dataclasses.dataclass(frozen=True)
class AlwaysOn:
    """Every client is up every round — the implicit pre-participation world."""

    def available(self, client: int, round_idx: int) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class BernoulliTrace:
    """IID per-(client, round) coin: up with probability `p`.

    Memoryless churn — the standard "each device reports with probability p"
    model.  Every coin is keyed by (seed, client, round), so two traces with
    the same seed agree draw-for-draw no matter the query order.
    """

    p: float = 0.9
    seed: int = 0

    def available(self, client: int, round_idx: int) -> bool:
        return _uniform(self.seed, "bernoulli", client, round_idx) < self.p


@dataclasses.dataclass
class GilbertElliottTrace:
    """Two-state Markov on/off process — bursty outages, not IID blips.

    From ON a client fails with `p_fail`; from OFF it recovers with
    `p_recover` (so mean outage length is 1/p_recover rounds).  The chain is
    sequential by nature, but each *transition draw* is independently keyed
    by (seed, client, round): states are computed once per client, cached,
    and identical regardless of which (client, round) is asked first.
    """

    p_fail: float = 0.1
    p_recover: float = 0.5
    seed: int = 0
    start_on: bool = True
    _chains: dict = dataclasses.field(default_factory=dict, repr=False, compare=False)

    def available(self, client: int, round_idx: int) -> bool:
        chain = self._chains.setdefault(client, [self.start_on])
        while len(chain) <= round_idx:
            t = len(chain)  # transition into round t
            u = _uniform(self.seed, "gilbert_elliott", client, t)
            chain.append((u >= self.p_fail) if chain[-1] else (u < self.p_recover))
        return chain[round_idx]

    def steady_state_up(self) -> float:
        """Long-run fraction of rounds a client is ON."""
        return self.p_recover / (self.p_fail + self.p_recover)


# --------------------------------------------------------------------------
# samplers
# --------------------------------------------------------------------------


@runtime_checkable
class Sampler(Protocol):
    """Which of a round's candidate clients participate.  Pure in
    (round_idx, clients): re-evaluating never changes the answer."""

    def participants(self, round_idx: int, clients: Sequence[int]) -> list[int]:
        ...


@dataclasses.dataclass(frozen=True)
class FullParticipation:
    """Everyone participates — the seed-parity default.  Drivers route this
    through the exact pre-participation code path (no masks anywhere), so
    trajectories are bit-identical to a run with no sampler at all."""

    def participants(self, round_idx: int, clients: Sequence[int]) -> list[int]:
        return list(clients)


@dataclasses.dataclass(frozen=True)
class AvailabilityAware:
    """Everyone the trace reports up participates; nobody else can."""

    trace: AvailabilityTrace = AlwaysOn()

    def participants(self, round_idx: int, clients: Sequence[int]) -> list[int]:
        return [c for c in clients if self.trace.available(c, round_idx)]


@dataclasses.dataclass(frozen=True)
class UniformK:
    """Uniformly sample (without replacement) at most `k` of the available
    clients per round — the FedAvg-style participation cap.  With no trace,
    everyone is a candidate.  The selection draw is keyed by (seed, round,
    candidate set) — still a pure function of the inputs, but distinct
    candidate sets queried in the same round (e.g. every cluster of a
    hierarchical round) draw independently instead of picking correlated
    positions."""

    k: int = 5
    seed: int = 0
    trace: AvailabilityTrace | None = None

    def participants(self, round_idx: int, clients: Sequence[int]) -> list[int]:
        avail = (
            list(clients)
            if self.trace is None
            else [c for c in clients if self.trace.available(c, round_idx)]
        )
        if len(avail) <= self.k:
            return avail
        g = np.random.default_rng(
            zlib.crc32(repr((self.seed, "uniform_k", round_idx, tuple(avail))).encode())
        )
        picked = g.choice(len(avail), size=self.k, replace=False)
        return sorted(avail[i] for i in picked)


def is_full_participation(sampler: Sampler | None) -> bool:
    """True when the driver should take the legacy no-mask path (bit-identical
    to the pre-participation stack)."""
    return sampler is None or isinstance(sampler, FullParticipation)


def participation_mask(members: Sequence[int], participating: Sequence[int]) -> np.ndarray:
    """1/0 float mask over `members` marking the participating subset."""
    part = set(participating)
    return np.asarray([1.0 if c in part else 0.0 for c in members], dtype=np.float32)


def schedule_participants(
    sampler: Sampler | None, rounds: int, clients: Sequence[int]
) -> list[list[int]]:
    """Precompute the whole run's participant sets over a fixed candidate
    list — samplers are pure in (round_idx, clients), so the scanned
    whole-run drivers evaluate them once up front and see exactly the sets
    the looped drivers would query round-by-round.  `None` (and
    `FullParticipation`) yields every client every round."""
    if is_full_participation(sampler):
        full = list(clients)
        return [list(full) for _ in range(rounds)]
    return [sampler.participants(t, clients) for t in range(rounds)]


def stack_masks(
    members: Sequence[int], parts_by_round: Sequence[Sequence[int]], width: int | None = None
) -> np.ndarray:
    """Stack per-round participation masks over `members` into one
    (rounds, width) float array — the scanned executor's per-round mask
    input.  `width` pads columns with zeros past len(members) (the engine's
    padded client slots for ragged clusters)."""
    n = len(members) if width is None else width
    out = np.zeros((len(parts_by_round), n), dtype=np.float32)
    for t, parts in enumerate(parts_by_round):
        out[t, : len(members)] = participation_mask(members, parts)
    return out
