# repro.part — the participation subsystem: who is up (availability traces),
# who reports (samplers), and the helpers that turn a participant set into
# the engine's mask slots.  Deadline-induced dropouts live in
# repro.netsim.adapters; pass-through scheduling in repro.core.scheduler.
from repro.part.traces import (
    AlwaysOn,
    AvailabilityAware,
    AvailabilityTrace,
    BernoulliTrace,
    FullParticipation,
    GilbertElliottTrace,
    Sampler,
    UniformK,
    is_full_participation,
    participation_mask,
    schedule_participants,
    stack_masks,
)

__all__ = [
    "AvailabilityTrace",
    "AlwaysOn",
    "BernoulliTrace",
    "GilbertElliottTrace",
    "Sampler",
    "FullParticipation",
    "AvailabilityAware",
    "UniformK",
    "is_full_participation",
    "participation_mask",
    "schedule_participants",
    "stack_masks",
]
