"""jit'd public wrappers around the Pallas kernels: flat-vector / pytree QSGD.

These handle padding to whole tiles, flattening, and pytree mapping; the
kernels themselves (qsgd.py) only see dense (n_blocks, block) tiles.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.qsgd import ROWS_PER_TILE, qsgd_dequantize_blocks, qsgd_quantize_blocks
from repro.kernels.ref import qsgd_dequantize_blocks_ref, qsgd_quantize_blocks_ref

PyTree = Any
DEFAULT_BLOCK = 1024


def _use_pallas() -> bool:
    # Off-TPU the Pallas kernels run in interpret mode (a grid-step loop of
    # dynamic slices — orders of magnitude slower than fused XLA, and worse
    # still under vmap). The pure-jnp oracle is bit-identical (enforced by
    # tests/test_kernels_qsgd.py), so route through it everywhere but TPU.
    return jax.default_backend() == "tpu"


def _pad_to_blocks(v: jnp.ndarray, block: int, rows_per_tile: int):
    n = v.size
    per_tile = block * rows_per_tile
    padded = ((n + per_tile - 1) // per_tile) * per_tile
    flat = jnp.zeros((padded,), jnp.float32).at[:n].set(v.reshape(-1).astype(jnp.float32))
    return flat.reshape(-1, block), n


@functools.partial(jax.jit, static_argnames=("s", "block"))
def qsgd_quantize(v: jnp.ndarray, key: jax.Array, *, s: int = 16, block: int = DEFAULT_BLOCK):
    """Quantize an arbitrary-shape f32 array. Returns (q, norms, orig_size)."""
    blocks, n = _pad_to_blocks(v, block, ROWS_PER_TILE)
    u = jax.random.uniform(key, blocks.shape, jnp.float32)
    if _use_pallas():
        q, norms = qsgd_quantize_blocks(blocks, u, s=s)
    else:
        q, norms = qsgd_quantize_blocks_ref(blocks, u, s)
    return q, norms, n


@functools.partial(jax.jit, static_argnames=("s", "shape", "block"))
def qsgd_dequantize(q, norms, *, s: int = 16, shape: tuple = (), block: int = DEFAULT_BLOCK):
    import numpy as np

    if _use_pallas():
        flat = qsgd_dequantize_blocks(q, norms, s=s).reshape(-1)
    else:
        flat = qsgd_dequantize_blocks_ref(q, norms, s).reshape(-1)
    n = int(np.prod(shape)) if shape else flat.size
    return flat[:n].reshape(shape)


def qsgd_roundtrip(v: jnp.ndarray, key: jax.Array, *, s: int = 16, block: int = DEFAULT_BLOCK):
    """quantize -> dequantize (the lossy channel a message actually traverses)."""
    q, norms, _ = qsgd_quantize(v, key, s=s, block=block)
    return qsgd_dequantize(q, norms, s=s, shape=tuple(v.shape), block=block)


def qsgd_compress_tree(tree: PyTree, key: jax.Array, *, s: int = 16) -> PyTree:
    """Apply the QSGD channel leaf-wise to a gradient pytree."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [qsgd_roundtrip(leaf, k, s=s).astype(leaf.dtype) for leaf, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


@functools.partial(jax.jit, static_argnames=("k",))
def topk_sparsify(v: jnp.ndarray, *, k: int) -> jnp.ndarray:
    """Keep the k largest-magnitude entries of v (any shape), zero the rest.

    The deterministic sparsification half of a Top-K channel: the receiver
    reconstructs the dense tensor from (value, index) pairs, so the lossy
    roundtrip is exactly this masking."""
    flat = v.reshape(-1)
    k = min(k, flat.size)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return (flat * mask).reshape(v.shape)


def topk_sparsify_tree(tree: PyTree, *, fraction: float) -> PyTree:
    """Whole-message Top-K: keep the ceil(fraction * total_size) largest-magnitude
    entries across ALL leaves of the pytree (one message = one flat vector), so
    the encoded size is exactly k (index, value) pairs over the full dimension."""
    import math

    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])
    sparse = topk_sparsify(flat, k=max(1, math.ceil(fraction * flat.size)))
    out, off = [], 0
    for leaf in leaves:
        out.append(sparse[off : off + leaf.size].reshape(leaf.shape).astype(leaf.dtype))
        off += leaf.size
    return jax.tree.unflatten(treedef, out)
