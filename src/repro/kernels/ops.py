"""jit'd public wrappers around the Pallas kernels: flat-vector / pytree QSGD.

These handle padding to whole tiles, flattening, and pytree mapping; the
kernels themselves (qsgd.py) only see dense (n_blocks, block) tiles.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.qsgd import (
    ROWS_PER_TILE,
    _pack_words,
    _unpack_words,
    qsgd_dequantize_blocks,
    qsgd_quantize_blocks,
    qsgd_quantize_pack_blocks,
    qsgd_unpack_dequantize_blocks,
)
from repro.kernels.ref import (
    qsgd_code_bits,
    qsgd_dequantize_blocks_ref,
    qsgd_dequantize_codes_ref,
    qsgd_quantize_blocks_ref,
    qsgd_quantize_codes_ref,
    signsgd_dequantize_codes_ref,
    signsgd_quantize_codes_ref,
)

PyTree = Any
DEFAULT_BLOCK = 1024


def _use_pallas() -> bool:
    # Off-TPU the Pallas kernels run in interpret mode (a grid-step loop of
    # dynamic slices — orders of magnitude slower than fused XLA, and worse
    # still under vmap). The pure-jnp oracle is bit-identical (enforced by
    # tests/test_kernels_qsgd.py), so route through it everywhere but TPU.
    return jax.default_backend() == "tpu"


def _pad_to_blocks(v: jnp.ndarray, block: int, rows_per_tile: int):
    n = v.size
    per_tile = block * rows_per_tile
    flat = v.reshape(-1).astype(jnp.float32)
    if n % per_tile:  # whole-tile sizes skip the pad copy entirely
        padded = ((n + per_tile - 1) // per_tile) * per_tile
        flat = jnp.zeros((padded,), jnp.float32).at[:n].set(flat)
    return flat.reshape(-1, block), n


def _cheap_uniform(key: jax.Array, shape: tuple) -> jnp.ndarray:
    """Stochastic-rounding dither: uniform on the 16-bit grid {k / 65536}.

    `jax.random.uniform` (threefry2x32: 20 mixing rounds per 4 output words)
    was ~95% of qsgd_quantize's CPU runtime at n=1M.  The dither only needs to
    be (a) deterministic in `key` and position, (b) uniform, (c) decorrelated
    across positions and across nearby keys — a keyed murmur3-fmix32 counter
    hash (two avalanche rounds, 12 int ops per word) delivers that at ~6x the
    throughput, and every 32-bit word yields TWO 16-bit dither samples.
    u = half / 65536 quantizes the rounding probability to 2^-16 — far below
    QSGD's own quantization variance, so unbiasedness tests are unaffected.
    Depends only on (key, size): scale-invariance of Q(v) is preserved.  NOT
    a general-purpose RNG — use only where the consumer is floor(p + u).
    """
    n = math.prod(shape)
    nw = (n + 1) // 2
    kd = key if key.dtype == jnp.uint32 else jax.random.key_data(key)
    x = jax.lax.iota(jnp.uint32, nw) ^ kd[0]
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    # second keyed avalanche: PRNGKey(i) streams differ only in kd[1], and one
    # fmix round after the xor is what decorrelates those streams
    x = x ^ (x >> 16) ^ kd[1]
    x = (x ^ (x >> 16)) * jnp.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    # interleave the halves with stack+reshape: XLA:CPU fuses it into the
    # elementwise chain, where a concatenate materializes both operands (~4x)
    halves = jnp.stack([x & jnp.uint32(0xFFFF), x >> 16], axis=1).reshape(-1)[:n]
    return (halves.astype(jnp.float32) * (1.0 / 65536.0)).reshape(shape)


@functools.partial(jax.jit, static_argnames=("s", "block"))
def qsgd_quantize(v: jnp.ndarray, key: jax.Array, *, s: int = 16, block: int = DEFAULT_BLOCK):
    """Quantize an arbitrary-shape f32 array. Returns (q, norms, orig_size)."""
    blocks, n = _pad_to_blocks(v, block, ROWS_PER_TILE)
    u = _cheap_uniform(key, blocks.shape)
    if _use_pallas():
        q, norms = qsgd_quantize_blocks(blocks, u, s=s)
    else:
        q, norms = qsgd_quantize_blocks_ref(blocks, u, s)
    return q, norms, n


@functools.partial(jax.jit, static_argnames=("s", "shape", "block"))
def qsgd_dequantize(q, norms, *, s: int = 16, shape: tuple = (), block: int = DEFAULT_BLOCK):
    import numpy as np

    if _use_pallas():
        flat = qsgd_dequantize_blocks(q, norms, s=s).reshape(-1)
    else:
        flat = qsgd_dequantize_blocks_ref(q, norms, s).reshape(-1)
    n = int(np.prod(shape)) if shape else flat.size
    return flat[:n].reshape(shape)


def qsgd_roundtrip(v: jnp.ndarray, key: jax.Array, *, s: int = 16, block: int = DEFAULT_BLOCK):
    """quantize -> dequantize (the lossy channel a message actually traverses)."""
    q, norms, _ = qsgd_quantize(v, key, s=s, block=block)
    return qsgd_dequantize(q, norms, s=s, shape=tuple(v.shape), block=block)


# --------------------------------------------------------------------------
# packed wire format: fused quantize→pack / unpack→dequantize
# --------------------------------------------------------------------------
# On TPU the fused Pallas kernels run; elsewhere the fallback is the same
# *vectorized* jnp pack/unpack the kernels use internally (`_pack_words` /
# `_unpack_words`: one iota + per-plane reduction, no python-per-bit index
# loops) composed with the oracle's vectorized quantize math — bit-identical
# to the naive `ref.pack_codes_ref` oracle (pinned by tests) but XLA-fusable.


def _leaf_blocks(n: int, block: int) -> int:
    return max(1, math.ceil(n / block))


@functools.partial(jax.jit, static_argnames=("s", "block"))
def qsgd_encode(v: jnp.ndarray, key: jax.Array, *, s: int = 16, block: int = DEFAULT_BLOCK):
    """Encode one leaf to its wire form: {'payload': uint32 (nb, b*block/32),
    'norms': f32 (nb,)} with nb = ceil(v.size / block) blocks *per leaf* —
    block boundaries never depend on anything outside this leaf, so stacking,
    padding, or concatenating messages cannot shift them.
    """
    # named_scope tags every op with op_name=".../qsgd_encode/..." so
    # roofline.attribution.phase_bytes can bill the quantize+pack cost
    with jax.named_scope("qsgd_encode"):
        n = v.size
        nb = _leaf_blocks(n, block)
        flat = v.reshape(-1).astype(jnp.float32)
        if n != nb * block:
            flat = jnp.zeros((nb * block,), jnp.float32).at[:n].set(flat)
        blocks = flat.reshape(nb, block)
        u = _cheap_uniform(key, blocks.shape)
        if _use_pallas():
            payload, norms = qsgd_quantize_pack_blocks(blocks, u, s=s)
        else:
            codes, norms = qsgd_quantize_codes_ref(blocks, u, s)
            payload = _pack_words(codes, qsgd_code_bits(s))
        return {"payload": payload, "norms": norms}


@functools.partial(jax.jit, static_argnames=("s", "shape", "block"))
def qsgd_decode(wire, *, s: int = 16, shape: tuple = (), block: int = DEFAULT_BLOCK):
    """Receiver side: unpack + dequantize a wire dict back to a (shape) f32 leaf."""
    with jax.named_scope("qsgd_decode"):
        payload, norms = wire["payload"], wire["norms"]
        if _use_pallas():
            blocks = qsgd_unpack_dequantize_blocks(payload, norms, s=s, block=block)
        else:
            codes = _unpack_words(payload, qsgd_code_bits(s))
            blocks = qsgd_dequantize_codes_ref(codes, norms, s)
        n = math.prod(shape) if shape else blocks.size
        return blocks.reshape(-1)[:n].reshape(shape)


def qsgd_encode_tree(tree: PyTree, key: jax.Array, *, s: int = 16,
                     block: int = DEFAULT_BLOCK) -> list:
    """Encode every leaf of a message pytree; returns wire dicts in leaf order
    (the packed payloads + norm sidecars are the values that cross a channel)."""
    leaves, _ = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return [qsgd_encode(leaf, k, s=s, block=block) for leaf, k in zip(leaves, keys)]


def qsgd_decode_tree(wires: list, like: PyTree, *, s: int = 16,
                     block: int = DEFAULT_BLOCK) -> PyTree:
    """Decode wire dicts (leaf order) back into the structure/dtypes of `like`."""
    leaves, treedef = jax.tree.flatten(like)
    out = [
        qsgd_decode(w, s=s, shape=tuple(leaf.shape), block=block).astype(leaf.dtype)
        for w, leaf in zip(wires, leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def qsgd_compress_tree(tree: PyTree, key: jax.Array, *, s: int = 16,
                       block: int = DEFAULT_BLOCK) -> PyTree:
    """The QSGD channel roundtrip: encode to the packed wire format, decode at
    the receiver. Leaf-wise with per-leaf PRNG keys."""
    return qsgd_decode_tree(qsgd_encode_tree(tree, key, s=s, block=block), tree,
                            s=s, block=block)


# -- sign-SGD (1-bit) ---------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block",))
def signsgd_encode(v: jnp.ndarray, *, block: int = DEFAULT_BLOCK):
    """1-bit sign codes + per-block mean-|v| scale. Deterministic (no key)."""
    with jax.named_scope("signsgd_encode"):
        n = v.size
        nb = _leaf_blocks(n, block)
        flat = v.reshape(-1).astype(jnp.float32)
        if n != nb * block:
            flat = jnp.zeros((nb * block,), jnp.float32).at[:n].set(flat)
        blocks = flat.reshape(nb, block)
        codes, scales = signsgd_quantize_codes_ref(blocks)
        return {"payload": _pack_words(codes, 1), "norms": scales}


@functools.partial(jax.jit, static_argnames=("shape", "block"))
def signsgd_decode(wire, *, shape: tuple = (), block: int = DEFAULT_BLOCK):
    with jax.named_scope("signsgd_decode"):
        codes = _unpack_words(wire["payload"], 1)
        blocks = signsgd_dequantize_codes_ref(codes, wire["norms"])
        n = math.prod(shape) if shape else blocks.size
        return blocks.reshape(-1)[:n].reshape(shape)


def signsgd_compress_tree(tree: PyTree, *, block: int = DEFAULT_BLOCK) -> PyTree:
    """Sign-SGD channel roundtrip, leaf-wise. Note the *padding* subtlety: the
    tail block's zero padding decodes to +scale like any non-negative entry,
    but those slots are sliced off before the leaf is rebuilt — and an all-zero
    leaf (e.g. a masked-out sender's delta) has scale 0 everywhere, so it
    decodes to exact zeros."""
    leaves, treedef = jax.tree.flatten(tree)
    out = [
        signsgd_decode(signsgd_encode(leaf, block=block), shape=tuple(leaf.shape),
                       block=block).astype(leaf.dtype)
        for leaf in leaves
    ]
    return jax.tree.unflatten(treedef, out)


@functools.partial(jax.jit, static_argnames=("k",))
def topk_sparsify(v: jnp.ndarray, *, k: int) -> jnp.ndarray:
    """Keep the k largest-magnitude entries of v (any shape), zero the rest.

    The deterministic sparsification half of a Top-K channel: the receiver
    reconstructs the dense tensor from (value, index) pairs, so the lossy
    roundtrip is exactly this masking."""
    flat = v.reshape(-1)
    k = min(k, flat.size)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return (flat * mask).reshape(v.shape)


def topk_sparsify_tree(tree: PyTree, *, fraction: float) -> PyTree:
    """Whole-message Top-K: keep the ceil(fraction * total_size) largest-magnitude
    entries across ALL leaves of the pytree (one message = one flat vector), so
    the encoded size is exactly k (index, value) pairs over the full dimension."""
    import math

    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])
    sparse = topk_sparsify(flat, k=max(1, math.ceil(fraction * flat.size)))
    out, off = [], 0
    for leaf in leaves:
        out.append(sparse[off : off + leaf.size].reshape(leaf.shape).astype(leaf.dtype))
        off += leaf.size
    return jax.tree.unflatten(treedef, out)
