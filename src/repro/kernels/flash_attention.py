"""Pallas TPU flash-attention kernel (q-blocked causal/windowed GQA).

Tiling: grid = (B * H, ceil(T / BLOCK_Q)). Each program holds one BLOCK_Q x hd
query tile in VMEM plus its kv-head's full (S, hd) K and V slabs (VMEM budget
= 2*S*hd*4 bytes; S<=2048 tiles at hd=128 are ~2 MiB — larger S is handled by
the pure-JAX online-softmax path in models/attention.py, which this kernel
mirrors numerically). The MXU sees (BLOCK_Q, hd) @ (hd, S) and
(BLOCK_Q, S) @ (S, hd) matmuls — both lane-aligned for hd, S multiples of 128.

GQA: query head h reads kv head h // (H // Hkv) via the K/V BlockSpec index
maps — no head replication in memory.

Used as the TPU fast path for short-S attention (local/sliding-window blocks);
validated in interpret mode against ref.py / models.attention oracles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  window: int | None, seq_len: int, block_q: int):
    iq = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale      # (bq, hd)
    k = k_ref[...].astype(jnp.float32)              # (S, hd)
    v = v_ref[...].astype(jnp.float32)              # (S, hd)
    s = q @ k.T                                     # (bq, S)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len                          # padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)  # noqa: E741 — flash-attn's row-sum name
    o_ref[...] = ((p @ v) / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q")
)
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q):
    """q (B,T,H,hd); k/v (B,S,Hkv,hd) -> (B,T,H,hd). S padded to 128 inside."""
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    g = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    pad_t = (-T) % block_q
    pad_s = (-S) % 128
    qp = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, 0))) if pad_t else q
    kp = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0))) if pad_s else k
    vp = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0))) if pad_s else v
    Tp, Sp = T + pad_t, S + pad_s

    qh = qp.transpose(0, 2, 1, 3).reshape(B * H, Tp, hd)
    kh = kp.transpose(0, 2, 1, 3).reshape(B * Hkv, Sp, hd)
    vh = vp.transpose(0, 2, 1, 3).reshape(B * Hkv, Sp, hd)

    grid = (B * H, Tp // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        seq_len=S, block_q=block_q,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, iq: (bh, iq, 0)),
            pl.BlockSpec((None, Sp, hd), lambda bh, iq, g=g: (bh // g, 0, 0)),
            pl.BlockSpec((None, Sp, hd), lambda bh, iq, g=g: (bh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda bh, iq: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tp, hd), q.dtype),
        interpret=_interpret(),
    )(qh, kh, vh)

    out = out.reshape(B, H, Tp, hd).transpose(0, 2, 1, 3)
    return out[:, :T]
