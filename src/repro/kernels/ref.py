"""Pure-jnp oracles for the Pallas kernels in this package.

QSGD (Alistarh et al., 2017) stochastic quantization, per-block:
  given a block v (size B) with L2 norm n = ||v||_2 and s levels,
  each entry i is encoded as sign(v_i) * q_i with
    p_i = |v_i| / n * s            (in [0, s])
    q_i = floor(p_i) + Bernoulli(p_i - floor(p_i))   (stochastic rounding)
  and decoded as  sign * q_i / s * n.
The stochastic rounding is driven by an explicit uniform tensor `u` so the
kernel and the oracle are bit-identical (and the kernel needs no on-chip RNG).
"""
from __future__ import annotations

import jax.numpy as jnp


def qsgd_quantize_blocks_ref(v: jnp.ndarray, u: jnp.ndarray, s: int):
    """v, u: (n_blocks, block) f32, u in [0,1). Returns (q int8 signed, norms f32).

    q carries the sign: q in [-s, s]. norms: (n_blocks,).
    """
    assert v.ndim == 2 and v.shape == u.shape
    norms = jnp.sqrt(jnp.sum(v * v, axis=1))  # (n_blocks,)
    safe = jnp.where(norms > 0, norms, 1.0)
    p = jnp.abs(v) / safe[:, None] * s
    q = jnp.floor(p + u)  # floor(p) + bernoulli(frac(p))  via shared uniform draw
    q = jnp.clip(q, 0, s)
    q = jnp.where(norms[:, None] > 0, q, 0.0)
    return (jnp.sign(v) * q).astype(jnp.int8), norms.astype(jnp.float32)


def qsgd_dequantize_blocks_ref(q: jnp.ndarray, norms: jnp.ndarray, s: int) -> jnp.ndarray:
    """Inverse map: (n_blocks, block) int8, (n_blocks,) f32 -> f32 blocks."""
    return q.astype(jnp.float32) * (norms[:, None] / s)


def weighted_aggregate_ref(grads: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Eq. (5) inner aggregation oracle: grads (n_clients, d), weights (n_clients,)
    -> (d,) gamma-weighted sum."""
    return jnp.einsum("n,nd->d", weights, grads)
