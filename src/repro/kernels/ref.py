"""Pure-jnp oracles for the Pallas kernels in this package.

QSGD (Alistarh et al., 2017) stochastic quantization, per-block:
  given a block v (size B) with L2 norm n = ||v||_2 and s levels,
  each entry i is encoded as sign(v_i) * q_i with
    p_i = |v_i| / n * s            (in [0, s])
    q_i = floor(p_i) + Bernoulli(p_i - floor(p_i))   (stochastic rounding)
  and decoded as  sign * q_i / s * n.
The stochastic rounding is driven by an explicit uniform tensor `u` so the
kernel and the oracle are bit-identical (and the kernel needs no on-chip RNG).

The packed wire format (what actually crosses a channel):
  * code  c = sign(v)*q + s  in [0, 2s]  — the sign is folded into the code,
    so one entry costs b = ceil(log2(2s+1)) bits (== 1 + ceil(log2(s+1)),
    the sign-bit + level-index count the accounting always claimed);
  * codes are bit-plane packed into uint32 words: with W = block/32 words
    per plane, word `j*W + w` of a block row holds bit j of the 32 codes
    {k*W + w : k in 0..31}, with code k*W+w's bit in bit position k.  The
    payload of an (n_blocks, block) code array is (n_blocks, b*W) uint32 —
    exactly b bits per entry, zero slack;
  * per-block L2 norms travel as an f32 sidecar (one word per block).
The interleaved entry->word map (stride W, not 32) keeps the pack reduction
over the *sublane* axis of a (rows, 32, W) reshape, so the lane axis of the
Pallas kernel is the word axis — the layout is chosen for the TPU, and the
oracles here define it bit-for-bit.
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def qsgd_code_bits(s: int) -> int:
    """Bits per packed QSGD entry: codes live in [0, 2s], sign included."""
    return max(1, math.ceil(math.log2(2 * s + 1)))


def qsgd_quantize_blocks_ref(v: jnp.ndarray, u: jnp.ndarray, s: int):
    """v, u: (n_blocks, block) f32, u in [0,1). Returns (q int8 signed, norms f32).

    q carries the sign: q in [-s, s]. norms: (n_blocks,).
    """
    assert v.ndim == 2 and v.shape == u.shape
    norms = jnp.sqrt(jnp.sum(v * v, axis=1))  # (n_blocks,)
    safe = jnp.where(norms > 0, norms, 1.0)
    p = jnp.abs(v) / safe[:, None] * s
    q = jnp.floor(p + u)  # floor(p) + bernoulli(frac(p))  via shared uniform draw
    q = jnp.clip(q, 0, s)
    q = jnp.where(norms[:, None] > 0, q, 0.0)
    return (jnp.sign(v) * q).astype(jnp.int8), norms.astype(jnp.float32)


def qsgd_dequantize_blocks_ref(q: jnp.ndarray, norms: jnp.ndarray, s: int) -> jnp.ndarray:
    """Inverse map: (n_blocks, block) int8, (n_blocks,) f32 -> f32 blocks."""
    return q.astype(jnp.float32) * (norms[:, None] / s)


def qsgd_quantize_codes_ref(v: jnp.ndarray, u: jnp.ndarray, s: int):
    """Sign-folded codes: (n_blocks, block) f32 -> (codes uint32 in [0, 2s],
    norms f32). code = sign(v)*q + s; zero-norm blocks emit the all-`s`
    (all-zero-valued) row."""
    q, norms = qsgd_quantize_blocks_ref(v, u, s)
    return (q.astype(jnp.int32) + s).astype(jnp.uint32), norms


def qsgd_dequantize_codes_ref(codes: jnp.ndarray, norms: jnp.ndarray, s: int) -> jnp.ndarray:
    """Inverse of the sign-folded map: c -> (c - s) * norm / s."""
    q = codes.astype(jnp.int32) - s
    return q.astype(jnp.float32) * (norms[:, None] / s)


def pack_codes_ref(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Bit-plane pack (naive double loop — the layout's definition).

    codes: (n_blocks, block) uint32, each < 2**bits, block % 32 == 0.
    Returns (n_blocks, bits * block/32) uint32, plane-major: plane j occupies
    words [j*W, (j+1)*W); word w of a plane packs bit j of codes
    {k*W + w : k in 0..31} with code k*W+w in bit position k.
    """
    nb, block = codes.shape
    assert block % 32 == 0, block
    w_per_plane = block // 32
    c = codes.astype(jnp.uint32).reshape(nb, 32, w_per_plane)
    planes = []
    for j in range(bits):
        word = jnp.zeros((nb, w_per_plane), jnp.uint32)
        for k in range(32):
            word = word | (((c[:, k, :] >> j) & 1) << k)
        planes.append(word)
    return jnp.concatenate(planes, axis=1)


def unpack_codes_ref(payload: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Exact inverse of `pack_codes_ref`: (n_blocks, bits*W) -> (n_blocks, 32*W)."""
    nb, total = payload.shape
    assert total % bits == 0, (total, bits)
    w_per_plane = total // bits
    c = jnp.zeros((nb, 32, w_per_plane), jnp.uint32)
    for j in range(bits):
        word = payload[:, j * w_per_plane : (j + 1) * w_per_plane]
        for k in range(32):
            c = c.at[:, k, :].set(c[:, k, :] | (((word >> k) & 1) << j))
    return c.reshape(nb, 32 * w_per_plane)


def signsgd_quantize_codes_ref(v: jnp.ndarray):
    """1-bit sign-SGD codes with per-block norm scaling: code 1 = non-negative,
    scale = mean |v| per block (the l1/n scaling of Bernstein et al.'s
    scaled signSGD). Returns (codes uint32 in {0,1}, scales f32)."""
    scales = jnp.mean(jnp.abs(v), axis=1)
    return (v >= 0).astype(jnp.uint32), scales.astype(jnp.float32)


def signsgd_dequantize_codes_ref(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Decode ±scale; all-zero blocks (scale 0) decode to exact zeros."""
    sign = codes.astype(jnp.float32) * 2.0 - 1.0
    return sign * scales[:, None]


def weighted_aggregate_ref(grads: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Eq. (5) inner aggregation oracle: grads (n_clients, d), weights (n_clients,)
    -> (d,) gamma-weighted sum."""
    return jnp.einsum("n,nd->d", weights, grads)
