"""Pallas TPU kernels for QSGD stochastic quantization / dequantization.

TPU adaptation notes (vs the CPU/GPU reference implementations of QSGD):
  * the quantizer is memory-bound (one read of v, one write of q) — the kernel
    tiles the (n_blocks, block) layout into VMEM tiles of ROWS_PER_TILE x block
    so each grid step streams a contiguous HBM slab through VMEM once;
  * block = 1024 keeps the lane dimension a multiple of 128 (VPU lane width)
    and the per-row reduction (the block L2 norm) a single-lane-axis reduce;
  * stochastic rounding consumes an explicit uniform tensor (generated
    outside — see `ops._cheap_uniform`) instead of on-chip RNG — keeps the kernel a pure
    function, bit-identical to ref.py, and validated under interpret=True.

The fused quantize→pack / unpack→dequantize pair emits/consumes the packed
uint32 wire format defined (bit-for-bit) by `ref.pack_codes_ref`: sign-folded
codes, bit-plane packed, b = ceil(log2(2s+1)) bits per entry.  The pack
reduction runs over the *sublane* axis of a (rows, 32, W) view — every word
sums 32 single-bit terms at distinct bit positions, so a uint32 add is an
exact bitwise OR — keeping the lane axis contiguous for the VPU.  `s` and
`bits` are static closure args (functools.partial), not scalar operands, so
the per-bit loop unrolls at trace time.

All wrappers accept any n_blocks: tail tiles are handled by host-side
pad-to-ROWS_PER_TILE + slice (padding rows are all-zero -> zero norms -> the
kernel's zero-norm guard makes them inert), so arbitrary model dims never
trip a grid assert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import qsgd_code_bits

ROWS_PER_TILE = 8  # 8 x 1024 f32 = 32 KiB per input tile; 4 tensors in flight << 16 MiB VMEM


def _auto_rows(n_blocks: int) -> int:
    """Tile height when the caller doesn't pin one: 8 rows (32 KiB tiles)
    keeps the tail-pad waste small for the many-small-leaves case; from 256
    blocks (1 MiB of input) up, 64-row tiles amortize the per-grid-step
    dispatch 8x while 4 tensors in flight still sit far under VMEM."""
    return 64 if n_blocks >= 256 else ROWS_PER_TILE


def _pad_rows(arrs, n_blocks: int, rows_per_tile: int):
    """Host-side tail-tile fix: zero-pad the leading (block-row) axis of every
    array to a multiple of rows_per_tile. Returns (padded arrays, padded rows)."""
    padded = ((n_blocks + rows_per_tile - 1) // rows_per_tile) * rows_per_tile
    if padded == n_blocks:
        return arrs, n_blocks
    out = [
        jnp.zeros((padded,) + a.shape[1:], a.dtype).at[:n_blocks].set(a) for a in arrs
    ]
    return out, padded


def _quantize_kernel(v_ref, u_ref, s_ref, q_ref, n_ref):
    v = v_ref[...]  # (rows, block) f32
    u = u_ref[...]
    s = s_ref[0]  # scalar f32 (levels)
    norms = jnp.sqrt(jnp.sum(v * v, axis=1))  # (rows,)
    safe = jnp.where(norms > 0, norms, 1.0)
    p = jnp.abs(v) / safe[:, None] * s
    q = jnp.clip(jnp.floor(p + u), 0.0, s)
    q = jnp.where(norms[:, None] > 0, q, 0.0)
    q_ref[...] = (jnp.sign(v) * q).astype(jnp.int8)
    n_ref[...] = norms.astype(jnp.float32)


def _dequantize_kernel(q_ref, n_ref, s_ref, v_ref):
    q = q_ref[...].astype(jnp.float32)
    norms = n_ref[...]
    s = s_ref[0]
    v_ref[...] = q * (norms[:, None] / s)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("s", "rows_per_tile"))
def qsgd_quantize_blocks(
    v: jnp.ndarray, u: jnp.ndarray, *, s: int, rows_per_tile: int | None = None
):
    """v, u: (n_blocks, block) f32 -> (q int8, norms f32). Any n_blocks."""
    n_blocks, block = v.shape
    rows_per_tile = rows_per_tile or _auto_rows(n_blocks)
    (v, u), padded = _pad_rows([v, u], n_blocks, rows_per_tile)
    grid = (padded // rows_per_tile,)
    s_arr = jnp.full((1,), float(s), jnp.float32)
    q, norms = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, block), jnp.int8),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=_interpret(),
    )(v, u, s_arr)
    return q[:n_blocks], norms[:n_blocks]


@functools.partial(jax.jit, static_argnames=("s", "rows_per_tile"))
def qsgd_dequantize_blocks(
    q: jnp.ndarray, norms: jnp.ndarray, *, s: int, rows_per_tile: int | None = None
):
    n_blocks, block = q.shape
    rows_per_tile = rows_per_tile or _auto_rows(n_blocks)
    (q, norms), padded = _pad_rows([q, norms], n_blocks, rows_per_tile)
    grid = (padded // rows_per_tile,)
    s_arr = jnp.full((1,), float(s), jnp.float32)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, block), jnp.float32),
        interpret=_interpret(),
    )(q, norms, s_arr)
    return out[:n_blocks]


# --------------------------------------------------------------------------
# fused quantize→bit-pack / unpack→dequantize (the packed wire format)
# --------------------------------------------------------------------------


def _pack_words(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(rows, block) uint32 codes -> (rows, bits * block/32) uint32 payload.

    Layout defined by `ref.pack_codes_ref`.  The (rows, 32, W) view puts the
    32 codes of a word on the sublane axis; each plane word is a 32-term sum
    of single bits at distinct positions (an exact OR in uint32 arithmetic).
    """
    rows, block = codes.shape
    w_per_plane = block // 32
    c = codes.reshape(rows, 32, w_per_plane)
    pos = jax.lax.broadcasted_iota(jnp.uint32, (rows, 32, w_per_plane), 1)
    planes = [
        jnp.sum(((c >> jnp.uint32(j)) & jnp.uint32(1)) << pos, axis=1, dtype=jnp.uint32)
        for j in range(bits)
    ]
    return jnp.concatenate(planes, axis=1)


def _unpack_words(payload: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Exact inverse of `_pack_words`: (rows, bits*W) uint32 -> (rows, 32*W)."""
    rows = payload.shape[0]
    w_per_plane = payload.shape[1] // bits
    pos = jax.lax.broadcasted_iota(jnp.uint32, (rows, 32, w_per_plane), 1)
    c = jnp.zeros((rows, 32, w_per_plane), jnp.uint32)
    for j in range(bits):
        word = jax.lax.slice_in_dim(payload, j * w_per_plane, (j + 1) * w_per_plane, axis=1)
        c = c | (((word[:, None, :] >> pos) & jnp.uint32(1)) << jnp.uint32(j))
    return c.reshape(rows, 32 * w_per_plane)


def _quantize_pack_kernel(v_ref, u_ref, payload_ref, n_ref, *, s: int, bits: int):
    v = v_ref[...]  # (rows, block) f32
    u = u_ref[...]
    norms = jnp.sqrt(jnp.sum(v * v, axis=1))
    safe = jnp.where(norms > 0, norms, 1.0)
    p = jnp.abs(v) / safe[:, None] * s
    q = jnp.clip(jnp.floor(p + u), 0.0, float(s))
    q = jnp.where(norms[:, None] > 0, q, 0.0)
    codes = (jnp.sign(v) * q + s).astype(jnp.uint32)  # sign-folded, in [0, 2s]
    payload_ref[...] = _pack_words(codes, bits)
    n_ref[...] = norms.astype(jnp.float32)


def _unpack_dequantize_kernel(payload_ref, n_ref, v_ref, *, s: int, bits: int):
    codes = _unpack_words(payload_ref[...], bits)
    q = codes.astype(jnp.int32) - s
    v_ref[...] = q.astype(jnp.float32) * (n_ref[...][:, None] / s)


@functools.partial(jax.jit, static_argnames=("s", "rows_per_tile"))
def qsgd_quantize_pack_blocks(
    v: jnp.ndarray, u: jnp.ndarray, *, s: int, rows_per_tile: int | None = None
):
    """Fused quantize + bit-pack: v, u (n_blocks, block) f32 ->
    (payload uint32 (n_blocks, bits*block/32), norms f32 (n_blocks,))."""
    n_blocks, block = v.shape
    rows_per_tile = rows_per_tile or _auto_rows(n_blocks)
    assert block % 32 == 0, block
    bits = qsgd_code_bits(s)
    words = bits * (block // 32)
    (v, u), padded = _pad_rows([v, u], n_blocks, rows_per_tile)
    grid = (padded // rows_per_tile,)
    payload, norms = pl.pallas_call(
        functools.partial(_quantize_pack_kernel, s=s, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_tile, words), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, words), jnp.uint32),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=_interpret(),
    )(v, u)
    return payload[:n_blocks], norms[:n_blocks]


@functools.partial(jax.jit, static_argnames=("s", "block", "rows_per_tile"))
def qsgd_unpack_dequantize_blocks(
    payload: jnp.ndarray,
    norms: jnp.ndarray,
    *,
    s: int,
    block: int,
    rows_per_tile: int | None = None,
):
    """Fused unpack + dequantize: (n_blocks, bits*block/32) uint32 payload +
    (n_blocks,) f32 norms -> (n_blocks, block) f32."""
    n_blocks = payload.shape[0]
    rows_per_tile = rows_per_tile or _auto_rows(n_blocks)
    bits = qsgd_code_bits(s)
    assert payload.shape[1] == bits * (block // 32), (payload.shape, bits, block)
    (payload, norms), padded = _pad_rows([payload, norms], n_blocks, rows_per_tile)
    grid = (padded // rows_per_tile,)
    out = pl.pallas_call(
        functools.partial(_unpack_dequantize_kernel, s=s, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, payload.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, block), jnp.float32),
        interpret=_interpret(),
    )(payload, norms)
    return out[:n_blocks]
