"""Pallas TPU kernels for QSGD stochastic quantization / dequantization.

TPU adaptation notes (vs the CPU/GPU reference implementations of QSGD):
  * the quantizer is memory-bound (one read of v, one write of q) — the kernel
    tiles the (n_blocks, block) layout into VMEM tiles of ROWS_PER_TILE x block
    so each grid step streams a contiguous HBM slab through VMEM once;
  * block = 1024 keeps the lane dimension a multiple of 128 (VPU lane width)
    and the per-row reduction (the block L2 norm) a single-lane-axis reduce;
  * stochastic rounding consumes an explicit uniform tensor (generated with
    jax.random outside) instead of on-chip RNG — keeps the kernel a pure
    function, bit-identical to ref.py, and validated under interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 8  # 8 x 1024 f32 = 32 KiB per input tile; 4 tensors in flight << 16 MiB VMEM


def _quantize_kernel(v_ref, u_ref, s_ref, q_ref, n_ref):
    v = v_ref[...]  # (rows, block) f32
    u = u_ref[...]
    s = s_ref[0]  # scalar f32 (levels)
    norms = jnp.sqrt(jnp.sum(v * v, axis=1))  # (rows,)
    safe = jnp.where(norms > 0, norms, 1.0)
    p = jnp.abs(v) / safe[:, None] * s
    q = jnp.clip(jnp.floor(p + u), 0.0, s)
    q = jnp.where(norms[:, None] > 0, q, 0.0)
    q_ref[...] = (jnp.sign(v) * q).astype(jnp.int8)
    n_ref[...] = norms.astype(jnp.float32)


def _dequantize_kernel(q_ref, n_ref, s_ref, v_ref):
    q = q_ref[...].astype(jnp.float32)
    norms = n_ref[...]
    s = s_ref[0]
    v_ref[...] = q * (norms[:, None] / s)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("s", "rows_per_tile"))
def qsgd_quantize_blocks(
    v: jnp.ndarray, u: jnp.ndarray, *, s: int, rows_per_tile: int = ROWS_PER_TILE
):
    """v, u: (n_blocks, block) f32 -> (q int8, norms f32). n_blocks % rows_per_tile == 0."""
    n_blocks, block = v.shape
    assert n_blocks % rows_per_tile == 0, (n_blocks, rows_per_tile)
    grid = (n_blocks // rows_per_tile,)
    s_arr = jnp.full((1,), float(s), jnp.float32)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=_interpret(),
    )(v, u, s_arr)


@functools.partial(jax.jit, static_argnames=("s", "rows_per_tile"))
def qsgd_dequantize_blocks(
    q: jnp.ndarray, norms: jnp.ndarray, *, s: int, rows_per_tile: int = ROWS_PER_TILE
):
    n_blocks, block = q.shape
    assert n_blocks % rows_per_tile == 0
    grid = (n_blocks // rows_per_tile,)
    s_arr = jnp.full((1,), float(s), jnp.float32)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
        interpret=_interpret(),
    )(q, norms, s_arr)
