"""Host-side span tracer: nested wall-clock spans over driver phases.

The tracer records ("B", name, ts) / ("E", name, ts) tuples in emission
order — Chrome-trace duration events.  Because spans are context managers
opened and closed on one host thread, emission order alone guarantees the
B/E pairs are well nested; `obs.export` re-emits them verbatim onto the
"host" track of the merged timeline.

Timestamps come from ``time.perf_counter()`` (monotonic, sub-µs), rebased
so the first event of a trace sits at t=0.  When ``profiler=True`` each
span additionally enters a ``jax.profiler.TraceAnnotation`` so the same
phase names show up inside a captured XLA profile — a passthrough only:
no profiler session is started here and the annotation is a no-op without
one.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field


@dataclass
class SpanTracer:
    """Collects nested host spans; cheap enough to leave on everywhere."""

    profiler: bool = False  # also emit jax.profiler.TraceAnnotation
    events: list[tuple[str, str, float]] = field(default_factory=list)
    _t0: float | None = None

    def _now(self) -> float:
        t = time.perf_counter()
        if self._t0 is None:
            self._t0 = t
        return t - self._t0

    @contextlib.contextmanager
    def span(self, name: str):
        ann = None
        if self.profiler:
            import jax.profiler

            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
        self.events.append(("B", name, self._now()))
        try:
            yield self
        finally:
            self.events.append(("E", name, self._now()))
            if ann is not None:
                ann.__exit__(None, None, None)

    def wall(self, name: str) -> float:
        """Total seconds spent inside spans called `name` (closed pairs)."""
        total, stack = 0.0, []
        for kind, n, ts in self.events:
            if n != name:
                continue
            if kind == "B":
                stack.append(ts)
            elif stack:
                total += ts - stack.pop()
        return total


def maybe_span(obs, name: str):
    """`obs.span(name)` when observability is on, else a no-op context.

    Drivers call this unconditionally; the `obs=None` fast path costs one
    `None` check per phase and touches no tracer state.
    """
    if obs is None:
        return contextlib.nullcontext()
    return obs.span(name)
