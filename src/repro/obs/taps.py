"""In-graph telemetry taps: pure-JAX training-health reductions.

These run *inside* the jitted round bodies (and inside `lax.scan`), so they
must be pure functions of tensors already present in the round — they add
new reduction ops that read existing values but never feed back into the
parameter/optimizer path, keeping the tapped graph's training outputs
bit-identical to the untapped one (pinned by tests/test_engine_parity.py).

Conventions: every tap returns a dict of f32 scalars (or (M,) per-cluster
vectors in multi-cluster mode) with keys

  update_norm — mean per-client L2 norm of the local update Δ_n
  drift       — client-drift dispersion, mean_n ‖Δ_n − Δ̄‖, where Δ̄ is
                the round's applied per-unit-weight aggregate when the
                engine provides it (see delta_taps) and the mean raw delta
                otherwise; the non-IID divergence signal Fed-CHS's
                sequential ES→ES pass is meant to tame
  comp_err    — L2 error the uplink channel injects into the APPLIED
                aggregate, ‖Σ_n γ_n (C(Δ_n) − Δ_n)‖ (0 for DenseChannel)
  mass        — effective participation mass: number of clients whose
                aggregation weight is nonzero this round
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_sq_norms(tree) -> jax.Array:
    """Per-client squared L2 norms: leaves have a leading client axis N."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(
        jnp.sum(jnp.reshape(x.astype(jnp.float32) ** 2, (x.shape[0], -1)), axis=1)
        for x in leaves
    )


def tree_client_norms(tree) -> jax.Array:
    """Per-client L2 norms over a stacked update pytree -> (N,)."""
    return jnp.sqrt(tree_sq_norms(tree))


def _flat_clients(tree) -> list[jax.Array]:
    """Leaves as f32 (n, d_leaf) matrices (leading client axis kept)."""
    return [jnp.reshape(x.astype(jnp.float32), (x.shape[0], -1))
            for x in jax.tree_util.tree_leaves(tree)]


def delta_taps(raw, applied, gammas, mask=None) -> dict[str, jax.Array]:
    """Taps for a delta-mode interaction: raw per-client deltas Δ_n, the
    interaction's APPLIED net update `applied` = new_params − params
    (= Σγ C(Δ_n) recovered from the scan carry, param-shaped, no client
    axis), and the aggregation weights γ_n (zero for non-participants).
    `mask` (n,) excludes padded / dropped-out slots from the means (their
    deltas are already exact zeros — without the mask they would dilute
    the health signals toward 0).

    The taps run inside the scanned hot loop, so both the tensors they
    read and every extra pass over the n×d client deltas are wall-clock
    the 10% overhead gate (benchmarks/run.py --json) charges us for:

    - the only per-client tree read is `raw` (materialised in the round
      regardless).  The channel output C(Δ_n) is deliberately NOT an
      input: reading it would force its dequantised form to materialise
      per interaction instead of fusing into the aggregation einsum, and
      reading the aggregate Σγ C(Δ_n) itself adds a consumer to the
      parameter-path einsum that shifts XLA's fusion choices by ~1 ulp,
      breaking the tapped==untapped bit-identity contract
      (tests/test_engine_parity.py).  `new_params − params` touches only
      scan-carry tensors, which are materialisation points already;
    - per-client squared norms and the γ-weighted raw sum R = Σγ Δ_n are
      elementwise sweeps (the client axis of R is a short unrolled FMA
      chain, not a reduction op) that fuse together;
    - drift centres on the applied per-unit-weight update Δ̄ = A / Σγ —
      arguably the more meaningful reference than the plain mean (how far
      do raw client updates disperse around the update the server
      actually applied) — via
      ‖Δ_n − Δ̄‖² = ‖Δ_n‖² − 2⟨Δ_n, A⟩/Σγ + ‖A‖²/Σγ², where the
      per-client inner products are `nd,d->n` matrix–vector einsums, the
      one contraction shape XLA:CPU lowers to a fast GEMV (batched
      `nd,nd->n` dots, `n×n` Gram matmuls, and the transposed `n,nd->d`
      weighted mean all lower to loops ~8× slower here, and a
      materialised centred copy of the deltas is worse still);
    - comp_err = ‖A − R‖ compares two d-sized vectors instead of taking a
      per-client mean over a materialised error tree.  Because A rides
      through the params carry, a lossless channel reads as a small
      floating-point residual (~ulp(params)) rather than an exact 0.

    Masked slots get garbage drift values (clamped at 0) but carry
    w_n = 0, so they never reach the output."""
    flat = _flat_clients(raw)
    n = flat[0].shape[0]
    sq = sum(jnp.sum(m * m, axis=1) for m in flat)
    if mask is None:
        mask = jnp.ones(sq.shape, sq.dtype)
    w = mask / jnp.maximum(jnp.sum(mask), 1.0)
    raw_agg = [sum(gammas[k] * m[k] for k in range(n)) for m in flat]
    agg_flat = [jnp.reshape(a.astype(jnp.float32), (-1,))
                for a in jax.tree_util.tree_leaves(applied)]
    ip_agg = sum(jnp.einsum("nd,d->n", m, a) for m, a in zip(flat, agg_flat))
    agg_sq = sum(jnp.einsum("d,d->", a, a) for a in agg_flat)
    denom = jnp.maximum(jnp.sum(gammas), jnp.finfo(jnp.float32).tiny)
    drift_sq = jnp.maximum(
        sq - 2.0 * ip_agg / denom + agg_sq / (denom * denom), 0.0)
    err_sq = sum(jnp.sum((a - r) ** 2)
                 for a, r in zip(agg_flat, raw_agg))
    return {
        "update_norm": jnp.sum(jnp.sqrt(sq) * w),
        "drift": jnp.sum(jnp.sqrt(drift_sq) * w),
        "comp_err": jnp.sqrt(err_sq),
        "mass": jnp.sum((gammas > 0).astype(jnp.float32)),
    }


def grad_taps(params, new_params, gammas) -> dict[str, jax.Array]:
    """Taps for grad-mode rounds (one SGD step, dense wire): the update is
    the whole-round parameter motion; there is no per-client delta or
    channel, so drift/comp_err are structurally zero."""
    step = jax.tree.map(lambda a, b: a - b, new_params, params)
    norm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(step)))
    zero = jnp.zeros((), jnp.float32)
    return {
        "update_norm": norm,
        "drift": zero,
        "comp_err": zero,
        "mass": jnp.sum((gammas > 0).astype(jnp.float32)),
    }
