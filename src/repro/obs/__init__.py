"""Unified run observability: in-graph telemetry taps, host span tracing,
and the merged Chrome-trace/Perfetto exporter.

Usage — pass a `RunTelemetry` to any driver config::

    from repro.obs import RunTelemetry
    obs = RunTelemetry()                      # taps + spans
    res = run_fed_chs(task, replace(cfg, obs=obs))
    res.telemetry is obs                      # attached to the RunResult

`obs=None` (the default everywhere) is the fast path: the compiled graphs,
scan bodies, and driver hot loops are byte-for-byte the current code — the
taps exist only as separately-cached jit variants (see core/engine.py).

Telemetry crosses to the host only at scan-chunk boundaries (the same
places losses already cross), so `transfer_guard("disallow")` holds on
the hot loop and scanned==looped parity is preserved.  By default the
crossing is LAZY: `record_stacked` stashes the stacked device arrays and
materializes them on first read, so the scanned driver keeps its
async-dispatch pipelining (the host stages chunk k+1 while the device is
still executing chunk k); `sync_chunks=True` restores the eager blocking
transfer so host spans measure real device execution per chunk.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.obs.export import (
    build_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
    write_metrics_jsonl,
)
from repro.obs.trace import SpanTracer, maybe_span

__all__ = [
    "RunTelemetry",
    "SpanTracer",
    "maybe_span",
    "build_chrome_trace",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_metrics_jsonl",
]


@dataclass
class RunTelemetry:
    """Carrier for one run's observability state.

    taps        — compute in-graph training-health metrics (update_norm,
                  drift, comp_err, mass) per round; False keeps spans only.
    profiler    — also wrap spans in jax.profiler.TraceAnnotation.
    sync_chunks — block on each chunk's tele transfer inside
                  `record_stacked`, so the enclosing scan_chunk span covers
                  the chunk's real device execution (accurate `--profile`
                  timelines).  False (default) defers materialization to
                  first read, keeping the scanned driver's async-dispatch
                  pipelining — this is what keeps tapped runs inside the
                  10% overhead gate (benchmarks/run.py --json).
    """

    taps: bool = True
    profiler: bool = False
    sync_chunks: bool = False
    tracer: SpanTracer = None  # type: ignore[assignment]
    _rounds: list[int] = field(default_factory=list, repr=False)
    _metrics: dict[str, list[Any]] = field(default_factory=dict, repr=False)
    _pending: list = field(default_factory=list, repr=False)

    def __post_init__(self):
        if self.tracer is None:
            self.tracer = SpanTracer(profiler=self.profiler)

    def span(self, name: str):
        return self.tracer.span(name)

    # -- tele ingestion ----------------------------------------------------
    @property
    def rounds(self) -> list[int]:
        """Round indices with recorded taps (flushes pending chunks)."""
        self._flush()
        return self._rounds

    @property
    def metrics(self) -> dict[str, list[Any]]:
        """Per-tap value lists aligned with `rounds` (flushes pending)."""
        self._flush()
        return self._metrics

    def _append(self, t: int, tele: dict) -> None:
        self._rounds.append(int(t))
        for k, v in tele.items():
            a = np.asarray(v)
            self._metrics.setdefault(k, []).append(
                float(a) if a.ndim == 0 else a.astype(np.float64))

    def _flush(self) -> None:
        while self._pending:
            rounds, tele = self._pending.pop(0)
            host = {k: np.asarray(v) for k, v in tele.items()}
            for i, t in enumerate(rounds):
                self._append(int(t), {k: v[i] for k, v in host.items()})

    def record_round(self, t: int, tele: dict) -> None:
        """One round's tele dict (looped drivers; device scalars fine)."""
        self._flush()
        self._append(t, tele)

    def record_stacked(self, rounds, tele: dict) -> None:
        """A chunk of stacked tele (scanned drivers): leaves have a leading
        round axis aligned with `rounds`.  Default: stash the device arrays
        and materialize lazily on first read, so the driver's dispatch loop
        never blocks here.  With `sync_chunks` the np.asarray happens
        inline — it blocks on the device, so the enclosing scan_chunk span
        covers the chunk's real execution time."""
        self._pending.append((list(rounds), dict(tele)))
        if self.sync_chunks:
            self._flush()

    # -- views -------------------------------------------------------------
    def metrics_rows(self) -> list[dict]:
        """One flat dict per recorded round (JSONL-ready)."""
        rows = []
        for i, t in enumerate(self.rounds):
            row: dict[str, Any] = {"round": t}
            for k, vs in self.metrics.items():
                v = vs[i]
                row[k] = v.tolist() if isinstance(v, np.ndarray) else v
            rows.append(row)
        return rows

    def summary(self) -> dict:
        """Per-metric mean/max over the run (scalarizing vector taps)."""
        out: dict[str, dict[str, float]] = {}
        for k, vs in self.metrics.items():
            flat = np.concatenate([np.atleast_1d(np.asarray(v, np.float64))
                                   for v in vs]) if vs else np.zeros(0)
            if flat.size:
                out[k] = {"mean": float(flat.mean()), "max": float(flat.max())}
        return {"rounds": len(self.rounds), "metrics": out,
                "spans": {name: self.tracer.wall(name)
                          for _, name, _ in self.tracer.events
                          if name}}
