"""Merged Chrome-trace/Perfetto export: host spans + comm events + netsim.

One run produces three streams of timed facts that previously lived in three
disconnected places:

  * host spans      — `SpanTracer` B/E pairs over driver phases (precompute,
                      stage, scan_chunk, round, eval, materialize): REAL
                      wall-clock of the simulation process;
  * comm events     — the `CommLedger`'s structured `CommEvent` stream: every
                      metered message of the protocol (no time of its own);
  * netsim timeline — `repro.netsim` job DAG replay: SIMULATED wall-clock of
                      the deployment (compute/transfer jobs on links/nodes).

`build_chrome_trace` merges them into one Chrome-trace JSON ("traceEvents"
array, ts/dur in µs) loadable in Perfetto (ui.perfetto.dev) or
chrome://tracing.  The three streams keep separate pids — the host clock and
the simulated clock are *different clocks* and must not be compared across
tracks:

  pid 1 "host"    — B/E duration events, µs of real time since the tracer's
                    first event;
  pid 2 "comm"    — one instant ("i") per CommEvent, one tid per hop.  With a
                    netsim replay supplied, each event is FIFO-matched to the
                    transfer job that carried it (via `CommLedger.event_index`
                    keyed (round, hop, "sender->receiver"), the same key the
                    adapters pin jobs to) and lands at that job's simulated
                    finish time; unmatched events (e.g. uploads a deadline
                    dropped) land at their round's end.  Without a replay, a
                    synthetic stream-order clock is used;
  pid 3 "netsim"  — one X (complete) event per simulated job, one tid per
                    resource, plus "dropped:<client>" instants from
                    `Timeline.dropped` and a per-round drop-count counter.

`validate_chrome_trace` checks the invariants CI's obs-smoke job enforces:
parseable structure, monotonic timestamps per track, matched B/E pairs, and
(optionally) comm-instant count == ledger event count.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Any

__all__ = [
    "build_chrome_trace",
    "write_chrome_trace",
    "write_metrics_jsonl",
    "validate_chrome_trace",
]

_S_TO_US = 1e6


def _meta(pid: int, name: str) -> dict:
    return {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": name}}


def _host_events(tracer) -> list[dict]:
    return [
        {"ph": kind, "pid": 1, "tid": "driver", "name": name,
         "ts": ts * _S_TO_US, "cat": "host"}
        for kind, name, ts in tracer.events
    ]


def _job_queues(jobs) -> dict[tuple, list]:
    """Transfer jobs grouped by the adapters' (round, hop, resource) key, in
    build order — mirrors `CommLedger.event_index` so zip() FIFO-matches."""
    queues: dict[tuple, list] = defaultdict(list)
    for j in jobs:
        if j.kind == "transfer" and j.resource is not None:
            queues[(j.round, j.label, j.resource)].append(j)
    return queues


def _comm_events(ledger, jobs=None, timeline=None) -> list[dict]:
    events = ledger.events
    ts_of = [float(i) for i in range(len(events))]  # synthetic fallback clock
    if jobs is not None and timeline is not None:
        queues = _job_queues(jobs)
        for key, positions in ledger.event_index().items():
            matched = queues.get(key, [])
            for pos, job in zip(positions, matched):
                ts_of[pos] = timeline.job_times[job.job_id][1] * _S_TO_US
            for pos in positions[len(matched):]:  # e.g. deadline-dropped uploads
                r = events[pos].round
                ts_of[pos] = timeline.round_end.get(r, timeline.makespan) * _S_TO_US
    out = [
        {"ph": "i", "pid": 2, "tid": ev.hop, "s": "t", "cat": "comm",
         "name": f"{ev.sender}->{ev.receiver}", "ts": ts_of[i],
         "args": {"round": ev.round, "phase": ev.phase, "bits": ev.n_bits}}
        for i, ev in enumerate(events)
    ]
    out.sort(key=lambda e: (e["tid"], e["ts"]))
    return out


def _netsim_events(jobs, timeline) -> list[dict]:
    out = []
    for j in jobs:
        start, finish = timeline.job_times[j.job_id]
        out.append({
            "ph": "X", "pid": 3, "tid": j.resource or f"({j.kind})",
            "name": f"{j.label}@r{j.round}", "cat": "netsim",
            "ts": start * _S_TO_US, "dur": (finish - start) * _S_TO_US,
            "args": {"round": j.round, "kind": j.kind, "tracked": j.tracked},
        })
    for r, clients in sorted(timeline.dropped.items()):
        ts = timeline.round_end.get(r, timeline.makespan) * _S_TO_US
        for c in sorted(clients):
            out.append({"ph": "i", "pid": 3, "tid": "dropped", "s": "t",
                        "name": f"dropped:{c}", "cat": "netsim",
                        "ts": ts, "args": {"round": r}})
    for r, n in sorted(timeline.drop_counts().items()):
        out.append({"ph": "C", "pid": 3, "tid": "drops", "name": "dropped_clients",
                    "ts": timeline.round_end.get(r, timeline.makespan) * _S_TO_US,
                    "args": {"count": n}})
    # emission order == schedule order per track (the simulator may run jobs
    # out of build order across resources)
    out.sort(key=lambda e: (str(e["tid"]), e["ts"]))
    return out


def build_chrome_trace(obs=None, ledger=None, jobs=None,
                       timeline=None) -> dict[str, Any]:
    """Merge whichever streams the caller has into one Chrome-trace dict.

    All arguments optional: pass `obs` (a `RunTelemetry`) for the host
    track, `ledger` for the comm track, and a `(jobs, timeline)` pair from
    `netsim.replay_run` for the netsim track (which also time-anchors the
    comm instants)."""
    trace_events: list[dict] = []
    if obs is not None:
        trace_events.append(_meta(1, "host (real wall-clock)"))
        trace_events += _host_events(obs.tracer)
    if ledger is not None and ledger.events:
        trace_events.append(_meta(2, "comm (CommLedger events)"))
        trace_events += _comm_events(ledger, jobs, timeline)
    if jobs is not None and timeline is not None:
        trace_events.append(_meta(3, "netsim (simulated deployment)"))
        trace_events += _netsim_events(jobs, timeline)
    meta: dict[str, Any] = {}
    if timeline is not None:
        meta = {"makespan_s": timeline.makespan,
                "dropped_bits": timeline.dropped_bits,
                "drop_counts": {str(r): n
                                for r, n in timeline.drop_counts().items()}}
    return {"traceEvents": trace_events, "displayTimeUnit": "ms",
            "otherData": meta}


def write_chrome_trace(trace: dict, path) -> None:
    with open(path, "w") as f:
        json.dump(trace, f)


def write_metrics_jsonl(obs, path) -> int:
    """Flat per-round telemetry rows as JSONL; returns the row count."""
    rows = obs.metrics_rows()
    with open(path, "w") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    return len(rows)


def validate_chrome_trace(trace: dict,
                          expected_comm_events: int | None = None) -> list[str]:
    """Structural invariants of a merged trace; returns problems (empty ==
    valid).  Checked: traceEvents list present, every event has a ts >= 0,
    per-(pid, tid) timestamps monotonic non-decreasing, B/E pairs matched
    and well nested per track, X durations non-negative, and — when
    `expected_comm_events` is given — exactly that many comm instants."""
    problems: list[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    last_ts: dict[tuple, float] = {}
    stacks: dict[tuple, list[str]] = defaultdict(list)
    n_comm = 0
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        key = (ev.get("pid"), ev.get("tid"))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if ts < last_ts.get(key, 0.0):
            problems.append(
                f"event {i}: ts {ts} < {last_ts[key]} on track {key}")
        last_ts[key] = ts
        if ph == "B":
            stacks[key].append(ev.get("name", ""))
        elif ph == "E":
            if not stacks[key]:
                problems.append(f"event {i}: E without B on track {key}")
            elif stacks[key][-1] != ev.get("name", ""):
                problems.append(
                    f"event {i}: E {ev.get('name')!r} closes "
                    f"B {stacks[key][-1]!r} on track {key}")
            else:
                stacks[key].pop()
        elif ph == "X":
            if ev.get("dur", 0) < 0:
                problems.append(f"event {i}: negative dur")
        elif ph == "i" and ev.get("cat") == "comm":
            n_comm += 1
    for key, stack in stacks.items():
        if stack:
            problems.append(f"unclosed B events {stack} on track {key}")
    if expected_comm_events is not None and n_comm != expected_comm_events:
        problems.append(
            f"comm instants {n_comm} != ledger events {expected_comm_events}")
    return problems
