from repro.data.synthetic import make_dataset, DATASETS, Dataset
from repro.data.partition import dirichlet_partition, assign_clusters, ClientData
from repro.data.loader import ClientLoader, batch_iterator
from repro.data.sources import ArraySource, DataSource, TokenSource
from repro.data.tokens import synthetic_lm_batch

__all__ = [
    "make_dataset",
    "DATASETS",
    "Dataset",
    "dirichlet_partition",
    "assign_clusters",
    "ClientData",
    "ClientLoader",
    "batch_iterator",
    "DataSource",
    "ArraySource",
    "TokenSource",
    "synthetic_lm_batch",
]
