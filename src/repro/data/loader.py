"""Per-client mini-batch sampling (ξ_{n,k} in Eq. 5)."""
from __future__ import annotations

import numpy as np

from repro.data.partition import ClientData
from repro.data.synthetic import Dataset


class ClientLoader:
    """Stateful sampler of random mini-batches ξ ⊆ D_n for one client."""

    def __init__(self, dataset: Dataset, client: ClientData, batch_size: int, *, seed: int = 0):
        assert client.size > 0, f"client {client.client_id} has no data"
        self.dataset = dataset
        self.client = client
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed + 7919 * client.client_id)

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        # Every batch is exactly batch_size so cluster batches stack for the
        # vmapped Eq. (5) aggregation; clients whose Dirichlet shard is
        # smaller than a batch sample with replacement (still a valid random
        # xi_{n,k} subset draw).
        idx = self.next_indices()
        return self.dataset.train_x[idx], self.dataset.train_y[idx]

    def next_indices(self, count: int = 1) -> np.ndarray:
        """Draw `count` batches' worth of sample indices, (count*B,) flat.

        Issues exactly `count` sequential `rng.choice` calls — the same rng
        state evolution as `count` `next_batch` calls — but defers the (much
        more expensive) dataset gather to the caller, which can fetch every
        staged batch of a whole scan chunk with one fancy-index read."""
        replace = self.client.size < self.batch_size
        draws = [
            self.rng.choice(self.client.indices, size=self.batch_size, replace=replace)
            for _ in range(count)
        ]
        return draws[0] if count == 1 else np.concatenate(draws)

    @property
    def num_samples(self) -> int:
        return self.client.size


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int):
    """Deterministic full pass (used for test-set evaluation)."""
    for i in range(0, len(x), batch_size):
        yield x[i : i + batch_size], y[i : i + batch_size]
