"""Synthetic LM token pipeline for the assigned-architecture substrate.

Generates structured (not uniform-random) token streams so that ~100M-scale
training in examples/ actually reduces loss: a first-order Markov chain over
the vocabulary with a small number of latent "topics".
"""
from __future__ import annotations

import numpy as np


def _markov_tables(vocab: int, topics: int, branch: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(topics, vocab, branch), dtype=np.int64)
    return succ


class MarkovTokens:
    def __init__(self, vocab_size: int, *, topics: int = 8, branch: int = 4, seed: int = 0):
        self.vocab = vocab_size
        self.succ = _markov_tables(vocab_size, topics, branch, seed)
        self.topics = topics
        self.branch = branch

    def sample(self, rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
        topic = rng.integers(0, self.topics, size=batch)
        return self.sample_topics(rng, topic, seq_len)

    def sample_topics(self, rng: np.random.Generator, topic: np.ndarray, seq_len: int
                      ) -> np.ndarray:
        """Walk the chain with a *given* per-row topic assignment — the hook
        non-IID federated sources use to skew each client's topic mixture."""
        batch = len(topic)
        out = np.empty((batch, seq_len), dtype=np.int32)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        choices = rng.integers(0, self.branch, size=(batch, seq_len))
        for t in range(1, seq_len):
            out[:, t] = self.succ[topic, out[:, t - 1], choices[:, t]]
        return out


def synthetic_lm_batch(
    vocab_size: int, batch: int, seq_len: int, *, seed: int = 0
) -> dict[str, np.ndarray]:
    """One (tokens, labels) LM batch; labels are next-token shifted."""
    gen = MarkovTokens(min(vocab_size, 32_768), seed=seed)
    rng = np.random.default_rng(seed)
    toks = gen.sample(rng, batch, seq_len + 1) % vocab_size
    return {"tokens": toks[:, :-1].astype(np.int32), "labels": toks[:, 1:].astype(np.int32)}
