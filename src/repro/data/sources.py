"""DataSource — per-client batch staging, generic over the batch pytree.

`FLTask` stages whole rounds of per-client batches for the engine's fused
scan; a `DataSource` is where those batches come from.  Each call to
`next_batch(client)` yields one mini-batch *pytree* of numpy arrays (the
classification sources yield ``{"x", "y"}``, the token source yields
``{"tokens", "labels"}``), and `eval_data()` yields whatever the task's
`FedModel.eval_metric` consumes — so the same drivers score accuracy for
MLP/LeNet and perplexity for a transformer LM.

Two sources ship here:

  * `ArraySource` — wraps the classification stack (`Dataset` + Dirichlet
    `ClientData` shards + `ClientLoader`).  Its per-client rng seeding and
    draw order are exactly the pre-FedTask `FLTask` internals, so fixed-seed
    classifier trajectories are bit-identical.
  * `TokenSource` — per-client non-IID Markov token streams over one shared
    transition table set; client n's batches concentrate on its dominant
    topic (label-skew's LM analogue).  Every draw is keyed by
    ``(seed, client, draw_index)`` — the stream position is explicit state,
    not a hidden generator, so resuming a run mid-way replays the exact
    batches instead of silently resampling from draw 0.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import numpy as np

from repro.data.loader import ClientLoader
from repro.data.partition import ClientData
from repro.data.synthetic import Dataset
from repro.data.tokens import MarkovTokens

Batch = Any  # pytree of numpy arrays with matching leading (B, ...) axes


@runtime_checkable
class DataSource(Protocol):
    """Per-client batch supply + held-out eval data for one FL experiment."""

    num_clients: int
    batch_size: int
    client_sizes: np.ndarray  # per-client dataset sizes (gamma weights)

    def reset(self, seed: int) -> None:
        """Rewind every client's stream (same-seed runs must be identical)."""
        ...

    def next_batch(self, client: int) -> Batch:
        """The client's next mini-batch pytree (numpy leaves)."""
        ...

    def eval_data(self) -> Any:
        """Held-out data in whatever form the task's FedModel evaluates."""
        ...


def scatter_put(index, reshape):
    """A `stage_chunk` scatter: writes one client's reshaped draw stack into
    the chunk buffer at a fixed fancy index, leaf-wise."""

    def put(batch: Batch, draws: Batch) -> None:
        jax.tree.map(lambda bl, dl: bl.__setitem__(index, reshape(dl)), batch, draws)

    return put


def stage_chunk(source: DataSource, plan, alloc) -> Batch:
    """Bulk-stage one scan chunk of per-client batches.

    `plan` is an iterable of ``(client, count, put)``: each client's `count`
    draws are fetched with ONE `bulk_batches` read and scattered into the
    chunk buffer by ``put(batch, draws)`` (see `scatter_put`).  The buffer is
    allocated lazily from the first draws — ``alloc(leaf) -> shape`` gives
    each zero-filled leaf's full chunk shape.  This is the one implementation
    of the alloc-on-first-draw + fancy-index scatter pattern all four scanned
    drivers stage through; returns None for an empty plan.
    """
    batch = None
    for client, count, put in plan:
        draws = bulk_batches(source, client, count)
        if batch is None:
            batch = jax.tree.map(lambda a: np.zeros(alloc(a), a.dtype), draws)
        put(batch, draws)
    return batch


def put_sharded(batch: Batch, shardings) -> Batch:
    """Move a staged host pytree to the device mesh, leaf-wise.

    `shardings` mirrors `batch` with a (Named)Sharding per leaf.  jax slices
    each host (numpy) leaf per shard before transfer, so the global stacked
    (chunk, clusters, clients, B, ...) tensor is never materialized on any
    single device — each device receives exactly its client/cluster window.
    This is the staged-gather counterpart of `bulk_batches`: bulk staging
    keeps the HOST work off the Python floor, `put_sharded` keeps the DEVICE
    footprint per-shard.  The sharded scan path installs it as
    `ScanPlan.xs_put`; the default path keeps plain `jax.device_put`."""
    return jax.device_put(batch, shardings)


def bulk_batches(source: DataSource, client: int, count: int) -> Batch:
    """`count` sequential draws for one client, stacked (count, B, ...).

    Uses the source's vectorized `next_batches` when it has one (ArraySource:
    one dataset gather for the whole chunk) and falls back to stacking
    `next_batch` calls otherwise — either way the per-client draw sequence is
    exactly what `count` incremental `next_batch` calls would return, so
    scanned-driver chunk staging is bit-identical to looped per-round
    staging."""
    fast = getattr(source, "next_batches", None)
    if fast is not None:
        return fast(client, count)
    batches = [source.next_batch(client) for _ in range(count)]
    return jax.tree.map(lambda *leaves: np.stack(leaves), *batches)


class ArraySource:
    """Classification batches from a `Dataset` + per-client index shards."""

    def __init__(self, dataset: Dataset, clients: list[ClientData], batch_size: int,
                 *, seed: int = 0):
        self.dataset = dataset
        self.clients = clients
        self.batch_size = batch_size
        self.num_clients = len(clients)
        self.client_sizes = np.array([c.size for c in clients], dtype=np.float64)
        self.reset(seed)

    def reset(self, seed: int) -> None:
        self.loaders = [
            ClientLoader(self.dataset, c, self.batch_size, seed=seed) for c in self.clients
        ]
        self.draw_counts = [0] * self.num_clients

    def fast_forward(self, draw_counts: list[int]) -> None:
        """Resume mid-run: advance each client's rng stream to an absolute
        batch-draw position by drawing and discarding indices — the generator
        state after `fast_forward([n, ...])` is bit-identical to `n` live
        draws, so a resumed run re-issues the exact remaining batches."""
        assert len(draw_counts) == self.num_clients
        for c, n in enumerate(draw_counts):
            delta = int(n) - self.draw_counts[c]
            assert delta >= 0, (
                f"client {c}: cannot rewind an rng stream "
                f"({self.draw_counts[c]} -> {n}); reset() first"
            )
            if delta:
                self.loaders[c].next_indices(delta)
                self.draw_counts[c] = int(n)

    def next_batch(self, client: int) -> Batch:
        self.draw_counts[client] += 1
        x, y = self.loaders[client].next_batch()
        return {"x": x, "y": y}

    def next_batches(self, client: int, count: int) -> Batch:
        """`count` sequential draws as stacked (count, B, ...) leaves.

        Bit-identical to `count` `next_batch` calls (same per-call rng state
        evolution — see `ClientLoader.next_indices`) but pays ONE dataset
        gather instead of `count`, which is what keeps the scanned drivers'
        chunk staging off the Python floor."""
        self.draw_counts[client] += count
        idx = self.loaders[client].next_indices(count).reshape(count, self.batch_size)
        return {"x": self.dataset.train_x[idx], "y": self.dataset.train_y[idx]}

    def eval_data(self) -> Dataset:
        return self.dataset


class TokenSource:
    """Non-IID LM batches: per-client topic-skewed Markov token streams.

    All clients share one transition-table set (`tables_seed`); client n's
    rows carry its dominant topic ``n % topics`` with probability
    `dominance`, the rest spread uniformly.  `eval_data()` is a fixed,
    seed-independent stack of uniform-mixture batches (leading eval-batch
    axis), so the perplexity metric is comparable across runs and seeds.
    """

    def __init__(self, vocab_size: int, num_clients: int, batch_size: int, seq_len: int,
                 *, topics: int = 4, branch: int = 4, dominance: float = 0.9,
                 tables_seed: int = 0, seed: int = 0, eval_batches: int = 4):
        assert topics >= 1 and 0.0 <= dominance <= 1.0
        self.vocab = vocab_size
        self.num_clients = num_clients
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.gen = MarkovTokens(vocab_size, topics=topics, branch=branch, seed=tables_seed)
        self.client_sizes = np.ones(num_clients, dtype=np.float64)
        off = (1.0 - dominance) / max(topics - 1, 1) if topics > 1 else 0.0
        self.topic_probs = np.full((num_clients, topics), off)
        for n in range(num_clients):
            self.topic_probs[n, n % topics] = dominance if topics > 1 else 1.0
        self._eval = self._make_eval(tables_seed, eval_batches)
        self.reset(seed)

    def _make_eval(self, tables_seed: int, eval_batches: int) -> Batch:
        rng = np.random.default_rng((tables_seed, 0x7EA1))
        toks = np.stack([
            self.gen.sample(rng, self.batch_size, self.seq_len + 1)
            for _ in range(eval_batches)
        ])  # (n_eval, B, T+1)
        return {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}

    def reset(self, seed: int) -> None:
        self.seed = seed
        self.draw_counts = [0] * self.num_clients

    def fast_forward(self, draw_counts: list[int]) -> None:
        """Resume mid-run: set each client's stream position explicitly."""
        assert len(draw_counts) == self.num_clients
        self.draw_counts = list(draw_counts)

    def next_batch(self, client: int) -> Batch:
        idx = self.draw_counts[client]
        self.draw_counts[client] = idx + 1
        # pure function of (seed, client, draw index): no hidden generator
        # state, so a resumed run re-issues the exact same batches
        rng = np.random.default_rng((self.seed, client, idx))
        topic = rng.choice(len(self.topic_probs[client]), size=self.batch_size,
                           p=self.topic_probs[client])
        toks = self.gen.sample_topics(rng, topic, self.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def eval_data(self) -> Batch:
        return self._eval
