"""Non-IID client partitioning.

Paper §5.1: "the label distribution on each device follows the Dirichlet
distribution with λ > 0 being a concentration parameter". We implement the
standard Dirichlet label-skew partitioner, plus the paper's Appendix-B
*partial heterogeneity* mode (Fig. 4): data distribution is IID **across
clusters** but non-IID across clients **within** every cluster.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClientData:
    """Index-based view into a dataset for one client."""

    client_id: int
    indices: np.ndarray  # int64 indices into the train split

    @property
    def size(self) -> int:
        return int(len(self.indices))


def dirichlet_partition(
    labels: np.ndarray,
    num_clients: int,
    alpha: float,
    *,
    seed: int = 0,
    min_size: int = 2,
) -> list[ClientData]:
    """Dirichlet(alpha) label-skew partition of `labels` into `num_clients`.

    For each class c, the class's samples are split across clients with
    proportions ~ Dirichlet(alpha * 1_N). Retries until every client has at
    least `min_size` samples (standard practice, e.g. Li et al. 2022).
    """
    assert alpha > 0 and num_clients >= 1
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    n = len(labels)
    for _attempt in range(100):
        idx_per_client: list[list[np.ndarray]] = [[] for _ in range(num_clients)]
        for c in range(num_classes):
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(num_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for client, chunk in enumerate(np.split(idx_c, cuts)):
                idx_per_client[client].append(chunk)
        sizes = [sum(len(ch) for ch in chunks) for chunks in idx_per_client]
        if min(sizes) >= min_size or n < num_clients * min_size:
            break
    clients = []
    for cid, chunks in enumerate(idx_per_client):
        idx = np.concatenate(chunks) if chunks else np.empty((0,), dtype=np.int64)
        rng.shuffle(idx)
        clients.append(ClientData(cid, idx.astype(np.int64)))
    return clients


def iid_partition(labels: np.ndarray, num_clients: int, *, seed: int = 0) -> list[ClientData]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels)).astype(np.int64)
    return [ClientData(cid, chunk) for cid, chunk in enumerate(np.array_split(idx, num_clients))]


def assign_clusters(num_clients: int, num_clusters: int, *, seed: int = 0) -> list[list[int]]:
    """Assign clients to clusters (ESs) — roughly equal-sized random clusters,
    matching the paper's 100 clients / 10 ES setup."""
    assert 1 <= num_clusters <= num_clients
    rng = np.random.default_rng(seed)
    order = rng.permutation(num_clients)
    return [sorted(int(c) for c in chunk) for chunk in np.array_split(order, num_clusters)]


def partial_heterogeneity_partition(
    labels: np.ndarray,
    num_clients: int,
    num_clusters: int,
    alpha: float,
    *,
    seed: int = 0,
) -> tuple[list[ClientData], list[list[int]]]:
    """Fig. 4 mode: clusters are IID copies of the global distribution; clients
    *within* a cluster are Dirichlet(alpha) non-IID over the cluster's shard."""
    rng = np.random.default_rng(seed)
    cluster_members = assign_clusters(num_clients, num_clusters, seed=seed)
    # IID split across clusters
    global_idx = rng.permutation(len(labels)).astype(np.int64)
    cluster_shards = np.array_split(global_idx, num_clusters)
    clients: list[ClientData | None] = [None] * num_clients
    for m, (members, shard) in enumerate(zip(cluster_members, cluster_shards)):
        sub = dirichlet_partition(labels[shard], len(members), alpha, seed=seed + 1000 + m)
        for local, cid in enumerate(members):
            clients[cid] = ClientData(cid, shard[sub[local].indices])
    return [c for c in clients if c is not None], cluster_members


def label_histogram(labels: np.ndarray, clients: list[ClientData], num_classes: int) -> np.ndarray:
    hist = np.zeros((len(clients), num_classes), dtype=np.int64)
    for c in clients:
        np.add.at(hist[c.client_id], labels[c.indices], 1)
    return hist
