"""Shape-faithful synthetic stand-ins for MNIST / CIFAR-10 / CIFAR-100.

The container has no network access, so we plant a learnable structure:
each class c has a smooth prototype image P_c; a sample is
x = clip(P_c + Gaussian noise). This keeps the paper's experimental axes
(dataset shapes, class counts, Dirichlet(λ) label skew, model families)
intact — only absolute accuracy values differ from the real datasets,
which DESIGN.md §6 records as a deviation.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    image_shape: tuple[int, int, int]  # H, W, C
    num_classes: int
    train_size: int
    test_size: int


DATASETS = {
    "mnist": DatasetSpec("mnist", (28, 28, 1), 10, 60_000, 10_000),
    "cifar10": DatasetSpec("cifar10", (32, 32, 3), 10, 50_000, 10_000),
    "cifar100": DatasetSpec("cifar100", (32, 32, 3), 100, 50_000, 10_000),
}


@dataclasses.dataclass
class Dataset:
    spec: DatasetSpec
    train_x: np.ndarray  # (N, H, W, C) float32 in [0, 1]
    train_y: np.ndarray  # (N,) int32
    test_x: np.ndarray
    test_y: np.ndarray


def _smooth_prototypes(rng: np.random.Generator, spec: DatasetSpec) -> np.ndarray:
    """Low-frequency class prototypes: random coefficients over a coarse 2-D
    cosine basis, so classes are separable but overlapping under noise."""
    h, w, c = spec.image_shape
    n_basis = 4
    ys = np.arange(h)[:, None] / h
    xs = np.arange(w)[None, :] / w
    basis = np.stack(
        [
            np.cos(np.pi * ky * ys) * np.cos(np.pi * kx * xs)
            for ky in range(n_basis)
            for kx in range(n_basis)
        ]
    )  # (n_basis^2, H, W)
    coef = rng.normal(size=(spec.num_classes, c, n_basis * n_basis))
    protos = np.einsum("kcb,bhw->khwc", coef, basis)
    # normalize to [0.2, 0.8] per class
    protos = protos - protos.min(axis=(1, 2, 3), keepdims=True)
    protos = protos / (protos.max(axis=(1, 2, 3), keepdims=True) + 1e-8)
    return (0.2 + 0.6 * protos).astype(np.float32)


def make_dataset(
    name: str,
    *,
    train_size: int | None = None,
    test_size: int | None = None,
    noise: float = 0.35,
    seed: int = 0,
) -> Dataset:
    spec = DATASETS[name]
    n_train = train_size if train_size is not None else spec.train_size
    n_test = test_size if test_size is not None else spec.test_size
    rng = np.random.default_rng(seed)
    protos = _smooth_prototypes(rng, spec)

    def sample(n: int) -> tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, spec.num_classes, size=n).astype(np.int32)
        x = protos[y] + rng.normal(scale=noise, size=(n, *spec.image_shape)).astype(np.float32)
        return np.clip(x, 0.0, 1.0).astype(np.float32), y

    train_x, train_y = sample(n_train)
    test_x, test_y = sample(n_test)
    return Dataset(spec, train_x, train_y, test_x, test_y)
