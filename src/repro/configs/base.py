"""Architecture config schema for the assigned model pool.

One frozen dataclass covers all six families (dense / moe / ssm / hybrid /
audio / vlm); family-specific fields default to "off". Every concrete config in
this package cites its source model card / paper in its docstring, and provides
a `smoke()` reduced variant (<=2 layers, d_model<=512, <=4 experts) used by the
per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2/V3 Multi-head Latent Attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // num_heads

    # attention flavor
    qkv_bias: bool = False               # qwen1.5
    qk_norm: bool = False                # qwen3
    rope_theta: float = 10_000.0
    sliding_window: int | None = None    # local/sliding-window attention width
    # per-layer block pattern, cycled: entries in {"attn", "local", "rglru", "ssd"}
    block_pattern: tuple[str, ...] = ("attn",)

    # FFN / MoE
    act: str = "silu"                    # "silu" (gated), "gelu" (plain)
    num_experts: int = 0                 # routed experts (0 = dense FFN)
    experts_per_token: int = 0
    num_shared_experts: int = 0          # deepseek-v3: 1
    router_aux_coef: float = 0.01
    # >1 = group-limited routing: tokens are routed within groups aligned to
    # the data-parallel shards (the TPU analogue of DeepSeek-V3's node-limited
    # routing). 1 = global expert-choice (paper-faithful baseline).
    moe_groups: int = 1
    # mesh axis carrying the expert dim: "model" (baseline TP-style),
    # or "both" = (data, model) — one expert per chip, all-to-all dispatch
    expert_axis: str = "model"
    # manual shard_map dispatch/combine interior (models/moe_shardmap.py);
    # set by launch.steps.apply_optimizations, needs an ambient mesh.
    moe_shardmap: bool = False

    # MLA (deepseek)
    mla: MLAConfig | None = None

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # RG-LRU (recurrentgemma)
    lru_width: int | None = None

    # encoder-decoder (whisper): decoder reuses the fields above
    encoder_layers: int = 0
    num_audio_frames: int = 0            # encoder input length (stub frontend)

    # vlm (phi-3-vision): stub patch embeddings prepended to the token stream
    num_patches: int = 0

    # deepseek multi-token prediction
    mtp_depth: int = 0

    # numerics / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # route training/prefill self-attention through the Pallas flash kernel
    # (models.attention._flash_attention_ad: fused forward, blockwise-oracle
    # recompute backward). Off by default — the pure-JAX blockwise path is
    # the reference everywhere else.
    use_flash: bool = False
    # decode support for the 500k shape (sub-quadratic archs + sliding-window dense)
    long_context_ok: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, "GQA group size must divide"
        if self.num_experts:
            assert self.experts_per_token >= 1

    # ---- derived ----
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def param_count(self) -> int:
        """Analytic total parameter count N (for the 6ND roofline term)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        for layer in range(L):
            kind = self.block_kind(layer)
            if kind in ("attn", "local"):
                if self.mla is not None:
                    m = self.mla
                    q_in = m.q_lora_rank if m.q_lora_rank else d
                    total += d * m.q_lora_rank if m.q_lora_rank else 0
                    total += q_in * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.num_heads * m.v_head_dim * d
                else:
                    total += d * self.num_heads * hd  # Q
                    total += 2 * d * self.num_kv_heads * hd  # K, V
                    total += self.num_heads * hd * d  # O
            elif kind == "ssd":
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                # split projections: [z,x] wide + [B,C] / dt narrow
                total += d * (2 * d_in + 2 * self.ssm_state + nheads)
                total += (d_in + 2 * self.ssm_state) * self.ssm_conv
                total += d_in * d  # out proj
            elif kind == "rglru":
                w = self.lru_width or d
                total += 2 * d * w + w * d + 3 * w  # in/gate proj, out proj, lru params
            # FFN
            if self.is_moe:
                e_ff = self.d_ff
                n_e = self.num_experts + self.num_shared_experts
                total += n_e * 3 * d * e_ff  # gated: w_in, w_gate, w_out
                total += d * self.num_experts  # router
            elif kind in ("attn", "local", "rglru"):
                mult = 3 if self.act == "silu" else 2
                total += mult * d * self.d_ff
            total += 2 * d  # norms
        # encoder (whisper): plain attn + gelu mlp
        for _ in range(self.encoder_layers):
            total += 4 * d * self.num_heads * hd + 2 * d * self.d_ff + 2 * d
        if self.is_encoder_decoder:  # decoder cross-attention
            total += L * 4 * d * self.num_heads * hd
        if self.mtp_depth:
            total += self.mtp_depth * (12 * d * d + 3 * d * self.d_ff)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        e_ff = self.d_ff
        all_routed = self.num_layers * self.num_experts * 3 * self.d_model * e_ff
        active_routed = self.num_layers * self.experts_per_token * 3 * self.d_model * e_ff
        return int(full - all_routed + active_routed)
