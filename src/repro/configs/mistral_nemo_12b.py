"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k context. [hf:mistralai/Mistral-Nemo-Base-2407]

long_500k: the base model is full-attention; to qualify a dense arch for the
500k decode shape (per the assignment's sliding-window clause) the launcher
serves the `long_variant()` below — identical weights, sliding-window(8192)
attention masks and a ring-buffer KV cache. Recorded in DESIGN.md §4.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    act="silu",
    sliding_window=8192,     # used only by the long-context serving variant
    long_context_ok=True,    # via long_variant()
)


def long_variant() -> ArchConfig:
    return dataclasses.replace(CONFIG, block_pattern=("local",))
