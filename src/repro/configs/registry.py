"""Architecture registry: `--arch <id>` resolution + reduced smoke variants.

Smoke variants obey the assignment bounds: <=2 layers (hybrids use one full
3-block pattern), d_model<=512, <=4 experts; float32 on CPU.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, MLAConfig
from repro.configs import (  # noqa: F401  (import side table below)
    dbrx_132b,
    deepseek_v3_671b,
    mamba2_370m,
    mistral_nemo_12b,
    phi3_vision_4_2b,
    qwen1_5_32b,
    qwen3_0_6b,
    recurrentgemma_9b,
    starcoder2_3b,
    whisper_tiny,
)

_MODULES = {
    "qwen1.5-32b": qwen1_5_32b,
    "dbrx-132b": dbrx_132b,
    "mamba2-370m": mamba2_370m,
    "qwen3-0.6b": qwen3_0_6b,
    "whisper-tiny": whisper_tiny,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "starcoder2-3b": starcoder2_3b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "mistral-nemo-12b": mistral_nemo_12b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return _MODULES[arch_id].CONFIG


def long_context_config(arch_id: str) -> ArchConfig:
    """Config actually served for long_500k (mistral-nemo swaps in SWA)."""
    cfg = get_config(arch_id)
    if arch_id == "mistral-nemo-12b":
        return mistral_nemo_12b.long_variant()
    assert cfg.long_context_ok, f"{arch_id} does not support long_500k"
    return cfg


def smoke_config(arch_id: str) -> ArchConfig:
    cfg = get_config(arch_id)
    plen = len(cfg.block_pattern)
    layers = plen if plen > 1 else 2
    updates: dict = dict(
        num_layers=layers,
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
    )
    if cfg.is_moe:
        updates.update(num_experts=4, experts_per_token=2)
    if cfg.mla is not None:
        updates.update(
            mla=MLAConfig(
                q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32,
            ),
            head_dim=48,
        )
    if cfg.block_pattern != ("attn",):
        # keep block kinds; shrink windows/states
        updates.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=16)
        if cfg.sliding_window:
            updates["sliding_window"] = 16
        if cfg.lru_width:
            updates["lru_width"] = 256
    if cfg.encoder_layers:
        updates.update(encoder_layers=2, num_audio_frames=24)
    if cfg.num_patches:
        updates["num_patches"] = 8
    return dataclasses.replace(cfg, **updates)
