"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288,
RG-LRU + local attention in a 2:1 pattern (two recurrent blocks per local-
attention block), window 2048, vocab=256000. [arXiv:2402.19427]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    sliding_window=2048,
    lru_width=4096,
    act="gelu",
    long_context_ok=True,  # O(1) recurrent state + bounded local window
)
