"""whisper-tiny [audio] — enc-dec, 4L decoder (+4L encoder) d_model=384 6H
d_ff=1536 vocab=51865. Conv/mel frontend is STUBBED per the assignment
carve-out: input_specs provide precomputed frame embeddings (1500, 384).
[arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    encoder_layers=4,
    num_audio_frames=1500,
)
