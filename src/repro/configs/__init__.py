from repro.configs.base import ArchConfig, MLAConfig

__all__ = ["ArchConfig", "MLAConfig"]
