"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP ViT-L/14 vision tower. The vision
tower is STUBBED per the assignment carve-out: input_specs provide 576
precomputed patch embeddings (dim 1024) which a learned projector maps to
d_model. [hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    act="silu",
    num_patches=576,
)
