"""deepseek-v3-671b [moe] — 61L d_model=7168 128H, MLA attention
(kv latent 512, rope 64), MoE: 1 shared + 256 routed top-8 (expert d_ff=2048),
MTP depth 1, vocab=129280. [arXiv:2412.19437]"""
from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,      # MLA replaces GQA; kept for schema uniformity
    head_dim=192,          # qk_nope (128) + qk_rope (64)
    d_ff=2048,             # per-expert width
    vocab_size=129280,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    mtp_depth=1,
    rope_theta=10_000.0,
    act="silu",
)
