"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936,
qk_norm, head_dim=128. [hf:Qwen/Qwen3-8B family card, 0.6B point]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
)
