"""mamba2-370m [ssm] — 48L d_model=1024, attention-free SSD blocks,
ssm_state=128, vocab=50280. [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=16,        # unused (attention-free); kept for schema uniformity
    num_kv_heads=16,
    d_ff=0,              # SSD blocks are mixer-only
    vocab_size=50280,
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    long_context_ok=True,  # constant-size recurrent state -> 500k decode
)
