"""shard_map MoE interior: provably-local expert dispatch/combine.

Why this exists (EXPERIMENTS.md §Perf pair 1): under GSPMD the expert-choice
combine is a vmapped scatter-add whose locality XLA cannot prove, so it
resolves it as operand-replicated scatter + an all-reduce of the FULL
(N, d) activation over every mesh axis — ~2 TB/device/step at deepseek-v3
scale. Writing the interior with `jax.shard_map` makes the layout explicit:

  * tokens stay on their `data` shard end-to-end (gather and scatter-add are
    ordinary local ops on the shard's (n_loc, d) block);
  * each `model` shard owns E/n_model experts and runs expert-choice over its
    *local* tokens (shard-granular group-limited routing — the same
    approximation `moe_groups` makes, at G = n_data instead of G = B);
  * the ONLY communication is one psum over `model` of the (n_loc, d)
    partial outputs + the (n_loc,) gate mass — the Megatron-style row-sum,
    ~n_loc*d bytes/layer instead of the full-activation all-reduce.

Semantics match `ffn.moe_forward(method="expert_choice")` with batch-row
groups when each data shard holds exactly one group (tested in
tests/test_moe_shardmap.py at mesh (2,2)); at mesh (1,1) it is bit-identical
to global expert choice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import activation


def _shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool):
    # newer jax exposes jax.shard_map(check_vma=...); older only has the
    # experimental API with the check_rep spelling
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def shardmap_supported(cfg: ArchConfig, mesh, batch: int) -> bool:
    """Routed-expert shard_map needs divisible shards and a (data, model) mesh."""
    if mesh is None or "data" not in mesh.axis_names or "model" not in mesh.axis_names:
        return False
    n_data, n_model = mesh.shape["data"], mesh.shape["model"]
    return (
        cfg.num_experts > 0
        and cfg.num_experts % n_model == 0
        and batch % n_data == 0
    )


def moe_routed_shardmap(cfg: ArchConfig, p: dict, x, mesh, *,
                        capacity_factor: float = 1.0):
    """Routed-experts-only forward. x (B, T, d) -> (y (B, T, d), aux scalar).

    Shared experts / aux-coef scaling are applied by the caller
    (ffn.moe_forward) exactly as for the GSPMD paths.
    """
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    n_model = mesh.shape["model"]
    E_loc = E // n_model
    act = activation(cfg.act)
    use_sigmoid = E > 32

    def interior(xb, router, w_gate, w_in, w_out):
        # xb (B_loc, T, d); router (d, E); w_* (E_loc, d, f) — local blocks.
        B_loc = xb.shape[0]
        n_loc = B_loc * T
        xf = xb.reshape(n_loc, d)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.sigmoid(logits) if use_sigmoid else jax.nn.softmax(logits, -1)

        # load-balance aux: global mean prob per expert (psum over data shards)
        me = jax.lax.psum(jnp.sum(probs, axis=0), "data") / (
            n_loc * mesh.shape["data"]
        )
        aux = E * jnp.sum(me * me)

        # local expert-choice: this shard's E_loc experts pick their top-C
        # tokens among the shard's n_loc tokens.
        cap = max(1, int(n_loc * k * capacity_factor) // E)
        e0 = jax.lax.axis_index("model") * E_loc
        scores = jax.lax.dynamic_slice(
            probs, (0, e0), (n_loc, E_loc)
        ).T  # (E_loc, n_loc)
        g, idx = jax.lax.top_k(scores, cap)  # (E_loc, C)
        xe = jnp.take(xf, idx.reshape(-1), axis=0).reshape(E_loc, cap, d)

        h = jnp.einsum("ecd,edf->ecf", xe, w_gate)
        u = jnp.einsum("ecd,edf->ecf", xe, w_in)
        ye = jnp.einsum("ecf,efd->ecd", act(h) * u, w_out)
        ye = ye * g[..., None].astype(x.dtype)

        # local combine + the one collective: row-sum over the model axis
        y = jnp.zeros((n_loc, d), x.dtype).at[idx.reshape(-1)].add(
            ye.reshape(-1, d)
        )
        mass = jnp.zeros((n_loc,), jnp.float32).at[idx.reshape(-1)].add(
            g.reshape(-1)
        )
        y = jax.lax.psum(y, "model")
        mass = jax.lax.psum(mass, "model")
        y = y / jnp.maximum(mass, 1e-9)[:, None].astype(x.dtype)
        return y.reshape(B_loc, T, d), aux

    axes = tuple(mesh.axis_names)  # may include "pod"; unmentioned axes replicate

    def rep(*spec):
        # pad a spec to full rank with Nones on unmentioned (leading) axes
        return P(*spec)

    y, aux = _shard_map(
        interior,
        mesh=mesh,
        in_specs=(
            rep("data", None, None),     # x: batch over data, repl. over model
            rep(None, None),             # router replicated
            rep("model", None, None),    # expert weights: E over model
            rep("model", None, None),
            rep("model", None, None),
        ),
        out_specs=(rep("data", None, None), rep()),
        check_vma=False,  # aux is replicated by construction (psum over data)
    )(x, p["router"], p["w_gate"], p["w_in"], p["w_out"])
    return y, aux
