"""Paper's experiment models (Appendix A): MLP and LeNet, pure JAX.

* MLP — two hidden FC layers: 200/200 (MNIST), 256/512 (CIFAR-10/100), ReLU.
  The paper treats its loss as (approximately) convex.
* LeNet — two conv+pool stages then two FC layers:
  MNIST: conv 64@5x5 -> pool 2x2 -> conv 256@5x5 -> pool -> FC 512 -> FC 128.
  CIFAR: conv 64@5x5 -> pool -> conv 64@5x5 -> pool -> FC 384 -> FC 192.
Both expose the same functional interface:
  params = init(key); logits = apply(params, x); loss/grad helpers below.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Classifier:
    name: str
    init: Callable[[jax.Array], dict]
    apply: Callable[[dict, jax.Array], jax.Array]  # (params, x NHWC) -> logits
    num_classes: int

    def loss(self, params: dict, x: jax.Array, y: jax.Array) -> jax.Array:
        logits = self.apply(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1))

    def loss_and_grad(self, params: dict, x: jax.Array, y: jax.Array):
        return jax.value_and_grad(self.loss)(params, x, y)

    def accuracy(self, params: dict, x: jax.Array, y: jax.Array) -> jax.Array:
        return jnp.mean((jnp.argmax(self.apply(params, x), axis=-1) == y).astype(jnp.float32))


def _dense_init(key, n_in, n_out, scale=None):
    scale = scale if scale is not None else float(np.sqrt(2.0 / n_in))
    kw, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(kw, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _conv_init(key, h, w, c_in, c_out):
    fan_in = h * w * c_in
    kw, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(kw, (h, w, c_in, c_out), jnp.float32) * np.sqrt(2.0 / fan_in),
        "b": jnp.zeros((c_out,), jnp.float32),
    }


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, window_dimensions=(1, 2, 2, 1), window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def _mlp_dims(dataset: str) -> tuple[int, int]:
    return (200, 200) if dataset == "mnist" else (256, 512)


def make_mlp(dataset: str, image_shape: tuple[int, int, int], num_classes: int) -> Classifier:
    h1, h2 = _mlp_dims(dataset)
    d_in = int(np.prod(image_shape))

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "fc1": _dense_init(k1, d_in, h1),
            "fc2": _dense_init(k2, h1, h2),
            "out": _dense_init(k3, h2, num_classes),
        }

    def apply(params, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]

    return Classifier(f"mlp-{dataset}", init, apply, num_classes)


def make_lenet(dataset: str, image_shape: tuple[int, int, int], num_classes: int,
               *, width_scale: float = 1.0) -> Classifier:
    """width_scale < 1 shrinks channel/FC widths uniformly (benchmark quick
    mode on CPU — conv FLOPs scale with c1*c2); 1.0 is the paper's Appendix-A
    LeNet exactly."""
    h, w, c = image_shape
    if dataset == "mnist":
        c1, c2, f1, f2 = 64, 256, 512, 128
    else:
        c1, c2, f1, f2 = 64, 64, 384, 192
    if width_scale != 1.0:
        c1, c2, f1, f2 = (max(8, int(v * width_scale)) for v in (c1, c2, f1, f2))
    h_out, w_out = h // 4, w // 4  # two 2x2 pools
    flat = h_out * w_out * c2

    def init(key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        return {
            "conv1": _conv_init(k1, 5, 5, c, c1),
            "conv2": _conv_init(k2, 5, 5, c1, c2),
            "fc1": _dense_init(k3, flat, f1),
            "fc2": _dense_init(k4, f1, f2),
            "out": _dense_init(k5, f2, num_classes),
        }

    def apply(params, x):
        x = _maxpool2(jax.nn.relu(_conv(x, params["conv1"])))
        x = _maxpool2(jax.nn.relu(_conv(x, params["conv2"])))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]

    return Classifier(f"lenet-{dataset}", init, apply, num_classes)


def make_classifier(model: str, dataset: str, image_shape, num_classes: int,
                    *, width_scale: float = 1.0) -> Classifier:
    if model == "mlp":
        return make_mlp(dataset, tuple(image_shape), num_classes)
    if model == "lenet":
        return make_lenet(dataset, tuple(image_shape), num_classes,
                          width_scale=width_scale)
    raise ValueError(f"unknown model {model!r}")
