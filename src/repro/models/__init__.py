from repro.models.classifier import Classifier, make_classifier

__all__ = ["Classifier", "make_classifier"]
