from repro.models.classifier import Classifier, make_classifier
from repro.models.fed import ClassifierFedModel, FedModel, LMFedModel, as_fed_model

__all__ = [
    "Classifier",
    "make_classifier",
    "FedModel",
    "ClassifierFedModel",
    "LMFedModel",
    "as_fed_model",
]
