"""FedModel — the one task abstraction the FL core is generic over.

A `FedModel` is what the round engine, the channels, the bit ledger, and the
netsim replay all see of a workload: how to initialise parameters, how to
score one mini-batch (a *pytree*, not a fixed (x, y) pair), and how to turn
held-out data into a scalar metric.  Everything protocol-side — which cluster
trains when, what traverses which hop, how bits are counted — is identical
whether the params pytree is a 3-layer MLP or a 100M-param transformer LM.

Implementations must be hashable (frozen dataclasses): the engine caches one
compiled round function per (model, channel, local-opt) triple.

Two implementations ship here:

  * `ClassifierFedModel` — adapts the paper's Appendix-A `Classifier`
    (MLP/LeNet); batches are ``{"x": images, "y": labels}`` and the metric is
    test-set accuracy (higher is better).  Its loss/eval computations are the
    exact expressions the pre-FedTask stack ran, so fixed-seed classifier
    trajectories are preserved bit-for-bit.
  * `LMFedModel` — a decoder transformer LM built from `configs.ArchConfig` +
    `models.transformer`; batches are ``{"tokens": ..., "labels": ...}`` and
    the metric is held-out perplexity (lower is better).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.classifier import Classifier

PyTree = Any
Batch = Any  # pytree of arrays sharing leading axes


@runtime_checkable
class FedModel(Protocol):
    """What the FL core needs from a workload. Hashable; methods traceable."""

    name: str
    metric_name: str        # e.g. "accuracy", "perplexity"
    metric_mode: str        # "max" (accuracy-like) or "min" (loss-like)

    def init(self, key: jax.Array) -> PyTree:
        """Fresh parameter pytree."""
        ...

    def loss(self, params: PyTree, batch: Batch) -> jax.Array:
        """Scalar training loss of one mini-batch pytree. Traceable."""
        ...

    def eval_metric(self, params: PyTree, eval_data: Any) -> float:
        """Scalar quality metric on held-out data (host-side, may batch)."""
        ...


@dataclasses.dataclass(frozen=True)
class ClassifierFedModel:
    """Appendix-A MLP/LeNet as a FedModel; batch = {"x": images, "y": labels}."""

    clf: Classifier
    metric_name: str = dataclasses.field(default="accuracy", init=False)
    metric_mode: str = dataclasses.field(default="max", init=False)

    @property
    def name(self) -> str:
        return self.clf.name

    def init(self, key: jax.Array) -> PyTree:
        return self.clf.init(key)

    def loss(self, params: PyTree, batch: Batch) -> jax.Array:
        return self.clf.loss(params, batch["x"], batch["y"])

    def eval_metric(self, params: PyTree, eval_data) -> float:
        """Test-set accuracy over `eval_data` (a `data.synthetic.Dataset`)."""
        from repro.data.loader import batch_iterator

        fn = _count_correct_fn(self.clf)
        n_correct, n = 0, 0
        for x, y in batch_iterator(eval_data.test_x, eval_data.test_y, 512):
            n_correct += int(fn(params, jnp.asarray(x), jnp.asarray(y)))
            n += len(y)
        return n_correct / max(n, 1)


@functools.cache
def _count_correct_fn(clf: Classifier):
    def correct(params, x, y):
        return jnp.sum((jnp.argmax(clf.apply(params, x), axis=-1) == y).astype(jnp.int32))

    return jax.jit(correct)


@dataclasses.dataclass(frozen=True)
class LMFedModel:
    """Decoder transformer LM as a FedModel.

    Batch = {"tokens": (B, T) int32, "labels": (B, T) int32}; the loss is the
    next-token cross entropy of `models.transformer.loss_fn`, and the metric
    is perplexity on a fixed held-out batch set (lower is better) — which is
    what lets `RunResult.rounds_to_accuracy`-style threshold queries, and
    therefore netsim time-to-loss, work unchanged for LM pretraining.
    """

    cfg: ArchConfig
    remat: bool = False
    flash: bool = False   # route self-attention through the Pallas flash
                          # kernel (sets cfg.use_flash); with remat=True this
                          # is the memory-lean LM training configuration the
                          # `client_microbatch` engine knob assumes

    metric_name: str = dataclasses.field(default="perplexity", init=False)
    metric_mode: str = dataclasses.field(default="min", init=False)

    @property
    def name(self) -> str:
        return f"lm-{self.cfg.name}"

    def _run_cfg(self) -> ArchConfig:
        if self.flash and not self.cfg.use_flash:
            return dataclasses.replace(self.cfg, use_flash=True)
        return self.cfg

    def init(self, key: jax.Array) -> PyTree:
        from repro.models import transformer as tf

        return tf.init_params(self.cfg, key)

    def loss(self, params: PyTree, batch: Batch) -> jax.Array:
        from repro.models import transformer as tf

        return tf.loss_fn(self._run_cfg(), params, batch, remat=self.remat)

    def eval_metric(self, params: PyTree, eval_data) -> float:
        """exp(mean next-token CE) over `eval_data`: a batch pytree with a
        leading eval-batch axis on every leaf."""
        mean_loss = _lm_eval_fn(self)(params, eval_data)
        return float(jnp.exp(mean_loss))


@functools.cache
def _lm_eval_fn(model: LMFedModel):
    def mean_loss(params, batches):
        losses = jax.lax.map(lambda b: model.loss(params, b), batches)
        return jnp.mean(losses)

    return jax.jit(mean_loss)


def as_fed_model(model: FedModel | Classifier) -> FedModel:
    """Normalize: raw `Classifier`s get wrapped, FedModels pass through.

    The wrapper is a frozen dataclass over the same Classifier instance, so
    repeated wrapping of one model hits the same engine compile cache."""
    if isinstance(model, Classifier):
        return ClassifierFedModel(model)
    return model
