"""Unified decoder-style LM covering all six assigned families.

Structure
---------
Layers are grouped into a scanned "superblock": the arch's `block_pattern`
(e.g. ("rglru","rglru","local") for recurrentgemma) is stacked
`num_layers // len(pattern)` times and run under jax.lax.scan (small HLO,
fast AOT compiles at 61+ layers); remainder layers run unscanned as a tail.

Per-layer block kinds: "attn" (full causal; MLA if cfg.mla), "local"
(sliding window), "ssd" (Mamba-2), "rglru" (Griffin). FFN is dense or MoE
(cfg.num_experts). Whisper adds an encoder stack + cross-attention; Phi-3-V
prepends projected patch embeddings; DeepSeek adds an MTP head.

Public entry points (all functional):
  init_params(cfg, key)
  forward(cfg, params, batch)           -> logits, aux_loss
  loss_fn(cfg, params, batch)           -> scalar
  make_train_step(cfg)                  -> (params, batch, lr) -> (params, loss)
  prefill(cfg, params, batch)           -> logits_last, caches
  init_caches(cfg, params, batch, cap)  -> caches (for decode dry-run specs)
  decode_step(cfg, params, caches, token[, ...]) -> logits, caches
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.common import cross_entropy_loss, dense_init, rms_norm

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ==========================================================================
# per-block init / forward / decode
# ==========================================================================


def _has_ffn(cfg: ArchConfig, kind: str) -> bool:
    return kind != "ssd"  # mamba2 blocks are mixer-only


def init_block(cfg: ArchConfig, kind: str, key, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if kind in ("attn", "local"):
        if cfg.mla is not None:
            p["attn"] = attn.init_mla(cfg, k1, dtype)
        else:
            p["attn"] = attn.init_attention(cfg, k1, dtype)
        if cfg.is_encoder_decoder:
            p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
            p["xattn"] = attn.init_attention(
                dataclasses.replace(cfg, qkv_bias=False, qk_norm=False), k3, dtype
            )
    elif kind == "ssd":
        p["mixer"] = ssd_mod.init_ssd_block(cfg, k1, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru_block(cfg, k1, dtype)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = ffn_mod.init_moe(cfg, k2, dtype) if cfg.is_moe else ffn_mod.init_ffn(
            cfg, k2, dtype
        )
    return p


def block_forward(cfg: ArchConfig, kind: str, p: dict, x, *, enc_out=None,
                  moe_method: str = "expert_choice"):
    """x (B,T,d) -> (x', aux). Causal training/prefill path."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.sliding_window if kind == "local" else None
        if cfg.mla is not None:
            y = attn.mla_forward(cfg, p["attn"], h)
        else:
            y = attn.attention_forward(cfg, p["attn"], h, window=window)
        x = x + y
        if cfg.is_encoder_decoder and enc_out is not None:
            hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
            x = x + _cross_attention(cfg, p["xattn"], hx, enc_out)
    elif kind == "ssd":
        x = x + ssd_mod.ssd_block_forward(cfg, p["mixer"], h)
    elif kind == "rglru":
        x = x + rglru_mod.rglru_block_forward(cfg, p["mixer"], h)
    if _has_ffn(cfg, kind):
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, aux = ffn_mod.moe_forward(cfg, p["ffn"], h2, method=moe_method)
        else:
            y = ffn_mod.ffn_forward(cfg, p["ffn"], h2)
        x = x + y
    return x, aux


def _cross_attention(cfg: ArchConfig, p, x, enc_out):
    """Decoder -> encoder attention (no RoPE, full visibility)."""
    B, T, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, h, hd)
    k = (enc_out @ p["wk"]).reshape(B, enc_out.shape[1], hkv, hd)
    v = (enc_out @ p["wv"]).reshape(B, enc_out.shape[1], hkv, hd)
    out = attn.blockwise_attention(q, k, v, causal=False)
    return out.reshape(B, T, -1) @ p["wo"]


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, capacity: int, dtype,
                     enc_len: int = 0) -> dict:
    c: dict = {}
    if kind in ("attn", "local"):
        cap = capacity if kind == "attn" else min(capacity, cfg.sliding_window)
        if cfg.mla is not None:
            c["self"] = attn.init_mla_cache(cfg, batch, cap, dtype)
        else:
            c["self"] = attn.init_attn_cache(cfg, batch, cap, dtype)
        if cfg.is_encoder_decoder:
            c["cross_k"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            c["cross_v"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    elif kind == "ssd":
        c["mixer"] = ssd_mod.init_ssd_cache(cfg, batch, dtype)
    elif kind == "rglru":
        c["mixer"] = rglru_mod.init_rglru_cache(cfg, batch, dtype)
    return c


def block_decode(cfg: ArchConfig, kind: str, p: dict, x, cache: dict,
                 moe_method: str = "expert_choice"):
    if kind in ("attn", "local"):
        window = cfg.sliding_window if kind == "local" else None
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if cfg.mla is not None:
            y, new_self = attn.mla_decode(cfg, p["attn"], h, cache["self"])
        else:
            y, new_self = attn.attention_decode(cfg, p["attn"], h, cache["self"], window=window)
        x = x + y
        cache = dict(cache, self=new_self)
        if cfg.is_encoder_decoder:
            hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
            B = x.shape[0]
            q = (hx @ p["xattn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
            out = attn.decode_attention(
                q, cache["cross_k"], cache["cross_v"],
                jnp.full((B,), cache["cross_k"].shape[1], jnp.int32),
            )
            x = x + out.reshape(B, 1, -1) @ p["xattn"]["wo"]
    else:
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if kind == "ssd":
            y, new_mixer = ssd_mod.ssd_block_decode(cfg, p["mixer"], h, cache["mixer"])
        else:
            y, new_mixer = rglru_mod.rglru_block_decode(cfg, p["mixer"], h, cache["mixer"])
        x = x + y
        cache = dict(cache, mixer=new_mixer)
    if _has_ffn(cfg, kind):
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, _ = ffn_mod.moe_forward(cfg, p["ffn"], h2, method=moe_method)
        else:
            y = ffn_mod.ffn_forward(cfg, p["ffn"], h2)
        x = x + y
    return x, cache


# ==========================================================================
# layer stacking: scanned superblocks + tail
# ==========================================================================


def _layout(cfg: ArchConfig) -> tuple[int, int]:
    """(n_super, n_tail): num_layers = n_super * len(pattern) + n_tail."""
    plen = len(cfg.block_pattern)
    return cfg.num_layers // plen, cfg.num_layers % plen


def _stack_trees(trees: list[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = _dtype(cfg)
    n_super, n_tail = _layout(cfg)
    plen = len(cfg.block_pattern)
    keys = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 8)

    # scanned superblocks: per pattern-position, stacked across n_super repeats
    super_params = []
    for pos, kind in enumerate(cfg.block_pattern):
        reps = [init_block(cfg, kind, keys[r * plen + pos], dtype) for r in range(n_super)]
        super_params.append(_stack_trees(reps))
    tail = [
        init_block(cfg, cfg.block_kind(n_super * plen + i), keys[n_super * plen + i], dtype)
        for i in range(n_tail)
    ]

    p: dict = {
        "embed": dense_init(keys[-1], cfg.vocab_size, cfg.d_model, scale=0.02, dtype=dtype),
        "super": super_params,
        "tail": tail,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.vocab_size, dtype=dtype)
    if cfg.is_encoder_decoder:
        ek = jax.random.split(keys[-3], cfg.encoder_layers)
        enc_cfg = dataclasses.replace(
            cfg, qkv_bias=False, qk_norm=False, num_experts=0, act="gelu",
            block_pattern=("attn",), mla=None,
        )
        p["encoder"] = {
            "blocks": _stack_trees(
                [_init_encoder_block(enc_cfg, k, dtype) for k in ek]
            ),
            "norm": jnp.ones((cfg.d_model,), dtype),
        }
    if cfg.num_patches:
        p["projector"] = dense_init(keys[-4], 1024, cfg.d_model, dtype=dtype)
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": dense_init(keys[-5], 2 * cfg.d_model, cfg.d_model, dtype=dtype),
            "block": init_block(cfg, "attn", keys[-6], dtype),
            "norm": jnp.ones((cfg.d_model,), dtype),
        }
    return p


def _init_encoder_block(cfg: ArchConfig, key, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(cfg, k1, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": ffn_mod.init_ffn(cfg, k2, dtype),
    }


def _encoder_forward(cfg: ArchConfig, p: dict, frames):
    """frames (B, F, d) — stub frontend output — -> encoder states."""
    x = frames.astype(_dtype(cfg))
    F = x.shape[1]
    # sinusoidal positions
    pos = np.arange(10_000)[:, None] / (
        10_000 ** (np.arange(0, cfg.d_model, 2)[None, :] / cfg.d_model)
    )
    pe = jnp.asarray(
        np.concatenate([np.sin(pos), np.cos(pos)], axis=-1)[None, :, :], _dtype(cfg)
    )
    x = x + pe[:, :F, : cfg.d_model]
    enc_cfg = dataclasses.replace(
        cfg, qkv_bias=False, qk_norm=False, num_experts=0, act="gelu",
        block_pattern=("attn",), mla=None,
    )

    def body(h, bp):
        y = attn.blockwise_attention(
            *_enc_qkv(enc_cfg, bp["attn"], rms_norm(h, bp["ln1"], cfg.norm_eps)),
            causal=False,
        )
        h = h + y.reshape(h.shape[0], h.shape[1], -1) @ bp["attn"]["wo"]
        h = h + ffn_mod.ffn_forward(enc_cfg, bp["ffn"], rms_norm(h, bp["ln2"], cfg.norm_eps))
        return h, None

    x, _ = jax.lax.scan(body, x, p["blocks"])
    return rms_norm(x, p["norm"], cfg.norm_eps)


def _enc_qkv(cfg: ArchConfig, p, x):
    B, T, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, h, hd)
    k = (x @ p["wk"]).reshape(B, T, hkv, hd)
    v = (x @ p["wv"]).reshape(B, T, hkv, hd)
    return q, k, v


# ==========================================================================
# full forward / loss / train step
# ==========================================================================


def _embed_inputs(cfg: ArchConfig, params: dict, batch: dict):
    """Returns (x (B, T', d), enc_out or None, n_prefix)."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    enc_out = None
    n_prefix = 0
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(cfg, params["encoder"], batch["frames"])
    if cfg.num_patches:
        patches = batch["patches"].astype(_dtype(cfg)) @ params["projector"]
        x = jnp.concatenate([patches, x], axis=1)
        n_prefix = patches.shape[1]
    return x, enc_out, n_prefix


def forward(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False,
            moe_method: str = "expert_choice", remat_policy=None,
            last_only: bool = False):
    """-> (logits (B, T_tokens, V) — or (B, 1, V) with `last_only` — , aux).

    `last_only` slices the hidden state to the final position BEFORE the LM
    head: a prefill only needs next-token logits, and the (B, T, V) logits
    tensor is otherwise the largest in the whole program (EXPERIMENTS.md
    §Perf pair 4)."""
    x, enc_out, n_prefix = _embed_inputs(cfg, params, batch)
    aux_total = jnp.zeros((), jnp.float32)
    plen = len(cfg.block_pattern)

    def super_body(carry, stacked_slice):
        h, aux = carry
        for pos, kind in enumerate(cfg.block_pattern):
            h, a = block_forward(cfg, kind, stacked_slice[pos], h, enc_out=enc_out,
                                 moe_method=moe_method)
            aux = aux + a
        return (h, aux), None

    if remat:
        body = jax.checkpoint(super_body, policy=remat_policy)
    else:
        body = super_body
    n_super, n_tail = _layout(cfg)
    if n_super:
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["super"])
    for i in range(n_tail):
        kind = cfg.block_kind(n_super * plen + i)
        x, a = block_forward(cfg, kind, params["tail"][i], x, enc_out=enc_out,
                             moe_method=moe_method)
        aux_total = aux_total + a

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    if last_only:
        x = x[:, -1:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux_total


def loss_fn(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False,
            moe_method: str = "expert_choice", remat_policy=None):
    logits, aux = forward(cfg, params, batch, remat=remat, moe_method=moe_method,
                          remat_policy=remat_policy)
    loss = cross_entropy_loss(logits, batch["labels"])
    if cfg.mtp_depth:
        loss = loss + 0.3 * _mtp_loss(cfg, params, batch)
    return loss + aux


def _mtp_loss(cfg: ArchConfig, params: dict, batch: dict):
    """DeepSeek-V3 multi-token prediction (depth 1): predict t_{i+2} from the
    embedding stream shifted by one, fused through one extra block."""
    tokens, labels = batch["tokens"], batch["labels"]
    x = jnp.take(params["embed"], tokens, axis=0)
    nxt = jnp.take(params["embed"], labels, axis=0)  # t_{i+1} embeddings
    m = params["mtp"]
    h = jnp.concatenate(
        [rms_norm(x, m["norm"], cfg.norm_eps), rms_norm(nxt, m["norm"], cfg.norm_eps)], -1
    ) @ m["proj"]
    h, _ = block_forward(cfg, "attn", m["block"], h)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    # labels for t_{i+2}: shift `labels` left by one (last position ignored)
    l2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
    return cross_entropy_loss(logits, l2)


def make_train_step(cfg: ArchConfig, *, remat: bool = True,
                    moe_method: str = "expert_choice"):
    """Plain SGD step — the Eq. (5)-compatible unit the FL layer composes."""

    def train_step(params, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat, moe_method=moe_method)
        )(params)
        new_params = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), params, grads)
        return new_params, loss

    return train_step


# ==========================================================================
# serving: prefill + single-token decode
# ==========================================================================


def init_caches(cfg: ArchConfig, batch: int, capacity: int, *, enc_len: int = 0):
    dtype = _dtype(cfg)
    n_super, n_tail = _layout(cfg)
    plen = len(cfg.block_pattern)
    super_caches = []
    for pos, kind in enumerate(cfg.block_pattern):
        reps = [
            init_block_cache(cfg, kind, batch, capacity, dtype, enc_len=enc_len)
            for _ in range(n_super)
        ]
        super_caches.append(_stack_trees(reps))
    tail = [
        init_block_cache(cfg, cfg.block_kind(n_super * plen + i), batch, capacity, dtype,
                         enc_len=enc_len)
        for i in range(n_tail)
    ]
    return {"super": super_caches, "tail": tail}


def set_cache_len(caches, new_len: int):
    """Mark caches as containing `new_len` tokens (dry-run decode specs)."""

    def upd(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "len":
            return jnp.full(leaf.shape, new_len, leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(upd, caches)


def decode_step(cfg: ArchConfig, params: dict, caches, token, *,
                moe_method: str = "expert_choice"):
    """token (B, 1) int32 -> (logits (B, V), new caches). One new token vs cache."""
    x = jnp.take(params["embed"], token, axis=0)
    plen = len(cfg.block_pattern)
    n_super, n_tail = _layout(cfg)

    def super_body(h, slices):
        param_slice, cache_slice = slices
        new_caches = []
        for pos, kind in enumerate(cfg.block_pattern):
            h, nc = block_decode(cfg, kind, param_slice[pos], h, cache_slice[pos],
                                 moe_method=moe_method)
            new_caches.append(nc)
        return h, tuple(new_caches)

    new_super = []
    if n_super:
        x, ys = jax.lax.scan(super_body, x, (params["super"], tuple(caches["super"])))
        new_super = list(ys)
    new_tail = []
    for i in range(n_tail):
        kind = cfg.block_kind(n_super * plen + i)
        x, nc = block_decode(cfg, kind, params["tail"][i], x, caches["tail"][i],
                             moe_method=moe_method)
        new_tail.append(nc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    return logits, {"super": new_super, "tail": new_tail}


def prefill(cfg: ArchConfig, params: dict, batch: dict, *, capacity: int | None = None,
            moe_method: str = "expert_choice"):
    """Run the full prompt, return (last-position logits, filled caches).

    Implemented as forward + cache construction per layer. For attention
    layers the K/V of every position are recomputed blockwise (cheap relative
    to the forward) — caches come back ready for decode_step.
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    capacity = capacity or T
    logits, _ = forward(cfg, params, batch, moe_method=moe_method)
    caches = init_caches(cfg, B, capacity,
                         enc_len=batch["frames"].shape[1] if cfg.is_encoder_decoder else 0)
    if cfg.is_encoder_decoder:
        caches = _fill_cross_caches(cfg, params, batch, caches)
    caches = _fill_caches_by_replay(cfg, params, batch, caches, moe_method=moe_method)
    return logits[:, -1], caches


def _fill_cross_caches(cfg: ArchConfig, params, batch, caches):
    """Encoder K/V are computed once per request and pinned in the cache."""
    enc_out = _encoder_forward(cfg, params["encoder"], batch["frames"])
    B, F, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim

    def fill(param_tree, cache_tree):
        def one(pp, cc):
            if "xattn" not in pp:
                return cc
            wk, wv = pp["xattn"]["wk"], pp["xattn"]["wv"]
            if wk.ndim == 3:  # stacked (n_super, d, hkv*hd)
                ck = jnp.einsum("bfd,ldk->lbfk", enc_out, wk).reshape(
                    wk.shape[0], B, F, hkv, hd
                )
                cv = jnp.einsum("bfd,ldk->lbfk", enc_out, wv).reshape(
                    wv.shape[0], B, F, hkv, hd
                )
            else:
                ck = (enc_out @ wk).reshape(B, F, hkv, hd)
                cv = (enc_out @ wv).reshape(B, F, hkv, hd)
            return dict(cc, cross_k=ck.astype(cc["cross_k"].dtype),
                        cross_v=cv.astype(cc["cross_v"].dtype))

        return one(param_tree, cache_tree)

    new_super = [
        fill(params["super"][pos], caches["super"][pos])
        for pos in range(len(cfg.block_pattern))
    ]
    n_super, n_tail = _layout(cfg)
    plen = len(cfg.block_pattern)
    new_tail = [
        fill(params["tail"][i], caches["tail"][i]) for i in range(n_tail)
    ]
    return {"super": new_super, "tail": new_tail}


def _fill_caches_by_replay(cfg: ArchConfig, params, batch, caches, *, moe_method):
    """Decode the prompt token-by-token to fill caches (reference-quality path;
    serving benchmarks at scale use the dry-run specs, not this loop)."""
    tokens = batch["tokens"]
    B, T = tokens.shape

    def step(carry, tok):
        c = carry
        _, c = decode_step(cfg, params, c, tok[:, None], moe_method=moe_method)
        return c, None

    caches, _ = jax.lax.scan(step, caches, tokens.T)
    return caches
