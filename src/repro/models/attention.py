"""Attention blocks: GQA (full / sliding-window) and DeepSeek MLA.

Training/prefill attention is *blockwise* (online-softmax over KV chunks via
lax.scan) so a 32k-token prefill never materialises the (T, T) score matrix —
the TPU-native equivalent of flash attention, and the shape the Pallas fast
path in repro/kernels/flash_attention.py mirrors. Decode attends one query
against a fixed-capacity cache (full or ring-buffered sliding window).

With `cfg.use_flash` the training/prefill path routes through the Pallas
kernel instead (`_flash_attention_ad`): the forward is the fused q-blocked
kernel, and the backward recomputes attention via this module's blockwise
oracle and differentiates THAT — the standard flash-attention recompute
trade (no (T, S) residuals saved; the two implementations agree to kernel
tolerance, pinned by tests/test_kernels.py).

Shapes: x (B, T, D); q (B, T, H, hd); kv (B, S, Hkv, hd); caches (B, S, Hkv, hd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import apply_rope, dense_init, rms_norm, rope_angles

NEG_INF = -1e30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------


def init_attention(cfg: ArchConfig, key, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype=dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_mla(cfg: ArchConfig, key, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(
            ks[3], m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype=dtype
        ),
        "wo": dense_init(ks[4], h * m.v_head_dim, d, dtype=dtype),
    }
    if m.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, m.q_lora_rank, dtype=dtype)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(ks[1], m.q_lora_rank, h * qk_head, dtype=dtype)
    else:
        p["wq"] = dense_init(ks[0], d, h * qk_head, dtype=dtype)
    return p


# --------------------------------------------------------------------------
# blockwise (flash-style) attention core
# --------------------------------------------------------------------------


def blockwise_attention(
    q, k, v, *, causal: bool, window: int | None = None, kv_block: int = 512,
    q_offset: int = 0,
):
    """Online-softmax attention. q (B,T,H,hd), k/v (B,S,Hkv,hd) -> (B,T,H,hd).

    Never materialises (T,S); scans over S in `kv_block` chunks keeping
    running (max, sum, acc). GQA: H % Hkv == 0, kv heads broadcast.
    `q_offset`: absolute position of q[0] (for prefill q==kv it is 0).
    """
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]  # MLA: value head dim may differ from qk head dim
    assert H % Hkv == 0
    g = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    # pad S to a multiple of kv_block
    pad = (-S) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nblk = Sp // kv_block

    qf = (q * scale).astype(jnp.float32).reshape(B, T, Hkv, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(T)

    kb = kf.reshape(B, nblk, kv_block, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(B, nblk, kv_block, Hkv, hd_v).transpose(1, 0, 2, 3, 4)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kblk, vblk, blk_idx = inp  # (B, kv_block, Hkv, hd)
        kv_pos = blk_idx * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bthgd,bshd->bthgs", qf, kblk)  # (B,T,Hkv,g,kv_block)
        mask = jnp.broadcast_to(kv_pos[None, :] < S, (T, kv_pos.shape[0]))  # padding
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bthgs,bshd->bthgd", p, vblk)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, T, Hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, g), jnp.float32)
    a0 = jnp.zeros((B, T, Hkv, g, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, T, H, hd_v).astype(q.dtype)


@functools.cache
def _flash_attention_ad(causal: bool, window: int | None):
    """Differentiable flash attention: Pallas kernel forward, blockwise-oracle
    backward.  The kernel itself has no VJP rule (it is a fused forward); on
    the backward pass we recompute the attention with `blockwise_attention`
    — numerically the same online softmax — and transpose through that.
    Residuals are just (q, k, v): activation memory stays O(T·hd), never
    O(T·S), which is the whole point of putting flash on the training path."""

    @jax.custom_vjp
    def fa(q, k, v):
        from repro.kernels.flash_attention import flash_attention

        return flash_attention(q, k, v, causal=causal, window=window)

    def fwd(q, k, v):
        return fa(q, k, v), (q, k, v)

    def bwd(res, ct):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q, k, v: blockwise_attention(q, k, v, causal=causal, window=window),
            q, k, v,
        )
        return vjp(ct)

    fa.defvjp(fwd, bwd)
    return fa


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int | None = None):
    """Single-step decode: q (B,1,H,hd) vs cache (B,S,Hkv,hd); positions
    >= cache_len are masked. Sliding-window caches are ring buffers, so all
    live entries are valid and `window` masking is already structural."""
    B, T, H, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).astype(jnp.float32).reshape(B, T, Hkv, g, hd)
    s = jnp.einsum("bthgd,bshd->bthgs", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)
    valid = pos[None, :] < cache_len[:, None]  # cache_len: (B,)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bthgs,bshd->bthgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA block apply
# --------------------------------------------------------------------------


def _project_qkv(cfg: ArchConfig, p, x, positions):
    B, T, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, h, hd)
    k = k.reshape(B, T, hkv, hd)
    v = v.reshape(B, T, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def attention_forward(cfg: ArchConfig, p, x, *, window: int | None = None):
    """Training / prefill self-attention (causal)."""
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _project_qkv(cfg, p, x, positions)
    if cfg.use_flash:
        out = _flash_attention_ad(True, window)(q, k, v)
    else:
        out = blockwise_attention(q, k, v, causal=True, window=window)
    return out.reshape(B, T, -1) @ p["wo"]


def attention_decode(cfg: ArchConfig, p, x, cache: dict, *, window: int | None = None):
    """One-token decode. cache = {"k": (B,S,Hkv,hd), "v": ..., "len": (B,)}.

    Full-attention caches write at index `len`; sliding-window caches are ring
    buffers written at `len % S`.
    """
    B, T, _ = x.shape
    assert T == 1
    positions = cache["len"][:, None]  # absolute position
    q, k, v = _project_qkv(cfg, p, x, positions)
    S = cache["k"].shape[1]
    slot = cache["len"] % S if window is not None else jnp.minimum(cache["len"], S - 1)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    new_len = cache["len"] + 1
    eff_len = jnp.minimum(new_len, S) if window is not None else new_len
    out = decode_attention(q, k_cache, v_cache, eff_len, window=window)
    y = out.reshape(B, T, -1) @ p["wo"]
    return y, {"k": k_cache, "v": v_cache, "len": new_len}


def init_attn_cache(cfg: ArchConfig, batch: int, capacity: int, dtype) -> dict:
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, capacity, hkv, hd), dtype),
        "v": jnp.zeros((batch, capacity, hkv, hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# --------------------------------------------------------------------------


def _mla_q(cfg: ArchConfig, p, x, positions):
    m = cfg.mla
    B, T, _ = x.shape
    h = cfg.num_heads
    if m.q_lora_rank:
        q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, T, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_forward(cfg: ArchConfig, p, x):
    """Training/prefill MLA: materialise per-head K/V from the latent."""
    m = cfg.mla
    B, T, _ = x.shape
    h = cfg.num_heads
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_nope, q_rope = _mla_q(cfg, p, x, positions)

    kv_a = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope.reshape(B, T, 1, m.qk_rope_head_dim), cos, sin)

    kv = (c_kv @ p["wkv_b"]).reshape(B, T, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, T, h, m.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    out = blockwise_attention(q, k, v, causal=True)
    return out.reshape(B, T, -1) @ p["wo"]


def mla_decode(cfg: ArchConfig, p, x, cache: dict):
    """Absorbed-form decode: the cache holds only (c_kv, k_rope) — MLA's point.

    score = q_nope^T W_ukT c_kv + q_rope^T k_rope;  out = (probs @ c_kv) W_uv.
    cache = {"c_kv": (B,S,r), "k_rope": (B,S,dr), "len": (B,)}.
    """
    m = cfg.mla
    B, T, _ = x.shape
    h = cfg.num_heads
    positions = cache["len"][:, None]
    q_nope, q_rope = _mla_q(cfg, p, x, positions)  # (B,1,h,*)

    kv_a = x @ p["wkv_a"]
    c_new, kr_new = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_new = rms_norm(c_new, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    kr_new = apply_rope(kr_new.reshape(B, T, 1, m.qk_rope_head_dim), cos, sin)[:, :, 0]

    bidx = jnp.arange(B)
    S = cache["c_kv"].shape[1]
    slot = jnp.minimum(cache["len"], S - 1)
    c_kv = cache["c_kv"].at[bidx, slot].set(c_new[:, 0])
    k_rope = cache["k_rope"].at[bidx, slot].set(kr_new[:, 0])
    new_len = cache["len"] + 1

    w_uk, w_uv = jnp.split(
        p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim),
        [m.qk_nope_head_dim],
        axis=-1,
    )
    # absorb: q_abs (B,1,h,r)
    q_abs = jnp.einsum("bthd,rhd->bthr", q_nope, w_uk)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("bthr,bsr->bths", q_abs, c_kv) + jnp.einsum(
        "bthd,bsd->bths", q_rope, k_rope
    )
    s = s.astype(jnp.float32) * scale
    valid = jnp.arange(S)[None, :] < new_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bths,bsr->bthr", probs, c_kv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bthr,rhd->bthd", ctx, w_uv)
    y = out.reshape(B, T, -1) @ p["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope, "len": new_len}


def init_mla_cache(cfg: ArchConfig, batch: int, capacity: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
