"""Feed-forward blocks: gated dense FFN and Mixture-of-Experts.

MoE dispatch (TPU adaptation, recorded in DESIGN.md): token-choice top-k
routing is realised with an *expert-choice capacity* dispatch — each expert
gathers its top-C tokens, C = num_tokens * k / E — which keeps every shape
static (XLA requirement), matches top-k FLOPs exactly, and maps onto
expert-parallel sharding (experts on the `model` mesh axis) with the same
all-to-all-shaped communication as a GPU token-shuffle. An exact dense top-k
path (`method="dense_topk"`, computes every expert then masks) is kept for
small-scale correctness tests.

DeepSeek-V3 details honoured: `num_shared_experts` always-on experts added to
the routed output; sigmoid router scores with top-k renormalisation; load
balance auxiliary loss (Switch-style) returned to the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import activation, dense_init


def _constrain(x, *axes):
    """Best-effort sharding constraint; no-op without a mesh context (tests)."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*axes))
    except Exception:  # no mesh / unknown axis names
        return x


# --------------------------------------------------------------------------
# dense FFN
# --------------------------------------------------------------------------


def init_ffn(cfg: ArchConfig, key, dtype, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "silu":  # gated (SwiGLU-family)
        return {
            "w_gate": dense_init(k1, d, f, dtype=dtype),
            "w_in": dense_init(k2, d, f, dtype=dtype),
            "w_out": dense_init(k3, f, d, dtype=dtype),
        }
    return {
        "w_in": dense_init(k1, d, f, dtype=dtype),
        "b_in": jnp.zeros((f,), dtype),
        "w_out": dense_init(k2, f, d, dtype=dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


def ffn_forward(cfg: ArchConfig, p: dict, x):
    act = activation(cfg.act)
    if "w_gate" in p:
        return (act(x @ p["w_gate"]) * (x @ p["w_in"])) @ p["w_out"]
    return act(x @ p["w_in"] + p["b_in"]) @ p["w_out"] + p["b_out"]


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------


def init_moe(cfg: ArchConfig, key, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k_r, k_g, k_i, k_o, k_s = jax.random.split(key, 5)
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": dense_init(k_r, d, E, dtype=jnp.float32),  # router math in f32
        "w_gate": (jax.random.normal(k_g, (E, d, f)) * scale_in).astype(dtype),
        "w_in": (jax.random.normal(k_i, (E, d, f)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k_o, (E, f, d)) * scale_out).astype(dtype),
    }
    if cfg.num_shared_experts:
        ks = jax.random.split(k_s, 3)
        fs = f * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[0], d, fs, dtype=dtype),
            "w_in": dense_init(ks[1], d, fs, dtype=dtype),
            "w_out": dense_init(ks[2], fs, d, dtype=dtype),
        }
    return p


def _router_probs(cfg: ArchConfig, p, x_flat):
    """x_flat (N, d) -> probs (N, E) in f32. DeepSeek-V3 uses sigmoid scores;
    classic MoEs use softmax. We use softmax for <=32 experts, sigmoid above."""
    logits = x_flat.astype(jnp.float32) @ p["router"]
    if cfg.num_experts > 32:
        return jax.nn.sigmoid(logits)
    return jax.nn.softmax(logits, axis=-1)


def _load_balance_loss(probs, E: int):
    """Switch-style: E * sum_e (mean prob_e) * (mean assignment_e) using soft
    assignment (differentiable, collapses to the standard form)."""
    me = jnp.mean(probs, axis=0)
    return E * jnp.sum(me * me)


def moe_forward(cfg: ArchConfig, p: dict, x, *, method: str = "expert_choice",
                capacity_factor: float = 1.0):
    """x (B, T, d) -> (y (B, T, d), aux_loss scalar)."""
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    act = activation(cfg.act)
    if method == "expert_choice" and cfg.moe_shardmap:
        # manual-collective interior (models/moe_shardmap.py): provably-local
        # dispatch/combine; one (n_loc, d) psum over `model` per layer instead
        # of the GSPMD operand-replicated scatter + full-activation all-reduce.
        from repro.models import moe_shardmap as msm
        from repro.sharding.ctx import current_mesh

        mesh = current_mesh()
        if mesh is not None and msm.shardmap_supported(cfg, mesh, B):
            y, aux = msm.moe_routed_shardmap(cfg, p, x, mesh,
                                             capacity_factor=capacity_factor)
            aux = aux * cfg.router_aux_coef
            if cfg.num_shared_experts:
                sp = p["shared"]
                xf = x.reshape(B * T, d)
                y = (y.reshape(B * T, d)
                     + (act(xf @ sp["w_gate"]) * (xf @ sp["w_in"])) @ sp["w_out"]
                     ).reshape(B, T, d)
            return y, aux

    xf = x.reshape(B * T, d)
    N = B * T
    probs = _router_probs(cfg, p, xf)  # (N, E) f32
    aux = _load_balance_loss(probs, E) * cfg.router_aux_coef

    if method == "dense_topk":
        # exact token-choice top-k: run every expert on every token, mask.
        topv, topi = jax.lax.top_k(probs, k)
        gates = jnp.zeros_like(probs).at[jnp.arange(N)[:, None], topi].set(topv)
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
        h = jnp.einsum("nd,edf->nef", xf, p["w_gate"])
        u = jnp.einsum("nd,edf->nef", xf, p["w_in"])
        y_e = jnp.einsum("nef,efd->ned", act(h) * u, p["w_out"])
        y = jnp.einsum("ne,ned->nd", gates.astype(x.dtype), y_e)
    elif method == "expert_choice":
        # group-limited expert choice: route within G token groups (G=1 ->
        # global routing, the paper-faithful baseline). With moe_groups > 1
        # the groups are the BATCH ROWS — the batch dim is already sharded
        # over `data`, so routing/gather/scatter and the expert matmuls stay
        # shard-local with no resharding (the TPU analogue of DeepSeek-V3's
        # node-limited routing; EXPERIMENTS.md §Perf iteration 1).
        G = B if (cfg.moe_groups > 1 and T * k >= E) else 1
        n = N // G
        cap = max(1, int(n * k * capacity_factor) // E)
        xg = xf.reshape(G, n, d)
        pg = probs.reshape(G, n, E)
        scores = pg.transpose(0, 2, 1)  # (G, E, n)
        g, idx = jax.lax.top_k(scores, cap)  # (G, E, C)
        xe = jnp.take_along_axis(
            xg, idx.reshape(G, E * cap)[..., None], axis=1
        ).reshape(G, E, cap, d)
        h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
        u = jnp.einsum("gecd,edf->gecf", xe, p["w_in"])
        ye = jnp.einsum("gecf,efd->gecd", act(h) * u, p["w_out"])
        ye = ye * g[..., None].astype(x.dtype)
        flat_idx = idx.reshape(G, E * cap)
        ye_flat = ye.reshape(G, E * cap, d)
        if G > 1:
            # pull expert outputs back to the tokens' home shards BEFORE the
            # combine scatter (one cheap all-to-all of N*k*d instead of
            # operand-replicated scatter + giant all-reduce)
            ye_flat = _constrain(ye_flat, "data", None, None)
            flat_idx = _constrain(flat_idx, "data", None)
        y = jnp.zeros((G, n, d), x.dtype)
        y = jax.vmap(lambda yi, ii, vi: yi.at[ii].add(vi))(y, flat_idx, ye_flat)
        mass = jax.vmap(lambda ii, gi: jnp.zeros((n,), jnp.float32).at[ii].add(gi))(
            flat_idx, g.reshape(G, E * cap)
        )
        y = (y / jnp.maximum(mass, 1e-9)[..., None].astype(x.dtype)).reshape(N, d)
    else:
        raise ValueError(method)

    if cfg.num_shared_experts:
        sp = p["shared"]
        y = y + (act(xf @ sp["w_gate"]) * (xf @ sp["w_in"])) @ sp["w_out"]
    return y.reshape(B, T, d), aux
