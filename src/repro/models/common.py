"""Shared neural building blocks (pure JAX, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, n_in, n_out, *, scale: float | None = None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(n_in)
    return (jax.random.normal(key, (n_in, n_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps) * weight + bias
    return out.astype(x.dtype)


def rope_angles(positions, head_dim: int, theta: float):
    """positions: (..., T) int -> cos, sin of shape (..., T, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., T, H, D); cos/sin: (..., T, 1-broadcastable, D/2). Rotate-half convention."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


def cross_entropy_loss(logits, labels, *, z_loss: float = 0.0):
    """logits (B, T, V) any float dtype; labels (B, T) int. Mean NLL in f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
