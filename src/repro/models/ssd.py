"""Mamba-2 SSD (state-space duality) block — chunked TPU-native implementation.

The recurrence per head (scalar decay a_t, state S in R^{P x N}):
    S_t = a_t * S_{t-1} + x_t B_t^T          (x_t in R^P, B_t in R^N)
    y_t = S_t C_t + D * x_t                  (C_t in R^N)

GPU Mamba-2 uses a fused Triton scan; the TPU adaptation (DESIGN.md §3) is the
*chunked dual form*: split T into chunks of length Q, compute intra-chunk
contributions as a masked (Q x Q) matmul (MXU-friendly), and carry only the
(H, P, N) state across chunks with a cheap lax.scan of length T/Q. Memory is
O(T·P + (T/Q)·P·N) instead of O(T·P·N).

Sharding note (EXPERIMENTS.md §Perf, pair 2): the projections for the wide
x/z streams (sharded on `model`) are SEPARATE from the tiny B/C/dt streams
(replicated). Mamba-2's reference code fuses them into one in_proj + one conv,
which on a TP mesh strands the B/C channels on individual model shards and
forces per-layer reshuffles; splitting them is mathematically identical.

Layout: x (B, T, H, P); a (B, T, H) in (0,1); B/C (B, T, N) (ngroups=1,
broadcast over heads like Mamba-2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense_init, rms_norm


def ssd_chunked(x, log_a, Bm, Cm, *, chunk: int):
    """x (B,T,H,P), log_a (B,T,H) (log decay, <=0), Bm/Cm (B,T,N).

    Returns y (B,T,H,P) and final state (B,H,P,N).
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P)
    lc = log_a.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    # cumulative log-decay within each chunk: csum[t] = sum_{u<=t} log_a[u]
    csum = jnp.cumsum(lc, axis=2)  # (B,nc,Q,H)

    # ---- intra-chunk (dual / attention-like) term ----
    # M[t,s] = exp(csum[t] - csum[s]) for s <= t (decay from s+1..t)
    seg = csum[:, :, :, None, :] - csum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # double-where: masked (s > t) entries have seg >> 0, exp overflows to
    # inf and d(exp)=inf would leak NaN through the where's backward.
    seg = jnp.where(tri, seg, 0.0)
    M = jnp.where(tri, jnp.exp(seg), 0.0)
    # scores[t,s] = C_t . B_s
    scores = jnp.einsum("bctn,bcsn->bcts", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", scores, M, xc.astype(jnp.float32))

    # ---- chunk-boundary states ----
    # state contribution of chunk c: sum_s exp(csum[Q-1] - csum[s]) * x_s B_s^T
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcsh,bcshp,bcsn->bchpn", decay_to_end, xc.astype(jnp.float32),
                     Bc.astype(jnp.float32))
    A_c = jnp.exp(csum[:, :, -1, :])  # total chunk decay (B,nc,H)

    # ---- inter-chunk scan over nc chunks (carry (B,H,P,N)) ----
    def step(S_prev, inp):
        A_k, S_k = inp  # (B,H), (B,H,P,N)
        S_new = A_k[..., None, None] * S_prev + S_k
        return S_new, S_prev  # emit the state *entering* the chunk

    S0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    S_final, S_in = jax.lax.scan(
        step, S0, (A_c.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4))
    )
    S_in = S_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # ---- inter-chunk output: y_t += C_t . (decay_from_start[t] * S_in) ----
    decay_from_start = jnp.exp(csum)  # (B,nc,Q,H)
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", Cc.astype(jnp.float32),
                         decay_from_start, S_in)

    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y.astype(x.dtype), S_final


def ssd_decode_step(x, log_a, Bm, Cm, state):
    """Single token: x (B,H,P), log_a (B,H), Bm/Cm (B,N), state (B,H,P,N)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = a * state + jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32),
                                   Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    return y.astype(x.dtype), state


# --------------------------------------------------------------------------
# full Mamba-2 block (in_proj -> short conv -> SSD -> gated out_proj)
# --------------------------------------------------------------------------


def init_ssd_block(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    ks = jax.random.split(key, 7)
    return {
        # wide streams (model-sharded): z (gate) and x
        "w_in": dense_init(ks[0], d, 2 * d_in, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_in)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        # narrow streams (replicated): B, C (state projections) and dt
        "w_bc": dense_init(ks[2], d, 2 * N, dtype=dtype),
        "conv_bc_w": (jax.random.normal(ks[3], (cfg.ssm_conv, 2 * N)) * 0.2).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * N,), dtype),
        "w_dt": dense_init(ks[5], d, H, dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), dtype),
        "norm": jnp.ones((d_in,), dtype),
        "w_out": dense_init(ks[6], d_in, d, dtype=dtype),
    }


def _causal_conv(xs, w, b):
    """Depthwise causal 1-D conv: xs (B,T,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xs.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _streams(cfg: ArchConfig, p: dict, x):
    """Project x -> (z, x_stream, B, C, dt). x: (B,T,d) or (B,d)."""
    d_in = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    zx = x @ p["w_in"]
    z, xs = jnp.split(zx, [d_in], axis=-1)
    bc = x @ p["w_bc"]
    dt = x @ p["w_dt"]
    return z, xs, bc, dt, d_in, N, H


def ssd_block_forward(cfg: ArchConfig, p: dict, x):
    """x (B,T,d) -> (B,T,d). Training / prefill path."""
    B, T, d = x.shape
    z, xs, bc, dt, d_in, N, H = _streams(cfg, p, x)
    xs = _causal_conv(xs, p["conv_w"], p["conv_b"])
    bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"])
    Bm, Cm = jnp.split(bc, [N], axis=-1)
    xs = xs.reshape(B, T, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    log_a = -jnp.exp(p["A_log"])[None, None, :] * dt  # log decay <= 0
    # dt also scales the input (mamba2 discretisation)
    x_in = xs * dt[..., None].astype(xs.dtype)
    pad_t = (-T) % cfg.ssm_chunk
    if pad_t:
        x_in = jnp.pad(x_in, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad_t), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_t), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_t), (0, 0)))
    y, _ = ssd_chunked(x_in, log_a, Bm, Cm, chunk=cfg.ssm_chunk)
    y = y[:, :T]
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, T, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["w_out"]


def init_ssd_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * N), dtype),
        "state": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
    }


def ssd_block_decode(cfg: ArchConfig, p: dict, x, cache: dict):
    """x (B,1,d); constant-time decode (this is why mamba2 runs long_500k)."""
    B, _, d = x.shape
    z, xs, bc, dt, d_in, N, H = _streams(cfg, p, x[:, 0])
    # rolling conv states (x stream and bc stream separately)
    hist = jnp.concatenate([cache["conv"], xs[:, None, :]], axis=1)  # (B,K,d_in)
    xs_t = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"])
    hist_bc = jnp.concatenate([cache["conv_bc"], bc[:, None, :]], axis=1)
    bc_t = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist_bc, p["conv_bc_w"]) + p["conv_bc_b"]
    )
    Bm, Cm = jnp.split(bc_t, [N], axis=-1)
    xs_t = xs_t.reshape(B, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    log_a = -jnp.exp(p["A_log"])[None, :] * dt
    x_in = xs_t * dt[..., None].astype(xs_t.dtype)
    y, state = ssd_decode_step(x_in, log_a, Bm, Cm, cache["state"])
    y = y + p["D"][None, :, None] * xs_t
    y = y.reshape(B, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z)[:, None, :], p["norm"], cfg.norm_eps)
    return y @ p["w_out"], {"conv": hist[:, 1:], "conv_bc": hist_bc[:, 1:], "state": state}
