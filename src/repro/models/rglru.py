"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (diagonal, per-channel):
    r_t = sigmoid(W_r x_t),  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)            c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses jax.lax.associative_scan over T (O(log T) depth, TPU-friendly);
decode is a constant-time state update — which is what qualifies
recurrentgemma for the 500k-context shape.

The full residual block is Griffin's "recurrent block": linear in-proj to
(x branch, gate branch), temporal conv1d(4) on the x branch, RG-LRU, gated
out-projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense_init

_C = 8.0


def _lru_scan(a, u):
    """h_t = a_t h_{t-1} + u_t via associative scan. a, u: (B, T, W)."""

    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, a2 * u1 + u2

    af, uf = jax.lax.associative_scan(combine, (a, u), axis=1)
    return uf  # uf[t] = sum_s (prod_{s<u<=t} a) u_s  == h_t with h_{-1}=0


def init_rglru_block(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a in (0.9, 0.999) at r=0.5 (paper's stable range)
    lam = jax.random.uniform(ks[4], (w,), jnp.float32, 0.3, 0.8)
    return {
        "w_x": dense_init(ks[0], d, w, dtype=dtype),
        "w_gate": dense_init(ks[1], d, w, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (4, w)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": dense_init(ks[3], w, w, dtype=dtype),
        "w_i": dense_init(ks[5], w, w, dtype=dtype),
        "lambda": lam,
        "w_out": dense_init(jax.random.fold_in(key, 9), w, d, dtype=dtype),
    }


def _causal_conv(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(K)) + b


def _gates(p, xb):
    r = jax.nn.sigmoid(xb @ p["w_r"]).astype(jnp.float32)
    i = jax.nn.sigmoid(xb @ p["w_i"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r  # (B,*,W) f32
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * xb.astype(jnp.float32)


def rglru_block_forward(cfg: ArchConfig, p: dict, x):
    """x (B,T,d) -> (B,T,d)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xb = x @ p["w_x"]
    xb = _causal_conv(xb, p["conv_w"], p["conv_b"])
    a, u = _gates(p, xb)
    h = _lru_scan(a, u).astype(x.dtype)
    return (h * gate) @ p["w_out"]


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),  # last K-1 conv inputs
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_block_decode(cfg: ArchConfig, p: dict, x, cache: dict):
    """x (B,1,d) -> (B,1,d); O(1) state update."""
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"])
    xb = x[:, 0] @ p["w_x"]
    hist = jnp.concatenate([cache["conv"], xb[:, None, :]], axis=1)  # (B,4,W)
    xb = jnp.einsum("bkw,kw->bw", hist, p["conv_w"]) + p["conv_b"]
    a, u = _gates(p, xb)
    h = a * cache["h"] + u
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y[:, None, :], {"conv": hist[:, 1:], "h": h}
