"""Learning-rate schedules from the paper's theory and experiments.

Remark 4.2 / 4.4 and Appendix B.1:
  * strongly convex, option 1:  η_k = 1 / (2 L K sqrt(k+1))
  * strongly convex, option 2:  η_k = 1 / (2 L K^q),   q >= 2
  * non-convex:                 K = T^{q1}, η = 1/(L T^{q2}),
                                q1 in (0,1), q2 >= q1, 1 + q1 > q2
  * experiments (B.1):          η_k = 1 / (K sqrt(k+1))   (L folded to 1)

All schedules return a function k -> eta_k for k in {0..K-1}.
"""
from __future__ import annotations

import math
from typing import Callable

Schedule = Callable[[int], float]


def paper_sqrt_schedule(K: int, L: float = 1.0, *, half: bool = True) -> Schedule:
    """η_k = 1/(2LK sqrt(k+1)); with half=False, the B.1 variant 1/(K sqrt(k+1))."""
    denom = (2.0 if half else 1.0) * L * K

    def eta(k: int) -> float:
        return 1.0 / (denom * math.sqrt(k + 1))

    return eta


def paper_power_schedule(K: int, q: float = 2.0, L: float = 1.0) -> Schedule:
    """η_k = 1/(2 L K^q), constant in k. q >= 2 gives the O(1/K^{q-1}) rate."""
    value = 1.0 / (2.0 * L * (K ** q))
    return lambda k: value


def nonconvex_schedule(T: int, q1: float = 0.5, q2: float = 0.5, L: float = 1.0) -> Schedule:
    """η = 1/(L T^{q2}) with K = T^{q1}; validity: q1 in (0,1), q2>=q1, 1+q1>q2."""
    assert 0 < q1 < 1 and q2 >= q1 and 1 + q1 > q2, "invalid (q1, q2) per Remark 4.4"
    value = 1.0 / (L * (T ** q2))
    return lambda k: value


def constant_schedule(eta: float) -> Schedule:
    return lambda k: eta


def schedule_satisfies_theorem(K: int, sched: Schedule, L: float, *, strongly_convex: bool) -> bool:
    """Check the step-size premise of Thm 4.1 (η_k <= 1/(2LK)) / Thm 4.3 (η_k <= 1/(LK))."""
    bound = 1.0 / ((2.0 if strongly_convex else 1.0) * L * K)
    return all(sched(k) <= bound + 1e-12 for k in range(K))


def nonconvex_K(T: int, q1: float = 0.5) -> int:
    return max(1, round(T ** q1))
