"""LocalOpt — the client-held local-optimizer plug point of the round engine.

Each FL client may carry private optimizer state (momentum, Adam moments)
across its local steps, across interactions, and across rounds.  That state
is *client-held*: it lives in the driver's per-cluster/per-client stacked
state pytrees and never traverses a `Channel` — uplinks carry model deltas
only, so switching SGD -> AdamW changes zero bits on the wire (pinned by
tests/test_local_opt.py).

Implementations are frozen dataclasses (hashable) so the engine can cache
one compiled round function per (model, channel, opt) triple.  `PlainSGD`
is the default and is *the* seed-parity path: its update is the exact
``w - lr * g`` expression the pre-FedTask engine inlined, so default-path
fixed-seed trajectories are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_step
from repro.optim.sgd import SGDConfig, sgd_init, sgd_step

PyTree = Any


@runtime_checkable
class LocalOpt(Protocol):
    """Per-client local optimizer: state init + one step. Traceable."""

    def init(self, params: PyTree) -> PyTree:
        """Fresh optimizer state for one client (empty pytree if stateless)."""
        ...

    def step(self, params: PyTree, state: PyTree, grads: PyTree, lr) -> tuple[PyTree, PyTree]:
        """One local update: -> (new_params, new_state)."""
        ...


@dataclasses.dataclass(frozen=True)
class PlainSGD:
    """Stateless ``w <- w - lr * g`` — the paper's Eq. (5) local step."""

    def init(self, params: PyTree) -> PyTree:
        return ()

    def step(self, params, state, grads, lr):
        return jax.tree.map(lambda w, g: w - lr * g, params, grads), state


@dataclasses.dataclass(frozen=True)
class MomentumSGD:
    """SGD with (optionally Nesterov) momentum, state = one velocity pytree."""

    momentum: float = 0.9
    weight_decay: float = 0.0
    nesterov: bool = False

    def _config(self) -> SGDConfig:
        return SGDConfig(self.momentum, self.weight_decay, self.nesterov)

    def init(self, params: PyTree) -> PyTree:
        return sgd_init(params, self._config())

    def step(self, params, state, grads, lr):
        return sgd_step(params, grads, state, lr, self._config())


@dataclasses.dataclass(frozen=True)
class AdamWOpt:
    """Client-held AdamW (first/second moments + step count stay local)."""

    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def _config(self) -> AdamWConfig:
        return AdamWConfig(self.b1, self.b2, self.eps, self.weight_decay)

    def init(self, params: PyTree) -> PyTree:
        return adamw_init(params)

    def step(self, params, state, grads, lr):
        return adamw_step(params, grads, state, lr, self._config())
