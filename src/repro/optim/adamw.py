"""AdamW for the transformer substrate (examples/train driver)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params: PyTree) -> dict:
    return {
        "mu": jax.tree.map(jnp.zeros_like, params),
        "nu": jax.tree.map(jnp.zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_step(
    params: PyTree, grads: PyTree, state: dict, lr, config: AdamWConfig = AdamWConfig()
) -> tuple[PyTree, dict]:
    count = state["count"] + 1
    mu = jax.tree.map(lambda m, g: config.b1 * m + (1 - config.b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: config.b2 * v + (1 - config.b2) * g * g, state["nu"], grads)
    c1 = 1 - config.b1 ** count.astype(jnp.float32)
    c2 = 1 - config.b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / c1
        vhat = v / c2
        return p - lr * (mhat / (jnp.sqrt(vhat) + config.eps) + config.weight_decay * p)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}
