"""SGD (optionally with momentum) over parameter pytrees."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    momentum: float = 0.0
    weight_decay: float = 0.0
    nesterov: bool = False


def sgd_init(params: PyTree, config: SGDConfig = SGDConfig()) -> PyTree:
    if config.momentum == 0.0:
        return ()
    return jax.tree.map(jnp.zeros_like, params)


def sgd_step(
    params: PyTree, grads: PyTree, opt_state: PyTree, lr, config: SGDConfig = SGDConfig()
) -> tuple[PyTree, PyTree]:
    if config.weight_decay:
        grads = jax.tree.map(lambda g, p: g + config.weight_decay * p, grads, params)
    if config.momentum == 0.0:
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new_params, opt_state
    new_state = jax.tree.map(lambda m, g: config.momentum * m + g, opt_state, grads)
    if config.nesterov:
        update = jax.tree.map(lambda m, g: config.momentum * m + g, new_state, grads)
    else:
        update = new_state
    new_params = jax.tree.map(lambda p, u: p - lr * u, params, update)
    return new_params, new_state
