from repro.optim.schedules import (
    paper_sqrt_schedule,
    paper_power_schedule,
    constant_schedule,
    nonconvex_schedule,
)
from repro.optim.sgd import sgd_init, sgd_step, SGDConfig
from repro.optim.adamw import adamw_init, adamw_step, AdamWConfig
from repro.optim.local import AdamWOpt, LocalOpt, MomentumSGD, PlainSGD

__all__ = [
    "paper_sqrt_schedule",
    "paper_power_schedule",
    "constant_schedule",
    "nonconvex_schedule",
    "sgd_init",
    "sgd_step",
    "SGDConfig",
    "adamw_init",
    "adamw_step",
    "AdamWConfig",
    "LocalOpt",
    "PlainSGD",
    "MomentumSGD",
    "AdamWOpt",
]
