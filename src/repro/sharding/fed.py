"""Population-scale federation: shard the engine's client/cluster axes.

The whole-run scan engine (`repro.core.engine`) stacks every per-client
quantity — batches, opt states, masks, PRNG subkeys — on leading
(clusters, clients) axes and vmaps over them.  That layout is exactly a
data-parallel device layout: this module partitions those stacked axes over
a ``("clusters", "clients")`` device mesh with `shard_map`, keeping the
Fed-CHS serial ES->ES chain a carried collective (the global params stay
replicated; cross-device communication happens only at aggregation points,
as `all_gather`s of the compressed uplinks).

Bit-parity contract
-------------------
A mesh run reproduces the single-device run of the same config exactly:
model params, eval metrics, and ledger aggregates are BIT-identical; the
per-round train-loss *log scalars* are bit-identical in grad mode and
within 1 ulp in delta modes (the lane-loss mean fuses with different
consumers under shard_map, the same reassociation the vmapped sweep
already documents in `core.sweep`; losses never feed back into training).
Pinned by tests/test_sharding_fed.py under forced 8 host devices.  One
backend caveat rides on top: XLA:CPU's batched-GEMM kernel choice can
depend on the vmap lane count for LARGE layers under the thread-starved
forced-host-device runtime (observed at 784x200, absent at <=128-wide
layers and absent under the default runtime), which perturbs local grads
at ~1e-7 before any of this module's collectives run.  The machinery
itself is width-exact:

  * aggregation is NOT a `psum` of partial sums — that would reassociate
    the gamma-weighted reduction.  Each shard compresses its local senders'
    deltas, the shards `all_gather` the compressed messages (tiled, in
    axis-index order == global slot order), and every device applies the
    SAME full-width einsum the unsharded body runs.
  * per-sender compression keys are `fold_in(sub, slot)` with GLOBAL slot
    ids (`axis_index * n_loc + arange(n_loc)`), so sender i sees the exact
    key it gets in the unsharded stack (`engine.compress_uplinks`).
  * client/cluster axes are zero-padded up to mesh-divisible widths: padded
    slots carry exact-zero gamma/mask (zero deltas, which every channel
    encodes to zero norms and decodes to exact zeros), and padded batch
    slots replicate slot 0 so their (discarded) local training stays
    finite — the same padding discipline the scan path already pins for
    ragged clusters.
  * gathered stacks are sliced back to the TRUE (unsharded) width before
    every cross-client reduction — a wider zero-tailed einsum is equal in
    exact arithmetic but lets XLA group the sum differently, so the
    reductions must see exactly the unsharded operands.

The single-device path is byte-for-byte untouched: with ``mesh=None`` the
drivers never import a sharded body, and `ScanPlan.chunk_fn`/`xs_put`
default to the unsharded chunk and plain `device_put`.

Axis mapping
------------
  * FedAvg / Fed-CHS: ONE cluster trains per round, so the flat client axis
    shards over BOTH mesh axes — ``P(("clusters", "clients"))``.
  * Hier-Local-QSGD: independent clusters shard over ``"clusters"``,
    clients within an ES shard over ``"clients"``; the intra-cluster
    aggregate gathers over ``"clients"`` only, the ES->PS hop over
    ``"clusters"`` only.
  * WRWGD (n = 1): degrades gracefully — the walk's single client pads to
    mesh width with zero-gamma slots (replicated compute, exact result).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import ScanPlan, _freeze_masked, _jit_round
from repro.core.oracles import local_opt_steps
from repro.data.sources import put_sharded
from repro.sharding.ctx import current_mesh
from repro.sharding.specs import FED_AXES, fed_engine_pspecs
from repro.utils import tree_add, tree_sub

PyTree = Any


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map (newer jax) with fallback to the experimental module.

    Replication checking is disabled either way: the bodies return
    all-gathered (hence replicated) values that the checker cannot prove
    replicated across the un-gathered axis."""
    try:
        return jax.shard_map(  # type: ignore[attr-defined]
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map

        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )


def resolve_mesh(mesh: Mesh | None) -> Mesh | None:
    """The federation mesh a driver should shard over, or None.

    An explicit ``config.mesh`` wins; otherwise adopt the ambient
    `sharding.ctx.model_mesh` mesh IF it is a federation mesh (axis names
    exactly ``("clusters", "clients")`` — a tensor-parallel model mesh is
    never silently adopted).  A 1-device mesh resolves to None: sharding a
    singleton mesh only adds collective overhead."""
    if mesh is None:
        amb = current_mesh()
        if amb is not None and tuple(amb.axis_names) == FED_AXES:
            mesh = amb
    if mesh is None:
        return None
    assert tuple(mesh.axis_names) == FED_AXES, (
        f"federation mesh must have axes {FED_AXES}, got {tuple(mesh.axis_names)}"
    )
    return mesh if mesh.size > 1 else None


# --------------------------------------------------------------------------
# padding: client/cluster axes grow to mesh-divisible widths
# --------------------------------------------------------------------------


def _ceil_to(n: int, q: int) -> int:
    return -(-n // q) * q


def _pad_np(a: np.ndarray, axis: int, to: int, *, edge0: bool) -> np.ndarray:
    """Pad `a` to width `to` along `axis`: zeros (weights/masks) or copies of
    index 0 (batches/subkeys — padded slots must stay finite/valid)."""
    pad = to - a.shape[axis]
    if pad <= 0:
        return a
    if edge0:
        reps = np.take(a, np.zeros(pad, np.intp), axis=axis)
        return np.concatenate([a, reps], axis=axis)
    width = [(0, 0)] * a.ndim
    width[axis] = (0, pad)
    return np.pad(a, width)


def _pad_leaf(a, axis: int, to: int):
    """Device-array edge0 pad (opt-state leaves; masked slots stay frozen)."""
    pad = to - a.shape[axis]
    if pad <= 0:
        return a
    reps = jnp.take(a, jnp.zeros(pad, jnp.int32), axis=axis)
    return jnp.concatenate([a, reps], axis=axis)


# --------------------------------------------------------------------------
# sharded scan bodies — the mesh twins of engine.scan_*_body.  Same
# (carry, x, consts) signatures, same per-slot computation; the only
# difference is WHERE each slot lives and the all_gather at each
# aggregation point.
# --------------------------------------------------------------------------


def _compress_shard(channel, deltas: PyTree, sub, slots):
    """`engine.compress_uplinks` for one shard of the stacked uplink:
    per-message channels key each local sender by its GLOBAL slot id, so the
    gathered stack carries exactly the keys the unsharded vmap hands out."""
    if getattr(channel, "per_message", False):
        return jax.vmap(
            lambda d, i: channel.compress(d, jax.random.fold_in(sub, i))
        )(deltas, slots)
    return channel.compress(deltas, sub)


def _gather(tree: PyTree, axes, axis: int = 0) -> PyTree:
    """Tiled all_gather in axis-index order — global slot order, so the
    downstream full-width einsum sees the unsharded operand layout."""
    return jax.tree.map(
        lambda leaf: jax.lax.all_gather(leaf, axes, axis=axis, tiled=True), tree
    )


@functools.cache
def sharded_grad_body(model, n: int):
    """Mesh twin of `scan_grad_body` (untapped): local per-step grads,
    all-gathered and sliced back to the true width `n`, then the SAME gamma
    einsum + SGD step on every device.  x["batch"] local leaves
    (K, n_loc, B, ...); gammas arrive padded full-width replicated."""
    grad_fn = jax.vmap(jax.value_and_grad(model.loss), in_axes=(None, 0))

    def body(params, x, consts):
        gammas = x["gammas"][:n]

        def step(p, inp):
            b_k, lr_k = inp
            losses, grads = grad_fn(p, b_k)
            grads = _gather(grads, FED_AXES)
            losses = jax.lax.all_gather(losses, FED_AXES, axis=0, tiled=True)[:n]
            agg = jax.tree.map(
                lambda g: jnp.einsum("n,n...->...", gammas, g[:n]), grads
            )
            p = jax.tree.map(lambda w, g: w - lr_k * g, p, agg)
            return p, jnp.dot(gammas, losses)

        return jax.lax.scan(step, params, (x["batch"], x["lrs"]))

    return body


def _sharded_masked_round(model, channel, opt, n: int):
    """Mesh twin of `engine._masked_round_body` (untapped): the flat client
    axis is sharded over the whole mesh; gammas/mask arrive padded full-width
    replicated, the body slices its local padded window, and every gathered
    stack is cut back to the true width `n` before reducing."""
    multi_local = jax.vmap(local_opt_steps(model, opt), in_axes=(None, 0, 0, None))

    def round_fn(params, opt_state, batch, gammas, mask, lrs, subs):
        n_loc = jax.tree.leaves(batch)[0].shape[1]
        start = jax.lax.axis_index(FED_AXES) * n_loc
        slots = start + jnp.arange(n_loc)
        mask_loc = jax.lax.dynamic_slice_in_dim(mask, start, n_loc)
        gammas_t, mask_t = gammas[:n], mask[:n]

        def interaction(carry, inp):
            p, s = carry
            b, lr, sub = inp
            new_p, new_s, losses = multi_local(p, s, b, lr)
            new_s = _freeze_masked(mask_loc, new_s, s)
            raw = jax.tree.map(
                lambda a, base: (a - base[None])
                * mask_loc.reshape((-1,) + (1,) * (a.ndim - 1)),
                new_p,
                p,
            )
            deltas = _gather(_compress_shard(channel, raw, sub, slots), FED_AXES)
            agg = jax.tree.map(
                lambda dl: jnp.einsum("n,n...->...", gammas_t, dl[:n]), deltas
            )
            new_params = tree_add(p, agg)
            g_losses = jax.lax.all_gather(losses, FED_AXES, axis=0, tiled=True)[:n]
            loss = jnp.sum(g_losses * mask_t) / jnp.maximum(jnp.sum(mask_t), 1.0)
            return (new_params, new_s), loss

        (p, s), losses = jax.lax.scan(
            interaction, (params, opt_state), (batch, lrs, subs)
        )
        return p, s, losses

    return round_fn


@functools.cache
def sharded_delta_body(model, channel, opt, n: int):
    """Mesh twin of `scan_delta_body` (FedAvg)."""
    round_fn = _sharded_masked_round(model, channel, opt, n)

    def body(carry, x, consts):
        params, opt_state = carry
        params, opt_state, losses = round_fn(
            params, opt_state, x["batch"], x["gammas"], x["mask"], consts["lrs"],
            x["subs"],
        )
        return (params, opt_state), losses

    return body


@functools.cache
def sharded_cluster_delta_body(model, channel, opt, n: int):
    """Mesh twin of `scan_cluster_delta_body` (Fed-CHS): the per-round active
    cluster's opt rows are gathered/scattered by x["m"] exactly as on one
    device — the cluster axis of the opt stack is NOT sharded (only one
    cluster trains per round); the client axis within it is."""
    round_fn = _sharded_masked_round(model, channel, opt, n)

    def body(carry, x, consts):
        params, opt_all = carry
        m = x["m"]
        s_m = jax.tree.map(
            lambda leaf: jax.lax.dynamic_index_in_dim(leaf, m, 0, keepdims=False),
            opt_all,
        )
        params, new_s, losses = round_fn(
            params, s_m, x["batch"], x["gammas"], x["mask"], consts["lrs"], x["subs"]
        )
        opt_all = jax.tree.map(
            lambda leaf, ns: jax.lax.dynamic_update_index_in_dim(leaf, ns, m, 0),
            opt_all,
            new_s,
        )
        return (params, opt_all), losses

    return body


@functools.cache
def sharded_multi_body(model, channel, es_channel, opt, M: int, n: int):
    """Mesh twin of `scan_multi_body` (Hier-Local-QSGD): clusters shard over
    "clusters", clients within each over "clients".  Intra-cluster
    aggregation gathers over "clients" only; the ES->PS hop gathers the
    compressed cluster deltas over "clusters" and applies the true-width
    (`M`, `n` — padding sliced off) weighted aggregate on every device."""
    multi_local = jax.vmap(local_opt_steps(model, opt), in_axes=(None, 0, 0, None))

    def body(carry, x, consts):
        params, opt_state = carry
        batch, gammas, mask = x["batch"], x["gammas"], x["mask"]
        lead = jax.tree.leaves(batch)[0].shape
        M_loc, n_loc = lead[1], lead[2]
        c_start = jax.lax.axis_index("clusters") * M_loc
        r_start = jax.lax.axis_index("clients") * n_loc
        slots = r_start + jnp.arange(n_loc)  # global client slot within a cluster

        # local windows of the replicated full-width schedule rows
        gam_c = jax.lax.dynamic_slice_in_dim(gammas, c_start, M_loc)
        mask_c = jax.lax.dynamic_slice_in_dim(mask, c_start, M_loc)
        mask_loc = jax.lax.dynamic_slice_in_dim(mask_c, r_start, n_loc, axis=1)
        subs_c = jax.lax.dynamic_slice_in_dim(x["subs"], c_start, M_loc, axis=1)
        es_subs_c = jax.lax.dynamic_slice_in_dim(x["es_subs"], c_start, M_loc)

        cparams0 = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf[None], (M_loc,) + leaf.shape), params
        )

        def interaction(carry, inp):
            cp, s = carry
            b, lr, sub = inp

            def one_cluster(p_m, s_m, b_m, g_m, msk_m, mskloc_m, sub_m):
                new_p, new_s, losses = multi_local(p_m, s_m, b_m, lr)
                new_s = _freeze_masked(mskloc_m, new_s, s_m)
                raw = jax.tree.map(
                    lambda a, base: (a - base[None])
                    * mskloc_m.reshape((-1,) + (1,) * (a.ndim - 1)),
                    new_p,
                    p_m,
                )
                deltas = _gather(
                    _compress_shard(channel, raw, sub_m, slots), "clients"
                )
                agg = jax.tree.map(
                    lambda dl: jnp.einsum("n,n...->...", g_m[:n], dl[:n]), deltas
                )
                new_pm = tree_add(p_m, agg)
                g_losses = jax.lax.all_gather(
                    losses, "clients", axis=0, tiled=True
                )[:n]
                loss = (jnp.sum(g_losses * msk_m[:n])
                        / jnp.maximum(jnp.sum(msk_m[:n]), 1.0))
                return new_pm, new_s, loss

            cp, s, ys = jax.vmap(one_cluster)(cp, s, b, gam_c, mask_c, mask_loc, sub)
            return (cp, s), ys

        (cparams, opt_state), losses = jax.lax.scan(
            interaction, (cparams0, opt_state), (batch, consts["lrs"], subs_c)
        )

        # ES -> PS: compressed local-cluster deltas, gathered over "clusters",
        # true-width weighted aggregate + broadcast (replicated result)
        es_deltas = jax.vmap(
            lambda p_m, sub_m: es_channel.compress(tree_sub(p_m, params), sub_m)
        )(cparams, es_subs_c)
        es_deltas = _gather(es_deltas, "clusters")
        agg = jax.tree.map(
            lambda x_: jnp.einsum("m,m...->...", x["es_weights"][:M], x_[:M]),
            es_deltas,
        )
        new_params = tree_add(params, agg)
        losses = jax.lax.all_gather(losses, "clusters", axis=1, tiled=True)[:, :M]
        return (new_params, opt_state), losses

    return body


_BODY_OF = {
    "grad": lambda model, channel, es_channel, opt, M, n:
        sharded_grad_body(model, n),
    "delta": lambda model, channel, es_channel, opt, M, n:
        sharded_delta_body(model, channel, opt, n),
    "cluster_delta": lambda model, channel, es_channel, opt, M, n:
        sharded_cluster_delta_body(model, channel, opt, n),
    "multi": lambda model, channel, es_channel, opt, M, n:
        sharded_multi_body(model, channel, es_channel, opt, M, n),
}


# --------------------------------------------------------------------------
# the shard_map-wrapped chunk + plan rewriting
# --------------------------------------------------------------------------


@functools.cache
def sharded_chunk_fn(kind: str, model, channel, es_channel, opt, mesh: Mesh,
                     clusters: int | None, clients: int):
    """jit(shard_map(scan-over-rounds)) for one (body, mesh) pair — the
    sharded hot loop `run_scan` drives through `ScanPlan.chunk_fn`.  Cached
    so repeated runs of the same config/mesh (parity tests, sweeps of
    configs) compile once, exactly like `engine.scan_chunk_fn`.
    `clusters`/`clients` are the TRUE stacked widths the reductions slice
    gathered stacks back to (see the module docstring)."""
    body = _BODY_OF[kind](model, channel, es_channel, opt, clusters, clients)
    specs = fed_engine_pspecs(kind)
    # the chunk's xs stack the body's x under a leading rounds axis
    xs_specs = dict(specs["xs"])
    xs_specs["batch"] = P(None, *xs_specs["batch"])

    def chunk(carry, xs, consts):
        return jax.lax.scan(lambda c, x: body(c, x, consts), carry, xs)

    return _jit_round(
        _shard_map(
            chunk,
            mesh=mesh,
            in_specs=(specs["carry"], xs_specs, P()),
            out_specs=(specs["carry"], specs["ys"]),
        )
    )


def _xs_shardings(xs: PyTree, kind: str, mesh: Mesh) -> PyTree:
    """NamedShardings mirroring one staged-xs pytree: batch leaves sharded on
    their client/cluster axes, schedule rows (gammas/mask/weights/subkeys)
    replicated."""
    batch_spec = fed_engine_pspecs(kind)["xs"]["batch"]
    chunk_batch = NamedSharding(mesh, P(None, *batch_spec))  # + leading chunk axis
    repl = NamedSharding(mesh, P())
    return {
        k: jax.tree.map(lambda _: chunk_batch if k == "batch" else repl, v)
        for k, v in xs.items()
    }


def shard_plan(plan: ScanPlan, mesh: Mesh, kind: str, *, model,
               channel=None, es_channel=None, opt=None,
               clients: int, clusters: int | None = None) -> ScanPlan:
    """Rewrite a single-device `ScanPlan` to execute on `mesh`.

    Pads the client (and, for "multi", cluster) axes of the staged inputs
    and the carry to mesh-divisible widths, installs the shard_map-wrapped
    chunk (`chunk_fn`) and the per-shard `device_put` (`xs_put`), and leaves
    everything else — schedule, recording, ledger glue — untouched.  The
    result is bit-identical to running `plan` unsharded (module docstring).
    """
    assert plan.obs is None, "telemetry is per-host state — unsupported on a mesh"
    assert kind in _BODY_OF, kind
    n_cl, n_ci = mesh.shape["clusters"], mesh.shape["clients"]

    if kind == "multi":
        assert clusters is not None
        M_pad = _ceil_to(clusters, n_cl)
        n_pad = _ceil_to(clients, n_ci)
    else:
        M_pad = None
        n_pad = _ceil_to(clients, n_cl * n_ci)

    stage0 = plan.stage

    def stage(idxs):
        xs = stage0(idxs)
        out = dict(xs)
        if kind == "multi":
            out["batch"] = jax.tree.map(
                lambda b: _pad_np(_pad_np(b, 3, n_pad, edge0=True),
                                  2, M_pad, edge0=True),
                xs["batch"],
            )
            for k in ("gammas", "mask"):
                out[k] = _pad_np(_pad_np(xs[k], 2, n_pad, edge0=False),
                                 1, M_pad, edge0=False)
            out["es_weights"] = _pad_np(xs["es_weights"], 1, M_pad, edge0=False)
            out["subs"] = _pad_np(xs["subs"], 2, M_pad, edge0=True)
            out["es_subs"] = _pad_np(xs["es_subs"], 1, M_pad, edge0=True)
        else:
            out["batch"] = jax.tree.map(
                lambda b: _pad_np(b, 2, n_pad, edge0=True), xs["batch"]
            )
            out["gammas"] = _pad_np(xs["gammas"], 1, n_pad, edge0=False)
            if "mask" in xs:
                out["mask"] = _pad_np(xs["mask"], 1, n_pad, edge0=False)
        return out

    # carry: params replicated; opt-state leaves sharded on their
    # client/cluster axes (padded slots replicate slot 0 — frozen by mask)
    specs = fed_engine_pspecs(kind)
    repl = NamedSharding(mesh, P())
    if kind == "grad":
        carry = jax.device_put(plan.carry, jax.tree.map(lambda _: repl, plan.carry))
    else:
        params, opt_state = plan.carry
        axis = 0 if kind == "delta" else 1  # client axis of the opt stack
        opt_state = jax.tree.map(lambda leaf: _pad_leaf(leaf, axis, n_pad), opt_state)
        if kind == "multi":
            opt_state = jax.tree.map(lambda leaf: _pad_leaf(leaf, 0, M_pad), opt_state)
        opt_ns = NamedSharding(mesh, specs["carry"][1])
        carry = (
            jax.device_put(params, jax.tree.map(lambda _: repl, params)),
            jax.device_put(opt_state, jax.tree.map(lambda _: opt_ns, opt_state)),
        )

    consts = jax.device_put(plan.consts, jax.tree.map(lambda _: repl, plan.consts))

    chunk_fn = sharded_chunk_fn(kind, model, channel, es_channel, opt, mesh,
                                clusters, clients)

    def xs_put(xs):
        return put_sharded(xs, _xs_shardings(xs, kind, mesh))

    return dataclasses.replace(
        plan, stage=stage, carry=carry, consts=consts,
        chunk_fn=chunk_fn, xs_put=xs_put,
    )
