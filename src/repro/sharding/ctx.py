"""Ambient mesh for model-interior manual collectives (shard_map regions).

Model code (`models/ffn.py`) is mesh-agnostic by default (GSPMD infers all
communication). The shard_map MoE interior needs the concrete Mesh object at
trace time; the launch layer publishes it here around `.lower()` instead of
threading a `mesh` argument through every block signature.
"""
from __future__ import annotations

import contextlib

from jax.sharding import Mesh

_STACK: list[Mesh] = []


@contextlib.contextmanager
def model_mesh(mesh: Mesh | None):
    """Publish `mesh` to model code for the duration of a trace/lowering."""
    if mesh is None:
        yield
        return
    _STACK.append(mesh)
    try:
        yield
    finally:
        _STACK.pop()


def current_mesh() -> Mesh | None:
    return _STACK[-1] if _STACK else None
