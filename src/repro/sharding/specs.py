"""Partition rules: parameter / activation / cache PartitionSpecs.

Tensor-parallel convention (the `model` mesh axis):
  * column-parallel in-projections (wq/wk/wv, FFN in/gate, SSD/LRU in-proj):
    P(None, "model") — output features sharded, no comm on entry;
  * row-parallel out-projections (wo, FFN out): P("model", None) — contraction
    over the sharded dim, XLA inserts the block all-reduce;
  * MoE expert tensors (E, d, f): experts sharded on "model" (expert parallel);
  * embedding (V, d): P(None, "model") (gather stays local);
    lm_head (d, V): P(None, "model") (vocab-sharded logits, small final
    all-reduce inside the softmax).
  * 1-D vectors (norm scales, biases, decay rates): replicated.

Leaves with extra leading dims (scan-stacked superblocks, Fed-CHS chain dim)
get Nones prepended — except the chain dim, which the launch layer maps to
"pod" explicitly.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

_ROW_PARALLEL = {"wo", "w_out"}
_COL_PARALLEL = {
    "wq", "wk", "wv", "w_gate", "w_in", "wq_b", "wkv_b", "w_x", "w_r", "w_i",
    "conv_w", "projector", "lm_head", "embed", "proj", "wq_a", "wkv_a",
}


def _leaf_name(path) -> str:
    last = path[-1]
    return str(last.key) if hasattr(last, "key") else str(last)


def _in_moe_ffn(path) -> bool:
    names = [str(p.key) for p in path if hasattr(p, "key")]
    return "ffn" in names


def _base_spec(path, leaf, num_experts: int, expert_axis: str = "model") -> P:
    """Trailing-dims spec for the *logical* parameter (stacking dims excluded)."""
    name = _leaf_name(path)
    if leaf.ndim <= 1:
        return P()
    if (
        num_experts
        and _in_moe_ffn(path)
        and name in ("w_gate", "w_in", "w_out")
        and leaf.ndim >= 3
        and leaf.shape[-3] == num_experts
    ):
        ax = ("data", "model") if expert_axis == "both" else expert_axis
        return P(ax, None, None)  # expert parallel (E, d, f)
    if name in _ROW_PARALLEL:
        return P("model", None)
    if name in _COL_PARALLEL:
        return P(None, "model")
    return P(None, None)


def param_pspecs(params: PyTree, *, num_experts: int = 0,
                 mesh: Mesh | None = None, expert_axis: str = "model") -> PyTree:
    """PartitionSpec tree matching `params` (handles scan-stacked leading dims).

    Specs are aligned to the TRAILING dims; leading stacking dims (scanned
    superblocks, FL chains) are replicated unless the caller maps them.
    When `mesh` is given, any sharded dim that does not divide its axis size
    falls back to replicated (e.g. vocab 50280 on a 16-way model axis)."""

    def spec(path, leaf):
        base = _base_spec(path, leaf, num_experts, expert_axis)
        extra = leaf.ndim - len(base)
        if extra > 0:
            base = P(*([None] * extra), *base)
        elif extra < 0:
            base = P(*base[-leaf.ndim:]) if leaf.ndim else P()
        if mesh is not None:
            dims = []
            for i, ax in enumerate(base):
                if ax is None:
                    dims.append(None)
                    continue
                n = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= mesh.shape[a]
                dims.append(ax if leaf.shape[i] % n == 0 else None)
            base = P(*dims)
        return base

    return jax.tree_util.tree_map_with_path(spec, params)


def batch_pspec(batch_size: int, mesh: Mesh, rank: int = 2) -> P:
    """Shard the batch dim over as many data-ish axes as divide it."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    use = []
    div = 1
    for a in axes:
        n = mesh.shape[a]
        if batch_size % (div * n) == 0:
            use.append(a)
            div *= n
    first = tuple(use) if use else None
    return P(first, *([None] * (rank - 1)))


def cache_pspecs(caches: PyTree, batch_size: int, mesh: Mesh) -> PyTree:
    """KV/state caches: batch dim sharded like the batch, kv-head/state dims
    sharded on "model" where they divide; scan-stacked leading dim replicated.

    Cache layouts (see models/*): attn k/v (L?, B, S, Hkv, hd); mla c_kv
    (L?, B, S, r); ssd state (L?, B, H, P, N); conv (L?, B, K, C);
    rglru h (L?, B, W); len (L?, B).
    """
    bspec = batch_pspec(batch_size, mesh, rank=1)
    baxes = bspec[0]

    n_model = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def spec(path, leaf):
        name = _leaf_name(path)
        # find batch dim: first dim whose size == batch_size
        dims: list = [None] * leaf.ndim
        bdim = None
        for i, s in enumerate(leaf.shape):
            if s == batch_size:
                dims[i] = baxes
                bdim = i
                break
        if name in ("k", "v") and leaf.ndim >= 4:
            hkv = leaf.shape[-2]
            sdim = leaf.ndim - 3  # (..., B, S, Hkv, hd) -> S
            if n_model > 1 and hkv % n_model == 0:
                dims[-2] = "model"  # kv-head parallel
            elif n_model > 1 and leaf.shape[sdim] % n_model == 0 and sdim != bdim:
                dims[sdim] = "model"  # sequence-parallel cache (flash-decode style)
        elif name == "c_kv" and leaf.ndim >= 3:
            sdim = leaf.ndim - 2  # (..., B, S, r) -> S
            if n_model > 1 and leaf.shape[sdim] % n_model == 0 and sdim != bdim:
                dims[sdim] = "model"
        elif name in ("state", "h", "conv", "cross_k", "cross_v"):
            tgt = leaf.ndim - 2 if name in ("cross_k", "cross_v") else leaf.ndim - 1
            if (
                n_model > 1
                and leaf.shape[tgt] % n_model == 0
                and leaf.shape[tgt] >= n_model
                and tgt != bdim
            ):
                dims[tgt] = "model"
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec, caches)


def named_shardings(mesh: Mesh, pspecs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------------
# federation mesh: the whole-run engine's stacked pytrees
# --------------------------------------------------------------------------

FED_AXES = ("clusters", "clients")


def fed_engine_pspecs(kind: str) -> dict:
    """PartitionSpecs for the engine scan-body pytrees on a federation mesh.

    One entry per scan-body kind (`engine.scan_*_body` / their
    `sharding.fed` mesh twins), keyed by the body's (carry, xs, ys) trees:

      * ``"grad"`` — `scan_grad_body` (WRWGD walks, Fed-CHS Eq.-(5) mode).
        carry = params, replicated; x["batch"] (K, n, B, ...) shards the
        flat client axis over BOTH mesh axes.
      * ``"delta"`` — `scan_delta_body` (FedAvg).  carry = (params,
        opt_state (n, ...)): params replicated, opt rows sharded with the
        clients; x["batch"] (J, n, E, B, ...).
      * ``"cluster_delta"`` — `scan_cluster_delta_body` (Fed-CHS delta
        mode).  Only ONE cluster trains per round, so the opt stack's
        cluster axis (M, n, ...) stays unsharded and its client axis shards
        over the whole mesh.
      * ``"multi"`` — `scan_multi_body` (3-tier HFL): batch
        (J, M, n_max, E, B, ...) and opt (M, n_max, ...) shard clusters
        over "clusters" and in-cluster clients over "clients".

    Schedule rows (gammas/mask/es_weights) and PRNG subkey chains are
    replicated — the sharded bodies slice their local window so the
    full-width aggregation einsums see the unsharded operand layout.
    Specs cover the leading stacked dims; trailing feature dims are
    replicated (trailing-None elision).  The staged-xs trees add a leading
    chunk axis on top of the batch specs (`fed._xs_shardings`).
    """
    flat = P(FED_AXES)
    if kind == "grad":
        return {
            "carry": P(),
            "xs": {"batch": P(None, FED_AXES), "gammas": P(), "lrs": P()},
            "ys": P(),
        }
    if kind == "delta":
        return {
            "carry": (P(), flat),
            "xs": {"batch": P(None, FED_AXES), "gammas": P(), "mask": P(),
                   "subs": P()},
            "ys": P(),
        }
    if kind == "cluster_delta":
        return {
            "carry": (P(), P(None, FED_AXES)),
            "xs": {"m": P(), "batch": P(None, FED_AXES), "gammas": P(),
                   "mask": P(), "subs": P()},
            "ys": P(),
        }
    if kind == "multi":
        return {
            "carry": (P(), P("clusters", "clients")),
            "xs": {"batch": P(None, "clusters", "clients"), "gammas": P(),
                   "mask": P(), "es_weights": P(), "subs": P(), "es_subs": P()},
            "ys": P(),
        }
    raise ValueError(f"unknown engine scan-body kind: {kind!r}")
