from repro.sharding.specs import param_pspecs, batch_pspec, cache_pspecs, named_shardings

__all__ = ["param_pspecs", "batch_pspec", "cache_pspecs", "named_shardings"]
