"""Production serving launcher (decode shapes).

Two modes, mirroring launch/train.py:

* default (lower-only): build the full assigned config and
  ``.lower().compile()`` the serve_step (ONE token vs a seq_len KV/state
  cache) on the production mesh — the deployment path for decode_32k /
  long_500k.

* ``--execute``: a real continuous-batching serving loop at reduced (smoke)
  scale on CPU: a request queue, fixed batch slots, per-slot prefill
  (teacher-forced cache fill), greedy decode, and slot recycling when a
  request finishes — the serving analogue of the train driver.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-32b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --execute --requests 12
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    # Set before any jax BACKEND INIT (jax reads XLA_FLAGS lazily, at first
    # use) — and only on the CLI path: importing this module (e.g. tests
    # pulling in serve_loop) must not force a 512-device partition on the
    # host process, which perturbs XLA:CPU's compute partitioning and with
    # it the bit-exact engine parity pins.
    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default=None,
                    help="model architecture (required except --federation)")
    ap.add_argument("--shape", default="decode_32k", choices=["decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--execute", action="store_true")
    ap.add_argument("--requests", type=int, default=8, help="execute: total requests")
    ap.add_argument("--slots", type=int, default=4, help="execute: concurrent batch slots")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--federation", action="store_true",
                    help="run the async federation service (repro.async_fl) "
                         "with continuous checkpointing instead of serving")
    ap.add_argument("--checkpoint", default=None,
                    help="federation: run-state path prefix (continuous save)")
    ap.add_argument("--resume", action="store_true",
                    help="federation: resume from --checkpoint if present")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--clusters", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--quorum-frac", type=float, default=1.0)
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--churn-p", type=float, default=1.0,
                    help="federation: per-(client, activation) availability")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-after-activation", type=int, default=None,
                    help=argparse.SUPPRESS)  # crash-test: os._exit after the
    #   checkpoint at this activation lands — simulates a hard kill mid-run
    args = ap.parse_args()
    if args.federation:
        _federation(args)
    else:
        if args.arch is None:
            ap.error("--arch is required unless --federation")
        if args.execute:
            _execute(args)
        else:
            _lower(args)


def _federation(args) -> None:
    """Async federation as a service: event-driven Fed-CHS with continuous
    crash-safe checkpointing.  Kill the process at any point; relaunching
    with --resume continues bit-identical to an uninterrupted run (the
    subprocess parity test in tests/test_resume_parity.py drives exactly
    this entry point, using the hidden --kill-after-activation switch to
    die mid-run immediately after a checkpoint lands)."""
    import json

    from repro.async_fl import AsyncFedCHSConfig, run_async_fed_chs
    from repro.core.simulation import FLTask
    from repro.data import assign_clusters, dirichlet_partition, make_dataset
    from repro.models.classifier import make_classifier
    from repro.part import AlwaysOn, BernoulliTrace

    ds = make_dataset("mnist", train_size=2000, test_size=400, seed=args.seed)
    clients = dirichlet_partition(ds.train_y, args.clients, 0.6, seed=args.seed)
    clusters = assign_clusters(args.clients, args.clusters, seed=args.seed)
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    task = FLTask(model, ds, clients, clusters, batch_size=16, seed=args.seed)

    on_checkpoint = None
    if args.kill_after_activation is not None:
        def on_checkpoint(a: int) -> None:
            if a >= args.kill_after_activation:
                print(f"killed after activation {a}", flush=True)
                os._exit(1)  # hard kill: no atexit, no flushes — a real crash

    trace = (AlwaysOn() if args.churn_p >= 1.0
             else BernoulliTrace(p=args.churn_p, seed=args.seed + 17))
    config = AsyncFedCHSConfig(
        rounds=args.rounds, local_steps=args.local_steps,
        initial_cluster=0, quorum_frac=args.quorum_frac,
        deadline_s=args.deadline_s, trace=trace, eval_every=5,
        seed=args.seed, checkpoint=args.checkpoint, resume=args.resume,
        on_checkpoint=on_checkpoint,
    )
    t0 = time.time()
    res = run_async_fed_chs(task, config)
    print(json.dumps({
        "algo": res.name,
        "rounds": res.rounds,
        "test_acc": res.test_acc,
        "sim_times": res.sim_times,
        "total_bits": int(res.ledger.total_bits()),
        "staleness": {str(k): v for k, v in
                      res.ledger.staleness_histogram().items()},
        "wall_s": round(time.time() - t0, 2),
    }))


def _lower(args) -> None:
    from repro.configs.registry import get_config, long_context_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_lowering, lower_spec

    cfg = (long_context_config(args.arch) if args.shape == "long_500k"
           else get_config(args.arch))
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    spec = build_lowering(cfg, args.shape, mesh)
    t0 = time.time()
    compiled = lower_spec(spec, mesh).compile()
    mem = compiled.memory_analysis()
    print(f"{spec.name} on {'2x16x16' if args.multi_pod else '16x16'} mesh: "
          f"compiled in {time.time() - t0:.1f}s")
    print("  bytes/device: "
          f"{(mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes) / 2**30:.2f} GiB")


def _splice_slot(base, donor, s: int):
    """Caches equal to `base` everywhere except batch slot `s`, taken from
    `donor`.  `tail` block caches carry the batch on axis 0; `super` blocks
    are `_stack_trees`-stacked, pushing batch to axis 1."""
    import jax

    def at(axis):
        def f(b, d):
            idx = (slice(None),) * axis + (s,)
            return b.at[idx].set(d[idx])

        return f

    return {
        "super": [jax.tree.map(at(1), b, d)
                  for b, d in zip(base["super"], donor["super"])],
        "tail": [jax.tree.map(at(0), b, d)
                 for b, d in zip(base["tail"], donor["tail"])],
    }


def serve_loop(cfg, params, *, requests: int, slots: int, prompt_len: int,
               max_new: int):
    """Continuous-batching greedy decode; returns ({request: tokens}, steps).

    Each request yields exactly `max_new` tokens: the prefill's last-position
    argmax plus `max_new - 1` batched decode steps (the retire test at
    `slot_gen >= max_new - 1` counts decode tokens only — the prefill token
    was appended at admit time).

    Admission prefills ONE slot against the shared (batch-wide) compiled
    decode step, then splices: the slot is first zeroed from a fresh cache
    (a recycled slot's `len` counter must restart at position 0), the
    prompt is teacher-forced through the batch step, and only slot `s`'s
    cache rows are kept — every other slot's KV/state is restored from the
    pre-admission snapshot.  Without the splice the batch-wide prefill
    advances ALL slots' caches `prompt_len` positions, corrupting every
    in-flight request (the cross-slot contamination bug this replaced):
    solo and batched decodes of the same request then diverge
    (tests/test_serve_exec.py pins solo == batched).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.tokens import synthetic_lm_batch
    from repro.models import transformer as tf

    S = slots
    capacity = prompt_len + max_new
    enc_len = cfg.num_audio_frames if cfg.is_encoder_decoder else 0
    fresh = tf.init_caches(cfg, S, capacity, enc_len=enc_len)
    caches = fresh
    step = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t))

    pending = list(range(requests))  # request ids
    prompts = {
        r: synthetic_lm_batch(cfg.vocab_size, 1, prompt_len, seed=r)["tokens"][0]
        for r in pending
    }
    # slot state: request id (or -1), tokens generated, next input token
    slot_req = [-1] * S
    slot_gen = [0] * S
    cur_tok = np.zeros((S, 1), np.int32)
    done: dict[int, list[int]] = {}
    steps = 0

    def admit(s: int) -> None:
        """Prefill request into slot s by teacher-forced ingestion."""
        nonlocal caches
        r = pending.pop(0)
        slot_req[s], slot_gen[s] = r, 0
        snapshot = caches
        caches = _splice_slot(caches, fresh, s)  # slot restarts at position 0
        for t in range(prompt_len):
            tok = np.array(cur_tok)
            tok[s, 0] = prompts[r][t]
            logits, caches = step(params, caches, jnp.asarray(tok))
        caches = _splice_slot(snapshot, caches, s)  # others: pre-admit state
        cur_tok[s, 0] = int(jnp.argmax(logits[s]))
        done[r] = [int(cur_tok[s, 0])]

    while pending or any(r >= 0 for r in slot_req):
        for s in range(S):
            if slot_req[s] < 0 and pending:
                admit(s)
        logits, caches = step(params, caches, jnp.asarray(cur_tok))
        steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s in range(S):
            r = slot_req[s]
            if r < 0:
                continue
            slot_gen[s] += 1
            done[r].append(int(nxt[s]))
            cur_tok[s, 0] = nxt[s]
            if slot_gen[s] >= max_new - 1:
                slot_req[s] = -1  # retire; slot is re-admitted next iteration

    return done, steps


def _execute(args) -> None:
    import jax

    from repro.configs.registry import smoke_config
    from repro.models import transformer as tf

    cfg = smoke_config(args.arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    t0 = time.time()
    done, steps = serve_loop(
        cfg, params, requests=args.requests, slots=args.slots,
        prompt_len=args.prompt_len, max_new=args.max_new,
    )
    dt = time.time() - t0
    total = sum(len(v) for v in done.values())
    print(f"arch={cfg.name} (reduced) | {args.requests} requests over "
          f"{args.slots} slots | "
          f"{total} tokens in {dt:.1f}s ({total / max(dt, 1e-9):.1f} tok/s, "
          f"{steps} batched decode steps)")
    for r in list(done)[:2]:
        print(f"request {r}: {done[r][:12]} ...")


if __name__ == "__main__":
    main()
