"""Production serving launcher (decode shapes).

Two modes, mirroring launch/train.py:

* default (lower-only): build the full assigned config and
  ``.lower().compile()`` the serve_step (ONE token vs a seq_len KV/state
  cache) on the production mesh — the deployment path for decode_32k /
  long_500k.

* ``--execute``: a real continuous-batching serving loop at reduced (smoke)
  scale on CPU: a request queue, fixed batch slots, per-slot prefill
  (teacher-forced cache fill), greedy decode, and slot recycling when a
  request finishes — the serving analogue of the train driver.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-32b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --execute --requests 12
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # before any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k", choices=["decode_32k", "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--execute", action="store_true")
    ap.add_argument("--requests", type=int, default=8, help="execute: total requests")
    ap.add_argument("--slots", type=int, default=4, help="execute: concurrent batch slots")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    if args.execute:
        _execute(args)
    else:
        _lower(args)


def _lower(args) -> None:
    from repro.configs.registry import get_config, long_context_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_lowering, lower_spec

    cfg = (long_context_config(args.arch) if args.shape == "long_500k"
           else get_config(args.arch))
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    spec = build_lowering(cfg, args.shape, mesh)
    t0 = time.time()
    compiled = lower_spec(spec, mesh).compile()
    mem = compiled.memory_analysis()
    print(f"{spec.name} on {'2x16x16' if args.multi_pod else '16x16'} mesh: "
          f"compiled in {time.time() - t0:.1f}s")
    print("  bytes/device: "
          f"{(mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes) / 2**30:.2f} GiB")


def _execute(args) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import smoke_config
    from repro.data.tokens import synthetic_lm_batch
    from repro.models import transformer as tf

    cfg = smoke_config(args.arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    S = args.slots
    capacity = args.prompt_len + args.max_new
    enc_len = cfg.num_audio_frames if cfg.is_encoder_decoder else 0
    caches = tf.init_caches(cfg, S, capacity, enc_len=enc_len)
    step = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t))

    rng = np.random.default_rng(0)
    pending = list(range(args.requests))  # request ids
    prompts = {
        r: synthetic_lm_batch(cfg.vocab_size, 1, args.prompt_len, seed=r)["tokens"][0]
        for r in pending
    }
    # slot state: request id (or -1), tokens generated, next input token
    slot_req = [-1] * S
    slot_gen = [0] * S
    cur_tok = np.zeros((S, 1), np.int32)
    done: dict[int, list[int]] = {}
    t0 = time.time()
    steps = 0

    def admit(s: int) -> None:
        """Prefill request into slot s by teacher-forced ingestion."""
        nonlocal caches
        r = pending.pop(0)
        slot_req[s], slot_gen[s] = r, 0
        for t in range(args.prompt_len):
            tok = np.array(cur_tok)
            tok[s, 0] = prompts[r][t]
            logits, caches = step(params, caches, jnp.asarray(tok))
        cur_tok[s, 0] = int(jnp.argmax(logits[s]))
        done[r] = [int(cur_tok[s, 0])]

    while pending or any(r >= 0 for r in slot_req):
        for s in range(S):
            if slot_req[s] < 0 and pending:
                admit(s)
        logits, caches = step(params, caches, jnp.asarray(cur_tok))
        steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s in range(S):
            r = slot_req[s]
            if r < 0:
                continue
            slot_gen[s] += 1
            done[r].append(int(nxt[s]))
            cur_tok[s, 0] = nxt[s]
            if slot_gen[s] >= args.max_new - 1:
                slot_req[s] = -1  # retire; slot is re-admitted next iteration

    dt = time.time() - t0
    total = sum(len(v) for v in done.values())
    print(f"arch={cfg.name} (reduced) | {args.requests} requests over {S} slots | "
          f"{total} tokens in {dt:.1f}s ({total / max(dt, 1e-9):.1f} tok/s, "
          f"{steps} batched decode steps)")
    for r in list(done)[:2]:
        print(f"request {r}: {done[r][:12]} ...")


if __name__ == "__main__":
    main()
