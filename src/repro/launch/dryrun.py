import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import: jax locks the device
#   count at first init, and the production meshes need 512 host placeholders.

# Multi-pod dry-run: lower + compile every (architecture x input shape) on the
# production meshes and record memory/cost/collective analysis.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --all            # full 2-mesh sweep
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --variant fedchs
#
# Results land in experiments/dryrun/<arch>__<shape>__<mesh>__<variant>.json and
# feed EXPERIMENTS.md §Dry-run / §Roofline via benchmarks/roofline.py.

import argparse
import json
import time
import traceback


from repro.configs.registry import ARCH_IDS, get_config, long_context_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import SHAPES, build_lowering, lower_spec
from repro.roofline.analysis import analyze_compiled, model_flops, roofline_terms

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def shape_supported(arch: str, shape: str) -> bool:
    cfg = get_config(arch)
    if shape == "long_500k":
        return cfg.long_context_ok
    return True


def config_for(arch: str, shape: str):
    if shape == "long_500k":
        return long_context_config(arch)
    return get_config(arch)


def run_one(arch: str, shape: str, mesh_kind: str, variant: str, *,
            out_dir: str = OUT_DIR, verbose: bool = True,
            optimized: bool = False) -> dict:
    cfg = config_for(arch, shape)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    spec = build_lowering(cfg, shape, mesh, variant=variant, optimized=optimized)
    lowered = lower_spec(spec, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    record = analyze_compiled(compiled)
    if optimized:
        variant = variant + "+opt" if SHAPES[shape]["mode"] == "train" else "opt"
    info = SHAPES[shape]
    tokens = info["global_batch"] * (info["seq_len"] if info["mode"] != "decode" else 1)
    kind = "train" if info["mode"] == "train" else "serve"
    n_params = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    mf = model_flops(n_params, tokens, kind="train" if kind == "train" else "serve")
    terms = roofline_terms(record)
    total_dev_flops = record["dot_flops_per_device"] * n_chips
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "chips": int(n_chips),
        "variant": variant if (info["mode"] == "train" or optimized) else "-",
        "mode": info["mode"],
        "seq_len": info["seq_len"],
        "global_batch": info["global_batch"],
        "params": int(cfg.param_count()),
        "active_params": int(n_params),
        "model_flops": mf,
        "model_vs_hlo": mf / total_dev_flops if total_dev_flops else None,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        **record,
        **terms,
    }
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}__{variant}.json")
    with open(fn, "w") as f:
        json.dump(result, f, indent=1, default=str)
    if verbose:
        print(
            f"OK  {arch:20s} {shape:12s} {mesh_kind:6s} {variant:7s} "
            f"compile={t_compile:6.1f}s bound={terms['bound']:10s} "
            f"comp={terms['compute_s']:.3e}s mem={terms['memory_s']:.3e}s "
            f"coll={terms['collective_s']:.3e}s "
            f"mem/dev={record['memory'].get('peak_bytes', 0)/1e9:.2f}GB",
            flush=True,
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--variant", default="fedchs", choices=["fedchs", "hfl"])
    ap.add_argument("--opt", action="store_true",
                    help="apply the beyond-paper perf config (§Perf)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    failures = []
    for arch in archs:
        for shape in shapes:
            if not shape_supported(arch, shape):
                print(f"SKIP {arch} {shape} (full-attention arch; see DESIGN.md §4)")
                continue
            for mesh_kind in meshes:
                try:
                    run_one(arch, shape, mesh_kind, args.variant, out_dir=args.out,
                            optimized=args.opt)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    print(f"FAIL {arch} {shape} {mesh_kind}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nAll dry-runs passed.")


if __name__ == "__main__":
    main()
