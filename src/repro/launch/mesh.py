"""Production meshes (TPU v5e).

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import.
"""
from __future__ import annotations

import logging

import jax

_log = logging.getLogger(__name__)


def _make_mesh(shape, axes, devices) -> jax.sharding.Mesh:
    # axis_types / AxisType only exist on newer jax; older versions default
    # to Auto semantics anyway
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run via "
            "launch/dryrun.py which sets xla_force_host_platform_device_count"
        )
    return _make_mesh(shape, axes, devices)


def make_debug_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Tiny mesh over however many real devices exist (tests).

    Falls back to a single-device mesh (with a logged warning, not an
    error) when the requested shape exceeds `jax.device_count()`, so
    examples written against a forced-device count still run on 1-device
    CPU."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    if n > jax.device_count():
        _log.warning(
            "debug mesh %s needs %d devices but only %d exist — "
            "falling back to a single-device mesh",
            dict(zip(axes, shape)), n, jax.device_count(),
        )
        shape = tuple(1 for _ in shape)
        n = 1
    return _make_mesh(shape, axes, jax.devices()[:n])


def make_federation_mesh(clusters: int = 1, clients: int | None = None):
    """The population mesh for device-sharded FL runs: axes
    ``("clusters", "clients")`` (see `repro.sharding.fed`).

    `clients=None` spreads all remaining devices across the client axis.
    Publish it to the drivers either explicitly (``config.mesh``) or
    ambiently via `sharding.ctx.model_mesh`::

        with model_mesh(make_federation_mesh(clusters=2, clients=4)):
            run_fed_chs(task, config)   # sharded; mesh=None configs adopt it

    Falls back to a single-device mesh with a logged warning when the
    requested shape exceeds `jax.device_count()` — a mesh=None-equivalent
    run, never an error."""
    if clients is None:
        clients = max(jax.device_count() // clusters, 1)
    n = clusters * clients
    if n > jax.device_count():
        _log.warning(
            "federation mesh (clusters=%d, clients=%d) needs %d devices but "
            "only %d exist — falling back to a single-device mesh",
            clusters, clients, n, jax.device_count(),
        )
        clusters = clients = n = 1
    return _make_mesh((clusters, clients), ("clusters", "clients"),
                      jax.devices()[:n])


POD_CHIPS = 256
MULTI_POD_CHIPS = 512
