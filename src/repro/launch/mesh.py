"""Production meshes (TPU v5e).

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes, devices) -> jax.sharding.Mesh:
    # axis_types / AxisType only exist on newer jax; older versions default
    # to Auto semantics anyway
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run via "
            "launch/dryrun.py which sets xla_force_host_platform_device_count"
        )
    return _make_mesh(shape, axes, devices)


def make_debug_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Tiny mesh over however many real devices exist (tests)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return _make_mesh(shape, axes, jax.devices()[:n])


POD_CHIPS = 256
MULTI_POD_CHIPS = 512
