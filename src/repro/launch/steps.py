"""Lowering units for the dry-run and the production launcher.

Three step kinds per architecture:

* ``train`` — one Fed-CHS round (the paper's technique, TPU-native):
  the `pod` mesh axis carries one *chain* (= active-model copy) per pod;
  each pod is one cluster (ES + its clients = the pod's data shards).
  Eq. (5)'s within-cluster aggregation is the gradient all-reduce over the
  `data` axis only; the sequential ES->ES pass is a roll over the chain dim,
  which XLA lowers to a pod-axis collective-permute. With `variant="hfl"`
  the roll is replaced by the star-shaped chain-mean (all-reduce over `pod`)
  — the conventional HFL/FedAvg baseline the paper compares against.
  Running pods concurrently on staggered chains is our throughput
  pipelining of the (single-active-cluster) paper protocol; each chain's
  visit order is exactly the 2-step scheduler's (ring for 2 pods).

* ``prefill`` — forward over the full prompt (logits; cache extraction is a
  layout epilogue, see DESIGN.md).

* ``decode`` — serve_step: ONE new token against a seq_len KV/state cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.sharding.specs import batch_pspec, cache_pspecs, named_shardings, param_pspecs

PyTree = Any

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, mode="decode"),
}


def num_chains(mesh: Mesh) -> int:
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1


def _vocab_axis(cfg: ArchConfig, mesh: Mesh):
    n_model = mesh.shape["model"] if "model" in mesh.axis_names else 1
    return "model" if n_model > 1 and cfg.vocab_size % n_model == 0 else None


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------


def _token_batch_struct(cfg: ArchConfig, batch: int, seq: int, *, chain: int | None,
                        dtype) -> dict:
    lead = (chain,) if chain else ()
    toks = jax.ShapeDtypeStruct((*lead, batch, seq), jnp.int32)
    out = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct(
            (*lead, batch, cfg.num_audio_frames, cfg.d_model), dtype
        )
    if cfg.num_patches:
        out["patches"] = jax.ShapeDtypeStruct((*lead, batch, cfg.num_patches, 1024), dtype)
    return out


def abstract_params(cfg: ArchConfig, *, chains: int = 0) -> PyTree:
    p = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    if chains:
        p = jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct((chains, *leaf.shape), leaf.dtype), p
        )
    return p


def abstract_caches(cfg: ArchConfig, batch: int, capacity: int) -> PyTree:
    enc_len = cfg.num_audio_frames if cfg.is_encoder_decoder else 0
    return jax.eval_shape(
        lambda: tf.init_caches(cfg, batch, capacity, enc_len=enc_len)
    )


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------


def make_train_round(cfg: ArchConfig, *, variant: str = "fedchs", remat: bool = True,
                     remat_policy=None, spmd_axis: str | None = None):
    """(stacked_params (C, ...), batch {tokens (C, B/C, T), ...}, lr) -> (params, loss).

    `spmd_axis` ("pod" on multi-pod meshes) is passed to jax.vmap as
    spmd_axis_name so shard_map interiors inside the per-chain loss see the
    chain dim as pod-sharded (the per-chain psums then stay within the
    chain's own pod — exactly Eq. (5)'s within-cluster aggregation)."""

    def chain_loss(params, batch):
        return tf.loss_fn(cfg, params, batch, remat=remat, remat_policy=remat_policy)

    def round_fn(stacked_params, batch, lr):
        C = jax.tree.leaves(stacked_params)[0].shape[0]
        if C == 1:
            # single chain: skip the vmap so model interiors may use
            # shard_map (vmap-of-shard_map is unsupported); the sequential
            # pass / star mean are identities over a size-1 chain dim.
            sq = jax.tree.map(lambda x: x[0], stacked_params)
            bq = jax.tree.map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(chain_loss)(sq, bq)
            new = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), sq, grads)
            return jax.tree.map(lambda x: x[None], new), loss
        losses, grads = jax.vmap(jax.value_and_grad(chain_loss),
                                 spmd_axis_name=spmd_axis)(stacked_params, batch)
        new = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), stacked_params, grads)
        if variant == "fedchs":
            # sequential ES->ES pass: chain c moves to pod (c+1) % C.
            # (2-pod ring == the 2-step scheduler's order; lowers to
            # collective-permute over the pod axis.)
            passed = jax.tree.map(lambda x: jnp.roll(x, 1, axis=0), new)
        elif variant == "hfl":
            # star aggregation at the PS: chain-mean, broadcast back.
            passed = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    jnp.mean(x, axis=0, keepdims=True), x.shape
                ).astype(x.dtype),
                new,
            )
        else:
            raise ValueError(variant)
        return passed, jnp.mean(losses)

    return round_fn


def make_prefill_step(cfg: ArchConfig, *, last_only: bool = False):
    """last_only (the --opt serving path) slices the hidden state before the
    LM head instead of materialising (B, T, V) logits and slicing after —
    §Perf pair 4."""

    def prefill_fn(params, batch):
        logits, aux = tf.forward(cfg, params, batch, last_only=last_only)
        return logits[:, -1]

    return prefill_fn


def make_decode_step(cfg: ArchConfig):
    def decode_fn(params, caches, token):
        return tf.decode_step(cfg, params, caches, token)

    return decode_fn


# --------------------------------------------------------------------------
# dry-run assembly: (fn, abstract args, in/out shardings)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LoweringSpec:
    name: str
    fn: Any
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()  # production buffers (params / caches) are donated


def apply_optimizations(cfg: ArchConfig, mesh: Mesh) -> ArchConfig:
    """Beyond-paper-baseline performance config (EXPERIMENTS.md §Perf):
    group-limited MoE routing aligned to the data shards; the MoE interior
    is a shard_map with manual collectives (models/moe_shardmap.py). On
    multi-pod meshes the chain vmap passes spmd_axis_name="pod" so the
    interior's psums stay within each chain's pod."""
    updates: dict = {}
    if cfg.is_moe and "data" in mesh.axis_names:
        updates["moe_groups"] = int(mesh.shape["data"])
        updates["moe_shardmap"] = True  # multi-pod: vmap(spmd_axis_name="pod")
    return dataclasses.replace(cfg, **updates) if updates else cfg


DP_PARAM_THRESHOLD = 1_000_000_000


def _use_pure_dp(cfg: ArchConfig, per_chain_batch: int, mesh: Mesh) -> bool:
    """Sub-1B models are over-sharded by 16-way TP (tiny matmul shards +
    per-layer activation all-reduces dominate). Replicate params and shard
    the batch over (data, model) instead — EXPERIMENTS.md §Perf pair 2."""
    chips = 1
    for a in ("data", "model"):
        if a in mesh.axis_names:
            chips *= mesh.shape[a]
    return cfg.param_count() < DP_PARAM_THRESHOLD and per_chain_batch % chips == 0


def build_lowering(cfg: ArchConfig, shape_name: str, mesh: Mesh, *,
                   variant: str = "fedchs", optimized: bool = False) -> LoweringSpec:
    if optimized:
        cfg = apply_optimizations(cfg, mesh)
    info = SHAPES[shape_name]
    seq, gbatch, mode = info["seq_len"], info["global_batch"], info["mode"]
    dtype = jnp.dtype(cfg.dtype)

    if mode == "train":
        C = num_chains(mesh)
        assert gbatch % C == 0
        params = abstract_params(cfg, chains=C)
        per_chain = gbatch // C
        pure_dp = optimized and _use_pure_dp(cfg, per_chain, mesh)
        pspecs = param_pspecs(jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0))), num_experts=cfg.num_experts, mesh=mesh, expert_axis=cfg.expert_axis)
        if pure_dp:
            pspecs = jax.tree.map(
                lambda s: P(*([None] * len(s))), pspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
        chain_axis = "pod" if C > 1 else None
        pspecs = jax.tree.map(lambda s: P(chain_axis, *s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        batch = _token_batch_struct(cfg, gbatch // C, seq, chain=C, dtype=dtype)
        if pure_dp:
            data_axis = ("data", "model")
        else:
            data_axis = "data" if per_chain % mesh.shape["data"] == 0 else None
        bspec = {
            k: P(chain_axis, data_axis, *([None] * (v.ndim - 2)))
            for k, v in batch.items()
        }
        remat_policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable if optimized else None
        )
        spmd_axis = ("pod" if (optimized and cfg.moe_shardmap and C > 1
                              and "pod" in mesh.axis_names) else None)
        fn = make_train_round(cfg, variant=variant, remat_policy=remat_policy,
                              spmd_axis=spmd_axis)
        args = (params, batch, jax.ShapeDtypeStruct((), jnp.float32))
        in_sh = (
            named_shardings(mesh, pspecs),
            named_shardings(mesh, bspec),
            NamedSharding(mesh, P()),
        )
        out_sh = (named_shardings(mesh, pspecs), NamedSharding(mesh, P()))
        return LoweringSpec(f"{cfg.name}:{shape_name}:{variant}", fn, args, in_sh, out_sh,
                            donate_argnums=(0,))

    params = abstract_params(cfg)
    pspecs = param_pspecs(params, num_experts=cfg.num_experts, mesh=mesh, expert_axis=cfg.expert_axis)

    if mode == "prefill":
        batch = _token_batch_struct(cfg, gbatch, seq, chain=None, dtype=dtype)
        bspec = {k: P(batch_pspec(gbatch, mesh, rank=1)[0], *([None] * (v.ndim - 1)))
                 for k, v in batch.items()}
        fn = make_prefill_step(cfg)
        args = (params, batch)
        in_sh = (named_shardings(mesh, pspecs), named_shardings(mesh, bspec))
        logits_spec = NamedSharding(
            mesh, P(batch_pspec(gbatch, mesh, rank=1)[0], _vocab_axis(cfg, mesh))
        )
        return LoweringSpec(f"{cfg.name}:{shape_name}", fn, args, in_sh, logits_spec)

    # decode
    caches = abstract_caches(cfg, gbatch, seq)
    cspecs = cache_pspecs(caches, gbatch, mesh)
    token = jax.ShapeDtypeStruct((gbatch, 1), jnp.int32)
    tspec = P(batch_pspec(gbatch, mesh, rank=1)[0], None)
    fn = make_decode_step(cfg)
    args = (params, caches, token)
    in_sh = (
        named_shardings(mesh, pspecs),
        named_shardings(mesh, cspecs),
        NamedSharding(mesh, tspec),
    )
    out_sh = (
        NamedSharding(mesh, P(batch_pspec(gbatch, mesh, rank=1)[0], _vocab_axis(cfg, mesh))),
        named_shardings(mesh, cspecs),
    )
    return LoweringSpec(f"{cfg.name}:{shape_name}", fn, args, in_sh, out_sh,
                        donate_argnums=(1,))


def lower_spec(spec: LoweringSpec, mesh: Mesh):
    from repro.sharding.ctx import model_mesh

    with mesh, model_mesh(mesh):
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        return jitted.lower(*spec.args)
