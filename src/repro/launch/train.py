"""Production training launcher.

Two modes, selected by ``--execute``:

* default (lower-only): build the full assigned config on the production
  mesh (single- or multi-pod) and ``.lower().compile()`` the Fed-CHS round
  — the deployment path. On this CPU container the mesh is made of
  placeholder host devices (the launcher sets
  ``xla_force_host_platform_device_count`` before any jax import, same as
  dryrun.py), on a real v5e slice it is the actual chips.

* ``--execute``: run a REAL multi-round Fed-CHS training loop at reduced
  (smoke) scale on the available devices — per-cluster non-IID Markov token
  streams, the paper's eta_k schedule, sequential chain passing. This is
  what CI and the quickstart exercise.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --multi-pod
  PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b --execute --rounds 50
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # before any jax import (device count locks at init)
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=["train_4k"])
    ap.add_argument("--variant", default="fedchs", choices=["fedchs", "hfl"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized lowering (EXPERIMENTS.md §Perf)")
    ap.add_argument("--execute", action="store_true",
                    help="run a real reduced-scale training loop instead of lowering")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--chains", type=int, default=2, help="clusters (execute mode)")
    ap.add_argument("--batch", type=int, default=4, help="per-chain batch (execute mode)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--K", type=int, default=20, help="paper's within-cluster steps")
    ap.add_argument("--ckpt", default=None,
                    help="execute: checkpoint dir (resumes if one exists)")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    if args.execute:
        _execute(args)
    else:
        _lower(args)


def _lower(args) -> None:
    import jax

    from repro.configs.registry import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_lowering, lower_spec

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    spec = build_lowering(cfg, args.shape, mesh, variant=args.variant,
                          optimized=args.opt)
    t0 = time.time()
    lowered = lower_spec(spec, mesh)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    print(f"{spec.name} on {'2x16x16' if args.multi_pod else '16x16'} mesh: "
          f"compiled in {time.time() - t0:.1f}s")
    print("  bytes/device (argument+output+temp): "
          f"{(mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes) / 2**30:.2f} GiB")
    if cost:
        flops = cost.get("flops", 0.0)
        print(f"  HLO flops/device: {flops:.3e}")
    print("  (roofline terms: python -m repro.launch.dryrun --arch ... ; "
          "table in EXPERIMENTS.md §Roofline)")


def _execute(args) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import smoke_config
    from repro.data.tokens import MarkovTokens
    from repro.launch.steps import make_train_round
    from repro.models import transformer as tf
    from repro.optim.schedules import paper_sqrt_schedule

    cfg = smoke_config(args.arch)
    print(f"{args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}) "
          f"-> {cfg.param_count() / 1e6:.1f}M params, variant={args.variant}")

    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    C = args.chains
    stacked = jax.tree.map(lambda x: jnp.stack([x] * C), params)

    # per-cluster non-IID corpora: disjoint Markov topic mixtures; the rng is
    # derived from (cluster, round) so a checkpoint resume replays the exact
    # same stream.
    gens = [MarkovTokens(cfg.vocab_size, topics=4, seed=100 + c) for c in range(C)]

    def batch_for(t):
        toks = np.stack(
            [g.sample(np.random.default_rng((c + 1) * 100003 + t), args.batch,
                      args.seq + 1) for c, g in enumerate(gens)]
        )
        batch = {
            "tokens": jnp.asarray(toks[:, :, :-1]),
            "labels": jnp.asarray(toks[:, :, 1:]),
        }
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros((C, args.batch, cfg.num_audio_frames, cfg.d_model),
                                        jnp.float32)
        if cfg.num_patches:
            batch["patches"] = jnp.zeros((C, args.batch, cfg.num_patches, 1024), jnp.float32)
        return batch

    # round-resumable checkpointing (npz pytree, repro/checkpoint)
    t_start = 0
    if args.ckpt:
        from repro.checkpoint.io import load_pytree, save_pytree

        pfile = os.path.join(args.ckpt, "params.npz")
        mfile = os.path.join(args.ckpt, "meta.npz")
        if os.path.exists(pfile) and os.path.exists(mfile):
            import numpy as _np

            stacked = load_pytree(pfile, stacked)
            t_start = int(_np.load(mfile)["round"]) + 1
            print(f"resumed from {args.ckpt} at round {t_start}")

    round_fn = jax.jit(make_train_round(cfg, variant=args.variant, remat=False),
                       donate_argnums=(0,))
    sched = paper_sqrt_schedule(K=args.K, half=False)
    t0 = time.time()
    for t in range(t_start, args.rounds):
        lr = jnp.float32(args.lr * sched(0) * args.K)
        stacked, loss = round_fn(stacked, batch_for(t), lr)
        if t % max(args.rounds // 10, 1) == 0 or t == args.rounds - 1:
            print(f"round {t:4d}  loss {float(loss):.4f}", flush=True)
        if args.ckpt and (t % args.ckpt_every == 0 or t == args.rounds - 1):
            import numpy as _np

            save_pytree(os.path.join(args.ckpt, "params.npz"), stacked)
            _np.savez(os.path.join(args.ckpt, "meta.npz"), round=_np.int64(t))
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
