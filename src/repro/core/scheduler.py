"""Next-passing-cluster selection — the paper's deterministic 2-step rule.

Section 3.2: from the neighbors A(m(t)) of the currently active ES,
  Step 1: C(t) = argmin_{m' in A(m(t))} c(m')   (least traversed so far)
  Step 2: if |C(t)| > 1, pick argmax cluster dataset size D_{A,m'}.
The chosen node's visit count is incremented (Algorithm 1 line 17).

We also ship alternative schedulers to reproduce the baselines' walks:
`RandomWalkScheduler` (uniform over neighbors — WRWGD's walk) and
`RingScheduler` (fixed order — ring-topology SFL), plus a link-aware
variant the paper's topology-free rule invites: `LatencyAwareScheduler`
breaks the least-traversed tie by *smallest ES->ES link delay* (from a
`repro.netsim` link model) instead of largest dataset — the natural rule
when the sequential model pass itself is the wall-clock bottleneck.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.topology import Topology


@dataclasses.dataclass
class SchedulerState:
    current: int
    visit_counts: np.ndarray  # c(m), length M
    step: int = 0


class FedCHSScheduler:
    """The paper's 2-step deterministic rule."""

    def __init__(self, topology: Topology, cluster_sizes: list[int], initial: int = 0):
        assert len(cluster_sizes) == topology.num_nodes
        self.topology = topology
        self.cluster_sizes = np.asarray(cluster_sizes)
        counts = np.zeros(topology.num_nodes, dtype=np.int64)
        counts[initial] = 1  # the starting ES has been visited once
        self.state = SchedulerState(current=initial, visit_counts=counts)

    def set_topology(self, topology: Topology) -> None:
        """Swap the connectivity graph between rounds (dynamic networks —
        core/dynamics.py). Visit counts and the current node persist: the
        2-step rule itself is topology-free."""
        assert topology.num_nodes == self.topology.num_nodes
        self.topology = topology

    def _candidate_pool(self, nbrs: list[int]) -> list[int]:
        """Neighbors eligible for the 2-step rule (hook for availability-aware
        variants). The base rule considers every neighbor."""
        return nbrs

    def peek(self) -> int:
        """Apply the 2-step rule without mutating state."""
        st = self.state
        nbrs = self._candidate_pool(list(self.topology.neighbors(st.current)))
        counts = st.visit_counts[list(nbrs)]
        least = counts.min()
        candidates = [m for m, c in zip(nbrs, counts) if c == least]
        if len(candidates) == 1:
            return candidates[0]
        return self._tie_break(st.current, candidates)

    def _tie_break(self, current: int, candidates: list[int]) -> int:
        """Step 2: the paper picks the largest cluster dataset."""
        del current
        sizes = self.cluster_sizes[candidates]
        return candidates[int(np.argmax(sizes))]

    def advance(self) -> int:
        nxt = self.peek()
        self.state.visit_counts[nxt] += 1
        self.state.current = nxt
        self.state.step += 1
        return nxt

    def schedule(self, rounds: int) -> list[int]:
        """The full deterministic visiting order for `rounds` rounds (m(0)..m(T-1)).

        Does not mutate `self`; replays on a copy.
        """
        return list(self.precompute(rounds))

    def precompute(self, rounds: int, dynamic=None) -> np.ndarray:
        """Precompute the whole run's visit order as one int array.

        The 2-step rule (and its latency-/availability-aware variants, whose
        tie-break and candidate-pool hooks are deterministic functions of
        (topology, link delays, participation traces)) is fully determined by
        its inputs, so the scanned whole-run executor (`engine.run_scan`)
        consumes this instead of advancing the scheduler round-by-round on
        the host.  Replays `advance()` on a state copy — `self` is not
        mutated, and the replay is step-exact with the looped drivers'
        advances (including the `state.step`-indexed availability probes).

        `dynamic` (a `core.dynamics` callable t -> Topology) replays a
        dynamic network: the graph is swapped to `dynamic(t)` before the
        advance that leaves round t, exactly where the looped driver calls
        `set_topology` — IoV/LEO graphs are seed-deterministic functions of
        the round index, so the whole visit order is just as precomputable.
        The scheduler's own topology is restored after the replay.
        """
        saved = SchedulerState(self.state.current, self.state.visit_counts.copy(), self.state.step)
        saved_topo = self.topology
        order = [self.state.current]
        for t in range(rounds - 1):
            if dynamic is not None:
                self.set_topology(dynamic(t))
            order.append(self.advance())
        self.state = saved
        self.topology = saved_topo
        return np.asarray(order, dtype=np.int64)


class LatencyAwareScheduler(FedCHSScheduler):
    """2-step rule, tie broken by link delay instead of dataset size.

    Step 1 is unchanged (least traversed — the fairness half of the paper's
    rule).  Step 2 picks the candidate with the smallest ES->ES link delay
    from the current node; remaining exact-delay ties fall back to the
    paper's largest-dataset rule.  `link_delay(a, b) -> seconds` is any
    deterministic pair cost, e.g. `NetworkModel.backhaul_delay` bound to the
    model-message size (see repro/netsim/links.py).
    """

    def __init__(
        self,
        topology,
        cluster_sizes: list[int],
        link_delay: Callable[[int, int], float],
        initial: int = 0,
    ):
        super().__init__(topology, cluster_sizes, initial=initial)
        self.link_delay = link_delay

    def _tie_break(self, current: int, candidates: list[int]) -> int:
        delays = np.array([self.link_delay(current, m) for m in candidates])
        best = delays.min()
        fastest = [m for m, d in zip(candidates, delays) if d == best]
        if len(fastest) == 1:
            return fastest[0]
        return super()._tie_break(current, fastest)


class AvailabilityAwareScheduler(FedCHSScheduler):
    """2-step rule over the *reachable* neighbors only.

    A cluster is reachable for a round when it will have at least one
    participating client (`reachable(cluster, round_idx) -> bool`, typically
    closed over a `repro.part` sampler and the task's cluster membership).
    Step 1/Step 2 of the paper's rule then run over the reachable subset —
    the EdgeFLow-style sequential migration that skips unavailable edges
    entirely.  When NO neighbor is reachable the rule falls back to the full
    neighbor set: the model still has to move, and the receiving ES simply
    becomes a pass-through hop that round (forwarded model, no training).

    Round accounting: the scheduler picks m(t+1) while round t = `state.step`
    is finishing, so reachability is probed at ``state.step + 1``.
    """

    def __init__(
        self,
        topology,
        cluster_sizes: list[int],
        reachable: Callable[[int, int], bool],
        initial: int = 0,
    ):
        super().__init__(topology, cluster_sizes, initial=initial)
        self.reachable = reachable

    def _candidate_pool(self, nbrs: list[int]) -> list[int]:
        next_round = self.state.step + 1
        live = [m for m in nbrs if self.reachable(m, next_round)]
        return live or nbrs


class RandomWalkScheduler:
    """Uniform random neighbor — models WRWGD-style random walks."""

    def __init__(self, topology: Topology, initial: int = 0, seed: int = 0):
        self.topology = topology
        self.rng = np.random.default_rng(seed)
        self.state = SchedulerState(
            current=initial, visit_counts=np.zeros(topology.num_nodes, dtype=np.int64)
        )
        self.state.visit_counts[initial] = 1

    def advance(self) -> int:
        nbrs = self.topology.neighbors(self.state.current)
        nxt = int(self.rng.choice(nbrs))
        self.state.visit_counts[nxt] += 1
        self.state.current = nxt
        self.state.step += 1
        return nxt


class RingScheduler:
    """Fixed-order traversal (requires / induces a ring)."""

    def __init__(self, num_nodes: int, initial: int = 0):
        self.num_nodes = num_nodes
        self.state = SchedulerState(
            current=initial, visit_counts=np.zeros(num_nodes, dtype=np.int64)
        )
        self.state.visit_counts[initial] = 1

    def advance(self) -> int:
        nxt = (self.state.current + 1) % self.num_nodes
        self.state.visit_counts[nxt] += 1
        self.state.current = nxt
        self.state.step += 1
        return nxt
