"""Precision — the mixed-precision policy of the round engine.

One frozen dataclass names the three dtypes a federated round touches:

  * ``compute`` — the dtype clients train in: forward/backward, local
    optimizer steps, and the raw deltas all run here.  bf16 halves the
    per-client params/activation footprint, which is what makes
    `client_microbatch` + remat land a 0.6B-param LM round on one host.
  * ``master`` — the dtype of the authoritative params held at the ES (the
    whole-run scan carry) and of the delta accumulator: client deltas are
    cast UP before the gamma-weighted aggregate, so rounding happens once
    per client message, not once per accumulation step.
  * ``wire`` — the dtype a dense uplink/broadcast travels in.  The engine
    does not consume this field directly; drivers build the matching
    `DenseChannel(wire_dtype=...)` from it (`dense_wire_channel`) and price
    the ledger off the channel, so recorded bits always match the payload.

The policy is threaded through the engine as a static (hashable) argument:
each `Precision` value compiles its own round function, and ``None`` keeps
the exact pre-mixed-precision f32 graphs byte-for-byte (the default-path
parity contract in tests/test_engine_parity.py).  Client-held optimizer
state follows ``compute`` — it is initialized from the compute-cast params —
so only the ES keeps f32 state; grad mode (the paper-literal Eq. (5) path)
ignores the policy entirely and the drivers' grad-mode gate excludes it.

The engine tags its casts with `jax.named_scope`: "precision_cast" (going
down) survives jit into compiled op_names, so
`roofline.attribution.phase_bytes` bills the down-cast traffic directly.
The up-cast ("master_accumulate") fuses into the gamma-weighted aggregate
einsum, whose op_name carries the engine's "intra_agg" scope — so the
accumulate cost of a mixed-precision round is billed there (the fused
aggregate reads bf16 and writes f32); see
tests/test_attribution.py::test_phase_bytes_attributes_mixed_precision_round.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

# the dtype names a policy accepts (widths come from comm.bits.dtype_bits;
# pinned in sync by tests/test_channels.py::test_precision_dtype_table_sync)
_SUPPORTED = ("float32", "bfloat16", "float16", "float8_e4m3fn")


@dataclasses.dataclass(frozen=True)
class Precision:
    """Mixed-precision policy: compute / master / wire dtype names."""

    compute: str = "bfloat16"
    master: str = "float32"
    wire: str = "bfloat16"

    def __post_init__(self):
        for field in ("compute", "master", "wire"):
            dt = getattr(self, field)
            if dt not in _SUPPORTED:
                raise ValueError(
                    f"Precision.{field}={dt!r} not in {_SUPPORTED}")


def cast_floats(tree: PyTree, dtype) -> PyTree:
    """Cast every floating leaf of `tree` to `dtype` (ints/keys untouched)."""
    dt = jnp.dtype(dtype)

    def cast(leaf):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            return jnp.asarray(leaf).astype(dt)
        return leaf

    return jax.tree.map(cast, tree)


def compute_cast(tree: PyTree, precision: Precision | None) -> PyTree:
    """Params/batch/lr cast to the compute dtype, tagged for attribution.

    Identity (no ops inserted) when `precision` is None — the default path's
    graph must stay byte-for-byte the pre-mixed-precision round."""
    if precision is None:
        return tree
    with jax.named_scope("precision_cast"):
        return cast_floats(tree, precision.compute)


def master_cast(tree: PyTree, precision: Precision | None) -> PyTree:
    """Deltas cast up to the master dtype before accumulation, tagged."""
    if precision is None:
        return tree
    with jax.named_scope("master_accumulate"):
        return cast_floats(tree, precision.master)


def dense_wire_channel(precision: Precision):
    """The `DenseChannel` matching a policy's wire dtype: the uplink travels
    (and is priced) at ``precision.wire`` width — bf16 halves every dense
    message exactly (`comm.bits.dtype_bits`)."""
    from repro.comm.channels import DenseChannel

    return DenseChannel(wire_dtype=precision.wire)


def resolve_channel(precision: Precision | None, channel=None,
                    qsgd_levels: int | None = None, bits_per_param: int = 32):
    """The drivers' shared uplink-channel rule.  An explicit `channel` wins;
    a quantized config (`qsgd_levels`) wins over the policy wire (QSGD codes
    are already narrower than any float wire); otherwise a `precision`
    policy makes the dense uplink travel — and be priced — at wire width;
    else the historical dense channel, byte-for-byte."""
    from repro.comm.channels import make_channel

    if channel is not None:
        return channel
    if qsgd_levels is None and precision is not None:
        return dense_wire_channel(precision)
    return make_channel(qsgd_levels, bits_per_param)


def downlink_bits_per_param(precision: Precision | None,
                            bits_per_param: int = 32) -> int:
    """Width of a dense model broadcast (ES->client, ES->ES, ES<->PS): the
    policy's wire dtype when mixed precision is on — the server ships the
    compute-dtype model, so the ledger must price that — else the
    configured dense width."""
    from repro.comm.bits import dtype_bits

    return dtype_bits(precision.wire) if precision is not None else bits_per_param
