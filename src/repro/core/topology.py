"""ES-node network topologies for sequential (SFL) passing.

The paper (Appendix B.1) randomly generates a sparse topology where every ES
node connects to at most 3 other ES nodes. We also provide ring / star / line
topologies so the scheduler can be exercised on the shapes the related work
assumes (ring for fixed-order SFL, star for classic HFL).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Undirected connectivity graph over M ES nodes."""

    num_nodes: int
    adjacency: tuple[tuple[int, ...], ...]  # adjacency[m] = sorted neighbor ids

    def neighbors(self, m: int) -> tuple[int, ...]:
        return self.adjacency[m]

    def degree(self, m: int) -> int:
        return len(self.adjacency[m])

    def is_connected(self) -> bool:
        seen = {0}
        stack = [0]
        while stack:
            u = stack.pop()
            for v in self.adjacency[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return len(seen) == self.num_nodes

    def validate(self) -> None:
        assert len(self.adjacency) == self.num_nodes
        for m, nbrs in enumerate(self.adjacency):
            assert m not in nbrs, f"self-loop at {m}"
            for v in nbrs:
                assert 0 <= v < self.num_nodes
                assert m in self.adjacency[v], f"asymmetric edge {m}->{v}"


def _freeze(adj: list[set[int]]) -> Topology:
    topo = Topology(len(adj), tuple(tuple(sorted(s)) for s in adj))
    topo.validate()
    return topo


def ring(num_nodes: int) -> Topology:
    assert num_nodes >= 2
    if num_nodes == 2:
        return _freeze([{1}, {0}])
    adj = [{(m - 1) % num_nodes, (m + 1) % num_nodes} for m in range(num_nodes)]
    return _freeze(adj)


def line(num_nodes: int) -> Topology:
    assert num_nodes >= 2
    adj: list[set[int]] = [set() for _ in range(num_nodes)]
    for m in range(num_nodes - 1):
        adj[m].add(m + 1)
        adj[m + 1].add(m)
    return _freeze(adj)


def star(num_nodes: int) -> Topology:
    """Hub = node 0 (models the classic HFL PS-centred shape)."""
    assert num_nodes >= 2
    adj: list[set[int]] = [set(range(1, num_nodes))] + [{0} for _ in range(num_nodes - 1)]
    return _freeze(adj)


def full(num_nodes: int) -> Topology:
    assert num_nodes >= 2
    adj = [set(range(num_nodes)) - {m} for m in range(num_nodes)]
    return _freeze(adj)


def random_sparse(num_nodes: int, max_degree: int = 3, seed: int = 0) -> Topology:
    """Paper's Appendix B.1 topology: connected, degree <= max_degree.

    Built as a random spanning tree with bounded degree, then densified with
    random extra edges while respecting the degree cap.
    """
    assert num_nodes >= 2 and max_degree >= 2
    rng = np.random.default_rng(seed)
    order = rng.permutation(num_nodes)
    adj: list[set[int]] = [set() for _ in range(num_nodes)]
    # bounded-degree random spanning tree
    in_tree = [int(order[0])]
    for u in order[1:]:
        candidates = [v for v in in_tree if len(adj[v]) < max_degree]
        if not candidates:  # cannot happen for max_degree>=2, but stay safe
            candidates = in_tree
        v = int(rng.choice(candidates))
        adj[int(u)].add(v)
        adj[v].add(int(u))
        in_tree.append(int(u))
    # densify
    extra = num_nodes  # attempt a handful of extra edges
    for _ in range(extra):
        u, v = rng.integers(0, num_nodes, size=2)
        u, v = int(u), int(v)
        if u == v or v in adj[u]:
            continue
        if len(adj[u]) < max_degree and len(adj[v]) < max_degree:
            adj[u].add(v)
            adj[v].add(u)
    return _freeze(adj)


def make_topology(kind: str, num_nodes: int, *, max_degree: int = 3, seed: int = 0) -> Topology:
    factory = {
        "ring": ring,
        "line": line,
        "star": star,
        "full": full,
    }
    if kind in factory:
        return factory[kind](num_nodes)
    if kind == "random_sparse":
        return random_sparse(num_nodes, max_degree=max_degree, seed=seed)
    raise ValueError(f"unknown topology kind: {kind!r}")
