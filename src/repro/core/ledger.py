"""Bit-exact communication accounting.

The paper's §3.2 "Communication Overhead" paragraph and Fig. 2 count information
bits for three hop types:
  * client -> ES uplink (gradients)
  * ES -> client broadcast (model)
  * ES -> ES sequential pass (model)          [Fed-CHS only]
  * ES -> PS / PS -> ES / client <-> PS hops  [baselines]

Each model/gradient vector of d floats costs Q bits (Q = 32 d uncompressed; QSGD
compression changes Q per message and the ledger records the compressed size).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro.comm.bits import dense_message_bits, qsgd_message_bits, topk_message_bits

__all__ = [
    "HOPS",
    "CommLedger",
    "dense_message_bits",
    "qsgd_message_bits",
    "topk_message_bits",
]

HOPS = (
    "client_to_es",
    "es_to_client",
    "es_to_es",
    "es_to_ps",
    "ps_to_es",
    "client_to_ps",
    "ps_to_client",
    "client_to_client",
)


@dataclasses.dataclass
class CommLedger:
    bits: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    messages: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    history: list = dataclasses.field(default_factory=list)  # (round, total_bits) snapshots

    def record(self, hop: str, n_bits: int, count: int = 1) -> None:
        assert hop in HOPS, f"unknown hop {hop}"
        assert n_bits >= 0 and count >= 0
        self.bits[hop] += n_bits * count
        self.messages[hop] += count

    def snapshot(self, round_idx: int) -> None:
        self.history.append((round_idx, self.total_bits()))

    def total_bits(self) -> int:
        return sum(self.bits.values())

    def total_megabytes(self) -> float:
        return self.total_bits() / 8 / 1e6

    def breakdown(self) -> dict[str, int]:
        return {h: self.bits[h] for h in HOPS if self.bits[h]}

    def bits_until(self, predicate_round: int) -> int:
        """Total bits recorded at the first snapshot with round >= predicate_round."""
        for r, b in self.history:
            if r >= predicate_round:
                return b
        return self.total_bits()
