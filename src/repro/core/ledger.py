"""Bit-exact communication accounting + the structured message-event stream.

The paper's §3.2 "Communication Overhead" paragraph and Fig. 2 count information
bits for three hop types:
  * client -> ES uplink (gradients)
  * ES -> client broadcast (model)
  * ES -> ES sequential pass (model)          [Fed-CHS only]
  * ES -> PS / PS -> ES / client <-> PS hops  [baselines]

Each model/gradient vector of d floats costs Q bits (Q = 32 d uncompressed; QSGD
compression changes Q per message and the ledger records the compressed size).

§3.2 counts *bits*; it deliberately says nothing about *time*.  To let the
repo also answer "is Fed-CHS's serial ES->ES pass actually faster than the
baselines' parallel uploads on a real network?" (the HiFlash-style
time-to-accuracy question), `record` optionally attaches per-message metadata
— (round, phase, sender, receiver) — producing a structured `CommEvent`
stream that `repro.netsim` replays through link models into wall-clock
timestamps.  The metadata is accounting-neutral: aggregate `bits`/`messages`
are bit-identical whether or not metadata is supplied.

Node naming convention (shared with `repro.netsim`): ``"client:<i>"``,
``"es:<m>"``, ``"ps"``.  `phase` orders traffic within a round — for
in-cluster traffic it is the interaction index (each interaction is
broadcast -> local compute -> upload), and inter-tier hops (ES->ES, ES->PS,
PS->ES) use phases after the last interaction.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import NamedTuple

from repro.comm.bits import dense_message_bits, qsgd_message_bits, topk_message_bits

__all__ = [
    "HOPS",
    "CommEvent",
    "CommLedger",
    "dense_message_bits",
    "qsgd_message_bits",
    "topk_message_bits",
]

HOPS = (
    "client_to_es",
    "es_to_client",
    "es_to_es",
    "es_to_ps",
    "ps_to_es",
    "client_to_ps",
    "ps_to_client",
    "client_to_client",
)


class CommEvent(NamedTuple):
    """One metered message: who sent what to whom, when in the protocol."""

    round: int
    phase: int
    hop: str
    sender: str
    receiver: str
    n_bits: int


@dataclasses.dataclass
class CommLedger:
    bits: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    messages: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    history: list = dataclasses.field(default_factory=list)  # (round, total_bits) snapshots
    events: list = dataclasses.field(default_factory=list)   # CommEvent stream
    track_events: bool = True  # False drops metadata (saves memory at --full scale)
    staleness: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )  # histogram: staleness tau (in fold versions) -> message count; fed by
    #    the async drivers' fold-in path (tau=0 for on-time updates)

    def record(
        self,
        hop: str,
        n_bits: int,
        count: int = 1,
        *,
        round: int | None = None,
        phase: int = 0,
        sender: str | None = None,
        receiver: str | None = None,
        staleness: int | None = None,
    ) -> None:
        """Meter `count` messages of `n_bits` over `hop`.

        With (round, sender, receiver) metadata, also appends `count`
        structured `CommEvent`s for the network simulator; aggregates are
        identical either way.  `staleness` (async drivers: how many model
        versions behind the fold this update was computed at) feeds the
        per-message staleness histogram.
        """
        assert hop in HOPS, f"unknown hop {hop}"
        assert n_bits >= 0 and count >= 0
        self.bits[hop] += n_bits * count
        self.messages[hop] += count
        if staleness is not None:
            self.staleness[int(staleness)] += count
        if self.track_events and round is not None:
            ev = CommEvent(round, phase, hop, sender or "?", receiver or "?", n_bits)
            self.events.extend([ev] * count)

    def staleness_histogram(self) -> dict[int, int]:
        """{tau: messages folded at staleness tau}, sorted by tau."""
        return dict(sorted(self.staleness.items()))

    def state_dict(self) -> dict:
        """JSON-serialisable snapshot of the full ledger, for run checkpoints
        (`checkpoint.save_run_state`).  `load_state` restores bit-identically:
        aggregates, history, staleness histogram, and (when tracked) the
        structured event stream."""
        return {
            "bits": dict(self.bits),
            "messages": dict(self.messages),
            "history": [list(h) for h in self.history],
            "events": [list(e) for e in self.events],
            "track_events": self.track_events,
            "staleness": {str(k): v for k, v in self.staleness.items()},
        }

    def load_state(self, state: dict) -> None:
        self.bits = defaultdict(int, state["bits"])
        self.messages = defaultdict(int, state["messages"])
        self.history = [tuple(h) for h in state["history"]]
        self.events = [CommEvent(*e) for e in state["events"]]
        self.track_events = bool(state["track_events"])
        self.staleness = defaultdict(
            int, {int(k): v for k, v in state.get("staleness", {}).items()}
        )

    def snapshot(self, round_idx: int) -> None:
        self.history.append((round_idx, self.total_bits()))

    def materialize(self, traffic) -> None:
        """Deferred accounting: replay a precomputed per-round traffic plan.

        The scanned whole-run drivers (`engine.run_scan`) perform zero ledger
        appends in the hot loop; every message of a run is a closed-form
        function of the precomputed visit/participation schedule, so the
        driver reconstructs the stream *after* the run by materializing it
        here.  `traffic` yields ``(round_idx, entries)`` in round order, each
        entry a ``(hop, n_bits, count, phase, sender, receiver)`` tuple —
        per-message entries (count=1, named endpoints) when the event stream
        is tracked, aggregate entries otherwise.  Each round is snapshotted
        after its entries, exactly like the looped drivers' `end_round`, so
        aggregates, event stream, and history are bit-identical to a looped
        run of the same schedule (pinned by tests/test_engine_parity.py).
        """
        for round_idx, entries in traffic:
            for hop, n_bits, count, phase, sender, receiver in entries:
                self.record(hop, n_bits, count, round=round_idx, phase=phase,
                            sender=sender, receiver=receiver)
            self.snapshot(round_idx)

    def total_bits(self) -> int:
        return sum(self.bits.values())

    def total_megabytes(self) -> float:
        return self.total_bits() / 8 / 1e6

    def breakdown(self) -> dict[str, int]:
        return {h: self.bits[h] for h in HOPS if self.bits[h]}

    def round_events(self) -> dict[int, list[CommEvent]]:
        """Events grouped by round, each group sorted by (phase, hop, sender)."""
        grouped: dict[int, list[CommEvent]] = defaultdict(list)
        for ev in self.events:
            grouped[ev.round].append(ev)
        for evs in grouped.values():
            evs.sort(key=lambda e: (e.phase, e.hop, e.sender, e.receiver))
        return dict(grouped)

    def event_index(self) -> dict[tuple, list[int]]:
        """Event positions grouped by ``(round, hop, "sender->receiver")`` in
        stream order — the key the netsim adapters use for transfer-job IDs,
        so the merged-timeline exporter (repro.obs.export) can FIFO-match
        each CommEvent to the simulated job that carried it.  Requires
        `track_events`."""
        idx: dict[tuple, list[int]] = defaultdict(list)
        for i, ev in enumerate(self.events):
            idx[(ev.round, ev.hop, f"{ev.sender}->{ev.receiver}")].append(i)
        return dict(idx)

    def round_bits(self, hop: str | None = None) -> dict[int, int]:
        """Per-round bit totals from the event stream (optionally one hop) —
        the closed-form participation checks read this: under a sampler,
        a round's uplink bits are exactly |participants| * bits_per_message.
        Requires `track_events`."""
        out: dict[int, int] = defaultdict(int)
        for ev in self.events:
            if hop is None or ev.hop == hop:
                out[ev.round] += ev.n_bits
        return dict(out)

    def round_senders(self, round_idx: int, hop: str) -> set[str]:
        """Distinct senders over `hop` in one round (requires `track_events`).
        Under a participation sampler this is exactly the sampled set."""
        return {e.sender for e in self.events
                if e.round == round_idx and e.hop == hop}

    def bits_until(self, predicate_round: int) -> int:
        """Total bits recorded at the first snapshot with round >= predicate_round."""
        for r, b in self.history:
            if r >= predicate_round:
                return b
        return self.total_bits()
