"""Fed-CHS (Algorithm 1) — the paper's contribution, as a thin strategy driver
over the jitted round engine (`repro.core.engine`).

Round t:
  1. ES m(t) broadcasts w^t to its cluster's clients.
  2. K/E interactions: clients run E local optimizer steps from the broadcast
     model (E=1 + plain SGD reproduces Eq. (5) literally: the uploaded
     "delta" is eta_k * grad), upload their update, and the ES takes the
     gamma-weighted aggregate.  The whole inner loop — local steps, deltas,
     channel compression, aggregation — is one fused `lax.scan` on device;
     batches are staged a round at a time, and the only per-round host
     traffic is the params handle, the cluster's client-held optimizer
     states, plus one stacked loss array.
  3. m(t) selects m(t+1) by the 2-step least-traversed / largest-dataset rule
     and pushes w^{t+1} over a single ES->ES hop. No PS anywhere.

The driver is generic over the task's `FedModel` / `DataSource` / `LocalOpt`:
an Appendix-A MLP and a transformer LM take exactly this code path.
Communication is metered bit-exactly via CommLedger; uplinks traverse a
pluggable `Channel` (dense / Pallas-backed QSGD / Top-K) which owns both the
in-graph lossy transform and the per-message bit accounting.  Client-held
optimizer state (e.g. AdamW moments) never traverses a channel.  Every
message is also recorded as a structured `CommEvent` (round, interaction
phase, sender, receiver) so `repro.netsim` can replay the run through link
models and answer the wall-clock question §3.2's bit counting cannot:
whether the serial ES->ES chain beats the baselines' parallel-but-PS-bound
uploads.

Participation (repro.part): `FedCHSConfig.sampler` decides which of the
active cluster's clients report each round.  Participants run the masked
engine round (renormalized gammas, frozen opt state for everyone else); a
cluster whose clients are ALL unavailable degrades to a pass-through hop —
the ES forwards the model over the ES->ES pass without training, the
HiFlash-style staleness answer to dead clusters.  With
`availability_scheduler=True` the 2-step rule itself skips unreachable
neighbors (`AvailabilityAwareScheduler`).  The default
`FullParticipation`/None path is bit-identical to the pre-participation
stack.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import Channel, DenseChannel, make_channel
from repro.core.engine import RoundEngine, split_chain
from repro.core.ledger import CommLedger
from repro.core.scheduler import (
    AvailabilityAwareScheduler,
    FedCHSScheduler,
    LatencyAwareScheduler,
)
from repro.core.simulation import FLTask, RunResult
from repro.core.topology import make_topology
from repro.optim.local import LocalOpt, PlainSGD
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import Sampler, is_full_participation, participation_mask


@dataclasses.dataclass
class FedCHSConfig:
    rounds: int = 200                      # T
    local_steps: int = 20                  # K (total in-cluster iterations)
    local_epochs: int = 1                  # E (local steps per upload); K % E == 0
    topology: str = "random_sparse"        # paper B.1: random sparse, degree <= 3
    topology_seed: int = 0
    dynamic: str | None = None             # "leo" / "iov": per-round graphs
                                           # (core/dynamics.py, Appendix D)
    initial_cluster: int | None = None     # None -> random per Algorithm 1 line 4
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None         # uplink compression (None = dense)
    channel: Channel | None = None         # explicit uplink channel; overrides
                                           # qsgd_levels/bits_per_param
    local_opt: LocalOpt | None = None      # client-held optimizer; None = the
                                           # seed-parity plain-SGD Eq. (5) step
    link_delay: Callable[[int, int], float] | None = None
                                           # ES-pair delay (seconds); switches the
                                           # scheduler to LatencyAwareScheduler
    sampler: Sampler | None = None         # per-round participation (repro.part);
                                           # None / FullParticipation = the exact
                                           # seed-parity pre-participation path
    availability_scheduler: bool = False   # with a sampler: 2-step rule over
                                           # reachable neighbors only
                                           # (AvailabilityAwareScheduler)
    track_events: bool = True              # False: bits only, no CommEvent stream
                                           # (saves memory at --full scale)
    seed: int = 0
    schedule: Schedule | None = None       # default: paper eta_k = 1/(K sqrt(k+1))


def run_fed_chs(task: FLTask, config: FedCHSConfig) -> RunResult:
    task.reset_loaders(config.seed)
    assert config.local_steps % config.local_epochs == 0, "K must divide by E"
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.array([sched_fn(k) for k in range(K)], dtype=np.float32)
    lrs_flat = jnp.asarray(lrs)                              # (K,)  grad mode
    lrs_grouped = jnp.asarray(lrs.reshape(interactions, E))  # (J,E) delta mode

    dyn = None
    if config.dynamic is not None:
        from repro.core.dynamics import make_dynamic

        dyn = make_dynamic(config.dynamic, task.num_clusters, seed=config.topology_seed)
        topo = dyn(0)
    else:
        topo = make_topology(config.topology, task.num_clusters, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    m0 = (
        int(rng.integers(task.num_clusters))
        if config.initial_cluster is None
        else config.initial_cluster
    )
    full_part = is_full_participation(config.sampler)
    if config.availability_scheduler:
        assert config.sampler is not None, "availability_scheduler needs a sampler"

        def reachable(m_: int, r: int) -> bool:
            return len(config.sampler.participants(r, task.cluster_members[m_])) > 0

        scheduler = AvailabilityAwareScheduler(
            topo, task.cluster_sizes, reachable, initial=m0
        )
    elif config.link_delay is not None:
        scheduler = LatencyAwareScheduler(
            topo, task.cluster_sizes, config.link_delay, initial=m0
        )
    else:
        scheduler = FedCHSScheduler(topo, task.cluster_sizes, initial=m0)

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = (
        config.channel
        if config.channel is not None
        else make_channel(config.qsgd_levels, config.bits_per_param)
    )
    engine = RoundEngine(task.model, channel, local_opt=config.local_opt)
    key = jax.random.PRNGKey(config.seed + 1)

    down_bits = DenseChannel(config.bits_per_param).message_bits(d)  # model broadcast
    up_bits = channel.message_bits(d)

    # literal Eq. (5): E=1 dense plain-SGD interactions are gradient uplinks
    # fused into the per-step gamma-weighted SGD scan (explicit PlainSGD is
    # the same mathematical step, so it takes the same path as the default).
    # A non-full sampler forces delta mode: dropouts need the masked round.
    grad_mode = (
        full_part
        and E == 1
        and isinstance(channel, DenseChannel)
        and (config.local_opt is None or isinstance(config.local_opt, PlainSGD))
    )
    opt_states: dict[int, object] = {}  # cluster -> stacked client-held opt state

    rounds_log, acc_log, loss_log = [], [], []
    m = scheduler.state.current
    losses = jnp.full((1,), jnp.nan)  # stays nan until a first trained round
    for t in range(config.rounds):
        members = task.cluster_members[m]
        participating = (
            members if full_part else config.sampler.participants(t, members)
        )

        if grad_mode:
            gammas = jnp.asarray(task.cluster_weights(m))
            batch = task.sample_cluster_batches(m, K)
            params, losses = engine.grad_round(params, batch, gammas, lrs_flat)
        elif full_part:
            gammas = jnp.asarray(task.cluster_weights(m))
            batch = task.sample_round_batches(m, K, E)
            subs = None
            if channel.stochastic:
                key, subs = split_chain(key, interactions)
            if m not in opt_states:
                opt_states[m] = engine.init_opt_state(params, len(members))
            params, opt_states[m], losses = engine.cluster_round(
                params, batch, gammas, lrs_grouped, subs, opt_states[m]
            )
        elif participating:
            # masked round: gammas renormalized over the participating set;
            # batches are staged at full cluster width so the per-client data
            # schedule is independent of churn (dropped clients' draws are
            # consumed but masked out — their opt state stays frozen)
            pmask = participation_mask(members, participating)
            w = task.cluster_weights(m) * pmask
            gammas = jnp.asarray((w / w.sum()).astype(np.float32))
            batch = task.sample_round_batches(m, K, E)
            subs = None
            if channel.stochastic:
                key, subs = split_chain(key, interactions)
            if m not in opt_states:
                opt_states[m] = engine.init_opt_state(params, len(members))
            params, opt_states[m], losses = engine.cluster_round(
                params, batch, gammas, lrs_grouped, subs, opt_states[m],
                mask=pmask,
            )
        # else: the whole cluster is unavailable — the ES becomes a pass-
        # through hop: no training, no client traffic, the model is simply
        # forwarded on the ES->ES pass below (losses keeps its last value)

        # comm accounting: one broadcast + one upload per *participating*
        # client per interaction, metered per message so netsim sees the
        # phase barriers (with events off, the aggregate-identical single
        # records suffice).  Dropped clients cost zero uplink bits.
        es, prev_m = f"es:{m}", m
        if participating:
            if ledger.track_events:
                for j in range(interactions):
                    for i in participating:
                        ledger.record("es_to_client", down_bits, round=t, phase=j,
                                      sender=es, receiver=f"client:{i}")
                        ledger.record("client_to_es", up_bits, round=t, phase=j,
                                      sender=f"client:{i}", receiver=es)
            else:
                ledger.record("es_to_client", down_bits,
                              interactions * len(participating))
                ledger.record("client_to_es", up_bits,
                              interactions * len(participating))

        # next passing cluster (2-step rule) + one ES->ES model hop.
        # Under a dynamic network the ES sees *this round's* visibility graph
        # when choosing the next hop (Appendix-D scenarios).
        if dyn is not None:
            scheduler.set_topology(dyn(t))
        m = scheduler.advance()
        ledger.record("es_to_es", down_bits, round=t, phase=interactions,
                      sender=f"es:{prev_m}", receiver=f"es:{m}")
        engine.end_round(ledger, t)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(task.evaluate(params))
            loss_log.append(float(jnp.mean(losses)))

    return RunResult("fed_chs", rounds_log, acc_log, loss_log, ledger, params,
                     metric_mode=task.metric_mode)
