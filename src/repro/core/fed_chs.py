"""Fed-CHS (Algorithm 1) — the paper's contribution, faithful host-level protocol.

Round t:
  1. ES m(t) broadcasts w^t to its cluster's clients.
  2. K/E interactions: clients run E local SGD steps from the broadcast model
     (E=1 reproduces Eq. (5) literally: the uploaded "delta" is eta_k * grad),
     upload their update, and the ES takes the gamma-weighted aggregate.
  3. m(t) selects m(t+1) by the 2-step least-traversed / largest-dataset rule
     and pushes w^{t+1} over a single ES->ES hop. No PS anywhere.

Communication is metered bit-exactly via CommLedger; uplinks can traverse the
QSGD channel (Pallas kernel) to reproduce the Fig. 2 compression runs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import CommLedger, dense_message_bits, qsgd_message_bits
from repro.core.scheduler import FedCHSScheduler
from repro.core.simulation import (
    FLTask,
    RunResult,
    _cluster_sgd_fn,
    _multi_client_local_sgd_fn,
    evaluate,
    weighted_tree_sum,
)
from repro.core.topology import Topology, make_topology
from repro.kernels.ops import qsgd_compress_tree
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.utils import tree_sub, tree_add


@dataclasses.dataclass
class FedCHSConfig:
    rounds: int = 200                      # T
    local_steps: int = 20                  # K (total in-cluster iterations)
    local_epochs: int = 1                  # E (local steps per upload); K % E == 0
    topology: str = "random_sparse"        # paper B.1: random sparse, degree <= 3
    topology_seed: int = 0
    dynamic: str | None = None             # "leo" / "iov": per-round graphs
                                           # (core/dynamics.py, Appendix D)
    initial_cluster: int | None = None     # None -> random per Algorithm 1 line 4
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None         # uplink compression (None = dense)
    seed: int = 0
    schedule: Schedule | None = None       # default: paper eta_k = 1/(K sqrt(k+1))


def run_fed_chs(task: FLTask, config: FedCHSConfig) -> RunResult:
    task.reset_loaders(config.seed)
    assert config.local_steps % config.local_epochs == 0, "K must divide by E"
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.array([sched_fn(k) for k in range(K)], dtype=np.float32)

    dyn = None
    if config.dynamic is not None:
        from repro.core.dynamics import make_dynamic

        dyn = make_dynamic(config.dynamic, task.num_clusters, seed=config.topology_seed)
        topo = dyn(0)
    else:
        topo = make_topology(config.topology, task.num_clusters, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    m0 = (
        int(rng.integers(task.num_clusters))
        if config.initial_cluster is None
        else config.initial_cluster
    )
    scheduler = FedCHSScheduler(topo, task.cluster_sizes, initial=m0)

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger()
    cluster_phase = _cluster_sgd_fn(task.model)
    multi_local = _multi_client_local_sgd_fn(task.model)
    key = jax.random.PRNGKey(config.seed + 1)

    dense_bits = dense_message_bits(d, config.bits_per_param)
    up_bits = (
        qsgd_message_bits(d, config.qsgd_levels)
        if config.qsgd_levels is not None
        else dense_bits
    )

    rounds_log, acc_log, loss_log = [], [], []
    m = scheduler.state.current
    for t in range(config.rounds):
        members = task.cluster_members[m]
        gammas = jnp.asarray(task.cluster_weights(m))

        if E == 1 and config.qsgd_levels is None:
            # literal Eq. (5): gradient uplinks, gamma-weighted aggregate step
            xs, ys = task.sample_cluster_batches(m, K)
            params, loss = cluster_phase(params, xs, ys, gammas, jnp.asarray(lrs))
        else:
            # E>1 (Fig. 2) and/or QSGD channel: clients upload model deltas
            loss_acc = 0.0
            for j in range(interactions):
                lr_slice = jnp.asarray(lrs[j * E : (j + 1) * E])
                xs, ys = task.sample_cluster_batches(m, E)
                xs = jnp.swapaxes(xs, 0, 1)  # (n, E, B, ...)
                ys = jnp.swapaxes(ys, 0, 1)
                new_p, losses = multi_local(params, xs, ys, lr_slice)
                deltas = jax.tree.map(lambda np_, op: np_ - op[None], new_p, params)
                if config.qsgd_levels is not None:
                    key, sub = jax.random.split(key)
                    deltas = qsgd_compress_tree(deltas, sub, s=config.qsgd_levels)
                agg = jax.tree.map(lambda dl: jnp.einsum("n,n...->...", gammas, dl), deltas)
                params = tree_add(params, agg)
                loss_acc += float(jnp.mean(losses))
            loss = loss_acc / interactions

        # comm accounting for this round
        ledger.record("es_to_client", dense_bits, interactions * len(members))
        ledger.record("client_to_es", up_bits, interactions * len(members))

        # next passing cluster (2-step rule) + one ES->ES model hop.
        # Under a dynamic network the ES sees *this round's* visibility graph
        # when choosing the next hop (Appendix-D scenarios).
        if dyn is not None:
            scheduler.set_topology(dyn(t))
        m = scheduler.advance()
        ledger.record("es_to_es", dense_bits, 1)
        ledger.snapshot(t)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(evaluate(task.model, params, task.dataset))
            loss_log.append(float(loss))

    return RunResult("fed_chs", rounds_log, acc_log, loss_log, ledger, params)
