"""Fed-CHS (Algorithm 1) — the paper's contribution, as a thin strategy driver
over the jitted round engine (`repro.core.engine`).

Round t:
  1. ES m(t) broadcasts w^t to its cluster's clients.
  2. K/E interactions: clients run E local optimizer steps from the broadcast
     model (E=1 + plain SGD reproduces Eq. (5) literally: the uploaded
     "delta" is eta_k * grad), upload their update, and the ES takes the
     gamma-weighted aggregate.  The whole inner loop — local steps, deltas,
     channel compression, aggregation — is one fused `lax.scan` on device;
     batches are staged a round at a time, and the only per-round host
     traffic is the params handle, the cluster's client-held optimizer
     states, plus one stacked loss array.
  3. m(t) selects m(t+1) by the 2-step least-traversed / largest-dataset rule
     and pushes w^{t+1} over a single ES->ES hop. No PS anywhere.

The driver is generic over the task's `FedModel` / `DataSource` / `LocalOpt`:
an Appendix-A MLP and a transformer LM take exactly this code path.
Communication is metered bit-exactly via CommLedger; uplinks traverse a
pluggable `Channel` (dense / Pallas-backed QSGD / Top-K) which owns both the
in-graph lossy transform and the per-message bit accounting.  Client-held
optimizer state (e.g. AdamW moments) never traverses a channel.  Every
message is also recorded as a structured `CommEvent` (round, interaction
phase, sender, receiver) so `repro.netsim` can replay the run through link
models and answer the wall-clock question §3.2's bit counting cannot:
whether the serial ES->ES chain beats the baselines' parallel-but-PS-bound
uploads.

Participation (repro.part): `FedCHSConfig.sampler` decides which of the
active cluster's clients report each round.  Participants run the masked
engine round (renormalized gammas, frozen opt state for everyone else); a
cluster whose clients are ALL unavailable degrades to a pass-through hop —
the ES forwards the model over the ES->ES pass without training, the
HiFlash-style staleness answer to dead clusters.  With
`availability_scheduler=True` the 2-step rule itself skips unreachable
neighbors (`AvailabilityAwareScheduler`).  The default
`FullParticipation`/None path is bit-identical to the pre-participation
stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import Channel, DenseChannel, channel_wire_bits
from repro.core.engine import (
    RoundEngine,
    ScanPlan,
    run_scan,
    scan_cluster_delta_body,
    scan_grad_body,
    split_chain,
)
from repro.core.ledger import CommLedger
from repro.core.precision import (
    Precision,
    downlink_bits_per_param,
    resolve_channel,
)
from repro.core.scheduler import (
    AvailabilityAwareScheduler,
    FedCHSScheduler,
    LatencyAwareScheduler,
)
from repro.core.simulation import FLTask, RunRecorder, RunResult
from repro.core.topology import make_topology
from repro.obs.trace import maybe_span
from repro.data.sources import scatter_put, stage_chunk
from repro.optim.local import LocalOpt, PlainSGD
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import Sampler, is_full_participation, participation_mask
from repro.sharding.fed import resolve_mesh, shard_plan


@dataclasses.dataclass
class FedCHSConfig:
    rounds: int = 200                      # T
    local_steps: int = 20                  # K (total in-cluster iterations)
    local_epochs: int = 1                  # E (local steps per upload); K % E == 0
    topology: str = "random_sparse"        # paper B.1: random sparse, degree <= 3
    topology_seed: int = 0
    dynamic: str | None = None             # "leo" / "iov": per-round graphs
                                           # (core/dynamics.py, Appendix D)
    initial_cluster: int | None = None     # None -> random per Algorithm 1 line 4
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None         # uplink compression (None = dense)
    channel: Channel | None = None         # explicit uplink channel; overrides
                                           # qsgd_levels/bits_per_param
    local_opt: LocalOpt | None = None      # client-held optimizer; None = the
                                           # seed-parity plain-SGD Eq. (5) step
    client_microbatch: int | None = None   # engine memory knob: at most this
                                           # many client replicas train at once
                                           # (None = the all-clients vmap);
                                           # grad mode stays bit-identical,
                                           # delta modes <=1 ulp/interaction
    precision: Precision | None = None     # mixed-precision policy
                                           # (core/precision.py): bf16 client
                                           # compute, f32 master params at the
                                           # ES, wire-dtype dense messages.
                                           # None = the exact f32 seed path.
                                           # Forces delta mode (grad mode is
                                           # the paper-literal f32 arm).
    link_delay: Callable[[int, int], float] | None = None
                                           # ES-pair delay (seconds); switches the
                                           # scheduler to LatencyAwareScheduler
    sampler: Sampler | None = None         # per-round participation (repro.part);
                                           # None / FullParticipation = the exact
                                           # seed-parity pre-participation path
    availability_scheduler: bool = False   # with a sampler: 2-step rule over
                                           # reachable neighbors only
                                           # (AvailabilityAwareScheduler)
    track_events: bool = True              # False: bits only, no CommEvent stream
                                           # (saves memory at --full scale)
    scan_rounds: bool = True               # whole-run lax.scan executor (all
                                           # topologies: dynamic IoV/LEO graphs
                                           # replay host-side — seed-deterministic)
    chunk_rounds: int = 32                 # scanned mode: rounds staged/scanned per
                                           # chunk (bounds staged-batch memory)
    seed: int = 0
    schedule: Schedule | None = None       # default: paper eta_k = 1/(K sqrt(k+1))
    obs: Any = None                        # repro.obs.RunTelemetry: in-graph taps
                                           # + host spans; None (default) keeps the
                                           # compiled graphs byte-for-byte unchanged
    mesh: Any = None                       # jax Mesh with axes ("clusters",
                                           # "clients"): shard the scanned round's
                                           # stacked client axis over the devices
                                           # (repro.sharding.fed, bit-identical).
                                           # None adopts an ambient federation mesh
                                           # (sharding.ctx.model_mesh) if one is
                                           # published, else runs the byte-for-byte
                                           # single-device path.  Looped runs
                                           # (scan_rounds=False) ignore it.
    checkpoint: str | None = None          # path prefix: save the full run state
                                           # every checkpoint_every rounds (forces
                                           # the looped path — the scanned executor
                                           # has no round boundary to save at)
    checkpoint_every: int = 1
    resume: bool = False                   # load the checkpoint if present; the
                                           # resumed run is bit-identical to one
                                           # that was never interrupted


def _make_scheduler(task: FLTask, config: FedCHSConfig, topo, m0: int):
    """The looped and scanned paths build the identical scheduler."""
    if config.availability_scheduler:
        assert config.sampler is not None, "availability_scheduler needs a sampler"

        def reachable(m_: int, r: int) -> bool:
            return len(config.sampler.participants(r, task.cluster_members[m_])) > 0

        return AvailabilityAwareScheduler(topo, task.cluster_sizes, reachable, initial=m0)
    if config.link_delay is not None:
        return LatencyAwareScheduler(topo, task.cluster_sizes, config.link_delay, initial=m0)
    return FedCHSScheduler(topo, task.cluster_sizes, initial=m0)


def _fed_chs_scannable(task: FLTask, config: FedCHSConfig) -> bool:
    """Whether this run can take the whole-run scan path bit-identically.

    Always True now.  Ragged cluster sizes used to force stacked-leaf QSGD
    onto the looped driver (padding to n_max shifted block alignment); with
    per-leaf block boundaries and per-sender fold_in keys every channel is
    padding-invariant.  Dynamic topologies used to need per-round host
    decisions; IoV/LEO graphs are seed-deterministic functions of the round
    index, so `Scheduler.precompute(dynamic=...)` replays the whole visit
    order host-side (step-exact with the looped driver's
    `set_topology`/`advance` sequence).  Kept as a function: it documents
    the gate and gives future genuinely-unscannable configs a seam.
    """
    del task, config
    return True


def _save_sync_state(path: str, task, t_next: int, params, opt_states, key,
                     losses, scheduler, ledger, recorder) -> None:
    """Persist the looped driver's complete round-boundary state (atomic)."""
    from repro.checkpoint.io import save_run_state

    arrays = {
        "params": params,
        "key": key,
        "losses": losses,
        "opt": {str(m): s for m, s in opt_states.items()},
    }
    meta = {
        "algo": "fed_chs",
        "round": t_next,
        "scheduler": {
            "current": int(scheduler.state.current),
            "visit_counts": [int(c) for c in scheduler.state.visit_counts],
            "step": int(scheduler.state.step),
        },
        "opt_clusters": sorted(opt_states),
        "losses_shape": list(np.shape(losses)),
        "draw_counts": list(task.source.draw_counts),
        "ledger": ledger.state_dict(),
        "recorder": {
            "rounds": recorder.rounds_log,
            "acc": recorder.acc_log,
            "loss": recorder.loss_log,
        },
    }
    save_run_state(path, arrays, meta)


def _load_sync_state(path: str, task, params0, engine, scheduler, ledger,
                     recorder):
    """Restore the looped driver's state; returns (t, params, opt_states,
    key, losses).  Mutates scheduler/ledger/recorder/data-source in place."""
    import json

    from repro.checkpoint.io import load_run_state

    with open(path + ".meta.json") as f:
        meta = json.load(f)
    like = {
        "params": params0,
        "key": jax.random.PRNGKey(0),
        "losses": np.zeros(meta["losses_shape"], np.float32),
        "opt": {
            str(m): engine.init_opt_state(
                params0, len(task.cluster_members[int(m)]))
            for m in meta["opt_clusters"]
        },
    }
    arrays, meta = load_run_state(path, like)
    st = meta["scheduler"]
    scheduler.state.current = int(st["current"])
    scheduler.state.visit_counts = np.asarray(st["visit_counts"], np.int64)
    scheduler.state.step = int(st["step"])
    ledger.load_state(meta["ledger"])
    recorder.rounds_log = list(meta["recorder"]["rounds"])
    recorder.acc_log = list(meta["recorder"]["acc"])
    recorder.loss_log = list(meta["recorder"]["loss"])
    task.source.fast_forward(meta["draw_counts"])
    opt_states = {int(m): s for m, s in arrays["opt"].items()}
    return (int(meta["round"]), arrays["params"], opt_states, arrays["key"],
            arrays["losses"])


def run_fed_chs(task: FLTask, config: FedCHSConfig) -> RunResult:
    if (config.scan_rounds and _fed_chs_scannable(task, config)
            and not config.checkpoint):
        return _run_fed_chs_scanned(task, config)
    task.reset_loaders(config.seed)
    assert config.local_steps % config.local_epochs == 0, "K must divide by E"
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.array([sched_fn(k) for k in range(K)], dtype=np.float32)
    lrs_flat = jnp.asarray(lrs)                              # (K,)  grad mode
    lrs_grouped = jnp.asarray(lrs.reshape(interactions, E))  # (J,E) delta mode

    dyn = None
    if config.dynamic is not None:
        from repro.core.dynamics import make_dynamic

        dyn = make_dynamic(config.dynamic, task.num_clusters, seed=config.topology_seed)
        topo = dyn(0)
    else:
        topo = make_topology(config.topology, task.num_clusters, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    m0 = (
        int(rng.integers(task.num_clusters))
        if config.initial_cluster is None
        else config.initial_cluster
    )
    full_part = is_full_participation(config.sampler)
    scheduler = _make_scheduler(task, config, topo, m0)

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = resolve_channel(config.precision, config.channel,
                              config.qsgd_levels, config.bits_per_param)
    engine = RoundEngine(task.model, channel, local_opt=config.local_opt,
                         client_microbatch=config.client_microbatch,
                         precision=config.precision)
    key = jax.random.PRNGKey(config.seed + 1)

    # model broadcast travels at the wire width under a precision policy
    down_bits = DenseChannel(
        downlink_bits_per_param(config.precision, config.bits_per_param)
    ).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())

    # literal Eq. (5): E=1 dense plain-SGD interactions are gradient uplinks
    # fused into the per-step gamma-weighted SGD scan (explicit PlainSGD is
    # the same mathematical step, so it takes the same path as the default).
    # A non-full sampler forces delta mode: dropouts need the masked round.
    # Mixed precision also forces delta mode — grad mode is the paper-literal
    # f32 arm — as does a lossy dense wire (its cast must enter the uplink).
    grad_mode = (
        full_part
        and E == 1
        and isinstance(channel, DenseChannel)
        and channel.wire_dtype is None
        and config.precision is None
        and (config.local_opt is None or isinstance(config.local_opt, PlainSGD))
    )
    opt_states: dict[int, object] = {}  # cluster -> stacked client-held opt state

    obs = config.obs
    taps = obs is not None and obs.taps
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)
    m = scheduler.state.current
    losses = jnp.full((1,), jnp.nan)  # stays nan until a first trained round
    start_round = 0
    if config.resume and config.checkpoint:
        from repro.checkpoint.io import run_state_exists

        if run_state_exists(config.checkpoint):
            (start_round, params, opt_states, key, losses) = _load_sync_state(
                config.checkpoint, task, params, engine, scheduler, ledger,
                recorder,
            )
            m = scheduler.state.current
    for t in range(start_round, config.rounds):
        members = task.cluster_members[m]
        participating = (
            members if full_part else config.sampler.participants(t, members)
        )

        tele = None
        if grad_mode:
            gammas = jnp.asarray(task.cluster_weights(m))
            batch = task.sample_cluster_batches(m, K)
            with maybe_span(obs, "round"):
                out = engine.grad_round(params, batch, gammas, lrs_flat, taps=taps)
                params, losses, tele = out if taps else (*out, None)
        elif full_part:
            gammas = jnp.asarray(task.cluster_weights(m))
            batch = task.sample_round_batches(m, K, E)
            subs = None
            if channel.stochastic:
                key, subs = split_chain(key, interactions)
            if m not in opt_states:
                opt_states[m] = engine.init_opt_state(params, len(members))
            with maybe_span(obs, "round"):
                out = engine.cluster_round(
                    params, batch, gammas, lrs_grouped, subs, opt_states[m],
                    taps=taps,
                )
                params, opt_states[m], losses, tele = out if taps else (*out, None)
        elif participating:
            # masked round: gammas renormalized over the participating set;
            # batches are staged at full cluster width so the per-client data
            # schedule is independent of churn (dropped clients' draws are
            # consumed but masked out — their opt state stays frozen)
            pmask = participation_mask(members, participating)
            w = task.cluster_weights(m) * pmask
            gammas = jnp.asarray((w / w.sum()).astype(np.float32))
            batch = task.sample_round_batches(m, K, E)
            subs = None
            if channel.stochastic:
                key, subs = split_chain(key, interactions)
            if m not in opt_states:
                opt_states[m] = engine.init_opt_state(params, len(members))
            with maybe_span(obs, "round"):
                out = engine.cluster_round(
                    params, batch, gammas, lrs_grouped, subs, opt_states[m],
                    mask=pmask, taps=taps,
                )
                params, opt_states[m], losses, tele = out if taps else (*out, None)
        # else: the whole cluster is unavailable — the ES becomes a pass-
        # through hop: no training, no client traffic, the model is simply
        # forwarded on the ES->ES pass below (losses keeps its last value)
        if tele is not None:
            obs.record_round(t, tele)

        # comm accounting: one broadcast + one upload per *participating*
        # client per interaction, metered per message so netsim sees the
        # phase barriers (with events off, the aggregate-identical single
        # records suffice).  Dropped clients cost zero uplink bits.
        es, prev_m = f"es:{m}", m
        if participating:
            if ledger.track_events:
                for j in range(interactions):
                    for i in participating:
                        ledger.record("es_to_client", down_bits, round=t, phase=j,
                                      sender=es, receiver=f"client:{i}")
                        ledger.record("client_to_es", up_bits, round=t, phase=j,
                                      sender=f"client:{i}", receiver=es)
            else:
                ledger.record("es_to_client", down_bits,
                              interactions * len(participating))
                ledger.record("client_to_es", up_bits,
                              interactions * len(participating))

        # next passing cluster (2-step rule) + one ES->ES model hop.
        # Under a dynamic network the ES sees *this round's* visibility graph
        # when choosing the next hop (Appendix-D scenarios).
        if dyn is not None:
            scheduler.set_topology(dyn(t))
        m = scheduler.advance()
        ledger.record("es_to_es", down_bits, round=t, phase=interactions,
                      sender=f"es:{prev_m}", receiver=f"es:{m}")
        engine.end_round(ledger, t)
        recorder.record(t, params, losses)
        if config.checkpoint and (t + 1) % config.checkpoint_every == 0:
            _save_sync_state(config.checkpoint, task, t + 1, params,
                             opt_states, key, losses, scheduler, ledger,
                             recorder)

    return recorder.result("fed_chs", ledger, params)


# --------------------------------------------------------------------------
# scanned whole-run path (engine.run_scan): the entire schedule — visit
# order, participation masks, renormalized gammas, PRNG subkeys — is
# precomputed host-side, batches are staged a chunk of rounds at a time, and
# the hot loop is one lax.scan per chunk with zero host transfers between
# eval points.  Communication accounting is deferred (`CommLedger.
# materialize`).  Bit-identical params/metrics to the looped path at fixed
# seed (tests/test_engine_parity.py); pass-through rounds consume no data
# draws or subkeys, exactly like the looped driver.
# --------------------------------------------------------------------------


def _fed_chs_scan_plan(task: FLTask, source, config: FedCHSConfig):
    """Build the whole-run `ScanPlan` + deferred glue for one Fed-CHS run.

    `source` is the staging DataSource (the task's own for a single run; a
    per-seed copy for `run_sweep`).  Returns (plan, params_of, traffic) —
    `params_of(carry)` extracts the model params, `traffic(track_events)`
    yields the deferred per-round ledger entries.
    """
    source.reset(config.seed)
    assert config.local_steps % config.local_epochs == 0, "K must divide by E"
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.array([sched_fn(k) for k in range(K)], dtype=np.float32)

    dyn = None
    if config.dynamic is not None:
        from repro.core.dynamics import make_dynamic

        dyn = make_dynamic(config.dynamic, task.num_clusters, seed=config.topology_seed)
        topo = dyn(0)
    else:
        topo = make_topology(config.topology, task.num_clusters, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    m0 = (
        int(rng.integers(task.num_clusters))
        if config.initial_cluster is None
        else config.initial_cluster
    )
    full_part = is_full_participation(config.sampler)
    scheduler = _make_scheduler(task, config, topo, m0)
    # visit order incl. m(R): round R-1's ES->ES hop names its receiver;
    # dynamic (IoV/LEO) graphs replay seed-deterministically inside
    ms = scheduler.precompute(config.rounds + 1, dynamic=dyn)

    R = config.rounds
    members_of = task.cluster_members
    parts = [
        list(members_of[ms[t]]) if full_part
        else config.sampler.participants(t, members_of[ms[t]])
        for t in range(R)
    ]
    trained = np.array([len(p) > 0 for p in parts])

    params = task.init_params()
    d = task.num_params()
    channel = resolve_channel(config.precision, config.channel,
                              config.qsgd_levels, config.bits_per_param)
    engine = RoundEngine(task.model, channel, local_opt=config.local_opt,
                         client_microbatch=config.client_microbatch,
                         precision=config.precision)

    grad_mode = (
        full_part
        and E == 1
        and isinstance(channel, DenseChannel)
        and channel.wire_dtype is None
        and config.precision is None
        and (config.local_opt is None or isinstance(config.local_opt, PlainSGD))
    )
    taps = config.obs is not None and config.obs.taps

    M = task.num_clusters
    n_max = max(len(m) for m in members_of)

    # per-round gamma/mask rows, padded to n_max (zero-weight slots contribute
    # exact zeros — the padded computation matches the looped unpadded one)
    gammas_r = np.zeros((R, n_max), np.float32)
    mask_r = np.zeros((R, n_max), np.float32)
    for t in np.flatnonzero(trained):
        members = members_of[ms[t]]
        w = task.cluster_weights(ms[t])
        if full_part:
            gammas_r[t, : len(members)] = w
            mask_r[t, : len(members)] = 1.0
        else:
            pmask = participation_mask(members, parts[t])
            w = w * pmask
            gammas_r[t, : len(members)] = (w / w.sum()).astype(np.float32)
            mask_r[t, : len(members)] = pmask

    # PRNG subkeys: one fused split chain over the trained rounds reproduces
    # the looped per-round `split_chain(key, J)` calls draw-for-draw
    subs_r = np.zeros((R, interactions, 2), np.uint32)
    if channel.stochastic:
        n_tr = int(trained.sum())
        if n_tr:
            _, flat = split_chain(jax.random.PRNGKey(config.seed + 1), n_tr * interactions)
            subs_r[trained] = np.asarray(flat).reshape(n_tr, interactions, 2)

    def _occurrences(idxs):
        """chunk positions grouped by active cluster, in round order."""
        occ: dict[int, list[int]] = {}
        for c, t in enumerate(idxs):
            occ.setdefault(int(ms[t]), []).append(c)
        return occ

    def _stage_batches(idxs, reshape, alloc):
        """Draw every staged batch of the chunk with one bulk read per
        client; per-client draw order is identical to looped round-by-round
        staging (clients hold independent rng streams, so cross-client order
        is immaterial)."""
        plan, pads = [], []
        for m, cs in _occurrences(idxs).items():
            members = members_of[m]
            plan += [
                (client, K * len(cs),
                 scatter_put((cs, slice(None), slot),
                             lambda dl, n=len(cs): reshape(n, dl)))
                for slot, client in enumerate(members)
            ]
            if len(members) < n_max:
                pads.append((cs, len(members)))
        batch = stage_chunk(source, plan, lambda a, C=len(idxs): alloc(C, a))
        for cs, n_real in pads:  # padded slots replicate member 0
            jax.tree.map(
                lambda bl: bl.__setitem__(
                    (cs, slice(None), slice(n_real, None)), bl[cs, :, 0:1]),
                batch,
            )
        return batch

    if grad_mode:
        # leaves (C, K, n_max, B, ...); per-client draws (occ*K, B, ...) land
        # at [cs, :, slot] as (occ, K, B, ...)
        # Fed-CHS restarts the B.1 within-round decay every round (Eq. (5)),
        # so the staged per-round lrs rows are all identical
        lrs_r = np.broadcast_to(np.asarray(lrs, np.float32), (R, K))

        def stage(idxs):
            batch = _stage_batches(
                idxs,
                reshape=lambda n_occ, dl: dl.reshape(n_occ, K, *dl.shape[1:]),
                alloc=lambda C, a: (C, K, n_max) + a.shape[1:],
            )
            return {"batch": batch, "gammas": gammas_r[idxs],
                    "lrs": np.ascontiguousarray(lrs_r[idxs])}

        body = scan_grad_body(engine.model, taps, config.client_microbatch)
        carry = params
        consts = {}
        params_of = lambda c: c  # noqa: E731
    else:
        # leaves (C, J, n_max, E, B, ...); per-client draws reshape to
        # (occ, J, E, B, ...) — the same K -> (J, E) grouping as
        # FLTask._stage_round_np
        def stage(idxs):
            batch = _stage_batches(
                idxs,
                reshape=lambda n_occ, dl: dl.reshape(n_occ, interactions, E, *dl.shape[1:]),
                alloc=lambda C, a: (C, interactions, n_max, E) + a.shape[1:],
            )
            return {
                "m": ms[idxs].astype(np.int32),
                "batch": batch,
                "gammas": gammas_r[idxs],
                "mask": mask_r[idxs],
                "subs": subs_r[idxs],
            }

        body = scan_cluster_delta_body(engine.model, channel, engine.local_opt,
                                       taps, config.client_microbatch,
                                       config.precision)
        carry = (params, engine.init_opt_state(params, M, n_max))
        consts = {"lrs": jnp.asarray(lrs.reshape(interactions, E))}
        params_of = lambda c: c[0]  # noqa: E731

    plan = ScanPlan(body=body, carry=carry, consts=consts, stage=stage,
                    trained=trained, rounds=R, eval_every=config.eval_every,
                    chunk_rounds=config.chunk_rounds, obs=config.obs)

    mesh = resolve_mesh(config.mesh)
    if mesh is not None:
        # mutually exclusive memory strategies: the mesh shards the client
        # axis across devices, the microbatch scan folds it in time
        assert config.client_microbatch is None, \
            "client_microbatch and a federation mesh are mutually exclusive"
        # population sharding: the active cluster's client axis spreads over
        # the whole mesh (one cluster trains per round — see sharding.fed)
        if grad_mode:
            plan = shard_plan(plan, mesh, "grad", model=engine.model,
                              clients=n_max)
        else:
            plan = shard_plan(plan, mesh, "cluster_delta", model=engine.model,
                              channel=channel, opt=engine.local_opt,
                              clients=n_max)

    down_bits = DenseChannel(
        downlink_bits_per_param(config.precision, config.bits_per_param)
    ).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())

    def traffic(track_events: bool):
        """Closed-form per-round ledger entries from the precomputed
        schedule — byte-for-byte the looped driver's record stream."""
        for t in range(R):
            entries = []
            p = parts[t]
            if p:
                es = f"es:{ms[t]}"
                if track_events:
                    for j in range(interactions):
                        for i in p:
                            entries.append(("es_to_client", down_bits, 1, j,
                                            es, f"client:{i}"))
                            entries.append(("client_to_es", up_bits, 1, j,
                                            f"client:{i}", es))
                else:
                    entries.append(("es_to_client", down_bits,
                                    interactions * len(p), 0, None, None))
                    entries.append(("client_to_es", up_bits,
                                    interactions * len(p), 0, None, None))
            entries.append(("es_to_es", down_bits, 1, interactions,
                            f"es:{ms[t]}", f"es:{ms[t + 1]}"))
            yield t, entries

    return plan, params_of, traffic


def _run_fed_chs_scanned(task: FLTask, config: FedCHSConfig) -> RunResult:
    obs = config.obs
    with maybe_span(obs, "precompute"):
        plan, params_of, traffic = _fed_chs_scan_plan(task, task.source, config)
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)
    carry = run_scan(
        plan, lambda t, c, losses, _lt: recorder.record(t, params_of(c), losses)
    )
    ledger = CommLedger(track_events=config.track_events)
    with maybe_span(obs, "materialize"):
        ledger.materialize(traffic(config.track_events))
    return recorder.result("fed_chs", ledger, params_of(carry))
