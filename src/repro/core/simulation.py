"""Shared FL-simulation machinery: task bundling, jitted local SGD, evaluation.

Every algorithm (Fed-CHS and the three baselines) consumes an `FLTask` and
produces a `RunResult`; the jitted inner loops are shared so accuracy
comparisons are apples-to-apples.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import CommLedger
from repro.data.loader import ClientLoader, batch_iterator
from repro.data.partition import ClientData
from repro.data.synthetic import Dataset
from repro.models.classifier import Classifier
from repro.utils import tree_num_params

PyTree = Any


@dataclasses.dataclass
class FLTask:
    """Everything an FL algorithm needs to run one experiment."""

    model: Classifier
    dataset: Dataset
    clients: list[ClientData]
    cluster_members: list[list[int]]  # cluster m -> client ids
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        self.loaders = [
            ClientLoader(self.dataset, c, self.batch_size, seed=self.seed) for c in self.clients
        ]
        self._loader_seed = self.seed
        self.client_sizes = np.array([c.size for c in self.clients], dtype=np.float64)
        self.cluster_sizes = [
            int(sum(self.client_sizes[i] for i in members)) for members in self.cluster_members
        ]

    def reset_loaders(self, seed: int) -> None:
        """Reseed the per-client samplers — every algorithm run calls this so
        same-seed runs are deterministic and runs don't share rng state."""
        self.loaders = [
            ClientLoader(self.dataset, c, self.batch_size, seed=seed) for c in self.clients
        ]
        self._loader_seed = seed

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    @property
    def num_clusters(self) -> int:
        return len(self.cluster_members)

    def cluster_weights(self, m: int) -> np.ndarray:
        """gamma_n^m = D_n / D_{A,m} for clients in cluster m."""
        sizes = self.client_sizes[self.cluster_members[m]]
        return (sizes / sizes.sum()).astype(np.float32)

    def global_weights(self) -> np.ndarray:
        """gamma_n = D_n / D_A over all clients (FedAvg weighting)."""
        return (self.client_sizes / self.client_sizes.sum()).astype(np.float32)

    def sample_cluster_batches(self, m: int, steps: int):
        """Stacked batches for every client of cluster m:
        xs: (steps, n_clients_m, B, ...), ys: (steps, n_clients_m, B)."""
        members = self.cluster_members[m]
        xs, ys = [], []
        for _ in range(steps):
            bx, by = zip(*(self.loaders[i].next_batch() for i in members))
            xs.append(np.stack(bx))
            ys.append(np.stack(by))
        return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))

    def sample_client_batches(self, client: int, steps: int):
        bx, by = zip(*(self.loaders[client].next_batch() for _ in range(steps)))
        return jnp.asarray(np.stack(bx)), jnp.asarray(np.stack(by))

    def _stage_round_np(self, m: int, total_steps: int, epochs: int):
        """Host-side staging of one round of cluster-m batches as numpy:
        (J, n, E, B, ...). Per-client draw order is identical to epochs-sized
        incremental sampling, so trajectories don't depend on prefetch depth."""
        assert total_steps % epochs == 0
        members = self.cluster_members[m]
        xs, ys = [], []
        for _ in range(total_steps):
            bx, by = zip(*(self.loaders[i].next_batch() for i in members))
            xs.append(np.stack(bx))
            ys.append(np.stack(by))
        x = np.stack(xs)  # (K, n, B, ...)
        y = np.stack(ys)
        J = total_steps // epochs
        x = x.reshape(J, epochs, *x.shape[1:]).swapaxes(1, 2)
        y = y.reshape(J, epochs, *y.shape[1:]).swapaxes(1, 2)
        return x, y

    def sample_round_batches(self, m: int, total_steps: int, epochs: int):
        """Stage one whole round of cluster-m batches, grouped by interaction,
        for the engine's fused scan:
        xs: (J, n, E, B, ...), ys: (J, n, E, B) with J = total_steps // epochs.
        One host->device transfer per round."""
        x, y = self._stage_round_np(m, total_steps, epochs)
        return jnp.asarray(x), jnp.asarray(y)

    def sample_all_cluster_batches(self, total_steps: int, epochs: int):
        """Stage one 3-tier HFL round for EVERY cluster, padded to a uniform
        client width so the engine can vmap over clusters:
        xs: (J, M, n_max, E, B, ...), ys: (J, M, n_max, E, B).
        Padded client slots replicate the cluster's first member (their
        updates are masked out downstream — see `padded_cluster_weights`)."""
        n_max = max(len(members) for members in self.cluster_members)
        per_x, per_y = [], []
        for m in range(self.num_clusters):
            x, y = self._stage_round_np(m, total_steps, epochs)  # (J, n_m, E, ...)
            pad = n_max - x.shape[1]
            if pad:
                x = np.concatenate([x, np.repeat(x[:, :1], pad, axis=1)], axis=1)
                y = np.concatenate([y, np.repeat(y[:, :1], pad, axis=1)], axis=1)
            per_x.append(x)
            per_y.append(y)
        return jnp.asarray(np.stack(per_x, axis=1)), jnp.asarray(np.stack(per_y, axis=1))

    def padded_cluster_weights(self):
        """(gammas, mask), both (M, n_max): per-cluster client weights padded
        with zeros, and a 1/0 mask of real client slots."""
        n_max = max(len(members) for members in self.cluster_members)
        M = self.num_clusters
        gammas = np.zeros((M, n_max), np.float32)
        mask = np.zeros((M, n_max), np.float32)
        for m in range(M):
            w = self.cluster_weights(m)
            gammas[m, : len(w)] = w
            mask[m, : len(w)] = 1.0
        return jnp.asarray(gammas), jnp.asarray(mask)

    def init_params(self) -> PyTree:
        return self.model.init(jax.random.PRNGKey(self.seed))

    def num_params(self) -> int:
        return tree_num_params(self.init_params())


@dataclasses.dataclass
class RunResult:
    name: str
    rounds: list[int]
    test_acc: list[float]
    train_loss: list[float]
    ledger: CommLedger
    final_params: PyTree

    def best_acc(self) -> float:
        return max(self.test_acc) if self.test_acc else 0.0

    def final_acc(self) -> float:
        return self.test_acc[-1] if self.test_acc else 0.0

    def rounds_to_accuracy(self, gamma: float) -> int | None:
        for r, a in zip(self.rounds, self.test_acc):
            if a >= gamma:
                return r
        return None

    def bits_to_accuracy(self, gamma: float) -> int | None:
        r = self.rounds_to_accuracy(gamma)
        return None if r is None else self.ledger.bits_until(r)


# --------------------------------------------------------------------------
# jitted building blocks, cached per (model, shapes)
# --------------------------------------------------------------------------


@functools.cache
def _cluster_sgd_fn(model: Classifier):
    """One Eq.(5) in-cluster phase: scan over K steps of
    w <- w - eta_k * sum_n gamma_n grad_n(w, xi_{n,k}).
    xs: (K, n, B, ...), ys: (K, n, B), gammas: (n,), lrs: (K,).
    Returns (params, mean loss over steps/clients)."""

    grad_fn = jax.vmap(jax.value_and_grad(model.loss), in_axes=(None, 0, 0))

    def phase(params, xs, ys, gammas, lrs):
        def step(p, inp):
            x_k, y_k, lr_k = inp
            losses, grads = grad_fn(p, x_k, y_k)  # per-client
            agg = jax.tree.map(lambda g: jnp.einsum("n,n...->...", gammas, g), grads)
            p = jax.tree.map(lambda w, g: w - lr_k * g, p, agg)
            return p, jnp.dot(gammas, losses)

        params, losses = jax.lax.scan(step, params, (xs, ys, lrs))
        return params, jnp.mean(losses)

    return jax.jit(phase)


@functools.cache
def _local_sgd_fn(model: Classifier):
    """E plain local SGD steps for ONE client: xs (E, B, ...), ys (E, B), lrs (E,)."""

    grad_fn = jax.value_and_grad(model.loss)

    def run(params, xs, ys, lrs):
        def step(p, inp):
            x, y, lr = inp
            loss, g = grad_fn(p, x, y)
            return jax.tree.map(lambda w, gi: w - lr * gi, p, g), loss

        params, losses = jax.lax.scan(step, params, (xs, ys, lrs))
        return params, jnp.mean(losses)

    return jax.jit(run)


@functools.cache
def _multi_client_local_sgd_fn(model: Classifier):
    """vmap of _local_sgd_fn over a leading client axis (same E, B)."""

    grad_fn = jax.value_and_grad(model.loss)

    def run_one(params, xs, ys, lrs):
        def step(p, inp):
            x, y, lr = inp
            loss, g = grad_fn(p, x, y)
            return jax.tree.map(lambda w, gi: w - lr * gi, p, g), loss

        params, losses = jax.lax.scan(step, params, (xs, ys, lrs))
        return params, jnp.mean(losses)

    return jax.jit(jax.vmap(run_one, in_axes=(None, 0, 0, None)))


@functools.cache
def _eval_fn(model: Classifier):
    def correct(params, x, y):
        return jnp.sum((jnp.argmax(model.apply(params, x), axis=-1) == y).astype(jnp.int32))

    return jax.jit(correct)


def evaluate(model: Classifier, params: PyTree, dataset: Dataset, batch: int = 512) -> float:
    fn = _eval_fn(model)
    n_correct, n = 0, 0
    for x, y in batch_iterator(dataset.test_x, dataset.test_y, batch):
        n_correct += int(fn(params, jnp.asarray(x), jnp.asarray(y)))
        n += len(y)
    return n_correct / max(n, 1)


