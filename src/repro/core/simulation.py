"""Shared FL-simulation machinery: FedTask bundling, round staging, evaluation.

Every algorithm (Fed-CHS and the three baselines) consumes an `FLTask` and
produces a `RunResult`.  The task is generic over the workload: its model is
any `FedModel` (a raw Appendix-A `Classifier` is wrapped automatically), its
batches come from any `DataSource` (array classification shards or per-client
token streams), and its metric is whatever the model's `eval_metric` computes
— accuracy for classifiers, perplexity for LMs.  The jitted inner loops live
in `core/oracles.py` / `core/engine.py` and are shared, so quality
comparisons are apples-to-apples across algorithms AND workloads.

Staging helpers return *batch pytrees* (never bare (xs, ys) pairs) whose
leaves carry the engine's documented leading axes, e.g. ``(J, n, E, B, ...)``
for one delta-mode round.  The classifier path stages through the same
`ClientLoader` rng chain as before the FedTask refactor, so fixed-seed
trajectories are bit-identical (tests/test_engine_parity.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import CommLedger
from repro.data.partition import ClientData
from repro.data.sources import ArraySource, DataSource
from repro.data.synthetic import Dataset
from repro.models.classifier import Classifier
from repro.models.fed import FedModel, as_fed_model
from repro.obs.trace import maybe_span
from repro.utils import tree_num_params

PyTree = Any
Batch = Any


def _stack_batches(batches: list[Batch]) -> Batch:
    """Stack a list of equal-structure batch pytrees along a new leading axis."""
    return jax.tree.map(lambda *leaves: np.stack(leaves), *batches)


@dataclasses.dataclass
class FLTask:
    """Everything an FL algorithm needs to run one experiment.

    Classifier construction is unchanged: ``FLTask(clf, dataset, clients,
    cluster_members, batch_size)`` builds an `ArraySource` internally.  Any
    other workload passes `source=` (and leaves dataset/clients as None) or
    uses `FLTask.from_source`.
    """

    model: FedModel | Classifier
    dataset: Dataset | None
    clients: list[ClientData] | None
    cluster_members: list[list[int]]  # cluster m -> client ids
    batch_size: int
    seed: int = 0
    source: DataSource | None = None

    def __post_init__(self):
        self.fed_model: FedModel = as_fed_model(self.model)
        if self.source is None:
            assert self.dataset is not None and self.clients is not None, \
                "FLTask needs either (dataset, clients) or an explicit source"
            self.source = ArraySource(
                self.dataset, self.clients, self.batch_size, seed=self.seed
            )
        self.client_sizes = np.asarray(self.source.client_sizes, dtype=np.float64)
        self.cluster_sizes = [
            int(sum(self.client_sizes[i] for i in members)) for members in self.cluster_members
        ]

    @classmethod
    def from_source(cls, model: FedModel, source: DataSource,
                    cluster_members: list[list[int]], *, seed: int = 0) -> FLTask:
        """Build a task directly over a `DataSource` (no array dataset)."""
        return cls(model, None, None, cluster_members, source.batch_size,
                   seed=seed, source=source)

    def reset_loaders(self, seed: int) -> None:
        """Reseed the per-client samplers — every algorithm run calls this so
        same-seed runs are deterministic and runs don't share rng state."""
        self.source.reset(seed)

    @property
    def num_clients(self) -> int:
        return self.source.num_clients

    @property
    def num_clusters(self) -> int:
        return len(self.cluster_members)

    @property
    def metric_name(self) -> str:
        return self.fed_model.metric_name

    @property
    def metric_mode(self) -> str:
        return self.fed_model.metric_mode

    def cluster_weights(self, m: int) -> np.ndarray:
        """gamma_n^m = D_n / D_{A,m} for clients in cluster m."""
        sizes = self.client_sizes[self.cluster_members[m]]
        return (sizes / sizes.sum()).astype(np.float32)

    def global_weights(self) -> np.ndarray:
        """gamma_n = D_n / D_A over all clients (FedAvg weighting)."""
        return (self.client_sizes / self.client_sizes.sum()).astype(np.float32)

    # ---- batch staging (returns jnp batch pytrees) ------------------------

    def sample_cluster_batches(self, m: int, steps: int) -> Batch:
        """Stacked batches for every client of cluster m:
        leaves (steps, n_clients_m, B, ...)."""
        members = self.cluster_members[m]
        steps_np = _stack_batches([
            _stack_batches([self.source.next_batch(i) for i in members])
            for _ in range(steps)
        ])
        return jax.tree.map(jnp.asarray, steps_np)

    def sample_client_batches(self, client: int, steps: int) -> Batch:
        """One client's next `steps` batches: leaves (steps, B, ...)."""
        batch = _stack_batches([self.source.next_batch(client) for _ in range(steps)])
        return jax.tree.map(jnp.asarray, batch)

    def _stage_round_np(self, m: int, total_steps: int, epochs: int) -> Batch:
        """Host-side staging of one round of cluster-m batches as numpy:
        leaves (J, n, E, B, ...). Per-client draw order is identical to
        epochs-sized incremental sampling, so trajectories don't depend on
        prefetch depth."""
        assert total_steps % epochs == 0
        members = self.cluster_members[m]
        flat = _stack_batches([
            _stack_batches([self.source.next_batch(i) for i in members])
            for _ in range(total_steps)
        ])  # leaves (K, n, B, ...)
        J = total_steps // epochs
        return jax.tree.map(
            lambda a: a.reshape(J, epochs, *a.shape[1:]).swapaxes(1, 2), flat
        )

    def sample_round_batches(self, m: int, total_steps: int, epochs: int) -> Batch:
        """Stage one whole round of cluster-m batches, grouped by interaction,
        for the engine's fused scan: leaves (J, n, E, B, ...) with
        J = total_steps // epochs. One host->device transfer per round."""
        return jax.tree.map(jnp.asarray, self._stage_round_np(m, total_steps, epochs))

    def sample_all_cluster_batches(self, total_steps: int, epochs: int) -> Batch:
        """Stage one 3-tier HFL round for EVERY cluster, padded to a uniform
        client width so the engine can vmap over clusters:
        leaves (J, M, n_max, E, B, ...).
        Padded client slots replicate the cluster's first member (their
        updates are masked out downstream — see `padded_cluster_weights`)."""
        n_max = max(len(members) for members in self.cluster_members)
        per_cluster = []
        for m in range(self.num_clusters):
            b = self._stage_round_np(m, total_steps, epochs)  # (J, n_m, E, ...)
            pad = n_max - len(self.cluster_members[m])
            if pad:
                b = jax.tree.map(
                    lambda a: np.concatenate([a, np.repeat(a[:, :1], pad, axis=1)], axis=1),
                    b,
                )
            per_cluster.append(b)
        stacked = jax.tree.map(lambda *leaves: np.stack(leaves, axis=1), *per_cluster)
        return jax.tree.map(jnp.asarray, stacked)

    def padded_cluster_weights(self):
        """(gammas, mask), both (M, n_max): per-cluster client weights padded
        with zeros, and a 1/0 mask of real client slots."""
        n_max = max(len(members) for members in self.cluster_members)
        M = self.num_clusters
        gammas = np.zeros((M, n_max), np.float32)
        mask = np.zeros((M, n_max), np.float32)
        for m in range(M):
            w = self.cluster_weights(m)
            gammas[m, : len(w)] = w
            mask[m, : len(w)] = 1.0
        return jnp.asarray(gammas), jnp.asarray(mask)

    def init_params(self) -> PyTree:
        return self.fed_model.init(jax.random.PRNGKey(self.seed))

    def num_params(self) -> int:
        return tree_num_params(self.init_params())

    def param_leaf_sizes(self) -> tuple[int, ...]:
        """Per-leaf entry counts of the params pytree, in leaf order — what a
        wire channel needs to price a message exactly (packed blocks are laid
        out per leaf, so each leaf rounds up to whole blocks independently)."""
        return tuple(leaf.size for leaf in jax.tree.leaves(self.init_params()))

    def evaluate(self, params: PyTree) -> float:
        """The task's scalar quality metric (accuracy, perplexity, ...)."""
        return self.fed_model.eval_metric(params, self.source.eval_data())


@dataclasses.dataclass
class RunRecorder:
    """The ONE eval/log tail shared by every driver, looped or scanned.

    The four looped drivers used to carry four duplicated copies of the
    cadence check + metric/loss fetch; the scanned executor needs the same
    logic fired at chunk boundaries.  `record(t, params, losses)` appends to
    the logs iff t is an eval round (t % eval_every == 0, or the final
    round); `losses` is the last trained round's on-device loss array (any
    shape — the logged value is `float(jnp.mean(losses))`, the historical
    per-eval host sync) or None when nothing has trained yet (logs NaN, the
    looped drivers' sentinel).

    `obs` (repro.obs.RunTelemetry) is the run's observability carrier: every
    evaluation is wrapped in its "eval" span (the one place eval happens for
    both looped and scanned paths) and the finished telemetry rides out on
    `RunResult.telemetry`.
    """

    task: FLTask
    rounds: int
    eval_every: int
    obs: Any = None
    rounds_log: list = dataclasses.field(default_factory=list)
    acc_log: list = dataclasses.field(default_factory=list)
    loss_log: list = dataclasses.field(default_factory=list)

    def should_eval(self, t: int) -> bool:
        return t % self.eval_every == 0 or t == self.rounds - 1

    def record(self, t: int, params: PyTree, losses) -> None:
        if not self.should_eval(t):
            return
        self.rounds_log.append(t)
        with maybe_span(self.obs, "eval"):
            self.acc_log.append(self.task.evaluate(params))
        self.loss_log.append(float("nan") if losses is None else float(jnp.mean(losses)))

    def result(self, name: str, ledger: CommLedger, params: PyTree) -> RunResult:
        return RunResult(name, self.rounds_log, self.acc_log, self.loss_log, ledger,
                         params, metric_mode=self.task.metric_mode,
                         telemetry=self.obs)


@dataclasses.dataclass
class RunResult:
    name: str
    rounds: list[int]
    test_acc: list[float]  # the task metric per eval round (see metric_mode)
    train_loss: list[float]
    ledger: CommLedger
    final_params: PyTree
    metric_mode: str = "max"  # "max": accuracy-like; "min": perplexity-like
    telemetry: Any = None  # repro.obs.RunTelemetry when the run carried one
    sim_times: list | None = None  # simulated wall-clock (s) at each eval —
    #   set by the event-driven async drivers (repro.async_fl), where time is
    #   what the run executes rather than a netsim replay after the fact

    def _empty_metric(self) -> float:
        # an empty log must read as WORST-possible, whatever the metric's
        # direction: 0.0 for accuracy-like metrics, but +inf for
        # perplexity-like ones (0.0 would read as a *perfect* perplexity)
        return 0.0 if self.metric_mode == "max" else float("inf")

    def best_acc(self) -> float:
        if not self.test_acc:
            return self._empty_metric()
        return max(self.test_acc) if self.metric_mode == "max" else min(self.test_acc)

    def final_acc(self) -> float:
        return self.test_acc[-1] if self.test_acc else self._empty_metric()

    def _reached(self, value: float, gamma: float) -> bool:
        return value >= gamma if self.metric_mode == "max" else value <= gamma

    def rounds_to_accuracy(self, gamma: float) -> int | None:
        """First eval round where the metric crosses `gamma` (>= for "max"
        metrics, <= for "min" metrics such as perplexity)."""
        for r, a in zip(self.rounds, self.test_acc):
            if self._reached(a, gamma):
                return r
        return None

    def bits_to_accuracy(self, gamma: float) -> int | None:
        r = self.rounds_to_accuracy(gamma)
        return None if r is None else self.ledger.bits_until(r)

    def sim_time_to_accuracy(self, gamma: float) -> float | None:
        """First simulated wall-clock second at which the metric crosses
        `gamma` — only for runs that carry `sim_times` (async drivers)."""
        if self.sim_times is None:
            return None
        for t_s, a in zip(self.sim_times, self.test_acc):
            if self._reached(a, gamma):
                return t_s
        return None


def evaluate(model: Classifier | FedModel, params: PyTree, eval_data,
             batch: int = 512) -> float:
    """Back-compat scalar evaluation: `model.eval_metric` over `eval_data`
    (for classifiers: test-set accuracy over a `Dataset`, batched at 512)."""
    del batch  # fixed inside ClassifierFedModel.eval_metric
    return as_fed_model(model).eval_metric(params, eval_data)
