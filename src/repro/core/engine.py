"""Unified jitted cluster-round engine shared by all four FL algorithms.

Layering — the FedTask stack
----------------------------
The simulation stack is generic over one task abstraction: a `FedModel`
(params init + batch-pytree loss + eval metric), a `DataSource` (per-client
batch staging + held-out eval data), and a `LocalOpt` (client-held local
optimizer state).  An MLP classifier and a 100M-param transformer LM run
through the *same* layers:

  driver   (fed_chs.py, baselines/*.py)
      Owns the *protocol*: which cluster trains when, scheduler hops,
      ledger entries, evaluation cadence.  Pure host-side Python, one
      engine call per round, no per-interaction device syncs.  Drivers
      never look inside a batch — batches are opaque pytrees staged by the
      task's `DataSource`.

  engine   (this module)
      Owns the *round*: the E-local-steps x K/E-interactions inner loop —
      local optimizer steps (`core/oracles.py`), delta computation, channel
      compression, gamma-weighted aggregation — fused into a single
      jit-compiled `lax.scan` (with a `vmap` over clusters for 3-tier HFL).
      Batches for the whole round are staged up front
      (`FLTask.sample_round_batches`), so the only host<->device traffic
      per round is one params handle, the client-held optimizer states, and
      one stacked loss array.

  channel  (repro/comm/channels.py)
      Owns the *message*: the in-graph lossy transform (dense / QSGD /
      Top-K) and its `message_bits` accounting.  Compiled into the scan
      body, so adding a channel never touches a driver or the engine.
      Uplinks carry model deltas only — `LocalOpt` state (momentum, Adam
      moments) stays on the client and never traverses a channel.

A fourth, passive layer rides on the drivers' ledger entries:
`repro.netsim` replays the recorded per-message `CommEvent` stream through
link/compute models to price a run in wall-clock seconds — the paper's
§3.2 overhead model counts only bits, which is exactly what the event
metadata extends without changing (aggregate accounting is bit-identical).
`end_round` below is the uniform per-round bookkeeping hook every driver
calls once per round.

Round modes
-----------
* `grad_round`  — Eq. (5) literal: every in-cluster iteration uploads a
  gradient and the ES applies the gamma-weighted step (E=1, dense, plain
  SGD by definition).
* `cluster_round` — delta mode: clients run E local optimizer steps, upload
  channel-compressed model deltas, ES aggregates; scan over K/E
  interactions.  Per-client optimizer state enters and leaves the round as
  a stacked pytree (leading client axis) the driver holds between rounds.
* `multi_cluster_round` — the Hier-Local-QSGD round: the delta-mode
  interaction vmapped over all M clusters at once (ragged cluster sizes
  handled by padding + masking: padded client slots carry zero gamma
  weight and their deltas are masked to zero before compression), plus the
  ES->PS compress/aggregate/broadcast step, all inside one jit.

Memory & precision
------------------
Two orthogonal execution knobs on `RoundEngine` rescale the same round
computation from MLP toys to 0.6B-param LM clients on one host:

* `client_microbatch` — the delta/grad rounds above historically vmapped the
  E local steps over ALL n clients of the active cluster: n model replicas
  (plus n activation sets under AD) live simultaneously.  With
  `client_microbatch=mb` the engine scans over ceil(n/mb) client groups and
  accumulates the gamma-weighted aggregate in place
  (`_microbatched_cluster_step`), so peak memory is O(mb) replicas + the one
  master copy.  Grad mode stays BIT-identical (the per-step gradient stack
  feeds the unchanged einsum — `oracles.grad_phase`); delta modes match the
  vmapped aggregate to ≤1 ulp per interaction (exact at mb >= n) because
  only the reduction ORDER changes.
* `precision` — a `core.precision.Precision` policy: clients compute
  (forward/backward, local opt steps, raw deltas) in `precision.compute`
  (bf16 halves replica + activation bytes); the authoritative params the ES
  holds — the whole-run scan carry — and the delta accumulator stay in
  `precision.master`; dense wires travel at `precision.wire` width via
  `DenseChannel(wire_dtype=...)`, which the ledger prices exactly.  Casts
  are tagged ("precision_cast" / "master_accumulate") for
  roofline.attribution.  Grad mode — the paper-literal Eq. (5) arm —
  ignores the policy.

Both default to None, which traces the exact pre-knob graphs byte-for-byte
(same functools.cache entries, no inserted ops) — the default-path parity
contract in tests/test_engine_parity.py.  `scan_chunk_fn` additionally
donates the staged per-chunk xs on donation-capable backends, so a chunked
LM run's live set is master state + one chunk of batches + one microbatch
of activations.

Participation
-------------
Per-round participation (repro.part) flows into the rounds as masks riding
the same padded slots the vmapped HFL round already used: a dropped client's
slot carries zero gamma weight, its delta is zeroed before compression, its
loss is excluded from the average, and its `LocalOpt` state is frozen in
place (`_freeze_masked`).  `cluster_round(mask=...)` routes to a separate
compiled function so the default no-mask path stays byte-for-byte the
pre-participation computation; `multi_cluster_round`'s existing mask now
encodes padding AND dropouts, and a fully-dropped cluster degrades to a
zero-delta pass-through (its ES forwards the broadcast model unchanged).

Determinism
-----------
`split_chain(key, n)` reproduces n sequential `key, sub = split(key)`
draws as one fused scan, bit-identical to the eager chains the pre-engine
drivers used — so fixed-seed trajectories are preserved across the
refactor (see tests/test_engine_parity.py).  The default `PlainSGD` path
carries an empty opt-state pytree through the same scans the pre-FedTask
engine ran, so classifier trajectories are unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import Channel, DenseChannel
from repro.core.ledger import CommLedger
from repro.core.oracles import grad_phase, local_opt_steps
from repro.core.precision import Precision, cast_floats, compute_cast, master_cast
from repro.models.fed import FedModel, as_fed_model
from repro.obs.taps import delta_taps, grad_taps, tree_client_norms
from repro.obs.trace import maybe_span
from repro.optim.local import LocalOpt, PlainSGD
from repro.utils import tree_add, tree_sub

PyTree = Any
Batch = Any  # pytree of arrays sharing the documented leading axes

_log = logging.getLogger(__name__)


def _jit_round(fn):
    """jit with donated params where the backend supports buffer donation
    (CPU does not; donating there only emits warnings)."""
    if jax.default_backend() in ("tpu", "gpu"):
        return jax.jit(fn, donate_argnums=(0,))
    return jax.jit(fn)


# --------------------------------------------------------------------------
# PRNG plumbing
# --------------------------------------------------------------------------


@functools.cache
def _split_chain_fn(n: int):
    def chain(key):
        def step(k, _):
            k2, sub = jax.random.split(k)
            return k2, sub

        return jax.lax.scan(step, key, None, length=n)

    return jax.jit(chain)


def split_chain(key: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """n sequential `key, sub = jax.random.split(key)` draws fused into one
    jitted scan. Returns (advanced key, subs (n, 2))."""
    if n == 0:
        return key, jnp.zeros((0, 2), jnp.uint32)
    return _split_chain_fn(n)(key)


def dummy_subs(*lead: int) -> jnp.ndarray:
    """Placeholder key array for non-stochastic channels (never consumed)."""
    return jnp.zeros(tuple(lead) + (2,), jnp.uint32)


# --------------------------------------------------------------------------
# compiled round functions, cached per (model, channel, opt) — shapes are
# handled by jit's own shape-keyed cache
# --------------------------------------------------------------------------


def compress_uplinks(channel: Channel, deltas: PyTree, sub: jax.Array,
                     slots: jax.Array | None = None) -> PyTree:
    """Compress a stacked uplink (leading sender axis on every leaf).

    `per_message` channels (every lossy channel: QSGD/sign-SGD encode each
    sender's message against its own per-leaf blocks; Top-K selection couples
    entries within one message) are vmapped over the sender axis with
    per-sender `fold_in(sub, slot)` keys.  fold_in — not `random.split` — is
    load-bearing: split(sub, n) changes *every* subkey when n changes, while
    fold_in keys slot i independently of how many slots the stacked uplink
    carries, so a run padded to n_max senders (the whole-run scan path) hands
    each real sender the exact key the unpadded looped path would.  Padded
    slots carry zero deltas, which every wire channel encodes to zero norms
    and decodes to exact zeros.  Dense transforms the stack directly.

    `slots` overrides the per-sender key indices: the microbatched client
    path compresses one GROUP of the stacked uplink at a time and passes the
    group's global slot ids, so client i's message is keyed identically
    whether its group holds 1, 2, or all n senders."""
    if getattr(channel, "per_message", False):
        if slots is None:
            n = jax.tree.leaves(deltas)[0].shape[0]
            slots = jnp.arange(n)
        return jax.vmap(
            lambda d, i: channel.compress(d, jax.random.fold_in(sub, i))
        )(deltas, slots)
    return channel.compress(deltas, sub)


@functools.cache
def _grad_round_fn(model: FedModel, taps: bool = False,
                   microbatch: int | None = None):
    """Eq. (5) literal (see `oracles.grad_phase`): batch leaves (K, n, B, ...),
    gammas (n,), lrs (K,). Returns (params, per-step gamma-weighted losses).
    With `taps`, additionally returns the grad-mode tele dict (obs/taps.py).
    Telemetry variants are SEPARATE cache entries: the taps=False graph is
    the exact pre-telemetry round, so the obs=None fast path costs nothing.
    `microbatch` bounds concurrent client forward/backward passes at
    BIT-IDENTICAL output (`oracles.grad_phase`); grad mode is the
    paper-literal f32 path, so there is no precision knob here."""
    phase = grad_phase(model, microbatch)

    def round_fn(params, batch, gammas, lrs):
        with jax.named_scope("local_train"):
            new_params, losses = phase(params, batch, gammas, lrs)
        if taps:
            return new_params, losses, grad_taps(params, new_params, gammas)
        return new_params, losses

    return _jit_round(round_fn)


def _scan_and_tap_last(interaction, carry, xs, taps):
    """Scan `interaction` over a round's interactions; with `taps`, peel the
    FINAL interaction out of the scan and run it with `tap=True`, so the tap
    reductions trace exactly once per round and the tele dict is a
    final-interaction snapshot.  Alternatives measured worse on XLA:CPU
    inside the whole-run scan: a `lax.cond` on "is this the last
    interaction" copies its n×d operands through the conditional every
    interaction, and unconditional per-interaction taps re-run the
    reductions J times at memory speed.  The untapped path is the plain
    full-length scan — byte-for-byte the pre-telemetry graph.
    Returns (carry..., losses (J,)[, tele])."""
    if not taps:
        (a, b), losses = jax.lax.scan(interaction, carry, xs)
        return a, b, losses
    head = jax.tree.map(lambda x: x[:-1], xs)
    last = jax.tree.map(lambda x: x[-1], xs)
    carry, head_losses = jax.lax.scan(interaction, carry, head)
    (a, b), (last_loss, tele) = interaction(carry, last, tap=True)
    losses = jnp.concatenate([head_losses, last_loss[None]])
    return a, b, losses, tele


@functools.cache
def _delta_round_fn(model: FedModel, channel: Channel, opt: LocalOpt,
                    taps: bool = False, microbatch: int | None = None,
                    precision: Precision | None = None):
    """Delta mode: scan over J = K/E interactions; each interaction runs E
    local optimizer steps per client (vmapped), pushes channel-compressed
    deltas, and applies the gamma-weighted aggregate.
    batch leaves: (J, n, E, B, ...), opt_state leaves: (n, ...), lrs: (J, E),
    subs: (J, 2).
    Returns (params, opt_state, per-interaction mean losses (J,)); with
    `taps` also the per-round tele dict (a final-interaction snapshot — see
    `_scan_and_tap_last`).  The round phases are `jax.named_scope`-tagged
    (metadata only — numerics are untouched) so
    roofline.attribution.phase_bytes can bill a whole round.

    `microbatch` routes the interaction through `_microbatched_cluster_step`
    (peak params/activations O(microbatch) instead of O(n) model copies;
    ≤1-ulp vs the vmapped aggregate — see the helper's docstring).
    `precision` is the mixed-precision policy (core/precision.py): compute
    runs in `precision.compute`, the carry params/aggregation stay in the
    master dtype.  Both default to None, which traces the exact
    pre-mixed-precision vmapped graph byte-for-byte."""
    if microbatch is not None:
        assert not taps, "telemetry taps are unsupported with client_microbatch"
        step = _microbatched_cluster_step(
            local_opt_steps(model, opt), channel, int(microbatch), precision)

        def mb_round_fn(params, opt_state, batch, gammas, lrs, subs):
            ones = jnp.ones_like(gammas)

            def interaction(carry, inp):
                p, s = carry
                b, lr, sub = inp
                new_p, new_s, losses = step(p, s, b, gammas, ones, lr, sub)
                return (new_p, new_s), jnp.mean(losses)

            (p, s), losses = jax.lax.scan(interaction, (params, opt_state),
                                          (batch, lrs, subs))
            return p, s, losses

        return _jit_round(mb_round_fn)

    multi_local = jax.vmap(local_opt_steps(model, opt), in_axes=(None, 0, 0, None))

    def round_fn(params, opt_state, batch, gammas, lrs, subs):
        def interaction(carry, inp, tap=False):
            p, s = carry
            b, lr, sub = inp
            p_c = compute_cast(p, precision)
            with jax.named_scope("local_train"):
                new_p, new_s, losses = multi_local(
                    p_c, s, compute_cast(b, precision), compute_cast(lr, precision))
            with jax.named_scope("uplink"):
                raw = jax.tree.map(lambda a, base: a - base[None], new_p, p_c)
                deltas = compress_uplinks(channel, raw, sub)
            deltas = master_cast(deltas, precision)
            with jax.named_scope("intra_agg"):
                agg = jax.tree.map(
                    lambda dl: jnp.einsum("n,n...->...", gammas.astype(dl.dtype), dl),
                    deltas)
                new_params = tree_add(p, agg)
            loss = jnp.mean(losses)
            out = (loss, delta_taps(raw, tree_sub(new_params, p),
                                    gammas)) if tap else loss
            return (new_params, new_s), out

        return _scan_and_tap_last(interaction, (params, opt_state),
                                  (batch, lrs, subs), taps)

    return _jit_round(round_fn)


def _freeze_masked(mask: jax.Array, new_state: PyTree, old_state: PyTree) -> PyTree:
    """Keep masked-out clients' opt state frozen in place: slots with
    mask == 0 leave the round carrying exactly the state they entered with
    (element-wise select, so kept slots are bit-identical to the unmasked
    update)."""
    return jax.tree.map(
        lambda ns, os: jnp.where(mask.reshape((-1,) + (1,) * (ns.ndim - 1)) > 0, ns, os),
        new_state,
        old_state,
    )


def _microbatched_cluster_step(local_fn, channel: Channel, mb: int,
                               precision: Precision | None):
    """One cluster interaction with at most `mb` concurrent client replicas.

    The memory-lean core of `client_microbatch`: instead of vmapping the E
    local steps over all n clients (n model copies + n activation sets live
    at once), clients are processed in ceil(n/mb) groups of `mb` by a
    `lax.scan` that accumulates the gamma-weighted aggregate in place — the
    live set is ONE master params tree + `mb` compute-dtype replicas.  The
    tail group is padded with slot-0 replicas carrying zero gamma AND zero
    mask, so pad work contributes exact zeros and pad opt-state/losses are
    sliced off before returning.

    Numerics contract (pinned by tests/test_engine_parity.py): per-client
    local trajectories are BIT-IDENTICAL to the vmapped path (vmap width
    does not change per-lane arithmetic) and group uplinks are keyed with
    the clients' GLOBAL slot ids (`compress_uplinks(slots=...)`), so the
    deltas entering aggregation are bit-equal too.  Only the aggregation
    ORDER changes: `acc += einsum(gamma_group, delta_group)` vs one full
    einsum — XLA may contract the two differently, so aggregated params
    match to ≤1 ulp per interaction (exact when mb >= n: a single group's
    einsum IS the full einsum).  Grad mode needs none of this caveat — see
    `oracles.grad_phase`.

    Under a `precision` policy the helper is also the mixed-precision hot
    path: params/batch/lr are cast to `precision.compute` once per
    interaction (tagged "precision_cast"), the group deltas are cast up
    (tagged "master_accumulate") into a master-dtype accumulator, and the
    returned params stay master-dtype — the ES never holds a compute-dtype
    authority copy.

    Returns ``step(params, opt_state, batch, gammas, mask, lrs, sub) ->
    (new_params, new_opt_state, per-client losses (n,))`` with batch leaves
    (n, E, B, ...), opt-state leaves (n, ...), gammas/mask (n,), lrs (E,).
    """
    multi_local = jax.vmap(local_fn, in_axes=(None, 0, 0, None))

    def step(p, s, b, gammas, mask, lrs, sub):
        n = gammas.shape[0]
        pad = (-n) % mb
        groups = (n + pad) // mb
        if pad:
            zeros = lambda v: jnp.concatenate([v, jnp.zeros((pad,), v.dtype)])
            rep = lambda a: jnp.concatenate(
                [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])])
            gammas, mask = zeros(gammas), zeros(mask)
            b = jax.tree.map(rep, b)
            s = jax.tree.map(rep, s)
        group = lambda a: a.reshape((groups, mb) + a.shape[1:])

        p_c = compute_cast(p, precision)
        lrs_c = compute_cast(lrs, precision)
        b = compute_cast(b, precision)
        acc0 = jax.tree.map(jnp.zeros_like, p)  # master-dtype accumulator

        def one_group(acc, inp):
            s_j, b_j, g_j, msk_j, slots_j = inp
            with jax.named_scope("local_train"):
                new_p, new_s, losses = multi_local(p_c, s_j, b_j, lrs_c)
                new_s = _freeze_masked(msk_j, new_s, s_j)
            with jax.named_scope("uplink"):
                raw = jax.tree.map(
                    lambda a: a * msk_j.astype(a.dtype).reshape((-1,) + (1,) * (a.ndim - 1)),
                    jax.tree.map(lambda a, base: a - base[None], new_p, p_c),
                )
                deltas = compress_uplinks(channel, raw, sub, slots=slots_j)
            deltas = master_cast(deltas, precision)
            with jax.named_scope("intra_agg"):
                acc = jax.tree.map(
                    lambda a, dl: a + jnp.einsum(
                        "n,n...->...", g_j.astype(a.dtype), dl.astype(a.dtype)),
                    acc, deltas)
            return acc, (new_s, losses)

        xs = (jax.tree.map(group, s), jax.tree.map(group, b), group(gammas),
              group(mask), group(jnp.arange(n + pad)))
        acc, (new_s, losses) = jax.lax.scan(one_group, acc0, xs)
        new_params = tree_add(p, acc)
        new_s = jax.tree.map(lambda a: a.reshape((n + pad,) + a.shape[2:])[:n], new_s)
        return new_params, new_s, losses.reshape(n + pad)[:n]

    return step


@functools.cache
def _masked_round_body(model: FedModel, channel: Channel, opt: LocalOpt,
                       taps: bool = False, microbatch: int | None = None,
                       precision: Precision | None = None):
    """The pure (unjitted) masked delta round — shared verbatim by the
    per-round compiled function (`_masked_delta_round_fn`) and the whole-run
    scan bodies below, so the looped and scanned paths trace the exact same
    computation.  With `taps` the round additionally returns the tele dict
    (mask-weighted, a final-interaction snapshot — see `_scan_and_tap_last`);
    taps=False is its own cache entry tracing the exact pre-telemetry
    graph.  `microbatch`/`precision` as in `_delta_round_fn` (the microbatch
    path routes through `_microbatched_cluster_step`; None/None traces the
    pre-mixed-precision graph byte-for-byte)."""
    if microbatch is not None:
        assert not taps, "telemetry taps are unsupported with client_microbatch"
        step = _microbatched_cluster_step(
            local_opt_steps(model, opt), channel, int(microbatch), precision)

        def mb_round_fn(params, opt_state, batch, gammas, mask, lrs, subs):
            def interaction(carry, inp):
                p, s = carry
                b, lr, sub = inp
                new_p, new_s, losses = step(p, s, b, gammas, mask, lr, sub)
                loss = jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)
                return (new_p, new_s), loss

            (p, s), losses = jax.lax.scan(interaction, (params, opt_state),
                                          (batch, lrs, subs))
            return p, s, losses

        return mb_round_fn

    multi_local = jax.vmap(local_opt_steps(model, opt), in_axes=(None, 0, 0, None))

    def round_fn(params, opt_state, batch, gammas, mask, lrs, subs):
        def interaction(carry, inp, tap=False):
            p, s = carry
            b, lr, sub = inp
            p_c = compute_cast(p, precision)
            with jax.named_scope("local_train"):
                new_p, new_s, losses = multi_local(
                    p_c, s, compute_cast(b, precision), compute_cast(lr, precision))
                new_s = _freeze_masked(mask, new_s, s)
            with jax.named_scope("uplink"):
                raw = jax.tree.map(
                    lambda a, base: (a - base[None])
                    * mask.astype(a.dtype).reshape((-1,) + (1,) * (a.ndim - 1)),
                    new_p,
                    p_c,
                )
                deltas = compress_uplinks(channel, raw, sub)
            deltas = master_cast(deltas, precision)
            with jax.named_scope("intra_agg"):
                agg = jax.tree.map(
                    lambda dl: jnp.einsum("n,n...->...", gammas.astype(dl.dtype), dl),
                    deltas)
                new_params = tree_add(p, agg)
            loss = jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            out = (loss, delta_taps(raw, tree_sub(new_params, p),
                                    gammas, mask)) if tap else loss
            return (new_params, new_s), out

        return _scan_and_tap_last(interaction, (params, opt_state),
                                  (batch, lrs, subs), taps)

    return round_fn


@functools.cache
def _masked_delta_round_fn(model: FedModel, channel: Channel, opt: LocalOpt,
                           taps: bool = False, microbatch: int | None = None,
                           precision: Precision | None = None):
    """Delta mode with a per-client participation mask (n,): masked-out
    clients contribute zero delta (their slot is zeroed before compression),
    are excluded from the loss average, and keep their `LocalOpt` state
    frozen in place.  `gammas` must already be renormalized over the
    participating set (zero on masked slots).  Otherwise identical to
    `_delta_round_fn`; the unmasked function stays untouched so the default
    full-participation path is bit-identical to the pre-participation stack.
    """
    return _jit_round(_masked_round_body(model, channel, opt, taps,
                                         microbatch, precision))


@functools.cache
def _multi_round_body(model: FedModel, channel: Channel, es_channel: Channel, opt: LocalOpt,
                      taps: bool = False, microbatch: int | None = None,
                      precision: Precision | None = None):
    """Pure (unjitted) 3-tier HFL global round, vmapped over all M clusters at
    once — shared by `_multi_round_fn` and the whole-run scan body.
    batch leaves: (J, M, n_max, E, B, ...), opt_state leaves: (M, n_max, ...),
    gammas/mask: (M, n_max), es_weights: (M,), lrs: (J, E), subs: (J, M, 2),
    es_subs: (M, 2).  Padded client slots (mask == 0) carry zero gamma
    weight and their deltas are zeroed before compression.
    Returns (params, opt_state, per-(interaction, cluster) losses (J, M));
    with `taps` also a per-cluster (M,) tele dict (a final-interaction
    snapshot — see `_scan_and_tap_last` — + "es_comp_err" for the ES->PS
    channel).  taps=False traces the exact pre-telemetry graph.
    `microbatch`/`precision` as in `_delta_round_fn`: the per-cluster
    interaction routes through `_microbatched_cluster_step` (the M-cluster
    vmap stays — peak is M * microbatch compute replicas), and cluster/PS
    params stay master-dtype."""
    if microbatch is not None:
        assert not taps, "telemetry taps are unsupported with client_microbatch"
        mb_step = _microbatched_cluster_step(
            local_opt_steps(model, opt), channel, int(microbatch), precision)
    multi_local = jax.vmap(local_opt_steps(model, opt), in_axes=(None, 0, 0, None))

    def round_fn(params, opt_state, batch, gammas, mask, es_weights, lrs, subs, es_subs):
        M = mask.shape[0]
        cparams0 = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf[None], (M,) + leaf.shape), params
        )

        def interaction(carry, inp, tap=False):
            cp, s = carry
            b, lr, sub = inp

            def one_cluster_mb(p_m, s_m, b_m, g_m, msk_m, sub_m):
                new_pm, new_s, losses = mb_step(p_m, s_m, b_m, g_m, msk_m, lr, sub_m)
                loss = jnp.sum(losses * msk_m) / jnp.maximum(jnp.sum(msk_m), 1.0)
                return new_pm, new_s, loss

            def one_cluster(p_m, s_m, b_m, g_m, msk_m, sub_m):
                p_mc = compute_cast(p_m, precision)
                with jax.named_scope("local_train"):
                    new_p, new_s, losses = multi_local(
                        p_mc, s_m, compute_cast(b_m, precision),
                        compute_cast(lr, precision))
                    # masked slots (padding OR dropped-out clients) keep their opt
                    # state frozen; for real participating slots the select is a
                    # bit-exact identity, so default-path parity holds
                    new_s = _freeze_masked(msk_m, new_s, s_m)
                with jax.named_scope("uplink"):
                    raw = jax.tree.map(
                        lambda a, base: (a - base[None])
                        * msk_m.astype(a.dtype).reshape((-1,) + (1,) * (a.ndim - 1)),
                        new_p,
                        p_mc,
                    )
                    deltas = compress_uplinks(channel, raw, sub_m)
                deltas = master_cast(deltas, precision)
                with jax.named_scope("intra_agg"):
                    agg = jax.tree.map(
                        lambda dl: jnp.einsum("n,n...->...", g_m.astype(dl.dtype), dl),
                        deltas)
                    new_pm = tree_add(p_m, agg)
                # a fully-dropped cluster has sum(mask) == 0: its loss reads 0
                # and its params stay at the broadcast model (zero deltas)
                loss = jnp.sum(losses * msk_m) / jnp.maximum(jnp.sum(msk_m), 1.0)
                out = (loss, delta_taps(raw, tree_sub(new_pm, p_m),
                                        g_m, msk_m)) if tap else loss
                return new_pm, new_s, out

            cluster_fn = one_cluster_mb if microbatch is not None else one_cluster
            cp, s, ys = jax.vmap(cluster_fn)(cp, s, b, gammas, mask, sub)
            return (cp, s), ys

        out = _scan_and_tap_last(interaction, (cparams0, opt_state),
                                 (batch, lrs, subs), taps)
        cparams, opt_state = out[0], out[1]

        # ES -> PS: compressed cluster deltas, PS weighted-aggregates + broadcasts
        with jax.named_scope("es_hop"):
            if taps:
                raw_es = jax.vmap(lambda p_m: tree_sub(p_m, params))(cparams)
                es_deltas = jax.vmap(es_channel.compress)(raw_es, es_subs)
            else:
                es_deltas = jax.vmap(
                    lambda p_m, sub_m: es_channel.compress(tree_sub(p_m, params), sub_m)
                )(cparams, es_subs)
            agg = jax.tree.map(lambda x_: jnp.einsum("m,m...->...", es_weights, x_), es_deltas)
            new_params = tree_add(params, agg)
        if taps:
            losses, tele = out[2], dict(out[3])  # tele leaves: (M,)
            tele["es_comp_err"] = tree_client_norms(
                jax.tree.map(lambda c, r: c - r, es_deltas, raw_es))
            return new_params, opt_state, losses, tele
        return new_params, opt_state, out[2]

    return round_fn


@functools.cache
def _multi_round_fn(model: FedModel, channel: Channel, es_channel: Channel, opt: LocalOpt,
                    taps: bool = False, microbatch: int | None = None,
                    precision: Precision | None = None):
    """Compiled `_multi_round_body` (the per-round 3-tier HFL entry point)."""
    return _jit_round(_multi_round_body(model, channel, es_channel, opt, taps,
                                        microbatch, precision))


# --------------------------------------------------------------------------
# public facade
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundEngine:
    """Per-run facade over the cached compiled round functions.

    `model` may be a raw `Classifier` (wrapped to a `FedModel` on
    construction) or any `FedModel`.  `channel` compresses client -> ES
    uplinks; `es_channel` (3-tier HFL only) compresses ES -> PS uplinks and
    defaults to `channel`.  `local_opt` is the client-held local optimizer;
    the default `PlainSGD` is the seed-parity Eq. (5) step.

    `client_microbatch` bounds how many client replicas train concurrently
    inside a round (None = the historical all-clients vmap): peak memory
    drops from O(n) to O(microbatch) model copies — bit-identical in grad
    mode, ≤1 ulp in delta modes (`_microbatched_cluster_step`).
    `precision` is the mixed-precision policy (core/precision.py): clients
    compute in `precision.compute` while the engine's authoritative params
    and delta aggregation stay in `precision.master`; grad mode (the
    paper-literal Eq. (5) path) ignores it.  Both default to None, which
    keeps every compiled graph byte-for-byte the pre-knob round.
    """

    model: FedModel
    channel: Channel = DenseChannel()
    es_channel: Channel | None = None
    local_opt: LocalOpt | None = None  # None -> PlainSGD()
    client_microbatch: int | None = None
    precision: Precision | None = None

    def __post_init__(self):
        object.__setattr__(self, "model", as_fed_model(self.model))
        if self.local_opt is None:
            object.__setattr__(self, "local_opt", PlainSGD())
        if self.client_microbatch is not None:
            assert self.client_microbatch >= 1

    def init_opt_state(self, params: PyTree, *lead: int) -> PyTree:
        """Fresh stacked per-client optimizer state with leading axes `lead`
        (e.g. `(n,)` for one cluster, `(M, n_max)` for 3-tier HFL).  Empty
        pytree (zero cost) for the default stateless SGD.  Under a
        `precision` policy the state is seeded from the COMPUTE-dtype params:
        client-held moments live at compute width (only the ES keeps f32
        state), matching the dtype the local steps update them at."""
        if self.precision is not None:
            params = cast_floats(params, self.precision.compute)
        state = self.local_opt.init(params)
        for n in reversed(lead):
            state = jax.tree.map(
                lambda leaf, n=n: jnp.broadcast_to(leaf[None], (n,) + leaf.shape), state
            )
        return state

    def grad_round(self, params, batch, gammas, lrs, *, taps=False):
        return _grad_round_fn(self.model, taps, self.client_microbatch)(
            params, batch, gammas, lrs)

    def cluster_round(self, params, batch, gammas, lrs, subs=None, opt_state=None,
                      mask=None, *, taps=False):
        """One delta-mode round.  `mask` (n,) is the optional per-client
        participation mask (repro.part): masked-out clients contribute zero
        delta, are excluded from the loss, and keep their opt state frozen.
        With `mask=None` the compiled function is the exact pre-participation
        round — the bit-identical full-participation path.  `taps=True`
        appends the per-round tele dict to the return tuple (a separately
        cached compiled variant; the default path's graph is untouched)."""
        J = jax.tree.leaves(batch)[0].shape[0]
        n = jax.tree.leaves(batch)[0].shape[1]
        if subs is None:
            subs = dummy_subs(J)
        if opt_state is None:
            opt_state = self.init_opt_state(params, n)
        if mask is None:
            fn = _delta_round_fn(self.model, self.channel, self.local_opt, taps,
                                 self.client_microbatch, self.precision)
            return fn(params, opt_state, batch, gammas, lrs, subs)
        fn = _masked_delta_round_fn(self.model, self.channel, self.local_opt, taps,
                                    self.client_microbatch, self.precision)
        return fn(params, opt_state, batch, gammas, jnp.asarray(mask), lrs, subs)

    def multi_cluster_round(
        self, params, batch, gammas, mask, es_weights, lrs,
        subs=None, es_subs=None, opt_state=None, *, taps=False,
    ):
        J, M = jax.tree.leaves(batch)[0].shape[:2]
        if subs is None:
            subs = dummy_subs(J, M)
        if es_subs is None:
            es_subs = dummy_subs(M)
        if opt_state is None:
            opt_state = self.init_opt_state(params, M, mask.shape[1])
        fn = _multi_round_fn(
            self.model, self.channel, self.es_channel or self.channel, self.local_opt,
            taps, self.client_microbatch, self.precision,
        )
        return fn(params, opt_state, batch, gammas, mask, es_weights, lrs, subs, es_subs)

    def end_round(self, ledger: CommLedger, round_idx: int) -> None:
        """Uniform end-of-round bookkeeping: snapshot the ledger.

        Every driver calls this exactly once per round (instead of each
        driver deciding its own snapshot cadence), so `bits_until` always
        sees a complete per-round history regardless of algorithm.
        """
        ledger.snapshot(round_idx)


# --------------------------------------------------------------------------
# whole-run execution: lax.scan over rounds
# --------------------------------------------------------------------------
#
# The looped drivers pay per-round host costs: one jit dispatch, per-round
# batch `jnp.asarray` transfers, scheduler advances, and ledger appends.
# `run_scan` removes all of them from the hot loop: the driver precomputes
# the whole run's schedule host-side (visit order, participation masks, PRNG
# subkeys), stages batches a *chunk* of rounds at a time, and executes each
# chunk as one jitted `lax.scan` over rounds.  The only host<->device traffic
# between eval points is the chunk's single explicit `device_put`; communica-
# tion accounting is deferred to `CommLedger.materialize` after the run.
#
# Rounds in which nothing trains (an all-dark cluster, a zero-reporter FedAvg
# round, a pass-through walk visit) are pure no-ops on the model state, so
# the scan simply *skips* them: it runs over the trained rounds only, and the
# host-side schedule maps eval/ledger bookkeeping back to global round
# indices.  That keeps the scan body mask-free of `trained` flags and means
# dark rounds consume neither data draws nor PRNG subkeys — exactly the
# looped drivers' behavior.
#
# Scan bodies close over the SAME cached pure round bodies the per-round
# compiled functions use (`_masked_round_body`, `_multi_round_body`,
# `oracles.grad_phase`), so looped and scanned runs trace identical per-round
# computations: model params are bit-identical at fixed seed (pinned by
# tests/test_engine_parity.py); only the *reported* loss scalars may differ
# by ~1 ulp from reduction fusion across the scan boundary.


@functools.cache
def scan_grad_body(model: FedModel, taps: bool = False,
                   microbatch: int | None = None):
    """Whole-run body, Eq. (5) grad mode.  carry: params.
    x: {"batch": (K, n_max, B, ...), "gammas": (n_max,), "lrs": (K,)} (padded
    client slots carry zero gamma weight — exact-zero contributions; the step
    sizes are staged per round so decaying schedules can track the GLOBAL
    round index, e.g. WRWGD's walk).  Emits the per-step gamma-weighted
    losses (K,); with `taps` the ys are (losses, tele) so the chunk runner
    can split the stacked telemetry off.  `microbatch` bounds concurrent
    client backward passes bit-identically (`oracles.grad_phase`)."""
    phase = grad_phase(model, microbatch)

    def body(params, x, consts):
        del consts
        with jax.named_scope("local_train"):
            new_params, losses = phase(params, x["batch"], x["gammas"], x["lrs"])
        if taps:
            return new_params, (losses, grad_taps(params, new_params, x["gammas"]))
        return new_params, losses

    return body


@functools.cache
def scan_delta_body(model: FedModel, channel: Channel, opt: LocalOpt,
                    taps: bool = False, microbatch: int | None = None,
                    precision: Precision | None = None):
    """Whole-run body, delta mode over one fixed client set (FedAvg).
    carry: (params, opt_state (n, ...)).  x: {"batch": (J, n, E, B, ...),
    "gammas"/"mask": (n,), "subs": (J, 2)}.  consts: {"lrs": (J, E)}.
    Emits per-interaction masked mean losses (J,); with `taps` the ys are
    (losses, tele).  `microbatch`/`precision` as in `_delta_round_fn`."""
    round_fn = _masked_round_body(model, channel, opt, taps, microbatch, precision)

    def body(carry, x, consts):
        params, opt_state = carry
        out = round_fn(
            params, opt_state, x["batch"], x["gammas"], x["mask"], consts["lrs"], x["subs"]
        )
        if taps:
            params, opt_state, losses, tele = out
            return (params, opt_state), (losses, tele)
        params, opt_state, losses = out
        return (params, opt_state), losses

    return body


@functools.cache
def scan_cluster_delta_body(model: FedModel, channel: Channel, opt: LocalOpt,
                            taps: bool = False, microbatch: int | None = None,
                            precision: Precision | None = None):
    """Whole-run body, delta mode with a per-round active cluster (Fed-CHS).
    carry: (params, opt_states (M, n_max, ...)) — the active cluster's rows
    are gathered/scattered by the scanned cluster index x["m"].
    x adds "m": () int32 to the `scan_delta_body` inputs (all padded to
    n_max width).  `microbatch`/`precision` as in `_delta_round_fn`."""
    round_fn = _masked_round_body(model, channel, opt, taps, microbatch, precision)

    def body(carry, x, consts):
        params, opt_all = carry
        m = x["m"]
        s_m = jax.tree.map(
            lambda leaf: jax.lax.dynamic_index_in_dim(leaf, m, 0, keepdims=False), opt_all
        )
        out = round_fn(
            params, s_m, x["batch"], x["gammas"], x["mask"], consts["lrs"], x["subs"]
        )
        if taps:
            params, new_s, losses, tele = out
        else:
            params, new_s, losses = out
        opt_all = jax.tree.map(
            lambda leaf, ns: jax.lax.dynamic_update_index_in_dim(leaf, ns, m, 0),
            opt_all,
            new_s,
        )
        if taps:
            return (params, opt_all), (losses, tele)
        return (params, opt_all), losses

    return body


@functools.cache
def scan_multi_body(model: FedModel, channel: Channel, es_channel: Channel, opt: LocalOpt,
                    taps: bool = False, microbatch: int | None = None,
                    precision: Precision | None = None):
    """Whole-run body, 3-tier HFL global rounds (Hier-Local-QSGD).
    carry: (params, opt_state (M, n_max, ...)).  x: {"batch": (J, M, n_max,
    E, B, ...), "gammas"/"mask": (M, n_max), "es_weights": (M,), "subs":
    (J, M, 2), "es_subs": (M, 2)}.  Emits losses (J, M); with `taps` the ys
    are (losses, tele) with per-cluster (M,) tele leaves.
    `microbatch`/`precision` as in `_multi_round_body`."""
    round_fn = _multi_round_body(model, channel, es_channel, opt, taps,
                                 microbatch, precision)

    def body(carry, x, consts):
        params, opt_state = carry
        out = round_fn(
            params, opt_state, x["batch"], x["gammas"], x["mask"], x["es_weights"],
            consts["lrs"], x["subs"], x["es_subs"],
        )
        if taps:
            params, opt_state, losses, tele = out
            return (params, opt_state), (losses, tele)
        params, opt_state, losses = out
        return (params, opt_state), losses

    return body


@functools.cache
def _chunk_of(body):
    """The pure chunk function: scan `body` over a stacked-rounds xs pytree.
    Signature: (carry, xs, consts) -> (carry, stacked per-round losses)."""

    def chunk(carry, xs, consts):
        return jax.lax.scan(lambda c, x: body(c, x, consts), carry, xs)

    return chunk


@functools.cache
def scan_chunk_fn(body):
    """jit(chunk) — the whole-run hot loop.  Where the backend supports
    buffer donation (tpu/gpu; CPU donation only warns), BOTH chunk inputs
    are donated:

      * the carry (argnum 0) — run-level: params/opt-state buffers are
        reused across chunks, so the master params exist once;
      * the staged xs (argnum 1) — chunk-level: `_run_chunks` stages a
        FRESH xs pytree per chunk via `device_put` and never touches it
        again, so donating hands its batch buffers back to the allocator
        as the scan consumes them.

    Together with `client_microbatch` this is what pins the LM run's live
    set at (master params + opt states) + one chunk of staged batches +
    one microbatch of activations.  `consts` (argnum 2) is deliberately NOT
    donated: it is reused by every chunk of the run."""
    fn = _chunk_of(body)
    if jax.default_backend() in ("tpu", "gpu"):
        return jax.jit(fn, donate_argnums=(0, 1))
    return jax.jit(fn)


@functools.cache
def sweep_chunk_fn(body):
    """`scan_chunk_fn` vmapped over a leading seed axis on carry and xs
    (consts are shared) — one dispatch advances every seed of a sweep."""
    return _jit_round(jax.vmap(_chunk_of(body), in_axes=(0, 0, None)))


def eval_rounds(rounds: int, eval_every: int) -> list[int]:
    """The rounds every driver logs at: t % eval_every == 0, plus the final
    round — the exact looped-driver cadence."""
    ev = [t for t in range(rounds) if t % eval_every == 0]
    if rounds - 1 not in ev:
        ev.append(rounds - 1)
    return ev


@dataclasses.dataclass
class ScanPlan:
    """A precomputed whole-run schedule for `run_scan`.

    `trained` marks the rounds that actually train (all of them under full
    participation); the scan runs over those only.  `stage(idxs)` returns the
    stacked per-round scan inputs (numpy leaves, leading axis len(idxs)) for
    the given ascending *global* round indices — it is the only host work
    left in the loop, and `run_scan` moves its output to the device with one
    explicit `device_put` per chunk.
    """

    body: Any                 # a scan_*_body (hashable: keys the jit cache)
    carry: PyTree
    consts: PyTree
    stage: Any                # (np.ndarray of round idxs) -> xs pytree
    trained: Any              # (rounds,) bool numpy array
    rounds: int
    eval_every: int
    chunk_rounds: int = 32
    obs: Any = None           # repro.obs.RunTelemetry | None; when its taps
    #                           flag is set, `body` must be the tapped variant
    #                           (ys = (losses, tele)) — plan builders pair them
    chunk_fn: Any = None      # compiled (carry, xs, consts) -> (carry, ys)
    #                           override; None -> scan_chunk_fn(body).  The
    #                           device-mesh path (repro.sharding.fed) installs
    #                           its shard_map-wrapped chunk here so run_scan
    #                           itself never branches on sharding.
    xs_put: Any = None        # staged-xs host->device transfer override; None
    #                           -> plain jax.device_put.  The mesh path uses a
    #                           per-leaf NamedSharding put (each device
    #                           receives only its shard slice — the global
    #                           stacked tensor never lands on one device).


def run_scan(plan: ScanPlan, record) -> PyTree:
    """Execute a whole run as chunked `lax.scan`s over its trained rounds.

    Chunks are cut at eval rounds (and at `chunk_rounds` to bound staged-
    batch memory), so between eval points the only host<->device traffic is
    the per-chunk staged-input `device_put`.  `record(t, carry, losses, t_l)`
    fires at every eval round t with the carry after round t, the last
    trained round's on-device loss row (None if nothing trained yet), and
    that round's global index t_l.  Returns the final carry.

    Compile cost: each DISTINCT chunk length compiles its own scan program
    (jit's shape-keyed cache).  With full participation the segmentation
    yields at most ~3 lengths (1, the eval_every/chunk_rounds period, and a
    remainder); participation churn can produce more (trained-round counts
    vary per segment, bounded by chunk_rounds).  The cache is per-process and
    keyed on the cached scan body, so repeated runs of the same shapes — the
    sweep/benchmark pattern — compile nothing after the first.  Padding
    chunks to one fixed length would cap this at a single compile but would
    require staging dummy batches for pad rounds, breaking the invariant
    that skipped rounds consume no data draws — we take the extra compiles.
    """
    assert plan.chunk_rounds >= 1
    chunk = plan.chunk_fn if plan.chunk_fn is not None else scan_chunk_fn(plan.body)
    return _run_chunks(chunk, plan.carry, plan.stage, plan,
                       record, last_slice=lambda leaf: leaf[-1])


def run_scan_sweep(plans: list[ScanPlan], record, *, mesh=None) -> PyTree:
    """Run several same-config, different-seed `ScanPlan`s as ONE vmapped
    scan over a leading seed axis.  All plans must share body/consts/trained
    schedule (same config, full participation); per-seed divergence lives in
    the stacked carries and staged inputs (visit orders, PRNG subkeys, data
    draws).  `record(t, carry, losses, t_l)` sees seed-stacked carry/losses.
    Returns the final stacked carry.

    `mesh` shards the leading seed axis across every device of the given
    mesh (pure GSPMD — the vmapped scan is compiled unchanged, only the
    input layouts change, so per-lane trajectories stay bit-exact).  The
    seed count must divide `mesh.size`; a non-divisible sweep logs a
    warning and runs unsharded rather than silently padding lanes.
    """
    p0 = plans[0]
    assert p0.obs is None, "telemetry is unsupported in vmapped sweeps"
    assert all(p.body is p0.body for p in plans), "sweep plans must share a body"
    assert all(np.array_equal(np.asarray(p.trained), np.asarray(p0.trained)) for p in plans), \
        "sweep plans must share the trained-round schedule (full participation)"
    assert p0.chunk_fn is None, \
        "mesh-sharded plans (sharding.fed.shard_plan) cannot be swept — the " \
        "client axes are already mapped to devices; shard the seed axis " \
        "instead via run_scan_sweep(mesh=...)"
    carry = jax.tree.map(lambda *ls: jnp.stack(ls), *[p.carry for p in plans])

    def stage(idxs):
        return jax.tree.map(lambda *ls: np.stack(ls), *[p.stage(idxs) for p in plans])

    if mesh is not None and len(plans) % mesh.size != 0:
        _log.warning(
            "sweep of %d seeds does not divide mesh of %d devices — "
            "running unsharded", len(plans), mesh.size,
        )
        mesh = None
    if mesh is not None:
        # GSPMD: lay the seed axis over all mesh devices; the compiler
        # partitions the vmapped scan lane-by-lane (per-lane bit-exact)
        seed_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(tuple(mesh.axis_names))
        )
        carry = jax.device_put(carry, seed_sh)
        p0 = dataclasses.replace(
            p0,
            consts=jax.device_put(
                p0.consts, jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())),
            xs_put=lambda xs: jax.device_put(xs, seed_sh),
        )

    return _run_chunks(sweep_chunk_fn(p0.body), carry, stage, p0,
                       record, last_slice=lambda leaf: leaf[:, -1])


def _run_chunks(chunk, carry, stage, plan: ScanPlan, record, *, last_slice) -> PyTree:
    """The shared chunked-execution loop behind `run_scan`/`run_scan_sweep`:
    segment the trained rounds at eval boundaries (capped at `chunk_rounds`),
    stage + `device_put` + execute each chunk, track the last trained round's
    on-device loss row (`last_slice` absorbs the sweep's leading seed axis),
    and fire `record` at every eval round."""
    obs = plan.obs
    tapped = obs is not None and obs.taps
    xs_put = plan.xs_put if plan.xs_put is not None else jax.device_put
    trained_idx = np.flatnonzero(np.asarray(plan.trained))
    last_losses, last_t = None, None
    pos = 0
    for t_e in eval_rounds(plan.rounds, plan.eval_every):
        n_t = int(np.searchsorted(trained_idx, t_e, side="right"))
        while pos < n_t:
            take = min(plan.chunk_rounds, n_t - pos)
            idxs = trained_idx[pos : pos + take]
            with maybe_span(obs, "stage"):
                xs = xs_put(stage(idxs))
            with maybe_span(obs, "scan_chunk"):
                carry, ys = chunk(carry, xs, plan.consts)
                if tapped:
                    # hand the stacked tele to the recorder; by default it
                    # defers the host transfer (keeping this loop's async
                    # pipelining), while obs.sync_chunks blocks here so the
                    # span covers the chunk's real execution time
                    losses, tele = ys
                    obs.record_stacked(idxs.tolist(), tele)
                else:
                    losses = ys
            last_losses = jax.tree.map(last_slice, losses)
            last_t = int(idxs[-1])
            pos += take
        record(t_e, carry, last_losses, last_t)
    return carry
