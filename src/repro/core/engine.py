"""Unified jitted cluster-round engine shared by all four FL algorithms.

Layering
--------
The simulation stack has three layers:

  driver   (fed_chs.py, baselines/*.py)
      Owns the *protocol*: which cluster trains when, scheduler hops,
      ledger entries, evaluation cadence.  Pure host-side Python, one
      engine call per round, no per-interaction device syncs.

  engine   (this module)
      Owns the *round*: the E-local-steps x K/E-interactions inner loop —
      local SGD, delta computation, channel compression, gamma-weighted
      aggregation — fused into a single jit-compiled `lax.scan` (with a
      `vmap` over clusters for 3-tier HFL).  Batches for the whole round
      are staged up front (`FLTask.sample_round_batches`), so the only
      host<->device traffic per round is one params handle and one stacked
      loss array.

  channel  (repro/comm/channels.py)
      Owns the *message*: the in-graph lossy transform (dense / QSGD /
      Top-K) and its `message_bits` accounting.  Compiled into the scan
      body, so adding a channel never touches a driver or the engine.

A fourth, passive layer rides on the drivers' ledger entries:
`repro.netsim` replays the recorded per-message `CommEvent` stream through
link/compute models to price a run in wall-clock seconds — the paper's
§3.2 overhead model counts only bits, which is exactly what the event
metadata extends without changing (aggregate accounting is bit-identical).
`end_round` below is the uniform per-round bookkeeping hook every driver
calls once per round.

Round modes
-----------
* `grad_round`  — Eq. (5) literal: every in-cluster iteration uploads a
  gradient and the ES applies the gamma-weighted step (E=1, dense).
* `cluster_round` — delta mode: clients run E local steps, upload
  channel-compressed model deltas, ES aggregates; scan over K/E
  interactions.
* `multi_cluster_round` — the Hier-Local-QSGD round: the delta-mode
  interaction vmapped over all M clusters at once (ragged cluster sizes
  handled by padding + masking: padded client slots carry zero gamma
  weight and their deltas are masked to zero before compression), plus the
  ES->PS compress/aggregate/broadcast step, all inside one jit.

Determinism
-----------
`split_chain(key, n)` reproduces n sequential `key, sub = split(key)`
draws as one fused scan, bit-identical to the eager chains the pre-engine
drivers used — so fixed-seed trajectories are preserved across the
refactor (see tests/test_engine_parity.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.comm.channels import Channel, DenseChannel
from repro.core.ledger import CommLedger
from repro.models.classifier import Classifier
from repro.utils import tree_add, tree_sub

PyTree = Any


def _jit_round(fn):
    """jit with donated params where the backend supports buffer donation
    (CPU does not; donating there only emits warnings)."""
    if jax.default_backend() in ("tpu", "gpu"):
        return jax.jit(fn, donate_argnums=(0,))
    return jax.jit(fn)


# --------------------------------------------------------------------------
# PRNG plumbing
# --------------------------------------------------------------------------


@functools.cache
def _split_chain_fn(n: int):
    def chain(key):
        def step(k, _):
            k2, sub = jax.random.split(k)
            return k2, sub

        return jax.lax.scan(step, key, None, length=n)

    return jax.jit(chain)


def split_chain(key: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """n sequential `key, sub = jax.random.split(key)` draws fused into one
    jitted scan. Returns (advanced key, subs (n, 2))."""
    if n == 0:
        return key, jnp.zeros((0, 2), jnp.uint32)
    return _split_chain_fn(n)(key)


def dummy_subs(*lead: int) -> jnp.ndarray:
    """Placeholder key array for non-stochastic channels (never consumed)."""
    return jnp.zeros(tuple(lead) + (2,), jnp.uint32)


# --------------------------------------------------------------------------
# compiled round functions, cached per (model, channel) — shapes are handled
# by jit's own shape-keyed cache
# --------------------------------------------------------------------------


def compress_uplinks(channel: Channel, deltas: PyTree, sub: jax.Array) -> PyTree:
    """Compress a stacked uplink (leading sender axis on every leaf).

    `per_message` channels (e.g. Top-K, whose selection couples entries) are
    vmapped over the sender axis with per-sender keys; others transform the
    stacked leaves directly (QSGD's historical stacked-leaf semantics)."""
    if getattr(channel, "per_message", False):
        n = jax.tree.leaves(deltas)[0].shape[0]
        keys = jax.random.split(sub, n)
        return jax.vmap(lambda d, k: channel.compress(d, k))(deltas, keys)
    return channel.compress(deltas, sub)


def _local_sgd(model: Classifier):
    """E local SGD steps for one client: xs (E, B, ...), ys (E, B), lrs (E,)."""
    grad_fn = jax.value_and_grad(model.loss)

    def run_one(params, xs, ys, lrs):
        def step(p, inp):
            x, y, lr = inp
            loss, g = grad_fn(p, x, y)
            return jax.tree.map(lambda w, gi: w - lr * gi, p, g), loss

        params, losses = jax.lax.scan(step, params, (xs, ys, lrs))
        return params, jnp.mean(losses)

    return run_one


@functools.cache
def _grad_round_fn(model: Classifier):
    """Eq. (5) literal: scan over K steps of
    w <- w - eta_k * sum_n gamma_n grad_n(w, xi_{n,k}).
    xs: (K, n, B, ...), ys: (K, n, B), gammas: (n,), lrs: (K,).
    Returns (params, per-step gamma-weighted losses (K,))."""
    grad_fn = jax.vmap(jax.value_and_grad(model.loss), in_axes=(None, 0, 0))

    def round_fn(params, xs, ys, gammas, lrs):
        def step(p, inp):
            x_k, y_k, lr_k = inp
            losses, grads = grad_fn(p, x_k, y_k)
            agg = jax.tree.map(lambda g: jnp.einsum("n,n...->...", gammas, g), grads)
            p = jax.tree.map(lambda w, g: w - lr_k * g, p, agg)
            return p, jnp.dot(gammas, losses)

        return jax.lax.scan(step, params, (xs, ys, lrs))

    return _jit_round(round_fn)


@functools.cache
def _delta_round_fn(model: Classifier, channel: Channel):
    """Delta mode: scan over J = K/E interactions; each interaction runs E
    local steps per client (vmapped), pushes channel-compressed deltas, and
    applies the gamma-weighted aggregate.
    xs: (J, n, E, B, ...), ys: (J, n, E, B), lrs: (J, E), subs: (J, 2).
    Returns (params, per-interaction mean losses (J,))."""
    multi_local = jax.vmap(_local_sgd(model), in_axes=(None, 0, 0, None))

    def round_fn(params, xs, ys, gammas, lrs, subs):
        def interaction(p, inp):
            x, y, lr, sub = inp
            new_p, losses = multi_local(p, x, y, lr)
            deltas = jax.tree.map(lambda a, b: a - b[None], new_p, p)
            deltas = compress_uplinks(channel, deltas, sub)
            agg = jax.tree.map(lambda dl: jnp.einsum("n,n...->...", gammas, dl), deltas)
            return tree_add(p, agg), jnp.mean(losses)

        return jax.lax.scan(interaction, params, (xs, ys, lrs, subs))

    return _jit_round(round_fn)


@functools.cache
def _multi_round_fn(model: Classifier, channel: Channel, es_channel: Channel):
    """One 3-tier HFL global round, vmapped over all M clusters at once.
    xs: (J, M, n_max, E, B, ...), ys: (J, M, n_max, E, B), gammas/mask:
    (M, n_max), es_weights: (M,), lrs: (J, E), subs: (J, M, 2),
    es_subs: (M, 2).  Padded client slots (mask == 0) carry zero gamma
    weight and their deltas are zeroed before compression.
    Returns (params, per-(interaction, cluster) losses (J, M))."""
    multi_local = jax.vmap(_local_sgd(model), in_axes=(None, 0, 0, None))

    def round_fn(params, xs, ys, gammas, mask, es_weights, lrs, subs, es_subs):
        M = xs.shape[1]
        cparams0 = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf[None], (M,) + leaf.shape), params
        )

        def interaction(cp, inp):
            x, y, lr, sub = inp

            def one_cluster(p_m, x_m, y_m, g_m, msk_m, sub_m):
                new_p, losses = multi_local(p_m, x_m, y_m, lr)
                deltas = jax.tree.map(
                    lambda a, b: (a - b[None]) * msk_m.reshape((-1,) + (1,) * (a.ndim - 1)),
                    new_p,
                    p_m,
                )
                deltas = compress_uplinks(channel, deltas, sub_m)
                agg = jax.tree.map(lambda dl: jnp.einsum("n,n...->...", g_m, dl), deltas)
                loss = jnp.sum(losses * msk_m) / jnp.sum(msk_m)
                return tree_add(p_m, agg), loss

            cp, losses = jax.vmap(one_cluster)(cp, x, y, gammas, mask, sub)
            return cp, losses

        cparams, losses = jax.lax.scan(interaction, cparams0, (xs, ys, lrs, subs))

        # ES -> PS: compressed cluster deltas, PS weighted-aggregates + broadcasts
        es_deltas = jax.vmap(
            lambda p_m, sub_m: es_channel.compress(tree_sub(p_m, params), sub_m)
        )(cparams, es_subs)
        agg = jax.tree.map(lambda x_: jnp.einsum("m,m...->...", es_weights, x_), es_deltas)
        return tree_add(params, agg), losses

    return _jit_round(round_fn)


# --------------------------------------------------------------------------
# public facade
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundEngine:
    """Per-run facade over the cached compiled round functions.

    `channel` compresses client -> ES uplinks; `es_channel` (3-tier HFL
    only) compresses ES -> PS uplinks and defaults to `channel`.
    """

    model: Classifier
    channel: Channel = DenseChannel()
    es_channel: Channel | None = None

    def grad_round(self, params, xs, ys, gammas, lrs):
        return _grad_round_fn(self.model)(params, xs, ys, gammas, lrs)

    def cluster_round(self, params, xs, ys, gammas, lrs, subs=None):
        if subs is None:
            subs = dummy_subs(xs.shape[0])
        return _delta_round_fn(self.model, self.channel)(params, xs, ys, gammas, lrs, subs)

    def multi_cluster_round(
        self, params, xs, ys, gammas, mask, es_weights, lrs, subs=None, es_subs=None
    ):
        if subs is None:
            subs = dummy_subs(xs.shape[0], xs.shape[1])
        if es_subs is None:
            es_subs = dummy_subs(xs.shape[1])
        fn = _multi_round_fn(self.model, self.channel, self.es_channel or self.channel)
        return fn(params, xs, ys, gammas, mask, es_weights, lrs, subs, es_subs)

    def end_round(self, ledger: CommLedger, round_idx: int) -> None:
        """Uniform end-of-round bookkeeping: snapshot the ledger.

        Every driver calls this exactly once per round (instead of each
        driver deciding its own snapshot cadence), so `bits_until` always
        sees a complete per-round history regardless of algorithm.
        """
        ledger.snapshot(round_idx)
