"""Time-varying ES topologies — the paper's Appendix-D deployment scenarios.

Fed-CHS's selling point (§1) is being "general to network topology,
especially when the topology is highly dynamic or not in a star shape".
The two motivating systems, made concrete:

  * LEO constellation (`leo_constellation`): M satellites on a circular
    orbit; at any round only satellites within an angular window of each
    other have an inter-satellite link, and the whole ring ROTATES by one
    slot every `period` rounds (a satellite "sets" and its neighbor set
    shifts). The visibility graph is a rotating banded ring.
  * IoV roadside units (`iov_gilbert`): RSUs along a road with line links
    whose availability flaps round-to-round (Gilbert-style on/off fading,
    seeded per round — deterministic and replayable). Links may drop, but
    each round's graph is repaired to stay connected (a disconnected RSU
    would simply buffer, which the round-based protocol models by skipping).

Both return plain `Topology` objects per round, so the 2-step scheduler
needs nothing but `set_topology` between rounds — the rule itself is
topology-free, exactly the paper's claim.

For the network-time simulator (`repro.netsim`), connectivity alone is too
coarse: an IoV link that faded this round but was re-added by the repair
step is *flaky*, not free — the RSU relays through vehicles at a fraction
of the base bandwidth.  `iov_gilbert` therefore exposes the pre-repair drop
set as a `dropped(t)` attribute on the returned callable; `NetworkModel`
maps "dropped or invisible this round" to degraded bandwidth rather than a
missing edge (the paper's §3.2 overhead model counts the bits either way —
only the *time* differs).
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.topology import Topology, _freeze

DynamicTopology = Callable[[int], Topology]  # round index -> graph


def leo_constellation(num_nodes: int, *, window: int = 2, period: int = 1) -> DynamicTopology:
    """Rotating banded ring: node m sees nodes within `window` slots, with the
    band offset advancing every `period` rounds (orbital drift)."""
    assert num_nodes >= 3 and 1 <= window < num_nodes // 2 + 1

    def at(t: int) -> Topology:
        off = (t // max(period, 1)) % num_nodes
        adj: list[set[int]] = [set() for _ in range(num_nodes)]
        for m in range(num_nodes):
            for d in range(1, window + 1):
                v = (m + d + off) % num_nodes
                if v != m:
                    adj[m].add(v)
                    adj[v].add(m)
        return _freeze(adj)

    return at


def iov_gilbert(num_nodes: int, *, p_drop: float = 0.3, seed: int = 0) -> DynamicTopology:
    """Line of RSUs; each link is independently down with prob `p_drop` this
    round (seeded by (seed, t): replayable). The graph is then repaired to
    connectivity by re-adding the leftmost dropped link of each break."""
    assert num_nodes >= 2

    # base graph: the line plus vehicle-relay skip links (m, m+2)
    base = [(m, m + 1) for m in range(num_nodes - 1)]
    base += [(m, m + 2) for m in range(num_nodes - 2)]

    def dropped_at(t: int) -> frozenset[tuple[int, int]]:
        """The links Gilbert fading took down this round, *before* repair —
        replayable standalone because the drop draws precede the repair
        draws in the shared per-round rng."""
        rng = np.random.default_rng((seed + 1) * 1_000_003 + t)
        return frozenset(e for e in base if rng.random() < p_drop)

    def at(t: int) -> Topology:
        rng = np.random.default_rng((seed + 1) * 1_000_003 + t)
        up = [e for e in base if rng.random() >= p_drop]
        dropped = [e for e in base if e not in set(up)]

        def build(edges):
            adj: list[set[int]] = [set() for _ in range(num_nodes)]
            for a, b in edges:
                adj[a].add(b)
                adj[b].add(a)
            return adj

        adj = build(up)
        # repair to connectivity: re-add dropped links (the RSU buffers until
        # a link returns; the protocol sees the repaired graph that round)
        while dropped:
            topo = Topology(num_nodes, tuple(tuple(sorted(s)) for s in adj))
            if all(adj[m] for m in range(num_nodes)) and topo.is_connected():
                break
            up.append(dropped.pop(int(rng.integers(len(dropped)))))
            adj = build(up)
        return _freeze(adj)

    at.dropped = dropped_at  # degraded-link metadata for repro.netsim
    return at


def make_dynamic(kind: str, num_nodes: int, *, seed: int = 0) -> DynamicTopology:
    if kind == "leo":
        return leo_constellation(num_nodes, window=2, period=1)
    if kind == "iov":
        return iov_gilbert(num_nodes, seed=seed)
    raise ValueError(f"unknown dynamic topology {kind!r}")
