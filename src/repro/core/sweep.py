"""Vmapped multi-seed sweeps — N whole runs as ONE device dispatch stream.

The averaging regime the FL literature reports over (EdgeFLow, HiFlash:
mean +/- std across seeds) costs N sequential runs in a looped simulator.
With the whole-run scan executor the only per-seed state is the scan carry
and the staged inputs (visit orders, PRNG subkeys, data draws), so a sweep
vmaps the chunked scan over a leading seed axis (`engine.run_scan_sweep`):
one compile, one dispatch per chunk, N trajectories.

Plans are built exactly like the single-run scanned drivers', with per-seed
shallow-copied `DataSource`s so every seed draws its own batch stream from
shared dataset arrays.  Fidelity vs a standalone `run_*` call at the same
seed: Fed-CHS grad mode (the paper's E=1 dense setting) is bit-identical;
delta-mode sweeps consume identical data/subkeys but vmap's batched layout
reassociates the small bias-vector reductions by ~1 ulp per round (weights
stay bit-exact per round; stochastic quantization can amplify the ulp into
an occasional level flip), so those trajectories are numerically — not
bit- — identical to solo runs.  Both regimes are pinned by
tests/test_run_scan.py.

Scope: full-participation configs (the table-1 regime).  Samplers change
which rounds train per seed, which would give the seeds different scan
lengths — run those seeds sequentially instead.
"""
from __future__ import annotations

import copy
import dataclasses

import jax

from repro.core.baselines.fedavg import FedAvgConfig, _fedavg_scan_plan
from repro.core.baselines.hier_local_qsgd import HierLocalQSGDConfig, _hier_scan_plan
from repro.core.baselines.wrwgd import WRWGDConfig, _wrwgd_scan_plan
from repro.core.engine import run_scan_sweep
from repro.core.fed_chs import FedCHSConfig, _fed_chs_scan_plan, _fed_chs_scannable
from repro.core.ledger import CommLedger
from repro.core.simulation import FLTask, RunRecorder, RunResult
from repro.part import is_full_participation

_PLANNERS = {
    FedCHSConfig: ("fed_chs", _fed_chs_scan_plan),
    FedAvgConfig: ("fedavg", _fedavg_scan_plan),
    WRWGDConfig: ("wrwgd", _wrwgd_scan_plan),
    HierLocalQSGDConfig: ("hier_local_qsgd", _hier_scan_plan),
}


def run_sweep(task: FLTask, config, seeds, *, mesh=None) -> list[RunResult]:
    """Run `config` at every seed in `seeds` as one vmapped scanned dispatch.

    `config` is any of the four driver configs; returns one `RunResult` per
    seed, in order, running the same settings N separate `run_*(task,
    dataclasses.replace(config, seed=s))` calls would — bit-identically in
    Fed-CHS grad mode and WRWGD, within ~1 ulp/round for delta modes (see
    the module docstring for the exact fidelity contract).

    `mesh` device-shards the leading seed axis (GSPMD, per-lane bit-exact —
    see `engine.run_scan_sweep`); it is exclusive with `config.mesh`, which
    shards *within* a single run's client axes.
    """
    name, planner = _PLANNERS[type(config)]
    assert getattr(config, "mesh", None) is None, \
        "run_sweep shards the seed axis — a config.mesh (client-axis " \
        "sharding) cannot be combined with a vmapped sweep; pass " \
        "run_sweep(mesh=...) instead"
    assert config.scan_rounds, \
        "run_sweep is inherently scanned — a scan_rounds=False config asks " \
        "for looped-exact trajectories, which a vmapped sweep cannot " \
        "guarantee; run those seeds sequentially through the driver instead"
    assert is_full_participation(config.sampler), \
        "run_sweep vmaps over seeds with a shared trained-round schedule — " \
        "sampler-driven runs must go through the per-seed drivers"
    assert config.obs is None, \
        "telemetry is per-run host state — a vmapped sweep has no per-seed " \
        "chunk boundaries to materialize taps at; profile a single run instead"
    if isinstance(config, FedCHSConfig):
        assert _fed_chs_scannable(task, config), \
            "this Fed-CHS config cannot take the scanned path"

    seeds = list(seeds)
    plans, params_ofs, traffics = [], [], []
    for s in seeds:
        cfg = dataclasses.replace(config, seed=s)
        # per-seed batch streams over shared dataset arrays: shallow-copy the
        # source, then reset(seed) rebinds only its per-client rng state
        source = copy.copy(task.source)
        out = planner(task, source, cfg)
        plans.append(out[0])
        params_ofs.append(out[1])
        traffics.append(out[2])

    params_of = params_ofs[0]
    recorders = [RunRecorder(task, config.rounds, config.eval_every) for _ in seeds]

    def record(t, carry, losses, _last_t):
        stacked = params_of(carry)
        for i in range(len(seeds)):
            p_i = jax.tree.map(lambda leaf: leaf[i], stacked)
            l_i = None if losses is None else losses[i]
            recorders[i].record(t, p_i, l_i)

    carry = run_scan_sweep(plans, record, mesh=mesh)
    stacked = params_of(carry)
    results = []
    for i in range(len(seeds)):
        ledger = CommLedger(track_events=config.track_events)
        ledger.materialize(traffics[i](config.track_events))
        params_i = jax.tree.map(lambda leaf: leaf[i], stacked)
        results.append(recorders[i].result(name, ledger, params_i))
    return results
