"""FedAvg (McMahan et al., 2017) baseline, with optional compressed uplinks
(the paper's Fig. 2 "FedAvg compressed by QSGD" arm), driven by the shared
round engine.

Per round: every client runs K local optimizer steps from the PS model,
uploads the channel-compressed model delta to the PS (multi-hop in a real
deployment; the ledger records the client<->PS hop type so Fig. 2's
structural comparison is visible), and the PS takes the D_n/D_A-weighted
average.  A FedAvg round is one engine interaction with E=K: the whole round
is a single fused jit call.  Client-held `LocalOpt` state persists across
rounds without ever traversing the channel.

Participation (repro.part): `FedAvgConfig.sampler` picks the reporting
subset each round — dropped clients send nothing (zero uplink bits), keep
their opt state frozen, and the D_n weights renormalize over the reporters.
A round with zero reporters is skipped outright.  The default
`FullParticipation`/None path is bit-identical to the pre-participation
stack.

Whole-run execution: with `scan_rounds=True` (the default) the run executes
through `engine.run_scan` — per-round masks/gammas and PRNG subkeys are
precomputed, batches staged `chunk_rounds` rounds at a time, and every chunk
is one `lax.scan` over rounds; zero-reporter rounds are skipped by the scan
itself and the ledger is reconstructed after the run
(`CommLedger.materialize`).  Bit-identical to the looped path at fixed seed
(tests/test_engine_parity.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

import numpy as np

from repro.comm.channels import Channel, DenseChannel, channel_wire_bits
from repro.core.engine import (
    RoundEngine,
    ScanPlan,
    run_scan,
    scan_delta_body,
    split_chain,
)
from repro.core.ledger import CommLedger
from repro.core.precision import (
    Precision,
    downlink_bits_per_param,
    resolve_channel,
)
from repro.core.simulation import FLTask, RunRecorder, RunResult
from repro.data.sources import scatter_put, stage_chunk
from repro.obs.trace import maybe_span
from repro.optim.local import LocalOpt
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import (
    Sampler,
    is_full_participation,
    participation_mask,
    schedule_participants,
    stack_masks,
)
from repro.sharding.fed import resolve_mesh, shard_plan


@dataclasses.dataclass
class FedAvgConfig:
    rounds: int = 200
    local_steps: int = 20          # paper B.1: "training epochs in clients ... K=20"
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None
    channel: Channel | None = None  # explicit uplink channel
    local_opt: LocalOpt | None = None  # client-held optimizer (None = plain SGD)
    client_microbatch: int | None = None  # at most this many client replicas
                                          # train at once (None = full vmap)
    precision: Precision | None = None    # mixed-precision policy: bf16
                                          # client compute, f32 PS master,
                                          # wire-dtype dense messages
    sampler: Sampler | None = None     # per-round participation (repro.part);
                                       # None / FullParticipation = seed-parity path
    track_events: bool = True          # False: bits only, no CommEvent stream
    scan_rounds: bool = True           # whole-run lax.scan executor
    chunk_rounds: int = 32             # scanned mode: rounds staged per chunk
    seed: int = 0
    schedule: Schedule | None = None
    obs: Any = None                    # repro.obs.RunTelemetry; None = the
                                       # byte-for-byte untapped fast path
    mesh: Any = None                   # jax Mesh ("clusters", "clients"):
                                       # shard the scanned client axis
                                       # (repro.sharding.fed, bit-identical);
                                       # None adopts an ambient federation
                                       # mesh or stays single-device


def run_fedavg(task: FLTask, config: FedAvgConfig) -> RunResult:
    if config.scan_rounds:
        return _run_fedavg_scanned(task, config)
    task.reset_loaders(config.seed)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = jnp.asarray([[sched_fn(k) for k in range(K)]], dtype=jnp.float32)  # (1, K)

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = resolve_channel(config.precision, config.channel,
                              config.qsgd_levels, config.bits_per_param)
    engine = RoundEngine(task.model, channel, local_opt=config.local_opt,
                         client_microbatch=config.client_microbatch,
                         precision=config.precision)
    gammas = jnp.asarray(task.global_weights())
    key = jax.random.PRNGKey(config.seed + 1)

    down_bits = DenseChannel(
        downlink_bits_per_param(config.precision, config.bits_per_param)
    ).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())

    obs = config.obs
    taps = obs is not None and obs.taps
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)
    n = task.num_clients
    full_part = is_full_participation(config.sampler)
    all_clients = list(range(n))
    opt_state = engine.init_opt_state(params, n)  # client-held, cross-round
    losses = jnp.full((1,), jnp.nan)  # stays nan until a first trained round
    for t in range(config.rounds):
        participating = (
            all_clients if full_part else config.sampler.participants(t, all_clients)
        )
        if participating:
            # all clients stage K batches (full width even under churn, so the
            # data schedule is participation-independent); one E=K interaction
            per_client = [task.sample_client_batches(i, K) for i in range(n)]
            batch = jax.tree.map(lambda *leaves: jnp.stack(leaves)[None], *per_client)
            subs = None
            if channel.stochastic:
                key, subs = split_chain(key, 1)
            if full_part:
                with maybe_span(obs, "round"):
                    out = engine.cluster_round(
                        params, batch, gammas, lrs, subs, opt_state, taps=taps
                    )
                    params, opt_state, losses, tele = out if taps else (*out, None)
            else:
                # masked round: D_n weights renormalized over the participants,
                # dropped clients contribute zero delta + frozen opt state
                pmask = participation_mask(all_clients, participating)
                w = task.global_weights() * pmask
                gammas_r = jnp.asarray((w / w.sum()).astype(np.float32))
                with maybe_span(obs, "round"):
                    out = engine.cluster_round(
                        params, batch, gammas_r, lrs, subs, opt_state, mask=pmask,
                        taps=taps,
                    )
                    params, opt_state, losses, tele = out if taps else (*out, None)
            if tele is not None:
                obs.record_round(t, tele)

            if ledger.track_events:
                for i in participating:
                    ledger.record("ps_to_client", down_bits, round=t, phase=0,
                                  sender="ps", receiver=f"client:{i}")
                    ledger.record("client_to_ps", up_bits, round=t, phase=0,
                                  sender=f"client:{i}", receiver="ps")
            else:
                ledger.record("ps_to_client", down_bits, len(participating))
                ledger.record("client_to_ps", up_bits, len(participating))
        # else: nobody reported — the PS round is skipped outright (zero
        # traffic, params unchanged)
        engine.end_round(ledger, t)
        recorder.record(t, params, losses)

    return recorder.result("fedavg", ledger, params)


# --------------------------------------------------------------------------
# scanned whole-run path
# --------------------------------------------------------------------------


def _fedavg_scan_plan(task: FLTask, source, config: FedAvgConfig):
    """Whole-run `ScanPlan` + deferred glue (see `fed_chs._fed_chs_scan_plan`)."""
    source.reset(config.seed)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([[sched_fn(k) for k in range(K)]], dtype=np.float32)  # (1, K)

    params = task.init_params()
    d = task.num_params()
    channel = resolve_channel(config.precision, config.channel,
                              config.qsgd_levels, config.bits_per_param)
    engine = RoundEngine(task.model, channel, local_opt=config.local_opt,
                         client_microbatch=config.client_microbatch,
                         precision=config.precision)

    R = config.rounds
    n = task.num_clients
    full_part = is_full_participation(config.sampler)
    all_clients = list(range(n))
    parts = schedule_participants(config.sampler, R, all_clients)
    trained = np.array([len(p) > 0 for p in parts])

    mask_r = stack_masks(all_clients, parts)
    gammas_r = np.zeros((R, n), np.float32)
    gw = task.global_weights()
    for t in np.flatnonzero(trained):
        if full_part:
            gammas_r[t] = gw
        else:
            w = gw * mask_r[t]
            gammas_r[t] = (w / w.sum()).astype(np.float32)

    subs_r = np.zeros((R, 1, 2), np.uint32)
    if channel.stochastic:
        n_tr = int(trained.sum())
        if n_tr:
            _, flat = split_chain(jax.random.PRNGKey(config.seed + 1), n_tr)
            subs_r[trained] = np.asarray(flat).reshape(n_tr, 1, 2)

    def stage(idxs):
        C = len(idxs)
        cs = list(range(C))  # every trained round stages every client
        batch = stage_chunk(
            source,
            [(i, K * C,
              scatter_put((cs, 0, i), lambda dl: dl.reshape(C, K, *dl.shape[1:])))
             for i in range(n)],
            lambda a: (C, 1, n, K) + a.shape[1:],
        )
        return {
            "batch": batch,
            "gammas": gammas_r[idxs],
            "mask": mask_r[idxs],
            "subs": subs_r[idxs],
        }

    taps = config.obs is not None and config.obs.taps
    body = scan_delta_body(engine.model, channel, engine.local_opt, taps,
                           config.client_microbatch, config.precision)
    plan = ScanPlan(
        body=body,
        carry=(params, engine.init_opt_state(params, n)),
        consts={"lrs": jnp.asarray(lrs)},
        stage=stage,
        trained=trained,
        rounds=R,
        eval_every=config.eval_every,
        chunk_rounds=config.chunk_rounds,
        obs=config.obs,
    )

    mesh = resolve_mesh(config.mesh)
    if mesh is not None:
        assert config.client_microbatch is None, \
            "client_microbatch and a federation mesh are mutually exclusive"
        plan = shard_plan(plan, mesh, "delta", model=engine.model,
                          channel=channel, opt=engine.local_opt, clients=n)

    down_bits = DenseChannel(
        downlink_bits_per_param(config.precision, config.bits_per_param)
    ).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())

    def traffic(track_events: bool):
        for t in range(R):
            entries = []
            p = parts[t]
            if p:
                if track_events:
                    for i in p:
                        entries.append(("ps_to_client", down_bits, 1, 0,
                                        "ps", f"client:{i}"))
                        entries.append(("client_to_ps", up_bits, 1, 0,
                                        f"client:{i}", "ps"))
                else:
                    entries.append(("ps_to_client", down_bits, len(p), 0, None, None))
                    entries.append(("client_to_ps", up_bits, len(p), 0, None, None))
            yield t, entries

    return plan, (lambda c: c[0]), traffic


def _run_fedavg_scanned(task: FLTask, config: FedAvgConfig) -> RunResult:
    obs = config.obs
    with maybe_span(obs, "precompute"):
        plan, params_of, traffic = _fedavg_scan_plan(task, task.source, config)
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)
    carry = run_scan(
        plan, lambda t, c, losses, _lt: recorder.record(t, params_of(c), losses)
    )
    ledger = CommLedger(track_events=config.track_events)
    with maybe_span(obs, "materialize"):
        ledger.materialize(traffic(config.track_events))
    return recorder.result("fedavg", ledger, params_of(carry))
