"""FedAvg (McMahan et al., 2017) baseline, with optional compressed uplinks
(the paper's Fig. 2 "FedAvg compressed by QSGD" arm), driven by the shared
round engine.

Per round: every client runs K local optimizer steps from the PS model,
uploads the channel-compressed model delta to the PS (multi-hop in a real
deployment; the ledger records the client<->PS hop type so Fig. 2's
structural comparison is visible), and the PS takes the D_n/D_A-weighted
average.  A FedAvg round is one engine interaction with E=K: the whole round
is a single fused jit call.  Client-held `LocalOpt` state persists across
rounds without ever traversing the channel.

Participation (repro.part): `FedAvgConfig.sampler` picks the reporting
subset each round — dropped clients send nothing (zero uplink bits), keep
their opt state frozen, and the D_n weights renormalize over the reporters.
A round with zero reporters is skipped outright.  The default
`FullParticipation`/None path is bit-identical to the pre-participation
stack.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

import numpy as np

from repro.comm.channels import Channel, DenseChannel, make_channel
from repro.core.engine import RoundEngine, split_chain
from repro.core.ledger import CommLedger
from repro.core.simulation import FLTask, RunResult
from repro.optim.local import LocalOpt
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import Sampler, is_full_participation, participation_mask


@dataclasses.dataclass
class FedAvgConfig:
    rounds: int = 200
    local_steps: int = 20          # paper B.1: "training epochs in clients ... K=20"
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None
    channel: Channel | None = None  # explicit uplink channel
    local_opt: LocalOpt | None = None  # client-held optimizer (None = plain SGD)
    sampler: Sampler | None = None     # per-round participation (repro.part);
                                       # None / FullParticipation = seed-parity path
    track_events: bool = True          # False: bits only, no CommEvent stream
    seed: int = 0
    schedule: Schedule | None = None


def run_fedavg(task: FLTask, config: FedAvgConfig) -> RunResult:
    task.reset_loaders(config.seed)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = jnp.asarray([[sched_fn(k) for k in range(K)]], dtype=jnp.float32)  # (1, K)

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = (
        config.channel
        if config.channel is not None
        else make_channel(config.qsgd_levels, config.bits_per_param)
    )
    engine = RoundEngine(task.model, channel, local_opt=config.local_opt)
    gammas = jnp.asarray(task.global_weights())
    key = jax.random.PRNGKey(config.seed + 1)

    down_bits = DenseChannel(config.bits_per_param).message_bits(d)
    up_bits = channel.message_bits(d)

    rounds_log, acc_log, loss_log = [], [], []
    n = task.num_clients
    full_part = is_full_participation(config.sampler)
    all_clients = list(range(n))
    opt_state = engine.init_opt_state(params, n)  # client-held, cross-round
    losses = jnp.full((1,), jnp.nan)  # stays nan until a first trained round
    for t in range(config.rounds):
        participating = (
            all_clients if full_part else config.sampler.participants(t, all_clients)
        )
        if participating:
            # all clients stage K batches (full width even under churn, so the
            # data schedule is participation-independent); one E=K interaction
            per_client = [task.sample_client_batches(i, K) for i in range(n)]
            batch = jax.tree.map(lambda *leaves: jnp.stack(leaves)[None], *per_client)
            subs = None
            if channel.stochastic:
                key, subs = split_chain(key, 1)
            if full_part:
                params, opt_state, losses = engine.cluster_round(
                    params, batch, gammas, lrs, subs, opt_state
                )
            else:
                # masked round: D_n weights renormalized over the participants,
                # dropped clients contribute zero delta + frozen opt state
                pmask = participation_mask(all_clients, participating)
                w = task.global_weights() * pmask
                gammas_r = jnp.asarray((w / w.sum()).astype(np.float32))
                params, opt_state, losses = engine.cluster_round(
                    params, batch, gammas_r, lrs, subs, opt_state, mask=pmask
                )

            if ledger.track_events:
                for i in participating:
                    ledger.record("ps_to_client", down_bits, round=t, phase=0,
                                  sender="ps", receiver=f"client:{i}")
                    ledger.record("client_to_ps", up_bits, round=t, phase=0,
                                  sender=f"client:{i}", receiver="ps")
            else:
                ledger.record("ps_to_client", down_bits, len(participating))
                ledger.record("client_to_ps", up_bits, len(participating))
        # else: nobody reported — the PS round is skipped outright (zero
        # traffic, params unchanged)
        engine.end_round(ledger, t)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(task.evaluate(params))
            loss_log.append(float(jnp.mean(losses)))

    return RunResult("fedavg", rounds_log, acc_log, loss_log, ledger, params,
                     metric_mode=task.metric_mode)
