"""FedAvg (McMahan et al., 2017) baseline, with optional QSGD-compressed uplinks
(the paper's Fig. 2 "FedAvg compressed by QSGD" arm).

Per round: every client runs K local SGD steps from the PS model, uploads the
model delta to the PS (multi-hop in a real deployment; the ledger records the
client<->PS hop type so Fig. 2's structural comparison is visible), and the PS
takes the D_n/D_A-weighted average.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import CommLedger, dense_message_bits, qsgd_message_bits
from repro.core.simulation import FLTask, RunResult, _multi_client_local_sgd_fn, evaluate
from repro.kernels.ops import qsgd_compress_tree
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.utils import tree_add


@dataclasses.dataclass
class FedAvgConfig:
    rounds: int = 200
    local_steps: int = 20          # paper B.1: "training epochs in clients ... K=20"
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None
    seed: int = 0
    schedule: Schedule | None = None


def run_fedavg(task: FLTask, config: FedAvgConfig) -> RunResult:
    task.reset_loaders(config.seed)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = jnp.asarray([sched_fn(k) for k in range(K)], dtype=jnp.float32)

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger()
    multi_local = _multi_client_local_sgd_fn(task.model)
    gammas = jnp.asarray(task.global_weights())
    key = jax.random.PRNGKey(config.seed + 1)

    dense_bits = dense_message_bits(d, config.bits_per_param)
    up_bits = (
        qsgd_message_bits(d, config.qsgd_levels)
        if config.qsgd_levels is not None
        else dense_bits
    )

    rounds_log, acc_log, loss_log = [], [], []
    n = task.num_clients
    for t in range(config.rounds):
        # all clients sample K batches; stack to (n, K, B, ...)
        bx, by = zip(*(task.sample_client_batches(i, K) for i in range(n)))
        xs = jnp.stack(bx)
        ys = jnp.stack(by)
        new_p, losses = multi_local(params, xs, ys, lrs)
        deltas = jax.tree.map(lambda np_, op: np_ - op[None], new_p, params)
        if config.qsgd_levels is not None:
            key, sub = jax.random.split(key)
            deltas = qsgd_compress_tree(deltas, sub, s=config.qsgd_levels)
        agg = jax.tree.map(lambda dl: jnp.einsum("n,n...->...", gammas, dl), deltas)
        params = tree_add(params, agg)

        ledger.record("ps_to_client", dense_bits, n)
        ledger.record("client_to_ps", up_bits, n)
        ledger.snapshot(t)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(evaluate(task.model, params, task.dataset))
            loss_log.append(float(jnp.mean(losses)))

    return RunResult("fedavg", rounds_log, acc_log, loss_log, ledger, params)
