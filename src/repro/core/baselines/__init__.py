from repro.core.baselines.fedavg import FedAvgConfig, run_fedavg
from repro.core.baselines.wrwgd import WRWGDConfig, run_wrwgd
from repro.core.baselines.hier_local_qsgd import HierLocalQSGDConfig, run_hier_local_qsgd

__all__ = [
    "FedAvgConfig",
    "run_fedavg",
    "WRWGDConfig",
    "run_wrwgd",
    "HierLocalQSGDConfig",
    "run_hier_local_qsgd",
]
