"""Weighted Random-Walk Gradient Descent (Ayache & El Rouayheb, 2019) baseline.

The model walks over a *client-level* graph; each visited client runs K local
SGD steps (one engine grad-round with a single client), then forwards the
model to a neighbor chosen with probability proportional to a per-client
importance weight (the original uses local Lipschitz estimates; we use
dataset-size weighting, the standard "weighted" variant, with uniform as an
option). One client->client model hop per round, metered via the dense
channel.  The driver is model-agnostic: the batch is an opaque pytree staged
by the task's `DataSource`.

Participation (repro.part): `WRWGDConfig.sampler` gates both ends of the
walk — a visited client that is unavailable this round forwards the model
without training (pass-through), and the next hop is drawn from the
neighbors available *next* round (EdgeFLow-style: the walk skips dead
edges; if every neighbor is down the draw falls back to the full neighbor
set and the receiver passes through).  The default `FullParticipation`/None
path is bit-identical to the pre-participation stack.

Whole-run execution: the walk itself is host-side numpy rng — deterministic
given (seed, topology, sampler) — so with `scan_rounds=True` (default) the
entire visit sequence is precomputed and the training rounds run as chunked
`lax.scan`s over rounds (`engine.run_scan`); pass-through visits are skipped
by the scan and consume no data draws, exactly like the looped driver.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import DenseChannel, channel_wire_bits
from repro.core.engine import RoundEngine, ScanPlan, run_scan, scan_grad_body
from repro.core.ledger import CommLedger
from repro.core.simulation import FLTask, RunRecorder, RunResult
from repro.core.topology import make_topology
from repro.data.sources import scatter_put, stage_chunk
from repro.obs.trace import maybe_span
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import Sampler, is_full_participation
from repro.sharding.fed import resolve_mesh, shard_plan


@dataclasses.dataclass
class WRWGDConfig:
    rounds: int = 200
    local_steps: int = 20
    topology: str = "random_sparse"   # client-level graph, degree <= 3 (paper B.1)
    topology_seed: int = 0
    weighting: str = "data_size"      # or "uniform"
    sampler: Sampler | None = None    # per-round participation (repro.part);
                                      # None / FullParticipation = seed-parity path
    track_events: bool = True          # False: bits only, no CommEvent stream
    scan_rounds: bool = True           # whole-run lax.scan executor
    chunk_rounds: int = 32             # scanned mode: rounds staged per chunk
    eval_every: int = 10
    bits_per_param: int = 32
    client_microbatch: int | None = None  # accepted for config-surface parity
                                          # with the other drivers; a walk
                                          # visits ONE client per round, so
                                          # any value degrades to mb=1
    seed: int = 0
    schedule: Schedule | None = None  # walk round t -> eta_t, constant over the
                                      # K local steps of that visit; default
                                      # eta_t = 1/(K sqrt(t+1)) (B.1 decay
                                      # indexed by the GLOBAL round — see
                                      # run_wrwgd)
    obs: Any = None                    # repro.obs.RunTelemetry; None = the
                                       # byte-for-byte untapped fast path
    mesh: Any = None                   # jax Mesh ("clusters", "clients");
                                       # a 1-client walk degrades gracefully
                                       # to replicated compute — accepted so
                                       # all four drivers share the config
                                       # surface (repro.sharding.fed)


def _precompute_walk(task: FLTask, config: WRWGDConfig):
    """Replay the walk's host rng draw-for-draw: returns (visits (R,),
    trains (R,) bool, hops list of (sender, receiver)).  The looped driver
    issues exactly these `rng.integers`/`rng.choice` calls."""
    topo = make_topology(config.topology, task.num_clients, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    current = int(rng.integers(task.num_clients))
    full_part = is_full_participation(config.sampler)

    visits, trains, hops = [], [], []
    for t in range(config.rounds):
        visits.append(current)
        trains.append(
            full_part or bool(config.sampler.participants(t, [current]))
        )
        nbrs = list(topo.neighbors(current))
        if not full_part:
            live = config.sampler.participants(t + 1, nbrs)
            nbrs = live or nbrs
        if config.weighting == "data_size":
            w = task.client_sizes[nbrs]
            w = w / w.sum()
        else:
            w = np.full(len(nbrs), 1.0 / len(nbrs))
        nxt = int(rng.choice(nbrs, p=w))
        hops.append((current, nxt))
        current = nxt
    return np.asarray(visits), np.asarray(trains), hops


def _walk_round_lrs(config: WRWGDConfig) -> np.ndarray:
    """(R, K) step sizes: row t is eta_t repeated over the K local steps.

    The random walk revisits clients forever, so the decaying schedule must
    be indexed by the GLOBAL walk round t — restarting it at eta_0 on every
    visit (the old behaviour) keeps the step size permanently large and the
    single-client updates never anneal: the model rattles between client
    optima instead of converging (final_acc ~0.67 on the tier-1 task vs
    ~0.93 with per-round decay).  Within one visit the K local steps share
    eta_t, matching the per-iteration decay of Ayache & El Rouayheb's
    random-walk SGD where one walk step IS one SGD iteration."""
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    etas = np.asarray([sched_fn(t) for t in range(config.rounds)], np.float32)
    return np.repeat(etas[:, None], K, axis=1)


def run_wrwgd(task: FLTask, config: WRWGDConfig) -> RunResult:
    if config.scan_rounds:
        return _run_wrwgd_scanned(task, config)
    task.reset_loaders(config.seed)
    lrs_r = jnp.asarray(_walk_round_lrs(config))

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = DenseChannel(config.bits_per_param)
    engine = RoundEngine(task.model, channel,
                         client_microbatch=config.client_microbatch)
    hop_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())
    gamma_one = jnp.ones((1,), jnp.float32)

    # the walk is pure host rng, independent of the training state — both
    # paths consume the ONE precomputed replay (the walk rng and the data
    # loaders are separate streams, so hoisting the draws changes nothing)
    visits, trains_r, hops = _precompute_walk(task, config)
    obs = config.obs
    taps = obs is not None and obs.taps
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)
    losses = jnp.full((1,), jnp.nan)  # stays nan until a first trained round
    for t in range(config.rounds):
        if trains_r[t]:
            batch = jax.tree.map(
                lambda a: a[:, None],
                task.sample_client_batches(int(visits[t]), config.local_steps),
            )  # (K, 1, B, ...): a walk step is a 1-client cluster running Eq.(5)
            with maybe_span(obs, "round"):
                out = engine.grad_round(params, batch, gamma_one, lrs_r[t], taps=taps)
                params, losses, tele = out if taps else (*out, None)
            if tele is not None:
                obs.record_round(t, tele)
        # else: the visited client is down — pass-through, the model is
        # forwarded untouched (and the round consumes no data draws)
        prev, nxt = hops[t]
        ledger.record("client_to_client", hop_bits, round=t, phase=0,
                      sender=f"client:{prev}", receiver=f"client:{nxt}")
        engine.end_round(ledger, t)
        recorder.record(t, params, losses)

    return recorder.result("wrwgd", ledger, params)


# --------------------------------------------------------------------------
# scanned whole-run path
# --------------------------------------------------------------------------


def _wrwgd_scan_plan(task: FLTask, source, config: WRWGDConfig):
    """Whole-run `ScanPlan` + deferred glue (see `fed_chs._fed_chs_scan_plan`)."""
    source.reset(config.seed)
    K = config.local_steps
    lrs_r = _walk_round_lrs(config)

    params = task.init_params()
    d = task.num_params()
    channel = DenseChannel(config.bits_per_param)
    engine = RoundEngine(task.model, channel,
                         client_microbatch=config.client_microbatch)
    visits, trains, hops = _precompute_walk(task, config)
    R = config.rounds
    ones = np.ones((R, 1), np.float32)

    def stage(idxs):
        C = len(idxs)
        occ: dict[int, list[int]] = {}
        for c, t in enumerate(idxs):
            occ.setdefault(int(visits[t]), []).append(c)
        batch = stage_chunk(
            source,
            [(client, K * len(cs),
              scatter_put((cs, slice(None), 0),
                          lambda dl, n=len(cs): dl.reshape(n, K, *dl.shape[1:])))
             for client, cs in occ.items()],
            lambda a: (C, K, 1) + a.shape[1:],
        )
        return {"batch": batch, "gammas": ones[idxs], "lrs": lrs_r[idxs]}

    taps = config.obs is not None and config.obs.taps
    plan = ScanPlan(
        body=scan_grad_body(engine.model, taps, config.client_microbatch),
        carry=params,
        consts={},
        stage=stage,
        trained=trains,
        rounds=R,
        eval_every=config.eval_every,
        chunk_rounds=config.chunk_rounds,
        obs=config.obs,
    )

    mesh = resolve_mesh(config.mesh)
    if mesh is not None:
        plan = shard_plan(plan, mesh, "grad", model=engine.model, clients=1)

    hop_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())

    def traffic(track_events: bool):
        del track_events  # one metered hop per round either way
        for t, (prev, nxt) in enumerate(hops):
            yield t, [("client_to_client", hop_bits, 1, 0,
                       f"client:{prev}", f"client:{nxt}")]

    return plan, (lambda c: c), traffic


def _run_wrwgd_scanned(task: FLTask, config: WRWGDConfig) -> RunResult:
    obs = config.obs
    with maybe_span(obs, "precompute"):
        plan, params_of, traffic = _wrwgd_scan_plan(task, task.source, config)
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)
    carry = run_scan(
        plan, lambda t, c, losses, _lt: recorder.record(t, params_of(c), losses)
    )
    ledger = CommLedger(track_events=config.track_events)
    with maybe_span(obs, "materialize"):
        ledger.materialize(traffic(config.track_events))
    return recorder.result("wrwgd", ledger, params_of(carry))
