"""Weighted Random-Walk Gradient Descent (Ayache & El Rouayheb, 2019) baseline.

The model walks over a *client-level* graph; each visited client runs K local
SGD steps (one engine grad-round with a single client), then forwards the
model to a neighbor chosen with probability proportional to a per-client
importance weight (the original uses local Lipschitz estimates; we use
dataset-size weighting, the standard "weighted" variant, with uniform as an
option). One client->client model hop per round, metered via the dense
channel.  The driver is model-agnostic: the batch is an opaque pytree staged
by the task's `DataSource`.

Participation (repro.part): `WRWGDConfig.sampler` gates both ends of the
walk — a visited client that is unavailable this round forwards the model
without training (pass-through), and the next hop is drawn from the
neighbors available *next* round (EdgeFLow-style: the walk skips dead
edges; if every neighbor is down the draw falls back to the full neighbor
set and the receiver passes through).  The default `FullParticipation`/None
path is bit-identical to the pre-participation stack.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import DenseChannel
from repro.core.engine import RoundEngine
from repro.core.ledger import CommLedger
from repro.core.simulation import FLTask, RunResult
from repro.core.topology import make_topology
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import Sampler, is_full_participation


@dataclasses.dataclass
class WRWGDConfig:
    rounds: int = 200
    local_steps: int = 20
    topology: str = "random_sparse"   # client-level graph, degree <= 3 (paper B.1)
    topology_seed: int = 0
    weighting: str = "data_size"      # or "uniform"
    sampler: Sampler | None = None    # per-round participation (repro.part);
                                      # None / FullParticipation = seed-parity path
    track_events: bool = True          # False: bits only, no CommEvent stream
    eval_every: int = 10
    bits_per_param: int = 32
    seed: int = 0
    schedule: Schedule | None = None


def run_wrwgd(task: FLTask, config: WRWGDConfig) -> RunResult:
    task.reset_loaders(config.seed)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = jnp.asarray([sched_fn(k) for k in range(K)], dtype=jnp.float32)

    topo = make_topology(config.topology, task.num_clients, seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    current = int(rng.integers(task.num_clients))

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = DenseChannel(config.bits_per_param)
    engine = RoundEngine(task.model, channel)
    hop_bits = channel.message_bits(d)
    gamma_one = jnp.ones((1,), jnp.float32)

    full_part = is_full_participation(config.sampler)
    rounds_log, acc_log, loss_log = [], [], []
    losses = jnp.full((1,), jnp.nan)  # stays nan until a first trained round
    for t in range(config.rounds):
        trains = full_part or bool(config.sampler.participants(t, [current]))
        if trains:
            batch = jax.tree.map(
                lambda a: a[:, None], task.sample_client_batches(current, K)
            )  # (K, 1, B, ...): a walk step is a 1-client cluster running Eq.(5)
            params, losses = engine.grad_round(params, batch, gamma_one, lrs)
        # else: the visited client is down — pass-through, the model is
        # forwarded untouched (and the round consumes no data or rng draws
        # beyond the neighbor choice below)

        nbrs = list(topo.neighbors(current))
        if not full_part:
            # the walk skips edges that will be dead next round; when every
            # neighbor is down the model still has to move, so fall back to
            # the full set (the receiver then passes through)
            live = config.sampler.participants(t + 1, nbrs)
            nbrs = live or nbrs
        if config.weighting == "data_size":
            w = task.client_sizes[nbrs]
            w = w / w.sum()
        else:
            w = np.full(len(nbrs), 1.0 / len(nbrs))
        prev = current
        current = int(rng.choice(nbrs, p=w))
        ledger.record("client_to_client", hop_bits, round=t, phase=0,
                      sender=f"client:{prev}", receiver=f"client:{current}")
        engine.end_round(ledger, t)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(task.evaluate(params))
            loss_log.append(float(jnp.mean(losses)))

    return RunResult("wrwgd", rounds_log, acc_log, loss_log, ledger, params,
                     metric_mode=task.metric_mode)
