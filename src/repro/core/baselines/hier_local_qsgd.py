"""Hier-Local-QSGD (Liu et al., 2023a) baseline — classic 3-tier HFL with
quantized uplinks.

Per global round:
  * K/E edge aggregations: every cluster's clients run E local steps from the
    cluster model; the ES aggregates their (QSGD-quantized) deltas.
  * After the K in-cluster steps, every ES uploads its (QSGD-quantized) cluster
    delta to the PS, which takes the D_{A,m}/D_A-weighted average and
    broadcasts — the star-shaped, communication-heavy step Fed-CHS removes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import CommLedger, dense_message_bits, qsgd_message_bits
from repro.core.simulation import FLTask, RunResult, _multi_client_local_sgd_fn, evaluate
from repro.kernels.ops import qsgd_compress_tree, qsgd_roundtrip
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.utils import tree_add


@dataclasses.dataclass
class HierLocalQSGDConfig:
    rounds: int = 200
    local_steps: int = 20          # K in-cluster iterations per global round
    local_epochs: int = 5          # E (paper B.1: 5 local iterations per round)
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = 16   # uplink quantization (client->ES and ES->PS)
    seed: int = 0
    schedule: Schedule | None = None


def run_hier_local_qsgd(task: FLTask, config: HierLocalQSGDConfig) -> RunResult:
    task.reset_loaders(config.seed)
    assert config.local_steps % config.local_epochs == 0
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger()
    multi_local = _multi_client_local_sgd_fn(task.model)
    key = jax.random.PRNGKey(config.seed + 1)

    dense_bits = dense_message_bits(d, config.bits_per_param)
    q_bits = (
        qsgd_message_bits(d, config.qsgd_levels)
        if config.qsgd_levels is not None
        else dense_bits
    )

    M = task.num_clusters
    cluster_gammas = [jnp.asarray(task.cluster_weights(m)) for m in range(M)]
    es_weights = jnp.asarray(
        np.array(task.cluster_sizes, dtype=np.float32) / sum(task.cluster_sizes)
    )

    rounds_log, acc_log, loss_log = [], [], []
    for t in range(config.rounds):
        cluster_params = [params] * M
        loss_acc = 0.0
        for j in range(interactions):
            lr_slice = jnp.asarray(lrs[j * E : (j + 1) * E])
            for m in range(M):
                xs, ys = task.sample_cluster_batches(m, E)
                xs = jnp.swapaxes(xs, 0, 1)
                ys = jnp.swapaxes(ys, 0, 1)
                new_p, losses = multi_local(cluster_params[m], xs, ys, lr_slice)
                deltas = jax.tree.map(lambda np_, op: np_ - op[None], new_p, cluster_params[m])
                if config.qsgd_levels is not None:
                    key, sub = jax.random.split(key)
                    deltas = qsgd_compress_tree(deltas, sub, s=config.qsgd_levels)
                agg = jax.tree.map(
                    lambda dl, g=cluster_gammas[m]: jnp.einsum("n,n...->...", g, dl), deltas
                )
                cluster_params[m] = tree_add(cluster_params[m], agg)
                loss_acc += float(jnp.mean(losses))
                n_m = len(task.cluster_members[m])
                ledger.record("es_to_client", dense_bits, n_m)
                ledger.record("client_to_es", q_bits, n_m)

        # ES -> PS quantized cluster deltas, PS aggregates + broadcasts
        es_deltas = []
        for m in range(M):
            delta = jax.tree.map(lambda a, b: a - b, cluster_params[m], params)
            if config.qsgd_levels is not None:
                key, sub = jax.random.split(key)
                delta = jax.tree.map(
                    lambda leaf: qsgd_roundtrip(leaf, sub, s=config.qsgd_levels).astype(leaf.dtype),
                    delta,
                )
            es_deltas.append(delta)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *es_deltas)
        agg = jax.tree.map(lambda x: jnp.einsum("m,m...->...", es_weights, x), stacked)
        params = tree_add(params, agg)
        ledger.record("es_to_ps", q_bits, M)
        ledger.record("ps_to_es", dense_bits, M)
        ledger.snapshot(t)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(evaluate(task.model, params, task.dataset))
            loss_log.append(loss_acc / (interactions * M))

    return RunResult("hier_local_qsgd", rounds_log, acc_log, loss_log, ledger, params)
