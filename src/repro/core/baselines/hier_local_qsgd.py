"""Hier-Local-QSGD (Liu et al., 2023a) baseline — classic 3-tier HFL with
quantized uplinks, driven by the engine's vmapped multi-cluster round.

Per global round:
  * K/E edge aggregations: every cluster's clients run E local steps from the
    cluster model; the ES aggregates their channel-compressed deltas.  All M
    clusters advance together inside one jit call — the engine vmaps the
    cluster interaction over a padded/masked (M, n_max) client grid instead
    of looping clusters in Python.
  * After the K in-cluster steps, every ES uploads its compressed cluster
    delta to the PS (per-cluster PRNG keys, split per leaf inside the
    channel), which takes the D_{A,m}/D_A-weighted average and broadcasts —
    the star-shaped, communication-heavy step Fed-CHS removes.

The driver is generic over the task's `FedModel` / `DataSource` / `LocalOpt`:
batches are opaque pytrees, and client-held optimizer state lives in one
(M, n_max)-stacked pytree that persists across global rounds without ever
traversing a channel.

Participation (repro.part): `HierLocalQSGDConfig.sampler` picks each
cluster's reporters per round.  Dropouts fold into the engine's existing
padded/masked client slots (zero gamma, zero uplink bits, frozen opt
state); a fully-dropped cluster's ES is a pass-through — zero delta, zero
PS weight, no ES->PS upload, though it still receives the broadcast so it
stays in sync.  The default `FullParticipation`/None path is bit-identical
to the pre-participation stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import Channel, DenseChannel, channel_wire_bits
from repro.core.engine import (
    RoundEngine,
    ScanPlan,
    run_scan,
    scan_multi_body,
    split_chain,
)
from repro.core.ledger import CommLedger
from repro.core.precision import (
    Precision,
    downlink_bits_per_param,
    resolve_channel,
)
from repro.core.simulation import FLTask, RunRecorder, RunResult
from repro.data.sources import scatter_put, stage_chunk
from repro.obs.trace import maybe_span
from repro.optim.local import LocalOpt
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import Sampler, is_full_participation, participation_mask
from repro.sharding.fed import resolve_mesh, shard_plan


@dataclasses.dataclass
class HierLocalQSGDConfig:
    rounds: int = 200
    local_steps: int = 20          # K in-cluster iterations per global round
    local_epochs: int = 5          # E (paper B.1: 5 local iterations per round)
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = 16   # uplink quantization (client->ES and ES->PS)
    channel: Channel | None = None     # explicit client->ES channel
    es_channel: Channel | None = None  # explicit ES->PS channel (defaults to channel)
    local_opt: LocalOpt | None = None  # client-held optimizer (None = plain SGD)
    client_microbatch: int | None = None  # at most this many client replicas
                                          # per cluster train at once
                                          # (None = full vmap)
    precision: Precision | None = None    # mixed-precision policy: bf16
                                          # client compute, f32 master at the
                                          # PS, wire-dtype dense messages
    sampler: Sampler | None = None     # per-round participation (repro.part);
                                       # None / FullParticipation = seed-parity path
    track_events: bool = True          # False: bits only, no CommEvent stream
    scan_rounds: bool = True           # whole-run lax.scan executor
    chunk_rounds: int = 32             # scanned mode: rounds staged per chunk
    seed: int = 0
    schedule: Schedule | None = None
    obs: Any = None                    # repro.obs.RunTelemetry; None = the
                                       # byte-for-byte untapped fast path
    mesh: Any = None                   # jax Mesh ("clusters", "clients"):
                                       # shard clusters over "clusters" and
                                       # in-cluster clients over "clients"
                                       # (repro.sharding.fed, bit-identical);
                                       # None adopts an ambient federation
                                       # mesh or stays single-device


def _participation_arrays(task: FLTask, parts_t, M: int, n_max: int):
    """One round's participation-renormalized (gammas, mask, sizes) rows —
    the ONE implementation both the looped and scanned paths build their
    masked (M, n_max) slots from (scanned==looped bit-parity depends on it).
    Gamma rows renormalize over each cluster's reporters; a fully-dropped
    cluster keeps an all-zero row (its ES is a pass-through)."""
    pmask = np.zeros((M, n_max), np.float32)
    gnp = np.zeros((M, n_max), np.float32)
    sizes = np.zeros(M, np.float32)
    for m, members in enumerate(task.cluster_members):
        row = participation_mask(members, parts_t[m])
        pmask[m, : len(members)] = row
        w = task.cluster_weights(m) * row
        if w.sum() > 0:
            gnp[m, : len(members)] = w / w.sum()
        sizes[m] = sum(task.client_sizes[i] for i in parts_t[m])
    return gnp, pmask, sizes


def run_hier_local_qsgd(task: FLTask, config: HierLocalQSGDConfig) -> RunResult:
    if config.scan_rounds:
        return _run_hier_scanned(task, config)
    task.reset_loaders(config.seed)
    assert config.local_steps % config.local_epochs == 0, "K must divide by E"
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)
    lrs_grouped = jnp.asarray(lrs.reshape(interactions, E))

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = resolve_channel(config.precision, config.channel,
                              config.qsgd_levels, config.bits_per_param)
    es_channel = config.es_channel if config.es_channel is not None else channel
    engine = RoundEngine(task.model, channel, es_channel, local_opt=config.local_opt,
                         client_microbatch=config.client_microbatch,
                         precision=config.precision)
    key = jax.random.PRNGKey(config.seed + 1)

    down_bits = DenseChannel(
        downlink_bits_per_param(config.precision, config.bits_per_param)
    ).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())
    es_up_bits = channel_wire_bits(es_channel, d, task.param_leaf_sizes())

    M = task.num_clusters
    gammas, mask = task.padded_cluster_weights()
    es_weights = jnp.asarray(
        np.array(task.cluster_sizes, dtype=np.float32) / sum(task.cluster_sizes)
    )

    n_max = mask.shape[1]
    full_part = is_full_participation(config.sampler)
    opt_state = engine.init_opt_state(params, M, n_max)  # client-held, cross-round
    obs = config.obs
    taps = obs is not None and obs.taps
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)
    losses = jnp.full((1, 1), jnp.nan)  # stays nan until a first trained round
    for t in range(config.rounds):
        if full_part:
            parts = list(task.cluster_members)
            gammas_t, mask_t, es_weights_t = gammas, mask, es_weights
            any_participants = True
        else:
            # per-cluster participant sets -> masked (M, n_max) slots; gamma
            # rows renormalize over each cluster's reporters, ES weights over
            # the clusters that trained at all.  A fully-dropped cluster's ES
            # is a pass-through: zero delta, zero weight, no ES->PS upload.
            parts = [config.sampler.participants(t, members)
                     for members in task.cluster_members]
            gnp, pmask, sizes = _participation_arrays(task, parts, M, n_max)
            any_participants = sizes.sum() > 0
            if any_participants:
                gammas_t = jnp.asarray(gnp)
                mask_t = jnp.asarray(pmask)
                es_weights_t = jnp.asarray(sizes / sizes.sum())

        if any_participants:
            batch = task.sample_all_cluster_batches(K, E)  # (J, M, n_max, E, B, ...)
            subs = es_subs = None
            if channel.stochastic:
                key, flat = split_chain(key, interactions * M)
                subs = flat.reshape(interactions, M, 2)
            if es_channel.stochastic:
                key, es_subs = split_chain(key, M)
            with maybe_span(obs, "round"):
                out = engine.multi_cluster_round(
                    params, batch, gammas_t, mask_t, es_weights_t, lrs_grouped,
                    subs, es_subs, opt_state, taps=taps,
                )
                params, opt_state, losses, tele = out if taps else (*out, None)
            if tele is not None:
                obs.record_round(t, tele)
            if not full_part:
                # report loss over the clusters that actually trained (empty
                # clusters read 0 from the engine's guarded average)
                losses = losses[:, sizes > 0]

            if ledger.track_events:
                for j in range(interactions):
                    for m in range(M):
                        es = f"es:{m}"
                        for i in parts[m]:
                            ledger.record("es_to_client", down_bits, round=t, phase=j,
                                          sender=es, receiver=f"client:{i}")
                            ledger.record("client_to_es", up_bits, round=t, phase=j,
                                          sender=f"client:{i}", receiver=es)
                for m in range(M):
                    if parts[m]:  # pass-through ESs upload nothing
                        ledger.record("es_to_ps", es_up_bits, round=t,
                                      phase=interactions,
                                      sender=f"es:{m}", receiver="ps")
                    # every ES still receives the broadcast (stays in sync)
                    ledger.record("ps_to_es", down_bits, round=t,
                                  phase=interactions + 1,
                                  sender="ps", receiver=f"es:{m}")
            else:
                n_part = sum(len(p) for p in parts)
                ledger.record("es_to_client", down_bits, interactions * n_part)
                ledger.record("client_to_es", up_bits, interactions * n_part)
                ledger.record("es_to_ps", es_up_bits, sum(1 for p in parts if p))
                ledger.record("ps_to_es", down_bits, M)
        # else: nobody anywhere this round — zero traffic, params unchanged
        engine.end_round(ledger, t)
        recorder.record(t, params, losses)

    return recorder.result("hier_local_qsgd", ledger, params)


# --------------------------------------------------------------------------
# scanned whole-run path: per-round (gammas, mask, ES weights) and the
# uplink/ES subkey chains are precomputed, batches staged a chunk of global
# rounds at a time, every chunk one lax.scan; all-dark rounds are skipped by
# the scan and the ledger is reconstructed afterwards.  Bit-identical to the
# looped path at fixed seed — the looped driver already runs the padded/
# masked multi-cluster round, so the scan body is the very same computation.
# --------------------------------------------------------------------------


def _hier_scan_plan(task: FLTask, source, config: HierLocalQSGDConfig):
    """Whole-run `ScanPlan` + deferred glue.  Returns (plan, params_of,
    traffic, sel_of) — `sel_of(t)` is the boolean cluster selector the
    looped driver applies to round t's (J, M) loss grid before logging
    (None under full participation)."""
    source.reset(config.seed)
    assert config.local_steps % config.local_epochs == 0, "K must divide by E"
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)

    params = task.init_params()
    d = task.num_params()
    channel = resolve_channel(config.precision, config.channel,
                              config.qsgd_levels, config.bits_per_param)
    es_channel = config.es_channel if config.es_channel is not None else channel
    engine = RoundEngine(task.model, channel, es_channel, local_opt=config.local_opt,
                         client_microbatch=config.client_microbatch,
                         precision=config.precision)

    M = task.num_clusters
    gammas_full, mask_full = task.padded_cluster_weights()
    n_max = mask_full.shape[1]
    es_weights_full = np.asarray(
        np.array(task.cluster_sizes, dtype=np.float32) / sum(task.cluster_sizes)
    )
    full_part = is_full_participation(config.sampler)

    R = config.rounds
    members_of = task.cluster_members
    parts = [
        [list(m) for m in members_of] if full_part
        else [config.sampler.participants(t, m) for m in members_of]
        for t in range(R)
    ]

    gammas_r = np.zeros((R, M, n_max), np.float32)
    mask_r = np.zeros((R, M, n_max), np.float32)
    esw_r = np.zeros((R, M), np.float32)
    sizes_r = np.zeros((R, M), np.float32)
    trained = np.zeros(R, bool)
    for t in range(R):
        if full_part:
            gammas_r[t] = np.asarray(gammas_full)
            mask_r[t] = np.asarray(mask_full)
            esw_r[t] = es_weights_full
            sizes_r[t] = 1.0  # unused under full participation
            trained[t] = True
        else:
            gammas_r[t], mask_r[t], sizes_r[t] = _participation_arrays(
                task, parts[t], M, n_max)
            trained[t] = sizes_r[t].sum() > 0
            if trained[t]:
                esw_r[t] = sizes_r[t] / sizes_r[t].sum()

    # subkeys: per trained round, the looped driver splits J*M uplink keys
    # then M ES keys (each only when that channel is stochastic) — one fused
    # chain reproduces the interleaving draw-for-draw
    subs_r = np.zeros((R, interactions, M, 2), np.uint32)
    es_subs_r = np.zeros((R, M, 2), np.uint32)
    if channel.stochastic or es_channel.stochastic:
        key = jax.random.PRNGKey(config.seed + 1)
        per_round = (interactions * M if channel.stochastic else 0) + (
            M if es_channel.stochastic else 0
        )
        n_tr = int(trained.sum())
        if n_tr and per_round:
            _, flat = split_chain(key, n_tr * per_round)
            flat = np.asarray(flat).reshape(n_tr, per_round, 2)
            ofs = 0
            if channel.stochastic:
                subs_r[trained] = flat[:, : interactions * M].reshape(
                    n_tr, interactions, M, 2)
                ofs = interactions * M
            if es_channel.stochastic:
                es_subs_r[trained] = flat[:, ofs : ofs + M]

    def stage(idxs):
        C = len(idxs)
        cs = list(range(C))  # every trained round stages every cluster
        batch = stage_chunk(
            source,
            [(client, K * C,
              scatter_put((cs, slice(None), m, slot),
                          lambda dl: dl.reshape(C, interactions, E, *dl.shape[1:])))
             for m, members in enumerate(members_of)
             for slot, client in enumerate(members)],
            lambda a: (C, interactions, M, n_max, E) + a.shape[1:],
        )
        for m, members in enumerate(members_of):
            if len(members) < n_max:  # padded slots replicate member 0
                jax.tree.map(
                    lambda bl: bl.__setitem__(
                        (cs, slice(None), m, slice(len(members), None)),
                        bl[cs, :, m, 0:1],
                    ),
                    batch,
                )
        return {
            "batch": batch,
            "gammas": gammas_r[idxs],
            "mask": mask_r[idxs],
            "es_weights": esw_r[idxs],
            "subs": subs_r[idxs],
            "es_subs": es_subs_r[idxs],
        }

    taps = config.obs is not None and config.obs.taps
    plan = ScanPlan(
        body=scan_multi_body(engine.model, channel, es_channel, engine.local_opt,
                             taps, config.client_microbatch, config.precision),
        carry=(params, engine.init_opt_state(params, M, n_max)),
        consts={"lrs": jnp.asarray(lrs.reshape(interactions, E))},
        stage=stage,
        trained=trained,
        rounds=R,
        eval_every=config.eval_every,
        chunk_rounds=config.chunk_rounds,
        obs=config.obs,
    )

    mesh = resolve_mesh(config.mesh)
    if mesh is not None:
        assert config.client_microbatch is None, \
            "client_microbatch and a federation mesh are mutually exclusive"
        plan = shard_plan(plan, mesh, "multi", model=engine.model,
                          channel=channel, es_channel=es_channel,
                          opt=engine.local_opt, clusters=M, clients=n_max)

    down_bits = DenseChannel(
        downlink_bits_per_param(config.precision, config.bits_per_param)
    ).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())
    es_up_bits = channel_wire_bits(es_channel, d, task.param_leaf_sizes())

    def traffic(track_events: bool):
        for t in range(R):
            entries = []
            if trained[t]:
                if track_events:
                    for j in range(interactions):
                        for m in range(M):
                            es = f"es:{m}"
                            for i in parts[t][m]:
                                entries.append(("es_to_client", down_bits, 1, j,
                                                es, f"client:{i}"))
                                entries.append(("client_to_es", up_bits, 1, j,
                                                f"client:{i}", es))
                    for m in range(M):
                        if parts[t][m]:  # pass-through ESs upload nothing
                            entries.append(("es_to_ps", es_up_bits, 1, interactions,
                                            f"es:{m}", "ps"))
                        # every ES still receives the broadcast (stays in sync)
                        entries.append(("ps_to_es", down_bits, 1, interactions + 1,
                                        "ps", f"es:{m}"))
                else:
                    n_part = sum(len(p) for p in parts[t])
                    entries.append(("es_to_client", down_bits,
                                    interactions * n_part, 0, None, None))
                    entries.append(("client_to_es", up_bits,
                                    interactions * n_part, 0, None, None))
                    entries.append(("es_to_ps", es_up_bits,
                                    sum(1 for p in parts[t] if p), 0, None, None))
                    entries.append(("ps_to_es", down_bits, M, 0, None, None))
            yield t, entries

    def sel_of(t: int):
        return None if full_part else sizes_r[t] > 0

    return plan, (lambda c: c[0]), traffic, sel_of


def _run_hier_scanned(task: FLTask, config: HierLocalQSGDConfig) -> RunResult:
    obs = config.obs
    with maybe_span(obs, "precompute"):
        plan, params_of, traffic, sel_of = _hier_scan_plan(task, task.source, config)
    recorder = RunRecorder(task, config.rounds, config.eval_every, obs=obs)

    def record(t, carry, losses, last_t):
        if losses is not None:
            sel = sel_of(last_t)
            if sel is not None:
                # the looped driver logs the mean over the clusters that
                # actually trained in the last trained round
                losses = losses[:, sel]
        recorder.record(t, params_of(carry), losses)

    carry = run_scan(plan, record)
    ledger = CommLedger(track_events=config.track_events)
    with maybe_span(obs, "materialize"):
        ledger.materialize(traffic(config.track_events))
    return recorder.result("hier_local_qsgd", ledger, params_of(carry))
