"""Hier-Local-QSGD (Liu et al., 2023a) baseline — classic 3-tier HFL with
quantized uplinks, driven by the engine's vmapped multi-cluster round.

Per global round:
  * K/E edge aggregations: every cluster's clients run E local steps from the
    cluster model; the ES aggregates their channel-compressed deltas.  All M
    clusters advance together inside one jit call — the engine vmaps the
    cluster interaction over a padded/masked (M, n_max) client grid instead
    of looping clusters in Python.
  * After the K in-cluster steps, every ES uploads its compressed cluster
    delta to the PS (per-cluster PRNG keys, split per leaf inside the
    channel), which takes the D_{A,m}/D_A-weighted average and broadcasts —
    the star-shaped, communication-heavy step Fed-CHS removes.

The driver is generic over the task's `FedModel` / `DataSource` / `LocalOpt`:
batches are opaque pytrees, and client-held optimizer state lives in one
(M, n_max)-stacked pytree that persists across global rounds without ever
traversing a channel.

Participation (repro.part): `HierLocalQSGDConfig.sampler` picks each
cluster's reporters per round.  Dropouts fold into the engine's existing
padded/masked client slots (zero gamma, zero uplink bits, frozen opt
state); a fully-dropped cluster's ES is a pass-through — zero delta, zero
PS weight, no ES->PS upload, though it still receives the broadcast so it
stays in sync.  The default `FullParticipation`/None path is bit-identical
to the pre-participation stack.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channels import Channel, DenseChannel, make_channel
from repro.core.engine import RoundEngine, split_chain
from repro.core.ledger import CommLedger
from repro.core.simulation import FLTask, RunResult
from repro.optim.local import LocalOpt
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import Sampler, is_full_participation, participation_mask


@dataclasses.dataclass
class HierLocalQSGDConfig:
    rounds: int = 200
    local_steps: int = 20          # K in-cluster iterations per global round
    local_epochs: int = 5          # E (paper B.1: 5 local iterations per round)
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = 16   # uplink quantization (client->ES and ES->PS)
    channel: Channel | None = None     # explicit client->ES channel
    es_channel: Channel | None = None  # explicit ES->PS channel (defaults to channel)
    local_opt: LocalOpt | None = None  # client-held optimizer (None = plain SGD)
    sampler: Sampler | None = None     # per-round participation (repro.part);
                                       # None / FullParticipation = seed-parity path
    track_events: bool = True          # False: bits only, no CommEvent stream
    seed: int = 0
    schedule: Schedule | None = None


def run_hier_local_qsgd(task: FLTask, config: HierLocalQSGDConfig) -> RunResult:
    task.reset_loaders(config.seed)
    assert config.local_steps % config.local_epochs == 0, "K must divide by E"
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)
    lrs_grouped = jnp.asarray(lrs.reshape(interactions, E))

    params = task.init_params()
    d = task.num_params()
    ledger = CommLedger(track_events=config.track_events)
    channel = (
        config.channel
        if config.channel is not None
        else make_channel(config.qsgd_levels, config.bits_per_param)
    )
    es_channel = config.es_channel if config.es_channel is not None else channel
    engine = RoundEngine(task.model, channel, es_channel, local_opt=config.local_opt)
    key = jax.random.PRNGKey(config.seed + 1)

    down_bits = DenseChannel(config.bits_per_param).message_bits(d)
    up_bits = channel.message_bits(d)
    es_up_bits = es_channel.message_bits(d)

    M = task.num_clusters
    gammas, mask = task.padded_cluster_weights()
    es_weights = jnp.asarray(
        np.array(task.cluster_sizes, dtype=np.float32) / sum(task.cluster_sizes)
    )

    n_max = mask.shape[1]
    full_part = is_full_participation(config.sampler)
    opt_state = engine.init_opt_state(params, M, n_max)  # client-held, cross-round
    rounds_log, acc_log, loss_log = [], [], []
    losses = jnp.full((1, 1), jnp.nan)  # stays nan until a first trained round
    for t in range(config.rounds):
        if full_part:
            parts = list(task.cluster_members)
            gammas_t, mask_t, es_weights_t = gammas, mask, es_weights
            any_participants = True
        else:
            # per-cluster participant sets -> masked (M, n_max) slots; gamma
            # rows renormalize over each cluster's reporters, ES weights over
            # the clusters that trained at all.  A fully-dropped cluster's ES
            # is a pass-through: zero delta, zero weight, no ES->PS upload.
            parts = [config.sampler.participants(t, members)
                     for members in task.cluster_members]
            pmask = np.zeros((M, n_max), np.float32)
            gnp = np.zeros((M, n_max), np.float32)
            sizes = np.zeros(M, np.float32)
            for m, members in enumerate(task.cluster_members):
                row = participation_mask(members, parts[m])
                pmask[m, : len(members)] = row
                w = task.cluster_weights(m) * row
                if w.sum() > 0:
                    gnp[m, : len(members)] = w / w.sum()
                sizes[m] = sum(task.client_sizes[i] for i in parts[m])
            any_participants = sizes.sum() > 0
            if any_participants:
                gammas_t = jnp.asarray(gnp)
                mask_t = jnp.asarray(pmask)
                es_weights_t = jnp.asarray(sizes / sizes.sum())

        if any_participants:
            batch = task.sample_all_cluster_batches(K, E)  # (J, M, n_max, E, B, ...)
            subs = es_subs = None
            if channel.stochastic:
                key, flat = split_chain(key, interactions * M)
                subs = flat.reshape(interactions, M, 2)
            if es_channel.stochastic:
                key, es_subs = split_chain(key, M)
            params, opt_state, losses = engine.multi_cluster_round(
                params, batch, gammas_t, mask_t, es_weights_t, lrs_grouped,
                subs, es_subs, opt_state
            )
            if not full_part:
                # report loss over the clusters that actually trained (empty
                # clusters read 0 from the engine's guarded average)
                losses = losses[:, sizes > 0]

            if ledger.track_events:
                for j in range(interactions):
                    for m in range(M):
                        es = f"es:{m}"
                        for i in parts[m]:
                            ledger.record("es_to_client", down_bits, round=t, phase=j,
                                          sender=es, receiver=f"client:{i}")
                            ledger.record("client_to_es", up_bits, round=t, phase=j,
                                          sender=f"client:{i}", receiver=es)
                for m in range(M):
                    if parts[m]:  # pass-through ESs upload nothing
                        ledger.record("es_to_ps", es_up_bits, round=t,
                                      phase=interactions,
                                      sender=f"es:{m}", receiver="ps")
                    # every ES still receives the broadcast (stays in sync)
                    ledger.record("ps_to_es", down_bits, round=t,
                                  phase=interactions + 1,
                                  sender="ps", receiver=f"es:{m}")
            else:
                n_part = sum(len(p) for p in parts)
                ledger.record("es_to_client", down_bits, interactions * n_part)
                ledger.record("client_to_es", up_bits, interactions * n_part)
                ledger.record("es_to_ps", es_up_bits, sum(1 for p in parts if p))
                ledger.record("ps_to_es", down_bits, M)
        # else: nobody anywhere this round — zero traffic, params unchanged
        engine.end_round(ledger, t)

        if t % config.eval_every == 0 or t == config.rounds - 1:
            rounds_log.append(t)
            acc_log.append(task.evaluate(params))
            loss_log.append(float(jnp.mean(losses)))

    return RunResult("hier_local_qsgd", rounds_log, acc_log, loss_log, ledger, params,
                     metric_mode=task.metric_mode)
