# Fed-CHS: Sequential Federated Learning in Hierarchical Architecture.
# The paper's contribution lives here: the Algorithm-1 protocol (fed_chs),
# the 2-step next-passing-cluster scheduler, ES topologies, bit-exact
# communication accounting, baselines, the shared jitted round engine, and
# the TPU-native sharded variant.
from repro.core.engine import RoundEngine, split_chain
from repro.core.fed_chs import FedCHSConfig, run_fed_chs
from repro.core.ledger import CommEvent, CommLedger, dense_message_bits, qsgd_message_bits
from repro.core.oracles import cluster_sgd, local_sgd, multi_client_local_sgd
from repro.core.scheduler import (
    AvailabilityAwareScheduler,
    FedCHSScheduler,
    LatencyAwareScheduler,
    RandomWalkScheduler,
    RingScheduler,
)
from repro.core.simulation import FLTask, RunRecorder, RunResult, evaluate
from repro.core.sweep import run_sweep
from repro.core.topology import Topology, make_topology

__all__ = [
    "FedCHSConfig",
    "run_fed_chs",
    "RoundEngine",
    "split_chain",
    "CommEvent",
    "CommLedger",
    "dense_message_bits",
    "qsgd_message_bits",
    "AvailabilityAwareScheduler",
    "FedCHSScheduler",
    "LatencyAwareScheduler",
    "RandomWalkScheduler",
    "RingScheduler",
    "FLTask",
    "RunRecorder",
    "RunResult",
    "run_sweep",
    "evaluate",
    "local_sgd",
    "multi_client_local_sgd",
    "cluster_sgd",
    "Topology",
    "make_topology",
]
