"""Seed-parity local-update oracles — the ONE implementation of local SGD.

Before this module the repo carried three near-identical copies of the local
update loop (`engine._local_sgd`, `simulation._local_sgd_fn` /
`_multi_client_local_sgd_fn`, and the Eq.-(5) phase `_cluster_sgd_fn`).  They
now all live here, built from two generic factories:

  * `local_opt_steps(model, opt)` — E local optimizer steps for ONE client
    over a batch *pytree* (leaves ``(E, B, ...)``), threading the client-held
    `LocalOpt` state through the scan.  With the default `PlainSGD` the scan
    body is the exact ``w - lr * g`` expression the seed drivers ran, which
    is what keeps fixed-seed trajectories bit-identical (the contract in
    tests/test_engine_parity.py).
  * `grad_phase(model)` — the Eq. (5) literal: scan over K joint steps of
    ``w <- w - eta_k * sum_n gamma_n grad_n(w, xi_{n,k})``.

The jitted classifier-signature wrappers below (`local_sgd`,
`multi_client_local_sgd`, `cluster_sgd`) keep the historical
``(params, xs, ys, lrs)`` calling convention for the parity tests' reference
loops and benchmarks/engine_speedup.py's seed-style arms.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.classifier import Classifier
from repro.models.fed import FedModel, as_fed_model
from repro.optim.local import LocalOpt, PlainSGD

PyTree = Any


def local_opt_steps(model: FedModel, opt: LocalOpt):
    """E local steps for one client: batch leaves (E, B, ...), lrs (E,).

    Returns ``run(params, opt_state, batch, lrs) -> (params, opt_state,
    mean_loss)``; the opt state is the client's private carry — it never
    appears in the uplink deltas the engine computes from the params."""
    grad_fn = jax.value_and_grad(model.loss)

    def run(params, opt_state, batch, lrs):
        def step(carry, inp):
            p, s = carry
            b, lr = inp
            loss, g = grad_fn(p, b)
            p, s = opt.step(p, s, g, lr)
            return (p, s), loss

        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state), (batch, lrs))
        return params, opt_state, jnp.mean(losses)

    return run


def grad_phase(model: FedModel, microbatch: int | None = None):
    """Eq. (5) literal: scan over K steps of
    w <- w - eta_k * sum_n gamma_n grad_n(w, xi_{n,k}).
    batch leaves: (K, n, B, ...); gammas: (n,); lrs: (K,).
    Returns (params, per-step gamma-weighted losses (K,)).

    `microbatch` bounds how many clients' forward/backward passes are live at
    once: the all-clients vmap becomes a `lax.scan` over ceil(n/microbatch)
    client groups (tail group padded with client-0 replicas, sliced off before
    aggregation).  The per-step gradient STACK (n, ...) is still materialized
    — Eq. (5) aggregates all n gradients jointly, and feeding the stack to the
    very same einsum is what keeps the microbatched path BIT-IDENTICAL to the
    vmapped one (per-client grads are vmap-width-invariant; pinned by
    tests/test_engine_parity.py) — but activation memory drops from O(n) to
    O(microbatch) model evaluations, which is the dominant term for LMs."""
    grad_fn = jax.vmap(jax.value_and_grad(model.loss), in_axes=(None, 0))

    if microbatch is None:
        per_step = grad_fn
    else:
        mb = int(microbatch)
        assert mb >= 1

        def per_step(p, b_k):
            n = jax.tree.leaves(b_k)[0].shape[0]
            pad = (-n) % mb
            if pad:
                b_k = jax.tree.map(
                    lambda a: jnp.concatenate(
                        [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])]
                    ),
                    b_k,
                )
            b_g = jax.tree.map(
                lambda a: a.reshape(((n + pad) // mb, mb) + a.shape[1:]), b_k
            )

            def group(_, b_j):
                return None, grad_fn(p, b_j)

            _, (losses, grads) = jax.lax.scan(group, None, b_g)
            losses = losses.reshape(n + pad)[:n]
            grads = jax.tree.map(
                lambda a: a.reshape((n + pad,) + a.shape[2:])[:n], grads
            )
            return losses, grads

    def phase(params, batch, gammas, lrs):
        def step(p, inp):
            b_k, lr_k = inp
            losses, grads = per_step(p, b_k)
            agg = jax.tree.map(lambda g: jnp.einsum("n,n...->...", gammas, g), grads)
            p = jax.tree.map(lambda w, g: w - lr_k * g, p, agg)
            return p, jnp.dot(gammas, losses)

        return jax.lax.scan(step, params, (batch, lrs))

    return phase


# --------------------------------------------------------------------------
# jitted classifier-signature oracles (seed parity tests + benchmarks)
# --------------------------------------------------------------------------


def _classifier_local(model: Classifier):
    run = local_opt_steps(as_fed_model(model), PlainSGD())

    def fn(params, xs, ys, lrs):
        p, _, loss = run(params, (), {"x": xs, "y": ys}, lrs)
        return p, loss

    return fn


@functools.cache
def local_sgd(model: Classifier):
    """E plain local SGD steps for ONE client: xs (E, B, ...), ys (E, B), lrs (E,)."""
    return jax.jit(_classifier_local(model))


@functools.cache
def multi_client_local_sgd(model: Classifier):
    """`local_sgd` vmapped over a leading client axis (same E, B)."""
    return jax.jit(jax.vmap(_classifier_local(model), in_axes=(None, 0, 0, None)))


@functools.cache
def cluster_sgd(model: Classifier):
    """One Eq.(5) in-cluster phase: xs (K, n, B, ...), ys (K, n, B),
    gammas (n,), lrs (K,). Returns (params, mean loss over steps/clients)."""
    phase = grad_phase(as_fed_model(model))

    def fn(params, xs, ys, gammas, lrs):
        p, losses = phase(params, {"x": xs, "y": ys}, gammas, lrs)
        return p, jnp.mean(losses)

    return jax.jit(fn)
