"""repro — Fed-CHS: Sequential Federated Learning in Hierarchical Architecture,
built as a deployable JAX framework (protocol + model zoo + multi-pod runtime)."""

__version__ = "0.1.0"
