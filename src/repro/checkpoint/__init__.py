from repro.checkpoint.io import (
    load_fl_state,
    load_pytree,
    load_run_state,
    run_state_exists,
    save_fl_state,
    save_pytree,
    save_run_state,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "save_fl_state",
    "load_fl_state",
    "save_run_state",
    "load_run_state",
    "run_state_exists",
]
