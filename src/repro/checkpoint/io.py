"""Checkpointing: parameter pytrees and resumable FL protocol state.

npz-based (no external deps): leaves are stored under their tree paths, so a
checkpoint is stable across process restarts and readable with plain numpy.

Two layers:

  * `save_pytree` / `load_pytree` — one pytree of arrays per npz file, with a
    `__pytree_meta__` record (leaf order + treedef string) that `load_pytree`
    verifies so a checkpoint written for one structure can never be silently
    mis-mapped onto another.
  * `save_run_state` / `load_run_state` — a whole resumable run: an arbitrary
    array pytree (params, opt-state stacks, staleness buffers, PRNG keys)
    plus a JSON meta sidecar (cursors, draw counts, ledger state, recorder
    logs).  Writes are atomic (tmp + rename) so a process killed mid-save
    leaves either the previous complete checkpoint or the new one, never a
    torn file — the property the kill-and-resume parity tests lean on.

The legacy Fed-CHS helpers `save_fl_state` / `load_fl_state` remain as thin
wrappers for round-granular scheduler state.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_META_KEY = "__pytree_meta__"

# numpy cannot serialise the ml_dtypes float families natively.  A mixed-
# precision run state holds BOTH bf16 compute leaves (client opt states) and
# f32 master leaves (ES params) in ONE pytree, so each leaf is stored as the
# same-width unsigned-int bit pattern with its true dtype recorded in the
# meta — the round trip is bit-exact and the checkpoint stays half the size
# the old widen-to-f32 fallback paid for 16-bit leaves.
_BITCAST = {"bfloat16": np.uint16, "float16": np.uint16, "float8_e4m3fn": np.uint8}


def _is_ml_dtype(arr: np.ndarray) -> bool:
    return arr.dtype.kind == "V" or str(arr.dtype) in _BITCAST


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _atomic_replace(tmp: str, dst: str) -> None:
    os.replace(tmp, dst)


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    order = []
    dtypes = {}
    for keypath, leaf in flat:
        name = _path_str(keypath)
        order.append(name)
        arr = np.asarray(leaf)
        if _is_ml_dtype(arr):
            dt = str(arr.dtype)
            if dt not in _BITCAST:
                raise TypeError(f"cannot serialise leaf {name!r} of dtype {dt}")
            dtypes[name] = dt
            arr = arr.view(_BITCAST[dt])  # exact bit pattern, native width
        arrays[name] = arr
    arrays[_META_KEY] = np.frombuffer(
        json.dumps(
            {"order": order, "treedef": str(treedef), "dtypes": dtypes}
        ).encode(),
        dtype=np.uint8,
    )
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    _atomic_replace(tmp, path)


def _read_meta(data, path: str) -> dict | None:
    if _META_KEY not in data:
        return None
    try:
        return json.loads(bytes(data[_META_KEY]).decode())
    except (ValueError, UnicodeDecodeError) as e:
        raise ValueError(f"{path}: corrupt {_META_KEY} record: {e}") from e


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of `like`.

    The stored `__pytree_meta__` (leaf order + treedef) is verified against
    `like` — a structure mismatch raises instead of silently mis-mapping
    leaves; a missing leaf or a shape mismatch names the leaf and the file.
    """
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        order = [_path_str(kp) for kp, _ in flat]
        meta = _read_meta(data, path)
        if meta is not None:
            if meta.get("order") != order:
                stored, want = meta.get("order", []), order
                missing = [n for n in want if n not in stored]
                extra = [n for n in stored if n not in want]
                raise ValueError(
                    f"{path}: checkpoint leaf order does not match the requested "
                    f"structure (stored {len(stored)} leaves, want {len(want)}; "
                    f"missing={missing[:5]}, unexpected={extra[:5]})"
                )
            if meta.get("treedef") != str(treedef):
                raise ValueError(
                    f"{path}: checkpoint treedef mismatch — stored "
                    f"{meta.get('treedef')!r}, want {str(treedef)!r}"
                )
        leaves = []
        for (keypath, leaf), name in zip(flat, order):
            if name not in data:
                raise KeyError(
                    f"{path}: checkpoint has no leaf {name!r} "
                    f"(available: {sorted(k for k in data.files if k != _META_KEY)[:8]}...)"
                )
            arr = data[name]
            stored_dt = (meta or {}).get("dtypes", {}).get(name)
            if stored_dt is not None:
                # bit-pattern leaf: view back to its true (ml_dtypes) dtype —
                # the round trip is exact even when `like` names a different
                # width (the cast below then happens from the TRUE values)
                arr = arr.view(jax.numpy.dtype(stored_dt))
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"{path}: leaf {name!r} has shape {arr.shape}, "
                    f"want {tuple(leaf.shape)}"
                )
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree.unflatten(treedef, leaves)


# --------------------------------------------------------------------------
# generalized resumable run state: arrays npz + JSON meta sidecar
# --------------------------------------------------------------------------


def save_run_state(path: str, arrays: PyTree, meta: dict) -> None:
    """Persist one resumable run checkpoint.

    `arrays` is any pytree of arrays (params, opt-state stacks, buffer
    contents, raw PRNG key data); `meta` is a JSON-serialisable dict
    (round/event cursors, simulated clock, per-client draw counts, ledger
    state, recorder logs).  Both writes are atomic; meta is written LAST so
    its presence certifies a complete checkpoint."""
    save_pytree(path + ".arrays.npz", arrays)
    tmp = path + ".meta.json.tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "w") as f:
        json.dump(meta, f)
    _atomic_replace(tmp, path + ".meta.json")


def load_run_state(path: str, like_arrays: PyTree) -> tuple[PyTree, dict]:
    """Load a `save_run_state` checkpoint; returns ``(arrays, meta)``."""
    meta_path = path + ".meta.json"
    if not os.path.exists(meta_path):
        raise FileNotFoundError(
            f"{meta_path}: no complete checkpoint at {path!r} "
            "(meta sidecar missing — run was never checkpointed or the save "
            "was interrupted before the arrays finished)"
        )
    with open(meta_path) as f:
        meta = json.load(f)
    arrays = load_pytree(path + ".arrays.npz", like_arrays)
    return arrays, meta


def run_state_exists(path: str) -> bool:
    return os.path.exists(path + ".meta.json")


# --------------------------------------------------------------------------
# legacy Fed-CHS round-state helpers
# --------------------------------------------------------------------------


def save_fl_state(
    path: str, params: PyTree, *, round_idx: int, visit_counts: np.ndarray, current: int
) -> None:
    """Round-resumable Fed-CHS state: model + scheduler (c vector, m(t))."""
    save_pytree(path + ".params.npz", params)
    np.savez(
        path + ".sched.npz",
        round_idx=np.int64(round_idx),
        visit_counts=visit_counts.astype(np.int64),
        current=np.int64(current),
    )


def load_fl_state(path: str, like_params: PyTree):
    params = load_pytree(path + ".params.npz", like_params)
    with np.load(path + ".sched.npz") as s:
        return params, int(s["round_idx"]), s["visit_counts"].copy(), int(s["current"])
