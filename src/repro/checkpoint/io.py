"""Checkpointing: parameter pytrees and resumable FL protocol state.

npz-based (no external deps): leaves are stored under their tree paths, so a
checkpoint is stable across process restarts and readable with plain numpy.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_META_KEY = "__pytree_meta__"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    order = []
    for keypath, leaf in flat:
        name = _path_str(keypath)
        order.append(name)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16", "float8_e4m3fn"):
            # numpy cannot serialise ml_dtypes natively; store widened
            arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
        arrays[name] = arr
    arrays[_META_KEY] = np.frombuffer(
        json.dumps({"order": order, "treedef": str(treedef)}).encode(), dtype=np.uint8
    )
    np.savez(path, **arrays)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (names must match)."""
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for keypath, leaf in flat:
            name = _path_str(keypath)
            arr = data[name]
            assert arr.shape == tuple(leaf.shape), f"{name}: {arr.shape} != {leaf.shape}"
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree.unflatten(treedef, leaves)


def save_fl_state(
    path: str, params: PyTree, *, round_idx: int, visit_counts: np.ndarray, current: int
) -> None:
    """Round-resumable Fed-CHS state: model + scheduler (c vector, m(t))."""
    save_pytree(path + ".params.npz", params)
    np.savez(
        path + ".sched.npz",
        round_idx=np.int64(round_idx),
        visit_counts=visit_counts.astype(np.int64),
        current=np.int64(current),
    )


def load_fl_state(path: str, like_params: PyTree):
    params = load_pytree(path + ".params.npz", like_params)
    with np.load(path + ".sched.npz") as s:
        return params, int(s["round_idx"]), s["visit_counts"].copy(), int(s["current"])
