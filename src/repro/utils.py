"""Small shared utilities: pytree arithmetic, rng splitting, size accounting."""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_weighted_sum(trees: list[PyTree], weights) -> PyTree:
    """sum_i weights[i] * trees[i] — the ES aggregation primitive (Eq. 5)."""
    assert len(trees) == len(weights) and trees, "empty aggregation"
    acc = tree_scale(trees[0], weights[0])
    for t, w in zip(trees[1:], weights[1:]):
        acc = tree_axpy(w, t, acc)
    return acc


def tree_dot(a: PyTree, b: PyTree):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves)


def tree_sq_norm(tree: PyTree):
    return tree_dot(tree, tree)


def tree_num_params(tree: PyTree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_num_bytes(tree: PyTree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_any_nan(tree: PyTree) -> bool:
    return bool(any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(tree)))


def split_like(key: jax.Array, tree: PyTree) -> PyTree:
    """One PRNG key per leaf, same structure."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def cached_jit(fn: Callable, **jit_kwargs) -> Callable:
    return functools.lru_cache(maxsize=None)(lambda: jax.jit(fn, **jit_kwargs))
