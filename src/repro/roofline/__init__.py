from repro.roofline.analysis import (
    HW,
    analyze_compiled,
    roofline_terms,
    model_flops,
)

__all__ = ["HW", "analyze_compiled", "roofline_terms", "model_flops"]
