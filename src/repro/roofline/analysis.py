"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch x shape x mesh), in seconds:
    compute    = FLOPs_per_device / peak_FLOP/s
    memory     = HBM_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

Sources:
 * `compiled.cost_analysis()` for flops / bytes — but XLA counts a `while`
   body ONCE, so we re-derive flops by walking the post-optimisation HLO:
   every `dot` is priced as 2 * prod(out_shape) * prod(lhs_contracting_dims)
   and scaled by the product of enclosing-loop `known_trip_count`s.
 * collective bytes: output-shape bytes of every all-reduce / all-gather /
   reduce-scatter / all-to-all / collective-permute, trip-scaled the same way
   (all-reduce counted at 2x output bytes — reduce + broadcast phases).
 * memory bytes: cost_analysis "bytes accessed" scaled by the dot-flops
   ratio (documented approximation), plus memory_analysis() peak stats.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 per chip
    peak_flops_f32: float = 98.5e12  # f32 matmuls run the MXU at half rate
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link

V5E = HW()

# dtypes the MXU runs at full (low-precision) rate; everything else — f32
# master-weight matmuls above all — is priced at `peak_flops_f32`
_FULL_RATE_DTYPES = ("bf16", "f16", "f8e4m3fn", "f8e5m2", "s8", "u8")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-+]+)\s*\(.*->.*\{\s*$")
# shape may be a tuple containing /*index=N*/ comments (hence no [^=] class);
# the op is the first bare `word(` after the shape.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-+]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+\"?(\d+)')
_CALL_SINGLE_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-+]+)")
_CALL_MULTI_RE = re.compile(r"(?:branch_computations|called_computations)=\{([^}]*)\}")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over all tensors appearing in a (possibly tuple) shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def parse_hlo(text: str) -> dict:
    """Parse optimized HLO into {computation: [instr dicts]}, shape table,
    call edges and while trip counts."""
    comps: dict[str, list[dict]] = defaultdict(list)
    shapes: dict[str, str] = {}
    current = None
    entry = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if ("{" in line and "->" in line) else None
        if hdr and not line.startswith(" "):
            current = hdr.group(1)
            if line.startswith("ENTRY"):
                entry = current
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op, rest = m.groups()
        shapes[name] = shape_str.strip()
        instr = {"name": name, "shape": shape_str.strip(), "op": op, "rest": rest,
                 "line": line}
        comps[current].append(instr)
    return {"comps": dict(comps), "shapes": shapes, "entry": entry}


def _instr_callees(instr) -> list[str]:
    names = [m.group(1) for m in _CALL_SINGLE_RE.finditer(instr["line"])]
    for m in _CALL_MULTI_RE.finditer(instr["line"]):
        for nm in m.group(1).split(","):
            nm = nm.strip().lstrip("%")
            if nm:
                names.append(nm)
    return names


def _dot_flops(instr, shapes) -> float:
    out = _shape_dims(instr["shape"])
    cd = _CDIM_RE.search(instr["line"])
    # lhs operand name = first %ref in the args
    args = re.findall(r"%([\w.\-+]+)", instr["rest"])
    contract = 1
    if cd and args:
        lhs_shape = _shape_dims(shapes.get(args[0], ""))
        for d in cd.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                contract *= lhs_shape[int(d)]
    return 2.0 * float(np.prod(out, dtype=np.float64)) * contract if out else 0.0


def _conv_flops(instr, shapes) -> float:
    # convolution: 2 * prod(out) * prod(kernel spatial+input-feature dims)
    args = re.findall(r"%([\w.\-+]+)", instr["rest"])
    out = _shape_dims(instr["shape"])
    if len(args) < 2 or not out:
        return 0.0
    rhs = _shape_dims(shapes.get(args[1], ""))
    k = float(np.prod(rhs, dtype=np.float64)) / max(out[-1] if out else 1, 1)
    return 2.0 * float(np.prod(out, dtype=np.float64)) * max(k, 1.0)


def analyze_hlo_text(text: str) -> dict:
    """Trip-scaled dot flops + collective bytes by op type (per device)."""
    parsed = parse_hlo(text)
    comps, shapes, entry = parsed["comps"], parsed["shapes"], parsed["entry"]

    # while trip counts: map body/cond computation -> trip count
    trip_of_callee: dict[str, float] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins["op"] == "while":
                t = _TRIP_RE.search(ins["line"])
                trip = float(t.group(1)) if t else 1.0
                for callee in _instr_callees(ins):
                    trip_of_callee[callee] = trip

    flops = 0.0
    flops_by_dtype = defaultdict(float)
    coll = defaultdict(float)
    visited_stack: list[str] = []

    def _out_dtype(ins) -> str:
        m = _SHAPE_RE.search(ins["shape"])
        return m.group(1) if m else "?"

    def visit(cname: str, mult: float):
        if cname not in comps or cname in visited_stack:
            return
        visited_stack.append(cname)
        for ins in comps[cname]:
            op = ins["op"]
            if op == "dot":
                nonlocal flops
                f = mult * _dot_flops(ins, shapes)
                flops += f
                flops_by_dtype[_out_dtype(ins)] += f
            elif op == "convolution":
                f = mult * _conv_flops(ins, shapes)
                flops += f
                flops_by_dtype[_out_dtype(ins)] += f
            elif any(op.startswith(c) for c in _COLLECTIVES):
                base = _shape_bytes(ins["shape"])
                key = next(c for c in _COLLECTIVES if op.startswith(c))
                factor = 2.0 if key == "all-reduce" else 1.0
                coll[key] += mult * base * factor
            callees = _instr_callees(ins)
            for callee in callees:
                m2 = mult * trip_of_callee.get(callee, 1.0) if op == "while" else mult
                visit(callee, m2)
        visited_stack.pop()

    if entry:
        visit(entry, 1.0)
    return {
        "dot_flops_per_device": flops,
        "dot_flops_by_dtype": dict(flops_by_dtype),
        "collective_bytes_per_device": dict(coll),
        "collective_total_bytes": float(sum(coll.values())),
    }


def analyze_compiled(compiled, *, hints: dict | None = None) -> dict:
    """Full record for one compiled lowering (per-device numbers)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # per-device list in newer jax
        ca = ca[0] if ca else {}
    raw_flops = float(ca.get("flops", 0.0) or 0.0)
    raw_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)
    hlo = analyze_hlo_text(compiled.as_text())
    scaled_flops = hlo["dot_flops_per_device"]
    scale = scaled_flops / raw_flops if raw_flops > 0 and scaled_flops > raw_flops else 1.0
    try:
        mem = compiled.memory_analysis()
        arg = int(getattr(mem, "argument_size_in_bytes", 0))
        out = int(getattr(mem, "output_size_in_bytes", 0))
        tmp = int(getattr(mem, "temp_size_in_bytes", 0))
        alias = int(getattr(mem, "alias_size_in_bytes", 0))  # donated buffers
        mem_stats = {
            "argument_bytes": arg,
            "output_bytes": out,
            "temp_bytes": tmp,
            "alias_bytes": alias,
            # aliased (donated) buffers appear in both arg and out: count once
            "peak_bytes": arg + tmp + out - alias,
        }
    except Exception:  # pragma: no cover
        mem_stats = {}
    return {
        "raw_flops_per_device": raw_flops,
        "dot_flops_per_device": scaled_flops,
        "dot_flops_by_dtype": hlo["dot_flops_by_dtype"],
        "raw_bytes_per_device": raw_bytes,
        "scaled_bytes_per_device": raw_bytes * scale,
        "loop_scale_ratio": scale,
        "collectives": hlo["collective_bytes_per_device"],
        "collective_bytes_per_device": hlo["collective_total_bytes"],
        "memory": mem_stats,
        **({"hints": hints} if hints else {}),
    }


def compute_seconds(record: dict, *, hw: HW = V5E) -> float:
    """Dtype-aware compute term: each dot's flops are priced at the MXU rate
    its OUTPUT dtype actually achieves — bf16/f16/f8 at `peak_flops`, f32
    (and anything else) at `peak_flops_f32`.  A mixed-precision round is
    mostly-bf16 with a thin f32 master/accumulate slice, and pricing it all
    at the bf16 peak understates compute by up to 2x.  Records without the
    dtype breakdown (older artifacts) fall back to the flat bf16 rate."""
    by_dtype = record.get("dot_flops_by_dtype")
    if not by_dtype:
        return record["dot_flops_per_device"] / hw.peak_flops
    return sum(
        f / (hw.peak_flops if dt in _FULL_RATE_DTYPES else hw.peak_flops_f32)
        for dt, f in by_dtype.items()
    )


def arithmetic_intensity(record: dict) -> float:
    """FLOPs per HBM byte of the compiled program — compared against the
    machine balance (`hw.peak_flops / hw.hbm_bw`) it says which side of the
    roofline ridge a kernel sits on.  Bytes come from the dtype-priced shape
    walk, so a bf16 activation stream (2 B/elt) doubles the intensity of the
    same graph in f32 — exactly the effect the mixed-precision policy buys."""
    b = record.get("scaled_bytes_per_device") or record.get("raw_bytes_per_device", 0.0)
    return record["dot_flops_per_device"] / b if b else float("inf")


def roofline_terms(record: dict, *, hw: HW = V5E) -> dict:
    """Seconds per term + the dominant bottleneck."""
    compute = compute_seconds(record, hw=hw)
    memory = record["scaled_bytes_per_device"] / hw.hbm_bw
    collective = record["collective_bytes_per_device"] / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    return {**terms, "bound": dom.replace("_s", ""),
            "intensity_flops_per_byte": arithmetic_intensity(record)}


def model_flops(param_count: int, tokens: float, *, kind: str = "train") -> float:
    """Analytic MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D forward-only."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * float(param_count) * float(tokens)
