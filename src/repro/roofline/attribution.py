"""Per-instruction HLO attribution — the 'profiler' of the dry-run workflow.

`benchmarks/roofline.py` reports the three aggregate terms; when a term
dominates, these helpers answer *which ops* are responsible (EXPERIMENTS.md
§Perf iterations were driven by them):

  * collective_breakdown — trip-scaled bytes per (collective op, shape,
    source op_name), e.g. "the MoE combine all-reduces f32[65536,7168]
    61 times from .../shard_map/psum".
  * top_output_bytes — trip-scaled output bytes per instruction, skipping
    bookkeeping ops; a proxy for which tensors stream through HBM.
  * phase_bytes — trip-scaled output bytes grouped by op_name pattern, e.g.
    attributing the per-round quantize→pack cost to the `qsgd_encode` /
    `qsgd_decode` named_scopes that kernels/ops.py wraps around the packed
    wire transforms (benchmarks/kernels_micro.py reports these per round).

All parse `compiled.as_text()` (post-optimization, post-SPMD HLO) so shapes
are per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict

from repro.roofline.analysis import (
    _COLLECTIVES,
    _TRIP_RE,
    _instr_callees,
    _shape_bytes,
    parse_hlo,
)

_OPNAME_RE = re.compile(r'op_name="([^"]+)"')

# ops whose 'output' is bookkeeping, not data movement
_SKIP = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "while", "conditional", "call",
}


def _trip_counts(comps) -> dict[str, float]:
    trip_of: dict[str, float] = {}
    for instrs in comps.values():
        for ins in instrs:
            if ins["op"] == "while":
                t = _TRIP_RE.search(ins["line"])
                for callee in _instr_callees(ins):
                    trip_of[callee] = float(t.group(1)) if t else 1.0
    return trip_of


def _walk(comps, entry, visit_instr):
    """DFS over the call graph, multiplying while-body trip counts."""
    trip_of = _trip_counts(comps)
    stack: list[str] = []

    def visit(cname: str, mult: float):
        if cname not in comps or cname in stack:
            return
        stack.append(cname)
        for ins in comps[cname]:
            visit_instr(ins, mult)
            for callee in _instr_callees(ins):
                m2 = mult * trip_of.get(callee, 1.0) if ins["op"] == "while" else mult
                visit(callee, m2)
        stack.pop()

    if entry:
        visit(entry, 1.0)


def collective_breakdown(hlo_text: str, *, top: int = 20) -> list[dict]:
    """Trip-scaled collective bytes grouped by (op, shape, source op_name)."""
    parsed = parse_hlo(hlo_text)
    agg: dict[tuple, float] = defaultdict(float)

    def on_instr(ins, mult):
        op = ins["op"]
        if not op.startswith(_COLLECTIVES):
            return
        m = _OPNAME_RE.search(ins["line"])
        tag = m.group(1)[-80:] if m else "?"
        key = (op.split(".")[0], ins["shape"][:64], tag)
        factor = 2.0 if key[0] == "all-reduce" else 1.0
        agg[key] += mult * _shape_bytes(ins["shape"]) * factor

    _walk(parsed["comps"], parsed["entry"], on_instr)
    rows = [
        {"op": op, "shape": shape, "source": tag, "bytes": b}
        for (op, shape, tag), b in sorted(agg.items(), key=lambda kv: -kv[1])
    ]
    return rows[:top]


def phase_bytes(hlo_text: str, phases: dict[str, str]) -> dict[str, float]:
    """Trip-scaled output bytes per *phase*, where a phase is a regex matched
    against each instruction's op_name metadata (jax.named_scope tags land
    there after jit).  Unmatched instructions are billed to "other"; ops
    without op_name (bookkeeping fusions XLA synthesizes) too.

    Example — attribute the packed-QSGD wire cost inside a scanned round::

        phase_bytes(lowered.compile().as_text(),
                    {"encode": r"qsgd_encode", "decode": r"qsgd_decode"})
    """
    pats = {name: re.compile(p) for name, p in phases.items()}
    parsed = parse_hlo(hlo_text)
    agg: dict[str, float] = defaultdict(float)

    def on_instr(ins, mult):
        if ins["op"] in _SKIP:
            return
        b = mult * _shape_bytes(ins["shape"])
        m = _OPNAME_RE.search(ins["line"])
        tag = m.group(1) if m else ""
        for name, pat in pats.items():
            if pat.search(tag):
                agg[name] += b
                return
        agg["other"] += b

    _walk(parsed["comps"], parsed["entry"], on_instr)
    return dict(agg)


def top_output_bytes(hlo_text: str, *, top: int = 25) -> list[dict]:
    """Largest instructions by trip-scaled output bytes (HBM-traffic proxy).

    Caveats: dynamic-update-slice is counted at full-buffer size although the
    hardware writes only the slice; fusion-internal tensors never reach HBM.
    Use for *ranking* suspects, not absolute bytes.
    """
    parsed = parse_hlo(hlo_text)
    rows: list[tuple[float, dict]] = []

    def on_instr(ins, mult):
        if ins["op"] in _SKIP:
            return
        b = mult * _shape_bytes(ins["shape"])
        rows.append((b, {"op": ins["op"], "name": ins["name"],
                         "shape": ins["shape"][:64], "bytes": b}))

    _walk(parsed["comps"], parsed["entry"], on_instr)
    rows.sort(key=lambda r: -r[0])
    return [r for _, r in rows[:top]]
