"""Pure message-size formulas (no jax/repro imports — safe at any layer).

Shared by the channel abstraction (`repro.comm.channels`) and the ledger
(`repro.core.ledger`); both re-export them for back-compat.
"""
from __future__ import annotations

import math


def dense_message_bits(num_params: int, bits_per_param: int = 32) -> int:
    return num_params * bits_per_param


# itemsize * 8 of every dtype a dense wire may carry, kept jax-free so the
# ledger layer can price messages without importing jax (the engine-side
# mirror is repro.core.precision._SUPPORTED; a test pins the two in sync)
DTYPE_BITS = {
    "float32": 32,
    "bfloat16": 16,
    "float16": 16,
    "float8_e4m3fn": 8,
}


def dtype_bits(dtype: str) -> int:
    """Bits per parameter of a dense wire carrying `dtype` values."""
    try:
        return DTYPE_BITS[dtype]
    except KeyError:
        raise ValueError(
            f"no wire width for dtype {dtype!r} (choose {sorted(DTYPE_BITS)})"
        ) from None


def qsgd_code_bits(levels: int) -> int:
    """Bits per packed QSGD entry: the sign is folded into the code
    (c = q + s in [0, 2s]) so one entry costs ceil(log2(2s+1)) bits — equal,
    for every s >= 1, to the 1 sign bit + ceil(log2(s+1)) level-index bits the
    formula historically charged.  (Duplicated from `repro.kernels.ref` to
    keep this module jax-free; a test pins the two in sync.)"""
    return max(1, math.ceil(math.log2(2 * levels + 1)))


def qsgd_message_bits(num_params: int, levels: int, block: int = 1024) -> int:
    """Size of the *actual* packed QSGD wire message (Alistarh et al. 2017):
    ceil(n/block) blocks, each carrying block packed codes
    (ceil(log2(2s+1)) bits/entry, tail block zero-padded to full width) plus
    one f32 norm word.  This is exactly `payload.size * 32 + norms.size * 32`
    of the uint32 payload `qsgd_encode` emits for one flat n-vector."""
    n_blocks = max(1, math.ceil(num_params / block))
    return n_blocks * (qsgd_code_bits(levels) * block + 32)


def signsgd_message_bits(num_params: int, block: int = 1024) -> int:
    """1-bit sign-SGD wire size: 1 bit/entry (tail-padded) + one f32 scale
    per block."""
    n_blocks = max(1, math.ceil(num_params / block))
    return n_blocks * (block + 32)


def packed_wire_bits(leaf_sizes, code_bits: int, block: int = 1024) -> int:
    """Exact wire size of a multi-leaf packed message: blocks are laid out
    *per leaf* (padding-invariant block boundaries), so each leaf rounds up to
    whole blocks independently."""
    total = 0
    for n in leaf_sizes:
        total += max(1, math.ceil(n / block)) * (code_bits * block + 32)
    return total


def topk_message_bits(num_params: int, fraction: float, bits_per_param: int = 32) -> int:
    """Top-K sparse encoding: (index, value) pairs for the k survivors."""
    k = max(1, math.ceil(fraction * num_params))
    index_bits = max(1, math.ceil(math.log2(max(num_params, 2))))
    return k * (bits_per_param + index_bits)
