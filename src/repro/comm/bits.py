"""Pure message-size formulas (no jax/repro imports — safe at any layer).

Shared by the channel abstraction (`repro.comm.channels`) and the ledger
(`repro.core.ledger`); both re-export them for back-compat.
"""
from __future__ import annotations

import math


def dense_message_bits(num_params: int, bits_per_param: int = 32) -> int:
    return num_params * bits_per_param


def qsgd_message_bits(num_params: int, levels: int, block: int = 2048) -> int:
    """QSGD-encoded message size (Alistarh et al. 2017), per-block norm + per-entry
    sign + level index. levels = s quantization levels -> ceil(log2(s+1)) bits/entry,
    one f32 norm per block, one sign bit per entry.
    """
    level_bits = max(1, math.ceil(math.log2(levels + 1)))
    n_blocks = math.ceil(num_params / block)
    return num_params * (1 + level_bits) + n_blocks * 32


def topk_message_bits(num_params: int, fraction: float, bits_per_param: int = 32) -> int:
    """Top-K sparse encoding: (index, value) pairs for the k survivors."""
    k = max(1, math.ceil(fraction * num_params))
    index_bits = max(1, math.ceil(math.log2(max(num_params, 2))))
    return k * (bits_per_param + index_bits)
