"""Communication layer: the QSGD lossy channel (Pallas-backed) + bit accounting.

Re-exports the kernel wrappers so higher layers depend on `repro.comm`,
not on kernel internals.
"""
from repro.core.ledger import CommLedger, dense_message_bits, qsgd_message_bits
from repro.kernels.ops import (
    qsgd_compress_tree,
    qsgd_dequantize,
    qsgd_quantize,
    qsgd_roundtrip,
)

__all__ = [
    "CommLedger",
    "dense_message_bits",
    "qsgd_message_bits",
    "qsgd_compress_tree",
    "qsgd_dequantize",
    "qsgd_quantize",
    "qsgd_roundtrip",
]
