"""Communication layer: pluggable lossy channels (Pallas-backed QSGD, Top-K,
dense) + bit-exact accounting.

Re-exports the channel abstraction and kernel wrappers so higher layers
depend on `repro.comm`, not on kernel internals.
"""
from repro.comm.channels import (
    Channel,
    DenseChannel,
    QSGDChannel,
    SignSGDChannel,
    TopKChannel,
    channel_wire_bits,
    low_bit_channel,
    make_channel,
)
from repro.core.ledger import CommLedger, dense_message_bits, qsgd_message_bits
from repro.kernels.ops import (
    qsgd_compress_tree,
    qsgd_decode,
    qsgd_dequantize,
    qsgd_encode,
    qsgd_quantize,
    qsgd_roundtrip,
    signsgd_decode,
    signsgd_encode,
    topk_sparsify,
    topk_sparsify_tree,
)

__all__ = [
    "Channel",
    "DenseChannel",
    "QSGDChannel",
    "SignSGDChannel",
    "TopKChannel",
    "channel_wire_bits",
    "low_bit_channel",
    "make_channel",
    "CommLedger",
    "dense_message_bits",
    "qsgd_message_bits",
    "qsgd_compress_tree",
    "qsgd_decode",
    "qsgd_dequantize",
    "qsgd_encode",
    "qsgd_quantize",
    "qsgd_roundtrip",
    "signsgd_decode",
    "signsgd_encode",
    "topk_sparsify",
    "topk_sparsify_tree",
]
