"""Pluggable lossy communication channels.

A `Channel` owns BOTH sides of a message's cost model:
  * `compress(tree, key)` — the in-graph lossy transform a pytree message
    traverses (traceable under jit/vmap/scan, so the round engine can fuse it
    into the per-interaction loop);
  * `message_bits(num_params)` — the encoded size of one message, which is
    what `CommLedger` records.  Drivers never re-derive bit formulas.

Wire channels (QSGD, sign-SGD) additionally expose the split halves:
  * `encode(tree, key)` — sender side: per-leaf wire dicts
    `{"payload": uint32 (n_blocks, bits*block/32), "norms": f32 (n_blocks,)}`
    in leaf order.  The payload IS the cross-device value: its byte size is
    exactly `wire_bits(leaf_sizes) / 8`;
  * `decode(wires, like)` — receiver side, rebuilding `like`'s structure;
  * `wire_bits(leaf_sizes)` — the exact multi-leaf message size (blocks are
    per-leaf, so each leaf rounds up to whole blocks independently).
`compress` is exactly `decode ∘ encode` for these channels.

Channels are frozen dataclasses: hashable, so the engine can cache one
compiled round function per (model, channel) pair, and all quantization
hyper-parameters are static under jit.

`stochastic` tells the engine whether the channel consumes PRNG keys — the
drivers only advance their key chains for stochastic channels, which keeps
fixed-seed trajectories identical to the pre-engine implementations.

`per_message` declares how the channel treats a *stacked* uplink (the engine
hands it client deltas with a leading sender axis on every leaf): True means
each sender's message must be transformed independently (the engine vmaps
`compress` over that axis with per-sender `fold_in` keys).  Every lossy
channel here is per-message: QSGD/sign-SGD block boundaries are computed
per-leaf *within* one sender's message, so a sender's encoding can never
depend on how many other senders ride the same stacked uplink — that padding
invariance is what lets Fed-CHS+QSGD run under the whole-run scan on ragged
clusters.

Stochastic channels split their key per leaf internally (see
`qsgd_compress_tree`), so the historical bug class of reusing one subkey
across every layer of the model cannot reappear in a driver.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.comm.bits import (
    dense_message_bits,
    dtype_bits,
    packed_wire_bits,
    qsgd_code_bits,
    qsgd_message_bits,
    signsgd_message_bits,
    topk_message_bits,
)
from repro.kernels.ops import (
    DEFAULT_BLOCK,
    qsgd_compress_tree,
    qsgd_decode_tree,
    qsgd_encode_tree,
    signsgd_compress_tree,
    signsgd_decode,
    signsgd_encode,
    topk_sparsify_tree,
)

PyTree = Any


@runtime_checkable
class Channel(Protocol):
    """Lossy uplink abstraction: in-graph transform + bit accounting."""

    stochastic: bool
    per_message: bool

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        """Apply the lossy roundtrip (what the receiver decodes). Traceable."""
        ...

    def message_bits(self, num_params: int) -> int:
        """Encoded size in bits of one message of `num_params` parameters."""
        ...


def channel_wire_bits(channel: Channel, num_params: int, leaf_sizes=None) -> int:
    """The exact per-message bits a driver should put in the ledger: wire
    channels price the real multi-leaf payload (`wire_bits`); anything else
    falls back to the flat `message_bits` formula."""
    if leaf_sizes is not None and hasattr(channel, "wire_bits"):
        return channel.wire_bits(tuple(leaf_sizes))
    return channel.message_bits(num_params)


@dataclasses.dataclass(frozen=True)
class DenseChannel:
    """Uncompressed float transport.

    With the default ``wire_dtype=None`` the transform is the identity and
    `message_bits` prices `bits_per_param` per entry — byte-for-byte the
    historical dense channel.  Setting ``wire_dtype`` (e.g. ``"bfloat16"``
    from a `core.precision.Precision` policy) makes the wire real: `compress`
    round-trips every leaf through that dtype IN-GRAPH (so the lossy cast is
    part of the compiled round and `phase_bytes` sees the narrow tensors),
    `encode`/`decode`/`wire_bits` expose the exact payload the honesty test
    measures, and `bits_per_param` is overridden to the dtype's width — the
    ledger prices what actually travels, so a bf16 wire halves every dense
    message exactly."""

    bits_per_param: int = 32
    wire_dtype: str | None = None
    stochastic: bool = dataclasses.field(default=False, init=False)
    per_message: bool = dataclasses.field(default=False, init=False)

    def __post_init__(self):
        if self.wire_dtype is not None:
            # pricing follows the wire: the declared width is the dtype's
            object.__setattr__(self, "bits_per_param", dtype_bits(self.wire_dtype))

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        if self.wire_dtype is None:
            return tree
        wire = jnp.dtype(self.wire_dtype)
        with jax.named_scope("wire_cast"):
            return jax.tree.map(lambda a: a.astype(wire).astype(a.dtype), tree)

    def message_bits(self, num_params: int) -> int:
        return dense_message_bits(num_params, self.bits_per_param)

    # -- wire-channel surface (only meaningful with a wire_dtype; the f32
    # default is its own wire: encode is then a per-leaf identity) ----------

    def encode(self, tree: PyTree, key: jax.Array = None) -> list:
        wire = jnp.dtype(self.wire_dtype or "float32")
        with jax.named_scope("wire_encode"):
            return [{"payload": leaf.astype(wire)} for leaf in jax.tree.leaves(tree)]

    def decode(self, wires: list, like: PyTree) -> PyTree:
        leaves, treedef = jax.tree.flatten(like)
        with jax.named_scope("wire_decode"):
            return jax.tree.unflatten(
                treedef,
                [w["payload"].astype(leaf.dtype) for w, leaf in zip(wires, leaves)],
            )

    def wire_bits(self, leaf_sizes) -> int:
        return sum(n * self.bits_per_param for n in leaf_sizes)


@dataclasses.dataclass(frozen=True)
class QSGDChannel:
    """QSGD stochastic quantization (Alistarh et al., 2017), Pallas-backed,
    carrying the packed integer wire format in-graph.

    `levels` is the number of quantization levels s; `encode` emits, per leaf,
    a dense uint32 payload of ceil(log2(2s+1))-bit sign-folded codes plus a
    per-block f32 norm sidecar (fused quantize→pack kernel on TPU, vectorized
    jnp elsewhere).  levels=7 is the 4-bit variant, levels=1 the 2-bit
    (ternary) variant — see `low_bit_channel`.
    """

    levels: int = 16
    block: int = DEFAULT_BLOCK
    stochastic: bool = dataclasses.field(default=True, init=False)
    per_message: bool = dataclasses.field(default=True, init=False)

    def encode(self, tree: PyTree, key: jax.Array) -> list:
        return qsgd_encode_tree(tree, key, s=self.levels, block=self.block)

    def decode(self, wires: list, like: PyTree) -> PyTree:
        return qsgd_decode_tree(wires, like, s=self.levels, block=self.block)

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        return qsgd_compress_tree(tree, key, s=self.levels, block=self.block)

    def message_bits(self, num_params: int) -> int:
        return qsgd_message_bits(num_params, self.levels, self.block)

    def wire_bits(self, leaf_sizes) -> int:
        return packed_wire_bits(leaf_sizes, qsgd_code_bits(self.levels), self.block)


@dataclasses.dataclass(frozen=True)
class SignSGDChannel:
    """1-bit sign-SGD with per-block norm scaling (Bernstein et al., 2018):
    each entry travels as its sign bit, decoded as ±(mean |v| of its block).
    Deterministic — no PRNG — and per-message like QSGD; the payload packs 32
    entries per uint32 word with an f32 scale sidecar per block."""

    block: int = DEFAULT_BLOCK
    stochastic: bool = dataclasses.field(default=False, init=False)
    per_message: bool = dataclasses.field(default=True, init=False)

    def encode(self, tree: PyTree, key: jax.Array = None) -> list:
        leaves, _ = jax.tree.flatten(tree)
        return [signsgd_encode(leaf, block=self.block) for leaf in leaves]

    def decode(self, wires: list, like: PyTree) -> PyTree:
        leaves, treedef = jax.tree.flatten(like)
        out = [
            signsgd_decode(w, shape=tuple(leaf.shape), block=self.block).astype(leaf.dtype)
            for w, leaf in zip(wires, leaves)
        ]
        return jax.tree.unflatten(treedef, out)

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        return signsgd_compress_tree(tree, block=self.block)

    def message_bits(self, num_params: int) -> int:
        return signsgd_message_bits(num_params, self.block)

    def wire_bits(self, leaf_sizes) -> int:
        return packed_wire_bits(leaf_sizes, 1, self.block)


@dataclasses.dataclass(frozen=True)
class TopKChannel:
    """Deterministic magnitude Top-K sparsification.

    Keeps the ceil(fraction * d) largest-magnitude entries of the WHOLE
    message (all leaves flattened as one d-vector); the encoding is exactly k
    (index, value) pairs of ceil(log2(d)) + bits_per_param bits each, so
    `message_bits` is exact. Top-K selection couples entries, so the channel
    is `per_message`: the engine applies it to each sender's delta
    independently. Proof that the channel stack extends beyond the paper's
    QSGD arm.
    """

    fraction: float = 0.01
    bits_per_param: int = 32
    stochastic: bool = dataclasses.field(default=False, init=False)
    per_message: bool = dataclasses.field(default=True, init=False)

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        return topk_sparsify_tree(tree, fraction=self.fraction)

    def message_bits(self, num_params: int) -> int:
        return topk_message_bits(num_params, self.fraction, self.bits_per_param)


def make_channel(qsgd_levels: int | None, bits_per_param: int = 32) -> Channel:
    """Back-compat shim: the (qsgd_levels, bits_per_param) config pair every
    algorithm historically exposed, as a Channel."""
    if qsgd_levels is None:
        return DenseChannel(bits_per_param)
    return QSGDChannel(qsgd_levels)


def low_bit_channel(bits: int) -> Channel:
    """The low-bit channel family by wire width: 8/4/2-bit packed QSGD
    (s = 127 / 7 / 1 — the largest s whose sign-folded code fits) or the
    1-bit sign-SGD channel."""
    try:
        return {8: QSGDChannel(127), 4: QSGDChannel(7), 2: QSGDChannel(1),
                1: SignSGDChannel()}[bits]
    except KeyError:
        raise ValueError(f"no {bits}-bit channel (choose 1, 2, 4, or 8)") from None
