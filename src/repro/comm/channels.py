"""Pluggable lossy communication channels.

A `Channel` owns BOTH sides of a message's cost model:
  * `compress(tree, key)` — the in-graph lossy transform a pytree message
    traverses (traceable under jit/vmap/scan, so the round engine can fuse it
    into the per-interaction loop);
  * `message_bits(num_params)` — the encoded size of one message, which is
    what `CommLedger` records.  Drivers never re-derive bit formulas.

Channels are frozen dataclasses: hashable, so the engine can cache one
compiled round function per (model, channel) pair, and all quantization
hyper-parameters are static under jit.

`stochastic` tells the engine whether the channel consumes PRNG keys — the
drivers only advance their key chains for stochastic channels, which keeps
fixed-seed trajectories identical to the pre-engine implementations.

`per_message` declares how the channel treats a *stacked* uplink (the engine
hands it client deltas with a leading sender axis on every leaf): True means
each sender's message must be transformed independently (the engine vmaps
`compress` over that axis — required when the transform couples entries, like
Top-K selection), False means the whole stacked leaf may be transformed as
one vector (QSGD keeps the historical stacked-leaf semantics: its per-entry
quantization is sender-local anyway except at rare block boundaries, and
fixed-seed parity with the pre-engine drivers pins it).

Stochastic channels split their key per leaf internally (see
`qsgd_compress_tree`), so the historical bug class of reusing one subkey
across every layer of the model cannot reappear in a driver.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax

from repro.comm.bits import dense_message_bits, qsgd_message_bits, topk_message_bits
from repro.kernels.ops import qsgd_compress_tree, topk_sparsify_tree

PyTree = Any


@runtime_checkable
class Channel(Protocol):
    """Lossy uplink abstraction: in-graph transform + bit accounting."""

    stochastic: bool
    per_message: bool

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        """Apply the lossy roundtrip (what the receiver decodes). Traceable."""
        ...

    def message_bits(self, num_params: int) -> int:
        """Encoded size in bits of one message of `num_params` parameters."""
        ...


@dataclasses.dataclass(frozen=True)
class DenseChannel:
    """Uncompressed float transport — the identity transform."""

    bits_per_param: int = 32
    stochastic: bool = dataclasses.field(default=False, init=False)
    per_message: bool = dataclasses.field(default=False, init=False)

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        return tree

    def message_bits(self, num_params: int) -> int:
        return dense_message_bits(num_params, self.bits_per_param)


@dataclasses.dataclass(frozen=True)
class QSGDChannel:
    """QSGD stochastic quantization (Alistarh et al., 2017), Pallas-backed.

    `levels` is the number of quantization levels s; the roundtrip runs the
    TPU kernels in `repro.kernels.qsgd` leaf-wise with per-leaf PRNG keys.
    """

    levels: int = 16
    stochastic: bool = dataclasses.field(default=True, init=False)
    per_message: bool = dataclasses.field(default=False, init=False)

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        return qsgd_compress_tree(tree, key, s=self.levels)

    def message_bits(self, num_params: int) -> int:
        return qsgd_message_bits(num_params, self.levels)


@dataclasses.dataclass(frozen=True)
class TopKChannel:
    """Deterministic magnitude Top-K sparsification.

    Keeps the ceil(fraction * d) largest-magnitude entries of the WHOLE
    message (all leaves flattened as one d-vector); the encoding is exactly k
    (index, value) pairs of ceil(log2(d)) + bits_per_param bits each, so
    `message_bits` is exact. Top-K selection couples entries, so the channel
    is `per_message`: the engine applies it to each sender's delta
    independently. Proof that the channel stack extends beyond the paper's
    QSGD arm.
    """

    fraction: float = 0.01
    bits_per_param: int = 32
    stochastic: bool = dataclasses.field(default=False, init=False)
    per_message: bool = dataclasses.field(default=True, init=False)

    def compress(self, tree: PyTree, key: jax.Array) -> PyTree:
        return topk_sparsify_tree(tree, fraction=self.fraction)

    def message_bits(self, num_params: int) -> int:
        return topk_message_bits(num_params, self.fraction, self.bits_per_param)


def make_channel(qsgd_levels: int | None, bits_per_param: int = 32) -> Channel:
    """Back-compat shim: the (qsgd_levels, bits_per_param) config pair every
    algorithm historically exposed, as a Channel."""
    if qsgd_levels is None:
        return DenseChannel(bits_per_param)
    return QSGDChannel(qsgd_levels)
