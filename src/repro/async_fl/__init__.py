"""Async federation service: event-driven drivers where netsim arrival
traces decide what gets folded and when.

`run_async_fed_chs` is the tentpole — the paper's sequential ES->ES chain
made asynchronous with bounded-staleness buffers, HiFlash-style staleness
discounts, quorum/deadline fold triggers, and continuous crash-safe
checkpointing.  `run_async_fedavg` / `run_async_hier` are the classic
async-PS comparison arms (FedBuff / two-tier FedAsync) built from the same
kernels and the same network model.
"""
from repro.async_fl.arrivals import Dispatch, chain_arrival, dispatch_cohort, fire_time
from repro.async_fl.buffer import StalenessBuffer, Update, staleness_weight
from repro.async_fl.compute import client_updates_fn, fold_fn, stack_updates
from repro.async_fl.fed_chs import (
    AsyncFedCHSConfig,
    load_async_state,
    run_async_fed_chs,
    save_async_state,
)
from repro.async_fl.ps import AsyncPSConfig, run_async_fedavg, run_async_hier

__all__ = [
    "AsyncFedCHSConfig",
    "AsyncPSConfig",
    "Dispatch",
    "StalenessBuffer",
    "Update",
    "chain_arrival",
    "client_updates_fn",
    "dispatch_cohort",
    "fire_time",
    "fold_fn",
    "load_async_state",
    "run_async_fed_chs",
    "run_async_fedavg",
    "run_async_hier",
    "save_async_state",
    "stack_updates",
    "staleness_weight",
]
