"""The async drivers' two jitted kernels: local updates and weighted folds.

The synchronous engine fuses dispatch -> local train -> fold into one
barrier-round graph; the async event loop has to split them, because the
updates a fold consumes were computed at *different* times on *different*
model versions.  Both halves reuse the engine's building blocks verbatim —
`oracles.local_opt_steps` for the client step and `engine.compress_uplinks`
for per-sender channel keys — so a full-quorum, zero-staleness async fold
reproduces the synchronous `cluster_round` arithmetic (the anchor pinned in
tests/test_async_fl.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.comm.channels import Channel
from repro.core.engine import compress_uplinks, dummy_subs
from repro.core.oracles import local_opt_steps
from repro.models.fed import FedModel
from repro.optim.local import LocalOpt
from repro.utils import tree_add, tree_sub

PyTree = Any


@functools.cache
def client_updates_fn(model: FedModel, channel: Channel, opt: LocalOpt):
    """jit: (params, opt_state (n,...), batch (n,E,B,...), lrs (E,), sub) ->
    (deltas (n,...), new_opt (n,...), losses (n,)).

    Each of the n clients runs E local optimizer steps from the SAME
    broadcast params (exactly `engine._masked_round_body`'s interaction with
    J=1); the uploaded deltas traverse the channel with per-sender
    `fold_in(sub, slot)` keys (`compress_uplinks`), so compression is
    identical whether the cohort later folds together or one by one."""
    multi_local = jax.vmap(local_opt_steps(model, opt), in_axes=(None, 0, 0, None))

    def fn(params, opt_state, batch, lrs, sub):
        with jax.named_scope("local_train"):
            new_params, new_opt, losses = multi_local(params, opt_state, batch, lrs)
        deltas = jax.vmap(lambda np_: tree_sub(np_, params))(new_params)
        with jax.named_scope("uplink"):
            deltas = compress_uplinks(channel, deltas, sub)
        return deltas, new_opt, losses

    return jax.jit(fn)


@functools.cache
def fold_fn(model: FedModel):
    """jit: (params, deltas (j, ...), weights (j,)) -> params + sum_i w_i d_i.

    The einsum is the engine's aggregation expression; the async drivers
    supply renormalized staleness-discounted weights instead of the sync
    gammas."""
    del model  # cache key only — folds depend on the params structure alone

    def fn(params, deltas, weights):
        agg = jax.tree.map(
            lambda d: jnp.einsum("n,n...->...", weights, d), deltas
        )
        return tree_add(params, agg)

    return jax.jit(fn)


def stack_updates(deltas: list[PyTree]) -> PyTree:
    """Stack per-update delta pytrees along a new leading fold axis."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *deltas)


def no_subs(count: int = 1):
    """Placeholder per-dispatch key for non-stochastic channels."""
    return dummy_subs(count)[0] if count == 1 else dummy_subs(count)
