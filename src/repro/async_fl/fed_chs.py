"""Event-driven Fed-CHS: the ES->ES chain fires on quorum/deadline.

The synchronous driver advances one barrier round per ES visit; here the
netsim timeline *is* the control flow.  Activation a of the chain:

  1. The model lands at ES m(a) at simulated time t_a (the visit order is
     the paper's 2-step rule — time-free, so it is precomputed exactly as
     the sync scanned driver does).
  2. The ES broadcasts to the cluster members the availability trace says
     are up; each dispatched client's broadcast -> K-local-steps -> upload
     chain gets a deterministic arrival time from the `NetworkModel`
     (stragglers, heterogeneity, shared ingress all apply).
  3. The ES fires at `fire_time` — the quorum_frac-th arrival, capped by
     `deadline_s`.  On-time updates fold with staleness tau=0; late ones
     land in the ES's bounded `StalenessBuffer` and fold (HiFlash-style
     discounted by ``gamma * (1+tau)^(-alpha)``, tau in model versions)
     when the chain next visits this ES — or are evicted once they exceed
     `max_staleness`.
  4. One ES->ES hop to m(a+1); its transfer time advances the clock.

With AlwaysOn clients, quorum 1.0, no deadline and alpha arbitrary, every
fold is full-cohort at tau=0 and the arithmetic reproduces the synchronous
`run_fed_chs(local_epochs=K)` trajectory (tests/test_async_fl.py).

Continuous checkpointing: `checkpoint=` saves the COMPLETE run state at
every `checkpoint_every`-th activation boundary — params, per-cluster opt
stacks, buffered update deltas, the PRNG chain position, per-client data
draw counts, the simulated clock, ledger state and eval logs — via
`checkpoint.save_run_state`.  `resume=True` restores all of it, so a run
killed between two activations continues *bit-identical* to one that was
never interrupted (tests/test_resume_parity.py).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import numpy as np

from repro.async_fl.arrivals import dispatch_cohort, fire_time
from repro.async_fl.buffer import StalenessBuffer, Update, staleness_weight
from repro.async_fl.compute import client_updates_fn, fold_fn, no_subs, stack_updates
from repro.checkpoint.io import load_run_state, run_state_exists, save_run_state
from repro.comm.channels import Channel, DenseChannel, channel_wire_bits, make_channel
from repro.core.engine import split_chain
from repro.core.ledger import CommLedger
from repro.core.scheduler import FedCHSScheduler
from repro.core.simulation import FLTask, RunRecorder, RunResult
from repro.core.topology import make_topology
from repro.models.fed import as_fed_model
from repro.netsim.links import NetworkModel, edge_cloud_network
from repro.optim.local import LocalOpt, PlainSGD
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import AlwaysOn, AvailabilityTrace

PyTree = Any


@dataclasses.dataclass
class AsyncFedCHSConfig:
    rounds: int = 60                       # activations (ES visits)
    local_steps: int = 10                  # K local steps per dispatched update
    topology: str = "random_sparse"
    topology_seed: int = 0
    initial_cluster: int | None = None
    network: NetworkModel | None = None    # physical layer; default
                                           # edge_cloud_network()
    trace: AvailabilityTrace | None = None # per-(client, version) churn;
                                           # default AlwaysOn
    quorum_frac: float = 1.0               # fire at the ceil(frac*cohort)-th
                                           # arrival ...
    deadline_s: float | None = None        # ... capped by this wait (seconds)
    staleness_alpha: float = 0.5           # discount exponent (1+tau)^(-alpha)
    max_staleness: int | None = 8          # drop updates older than this many
                                           # model versions (None: unbounded)
    renormalize: bool = False              # True: fold weights sum to 1
                                           # (full-mass partial folds); False
                                           # keeps raw discounted gammas — the
                                           # sync-anchor-exact choice
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None
    channel: Channel | None = None
    local_opt: LocalOpt | None = None
    track_events: bool = True
    seed: int = 0
    schedule: Schedule | None = None       # local step k -> eta_k (the Eq.(5)
                                           # within-visit decay, as sync)
    checkpoint: str | None = None          # path prefix for continuous state
    checkpoint_every: int = 1              # activations between saves
    resume: bool = False                   # load the checkpoint if present
    on_checkpoint: Any = None              # service hook: called with the next
                                           # activation index after every save
                                           # (progress reporting; the serve
                                           # --federation kill switch)


@dataclasses.dataclass
class _AsyncState:
    """Everything the event loop carries across activations."""

    activation: int
    sim_time: float
    params: PyTree
    opt_states: dict            # cluster -> stacked (n_m, ...) opt pytree
    buffers: dict               # cluster -> StalenessBuffer
    key: jax.Array
    losses: Any                 # last fold's (j,) losses, or None
    ledger: CommLedger
    recorder: RunRecorder
    sim_eval_times: list
    draw_counts: list = dataclasses.field(default_factory=list)


def _resolve(config: AsyncFedCHSConfig):
    network = config.network or edge_cloud_network()
    trace = config.trace or AlwaysOn()
    channel = (
        config.channel
        if config.channel is not None
        else make_channel(config.qsgd_levels, config.bits_per_param)
    )
    opt = config.local_opt or PlainSGD()
    return network, trace, channel, opt


def _visit_order(task: FLTask, config: AsyncFedCHSConfig) -> np.ndarray:
    topo = make_topology(config.topology, task.num_clusters,
                         seed=config.topology_seed)
    rng = np.random.default_rng(config.seed)
    m0 = (
        int(rng.integers(task.num_clusters))
        if config.initial_cluster is None
        else config.initial_cluster
    )
    return FedCHSScheduler(topo, task.cluster_sizes, initial=m0).precompute(
        config.rounds + 1
    )


# --------------------------------------------------------------------------
# checkpoint plumbing
# --------------------------------------------------------------------------


def _state_arrays(state: _AsyncState) -> tuple[PyTree, dict]:
    pending_arrays: dict[str, PyTree] = {}
    pending_meta = []
    i = 0
    for m in sorted(state.buffers):
        for u in state.buffers[m].updates:
            k = f"u{i}"
            pending_arrays[k] = u.delta
            pending_meta.append({
                "key": k, "client": u.client, "cluster": u.cluster,
                "version": u.version, "arrival": u.arrival, "gamma": u.gamma,
            })
            i += 1
    arrays = {
        "params": state.params,
        "key": state.key,
        "opt": {str(m): s for m, s in state.opt_states.items()},
        "pending": pending_arrays,
    }
    meta = {
        "algo": "async_fed_chs",
        "activation": state.activation,
        "sim_time": state.sim_time,
        "pending": pending_meta,
        "dropped": {str(m): b.dropped for m, b in state.buffers.items()},
        "opt_clusters": sorted(state.opt_states),
        "ledger": state.ledger.state_dict(),
        "recorder": {
            "rounds": state.recorder.rounds_log,
            "acc": state.recorder.acc_log,
            "loss": state.recorder.loss_log,
            "sim": state.sim_eval_times,
        },
        "losses_shape": None if state.losses is None
        else list(np.shape(state.losses)),
    }
    if state.losses is not None:
        arrays["losses"] = state.losses
    return arrays, meta


def save_async_state(path: str, state: _AsyncState) -> None:
    arrays, meta = _state_arrays(state)
    meta["draw_counts"] = list(state.draw_counts)
    save_run_state(path, arrays, meta)


def load_async_state(path: str, task: FLTask, config: AsyncFedCHSConfig,
                      engine_like) -> _AsyncState:
    """Rebuild the full event-loop state from a `save_run_state` checkpoint.

    The meta sidecar is read first: it names the pending-update keys and the
    visited clusters, which is what lets us construct the `like` structure
    `load_pytree` verifies the arrays against."""
    params0, init_opt = engine_like
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    like = {
        "params": params0,
        "key": jax.random.PRNGKey(0),
        "opt": {
            str(m): init_opt(params0, len(task.cluster_members[int(m)]))
            for m in meta["opt_clusters"]
        },
        "pending": {p["key"]: params0 for p in meta["pending"]},
    }
    if meta["losses_shape"] is not None:
        like["losses"] = np.zeros(meta["losses_shape"], np.float32)
    arrays, meta = load_run_state(path, like)

    buffers: dict[int, StalenessBuffer] = {}
    for p in meta["pending"]:
        m = int(p["cluster"])
        buffers.setdefault(
            m, StalenessBuffer(max_staleness=config.max_staleness)
        ).add(Update(
            client=int(p["client"]), cluster=m, version=int(p["version"]),
            arrival=float(p["arrival"]), gamma=float(p["gamma"]),
            delta=arrays["pending"][p["key"]],
        ))
    for m_s, n in meta["dropped"].items():
        buffers.setdefault(
            int(m_s), StalenessBuffer(max_staleness=config.max_staleness)
        ).dropped = int(n)

    ledger = CommLedger(track_events=config.track_events)
    ledger.load_state(meta["ledger"])
    recorder = RunRecorder(task, config.rounds, config.eval_every)
    recorder.rounds_log = list(meta["recorder"]["rounds"])
    recorder.acc_log = list(meta["recorder"]["acc"])
    recorder.loss_log = list(meta["recorder"]["loss"])

    task.source.fast_forward(meta["draw_counts"])

    state = _AsyncState(
        activation=int(meta["activation"]),
        sim_time=float(meta["sim_time"]),
        params=arrays["params"],
        opt_states={int(m): s for m, s in arrays["opt"].items()},
        buffers=buffers,
        key=arrays["key"],
        losses=arrays.get("losses"),
        ledger=ledger,
        recorder=recorder,
        sim_eval_times=list(meta["recorder"]["sim"]),
    )
    return state


# --------------------------------------------------------------------------
# the event loop
# --------------------------------------------------------------------------


def run_async_fed_chs(task: FLTask, config: AsyncFedCHSConfig) -> RunResult:
    network, trace, channel, opt = _resolve(config)
    model = as_fed_model(task.model)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)

    ms = _visit_order(task, config)
    d = task.num_params()
    down_bits = DenseChannel(config.bits_per_param).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())
    updates = client_updates_fn(model, channel, opt)
    fold = fold_fn(model)

    def init_opt(params, n):
        state = opt.init(params)
        return jax.tree.map(
            lambda leaf: jax.numpy.broadcast_to(leaf[None], (n,) + leaf.shape),
            state,
        )

    task.reset_loaders(config.seed)
    if config.resume and config.checkpoint and run_state_exists(config.checkpoint):
        state = load_async_state(
            config.checkpoint, task, config, (task.init_params(), init_opt)
        )
    else:
        state = _AsyncState(
            activation=0,
            sim_time=0.0,
            params=task.init_params(),
            opt_states={},
            buffers={},
            key=jax.random.PRNGKey(config.seed + 1),
            losses=None,
            ledger=CommLedger(track_events=config.track_events),
            recorder=RunRecorder(task, config.rounds, config.eval_every),
            sim_eval_times=[],
        )

    ledger, recorder = state.ledger, state.recorder
    for a in range(state.activation, config.rounds):
        m = int(ms[a])
        members = task.cluster_members[m]
        es = f"es:{m}"
        gammas = task.cluster_weights(m)  # float32, member order
        buf = state.buffers.setdefault(
            m, StalenessBuffer(max_staleness=config.max_staleness)
        )

        # stale evictions: the bits were spent; meter them at their terminal
        # staleness so the histogram records what bounded staleness discarded
        for u in buf.evict_stale(a):
            ledger.record("client_to_es", up_bits, round=a, phase=1,
                          sender=f"client:{u.client}", receiver=f"es:{u.cluster}",
                          staleness=a - u.version)

        # dispatch this activation's cohort (availability probed at version a)
        dispatches = dispatch_cohort(
            network, trace, server=es, cluster=m, members=list(members),
            version=a, start=state.sim_time, down_bits=down_bits,
            up_bits=up_bits, num_params=d, batch_size=task.batch_size,
            local_steps=K,
        )
        cohort = [dsp.client for dsp in dispatches]
        cohort_updates: list[Update] = []
        if cohort:
            slots = [members.index(i) for i in cohort]
            # stage K draws per dispatched client, member order — clients
            # that are asleep consume nothing (their stream doesn't advance)
            per_client = [task.sample_client_batches(i, K) for i in cohort]
            batch = jax.tree.map(lambda *ls: jax.numpy.stack(ls), *per_client)
            if m not in state.opt_states:
                state.opt_states[m] = init_opt(state.params, len(members))
            opt_rows = jax.tree.map(
                lambda l: l[np.asarray(slots)], state.opt_states[m]
            )
            sub = no_subs()
            if channel.stochastic:
                state.key, subs = split_chain(state.key, 1)
                sub = subs[0]
            deltas, new_opt, losses = updates(
                state.params, opt_rows, batch, jax.numpy.asarray(lrs), sub
            )
            state.opt_states[m] = jax.tree.map(
                lambda l, ns: l.at[np.asarray(slots)].set(ns),
                state.opt_states[m], new_opt,
            )
            state.losses = losses
            for j, dsp in enumerate(dispatches):
                cohort_updates.append(Update(
                    client=dsp.client, cluster=m, version=a,
                    arrival=dsp.arrival, gamma=float(gammas[slots[j]]),
                    delta=jax.tree.map(lambda l, j=j: l[j], deltas),
                ))
            for dsp in dispatches:
                ledger.record("es_to_client", down_bits, round=a, phase=0,
                              sender=es, receiver=f"client:{dsp.client}")

        t_fire = fire_time(dispatches, quorum_frac=config.quorum_frac,
                           deadline_s=config.deadline_s, start=state.sim_time)

        folded = buf.take_arrived(t_fire)
        for u in cohort_updates:
            (folded if u.arrival <= t_fire else buf.updates).append(u)
        folded.sort(key=lambda u: (u.version, u.arrival, u.client))

        if folded:
            w = np.asarray(
                [staleness_weight(u.gamma, a - u.version, config.staleness_alpha)
                 for u in folded],
                np.float32,
            )
            if config.renormalize:
                w = w / w.sum()
            state.params = fold(
                state.params, stack_updates([u.delta for u in folded]),
                jax.numpy.asarray(w),
            )
            for u in folded:
                ledger.record("client_to_es", up_bits, round=a, phase=1,
                              sender=f"client:{u.client}", receiver=es,
                              staleness=a - u.version)

        # ES -> ES hop: the chain moves on at the fire time
        nxt = int(ms[a + 1])
        hop_s = network.transfer_time("es_to_es", es, f"es:{nxt}", down_bits,
                                      round_idx=a, phase=2)
        ledger.record("es_to_es", down_bits, round=a, phase=2,
                      sender=es, receiver=f"es:{nxt}")
        ledger.snapshot(a)
        state.sim_time = t_fire + hop_s

        if recorder.should_eval(a):
            state.sim_eval_times.append(t_fire)
        recorder.record(a, state.params, state.losses)

        state.activation = a + 1
        if config.checkpoint and (a + 1) % config.checkpoint_every == 0:
            state.draw_counts = list(task.source.draw_counts)
            save_async_state(config.checkpoint, state)
            if config.on_checkpoint is not None:
                config.on_checkpoint(a + 1)

    res = recorder.result("async_fed_chs", ledger, state.params)
    return dataclasses.replace(res, sim_times=list(state.sim_eval_times))
