"""Classic async parameter-server baselines: FedBuff FedAvg + two-tier Hier.

These give the event-driven Fed-CHS chain its comparison arms:

  * `run_async_fedavg` — one PS, FedBuff aggregation: clients continuously
    compute on whatever model version they last received; the PS buffers
    arriving updates and folds every `quorum_k` of them with
    staleness-discounted weights, then re-dispatches the folded clients.
  * `run_async_hier` — the 3-tier analogue: each ES runs a FedBuff over its
    cluster (wireless hops), and every ES-level fold is pushed to the PS
    over the WAN, folded FedAsync-style (immediately, staleness-discounted)
    into the global model, which returns to that ES for its next cohort.

Both share the Fed-CHS drivers' kernels (`compute.client_updates_fn`,
`compute.fold_fn`) and the netsim arrival machinery, so the comparison in
`benchmarks/fig_async.py` is apples-to-apples: same local step, same
channel accounting, same physical network, same availability churn.
PS-variant folds renormalize their weights to unit mass by default (the
FedBuff convention — a partial buffer still takes a full-size step).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any

import jax
import numpy as np

from repro.async_fl.arrivals import chain_arrival
from repro.async_fl.buffer import StalenessBuffer, Update, staleness_weight
from repro.async_fl.compute import client_updates_fn, fold_fn, no_subs, stack_updates
from repro.comm.channels import Channel, DenseChannel, channel_wire_bits, make_channel
from repro.core.engine import split_chain
from repro.core.ledger import CommLedger
from repro.core.simulation import FLTask, RunRecorder, RunResult
from repro.models.fed import as_fed_model
from repro.netsim.links import NetworkModel, edge_cloud_network, sgd_step_flops
from repro.optim.local import LocalOpt, PlainSGD
from repro.optim.schedules import Schedule, paper_sqrt_schedule
from repro.part import AlwaysOn, AvailabilityTrace

PyTree = Any


@dataclasses.dataclass
class AsyncPSConfig:
    rounds: int = 60                       # PS folds
    local_steps: int = 10
    quorum_k: int = 4                      # buffer size that triggers a fold
    staleness_alpha: float = 0.5
    max_staleness: int | None = 8
    renormalize: bool = True               # FedBuff convention: unit-mass folds
    server_lr: float = 1.0                 # scale on each folded aggregate
    network: NetworkModel | None = None
    trace: AvailabilityTrace | None = None
    eval_every: int = 10
    bits_per_param: int = 32
    qsgd_levels: int | None = None
    channel: Channel | None = None
    local_opt: LocalOpt | None = None
    track_events: bool = True
    seed: int = 0
    schedule: Schedule | None = None


def _common(task: FLTask, config: AsyncPSConfig):
    network = config.network or edge_cloud_network()
    trace = config.trace or AlwaysOn()
    channel = (
        config.channel
        if config.channel is not None
        else make_channel(config.qsgd_levels, config.bits_per_param)
    )
    opt = config.local_opt or PlainSGD()
    model = as_fed_model(task.model)
    K = config.local_steps
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)
    d = task.num_params()
    down_bits = DenseChannel(config.bits_per_param).message_bits(d)
    up_bits = channel_wire_bits(channel, d, task.param_leaf_sizes())
    return network, trace, channel, opt, model, lrs, d, down_bits, up_bits


def _fold_weights(folded: list[Update], version: int, config: AsyncPSConfig):
    w = np.asarray(
        [staleness_weight(u.gamma, version - u.version, config.staleness_alpha)
         for u in folded],
        np.float32,
    )
    if config.renormalize:
        w = w / w.sum()
    return jax.numpy.asarray(config.server_lr * w)


def run_async_fedavg(task: FLTask, config: AsyncPSConfig) -> RunResult:
    """Single-PS FedBuff: fold every `quorum_k` arrivals, redispatch."""
    (network, trace, channel, opt, model, lrs, d,
     down_bits, up_bits) = _common(task, config)
    updates = client_updates_fn(model, channel, opt)
    fold = fold_fn(model)
    task.reset_loaders(config.seed)

    params = task.init_params()
    N = task.num_clients
    gammas = task.global_weights()
    opt_state = None
    key = jax.random.PRNGKey(config.seed + 1)
    ledger = CommLedger(track_events=config.track_events)
    recorder = RunRecorder(task, config.rounds, config.eval_every)
    sim_eval_times: list[float] = []
    flops = config.local_steps * sgd_step_flops(d, task.batch_size)

    heap: list[tuple[float, int, Update]] = []   # (arrival, client, update)
    buf = StalenessBuffer(max_staleness=config.max_staleness)
    idle = list(range(N))
    version, wave, now = 0, 0, 0.0
    losses = None

    def dispatch(now: float):
        """Send the current model to every idle+available client; their
        updates (computed on `params` at `version`) enter the arrival heap."""
        nonlocal idle, opt_state, key, losses
        up = [i for i in idle if trace.available(i, wave)]
        if not up:
            return
        idle = [i for i in idle if i not in up]
        per_client = [task.sample_client_batches(i, config.local_steps)
                      for i in up]
        batch = jax.tree.map(lambda *ls: jax.numpy.stack(ls), *per_client)
        if opt_state is None:
            state0 = opt.init(params)
            opt_state = jax.tree.map(
                lambda leaf: jax.numpy.broadcast_to(leaf[None], (N,) + leaf.shape),
                state0,
            )
        rows = jax.tree.map(lambda l: l[np.asarray(up)], opt_state)
        sub = no_subs()
        if channel.stochastic:
            key, subs = split_chain(key, 1)
            sub = subs[0]
        deltas, new_opt, ls = updates(params, rows, batch,
                                      jax.numpy.asarray(lrs), sub)
        opt_state = jax.tree.map(
            lambda l, ns: l.at[np.asarray(up)].set(ns), opt_state, new_opt
        )
        losses = ls
        for j, i in enumerate(up):
            arrival = chain_arrival(
                network, server="ps", client=i, down_hop="ps_to_client",
                up_hop="client_to_ps", start=now, down_bits=down_bits,
                up_bits=up_bits, flops=flops, round_idx=wave, fan_in=len(up),
            )
            ledger.record("ps_to_client", down_bits, round=version, phase=0,
                          sender="ps", receiver=f"client:{i}")
            heapq.heappush(heap, (arrival, i, Update(
                client=i, cluster=0, version=version, arrival=arrival,
                gamma=float(gammas[i]),
                delta=jax.tree.map(lambda l, j=j: l[j], deltas),
            )))

    dispatch(now)
    for v in range(config.rounds):
        # drain arrivals until the buffer hits quorum (or nothing is left
        # in flight — then fold what we have; re-probe churned-out clients)
        while len(buf) < config.quorum_k:
            if not heap:
                if len(buf) > 0:
                    break
                wave += 1
                dispatch(now)
                if not heap:
                    wave += 1
                    continue
            t, _, u = heapq.heappop(heap)
            now = max(now, t)
            buf.add(u)

        for u in buf.evict_stale(version):
            ledger.record("client_to_ps", up_bits, round=version, phase=1,
                          sender=f"client:{u.client}", receiver="ps",
                          staleness=version - u.version)
        folded = buf.take()
        if folded:
            w = _fold_weights(folded, version, config)
            params = fold(params, stack_updates([u.delta for u in folded]), w)
            for u in folded:
                ledger.record("client_to_ps", up_bits, round=version, phase=1,
                              sender=f"client:{u.client}", receiver="ps",
                              staleness=version - u.version)
            idle.extend(sorted(u.client for u in folded))
        version += 1
        wave += 1
        ledger.snapshot(v)
        if recorder.should_eval(v):
            sim_eval_times.append(now)
        recorder.record(v, params, losses)
        dispatch(now)

    res = recorder.result("async_fedavg", ledger, params)
    return dataclasses.replace(res, sim_times=sim_eval_times)


def run_async_hier(task: FLTask, config: AsyncPSConfig) -> RunResult:
    """Two-tier async HFL: per-ES FedBuff + FedAsync ES->PS folds.

    Each ES keeps its own model copy (the PS model it last received, tagged
    with the PS version) and runs a FedBuff over its cluster; every
    `quorum_k`-sized ES fold produces one aggregated cluster delta that
    rides the WAN to the PS, folds immediately (staleness = PS folds since
    that ES last synced), and the refreshed global model returns to the ES.
    """
    (network, trace, channel, opt, model, lrs, d,
     down_bits, up_bits) = _common(task, config)
    updates = client_updates_fn(model, channel, opt)
    fold = fold_fn(model)
    task.reset_loaders(config.seed)

    params = task.init_params()          # PS model
    M = task.num_clusters
    key = jax.random.PRNGKey(config.seed + 1)
    ledger = CommLedger(track_events=config.track_events)
    recorder = RunRecorder(task, config.rounds, config.eval_every)
    sim_eval_times: list[float] = []
    flops = config.local_steps * sgd_step_flops(d, task.batch_size)

    es_model = [params for _ in range(M)]
    es_version = [0] * M                  # PS version each ES's model carries
    es_buf = [StalenessBuffer(max_staleness=config.max_staleness)
              for _ in range(M)]
    es_folds = [0] * M                    # local fold counter per ES
    opt_states: dict[int, PyTree] = {}
    idle = {m: list(task.cluster_members[m]) for m in range(M)}
    heap: list[tuple[float, int, int, Update]] = []  # (arrival, m, client, u)
    ps_version, wave, now = 0, 0, 0.0
    losses = None

    def dispatch(m: int, now: float):
        nonlocal key, losses
        members = task.cluster_members[m]
        up = [i for i in idle[m] if trace.available(i, wave)]
        if not up:
            return
        idle[m] = [i for i in idle[m] if i not in up]
        gammas = task.cluster_weights(m)
        slots = [members.index(i) for i in up]
        per_client = [task.sample_client_batches(i, config.local_steps)
                      for i in up]
        batch = jax.tree.map(lambda *ls: jax.numpy.stack(ls), *per_client)
        if m not in opt_states:
            state0 = opt.init(es_model[m])
            opt_states[m] = jax.tree.map(
                lambda leaf: jax.numpy.broadcast_to(
                    leaf[None], (len(members),) + leaf.shape),
                state0,
            )
        rows = jax.tree.map(lambda l: l[np.asarray(slots)], opt_states[m])
        sub = no_subs()
        if channel.stochastic:
            key, subs = split_chain(key, 1)
            sub = subs[0]
        deltas, new_opt, ls = updates(es_model[m], rows, batch,
                                      jax.numpy.asarray(lrs), sub)
        opt_states[m] = jax.tree.map(
            lambda l, ns: l.at[np.asarray(slots)].set(ns), opt_states[m], new_opt
        )
        losses = ls
        for j, i in enumerate(up):
            arrival = chain_arrival(
                network, server=f"es:{m}", client=i, down_hop="es_to_client",
                up_hop="client_to_es", start=now, down_bits=down_bits,
                up_bits=up_bits, flops=flops, round_idx=wave, fan_in=len(up),
            )
            ledger.record("es_to_client", down_bits, round=ps_version, phase=0,
                          sender=f"es:{m}", receiver=f"client:{i}")
            heapq.heappush(heap, (arrival, m, i, Update(
                client=i, cluster=m, version=es_folds[m], arrival=arrival,
                gamma=float(gammas[slots[j]]),
                delta=jax.tree.map(lambda l, j=j: l[j], deltas),
            )))

    for m in range(M):
        dispatch(m, now)

    for v in range(config.rounds):
        # advance client arrivals until SOME ES reaches its quorum
        fired_m = None
        while fired_m is None:
            if not heap:
                wave += 1
                ready = [m for m in range(M) if len(es_buf[m]) > 0]
                if ready:
                    fired_m = min(ready, key=lambda m: -len(es_buf[m]))
                    break
                for m in range(M):
                    dispatch(m, now)
                if not heap:
                    continue
            t, m, _, u = heapq.heappop(heap)
            now = max(now, t)
            es_buf[m].add(u)
            if len(es_buf[m]) >= config.quorum_k:
                fired_m = m
        m = fired_m

        for u in es_buf[m].evict_stale(es_folds[m]):
            ledger.record("client_to_es", up_bits, round=ps_version, phase=1,
                          sender=f"client:{u.client}", receiver=f"es:{m}",
                          staleness=es_folds[m] - u.version)
        folded = es_buf[m].take()
        if folded:
            w = np.asarray(
                [staleness_weight(u.gamma, es_folds[m] - u.version,
                                  config.staleness_alpha) for u in folded],
                np.float32,
            )
            if config.renormalize:
                w = w / w.sum()
            agg = stack_updates([u.delta for u in folded])
            cluster_delta = jax.tree.map(
                lambda dl: jax.numpy.einsum("n,n...->...",
                                            jax.numpy.asarray(w), dl), agg
            )
            for u in folded:
                ledger.record("client_to_es", up_bits, round=ps_version, phase=1,
                              sender=f"client:{u.client}", receiver=f"es:{m}",
                              staleness=es_folds[m] - u.version)
            idle[m].extend(sorted(u.client for u in folded))
            es_folds[m] += 1

            # ES -> PS (WAN), FedAsync: fold on arrival with PS staleness
            t_up = now + network.transfer_time(
                "es_to_ps", f"es:{m}", "ps", up_bits, round_idx=ps_version,
                phase=2,
            )
            now = t_up
            tau_ps = ps_version - es_version[m]
            w_ps = staleness_weight(1.0, tau_ps, config.staleness_alpha)
            params = fold(
                params,
                jax.tree.map(lambda l: l[None], cluster_delta),
                jax.numpy.asarray([config.server_lr * w_ps], np.float32),
            )
            ledger.record("es_to_ps", up_bits, round=ps_version, phase=2,
                          sender=f"es:{m}", receiver="ps", staleness=tau_ps)
            # PS -> ES: the refreshed model returns; the ES adopts it
            now += network.transfer_time(
                "ps_to_es", "ps", f"es:{m}", down_bits, round_idx=ps_version,
                phase=3,
            )
            ledger.record("ps_to_es", down_bits, round=ps_version, phase=3,
                          sender="ps", receiver=f"es:{m}")
            ps_version += 1
            es_model[m] = params
            es_version[m] = ps_version
        wave += 1
        ledger.snapshot(v)
        if recorder.should_eval(v):
            sim_eval_times.append(now)
        recorder.record(v, params, losses)
        dispatch(m, now)

    res = recorder.result("async_hier", ledger, params)
    return dataclasses.replace(res, sim_times=sim_eval_times)
