"""Arrival-event generation: netsim traces *drive* execution.

The synchronous stack runs barrier rounds and lets `repro.netsim` re-time
them after the fact (`adapters.replay_run`).  The async drivers invert that:
for every activation the dispatcher asks the availability trace who is up,
asks the `NetworkModel` how long each client's broadcast -> local-compute ->
upload chain takes, and the resulting *arrival times* decide what the
aggregator folds and when it fires.  Everything here is a pure function of
``(network seed, trace seed, ids, bits, activation)`` — no drawn state — so
a resumed run recomputes the exact timeline it was killed under (the
property the kill-and-resume parity tests pin).
"""
from __future__ import annotations

import dataclasses
import math

from repro.netsim.links import NetworkModel, sgd_step_flops
from repro.part import AvailabilityTrace


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """One client's scheduled update: dispatched at `start`, update in
    flight until `arrival` (absolute simulated seconds)."""

    client: int
    cluster: int
    version: int       # model version (global fold count) it computes on
    start: float       # broadcast begins
    arrival: float     # upload fully received by the aggregator


def chain_arrival(
    network: NetworkModel,
    *,
    server: str,
    client: int,
    down_hop: str,
    up_hop: str,
    start: float,
    down_bits: int,
    up_bits: int,
    flops: float,
    round_idx: int,
    fan_in: int = 1,
) -> float:
    """Absolute arrival time of one broadcast -> compute -> upload chain."""
    c = f"client:{client}"
    t = start
    t += network.transfer_time(down_hop, server, c, down_bits,
                               round_idx=round_idx, phase=0)
    t += network.compute_time(c, flops, round_idx=round_idx)
    t += network.transfer_time(up_hop, c, server, up_bits,
                               round_idx=round_idx, phase=1, fan_in=fan_in)
    return t


def dispatch_cohort(
    network: NetworkModel,
    trace: AvailabilityTrace,
    *,
    server: str,
    cluster: int,
    members: list[int],
    version: int,
    start: float,
    down_bits: int,
    up_bits: int,
    num_params: int,
    batch_size: int,
    local_steps: int,
    down_hop: str = "es_to_client",
    up_hop: str = "client_to_es",
) -> list[Dispatch]:
    """Broadcast to every *available* member and schedule their arrivals.

    Availability is probed at (client, version): a device asleep when the
    model lands at its ES simply isn't dispatched this activation — it costs
    no draws, no bits, no waiting.  `fan_in` is the cohort size, so under
    `shared_ingress` the concurrent uploads split the server's bandwidth
    (the PS-bottleneck model the async PS baselines inherit)."""
    up = [i for i in members if trace.available(i, version)]
    flops = local_steps * sgd_step_flops(num_params, batch_size)
    return [
        Dispatch(
            client=i,
            cluster=cluster,
            version=version,
            start=start,
            arrival=chain_arrival(
                network, server=server, client=i, down_hop=down_hop,
                up_hop=up_hop, start=start, down_bits=down_bits,
                up_bits=up_bits, flops=flops, round_idx=version,
                fan_in=len(up),
            ),
        )
        for i in up
    ]


def fire_time(
    dispatches: list[Dispatch], *, quorum_frac: float, deadline_s: float | None,
    start: float,
) -> float:
    """When the aggregator stops waiting: the q-th arrival (q = ceil(frac *
    cohort)) capped by `start + deadline_s`.  An empty cohort fires at the
    deadline (or immediately without one) — the pass-through activation."""
    cap = float("inf") if deadline_s is None else start + deadline_s
    if not dispatches:
        return start if deadline_s is None else cap
    q = min(max(1, math.ceil(len(dispatches) * quorum_frac)), len(dispatches))
    arrivals = sorted(d.arrival for d in dispatches)
    return min(arrivals[q - 1], cap)
