"""Bounded-staleness update buffers + HiFlash-style staleness discounts.

An `Update` is one client's post-channel delta pytree tagged with the model
version it was computed on; `staleness_weight` is the polynomial discount
``gamma * (1 + tau)^(-alpha)`` (HiFlash's adaptive staleness control uses an
inverse-polynomial family; alpha=0 recovers undiscounted FedBuff).  A
`StalenessBuffer` holds the updates an aggregator has received but not yet
folded, and evicts anything older than `max_staleness` model versions — the
bounded-staleness guarantee that keeps a long-dead straggler from dragging
the model backwards.
"""
from __future__ import annotations

import dataclasses
from typing import Any

PyTree = Any


@dataclasses.dataclass
class Update:
    """One arrived client update, waiting in an aggregator's buffer."""

    client: int
    cluster: int
    version: int      # model version the delta was computed on
    arrival: float    # simulated seconds at which the upload completed
    gamma: float      # data-size base weight (within its cluster)
    delta: PyTree     # post-channel delta, same structure as params


def staleness_weight(gamma: float, tau: int, alpha: float) -> float:
    """``gamma * (1 + tau)^(-alpha)`` — tau is in model versions (folds)."""
    assert tau >= 0
    return gamma * (1.0 + tau) ** (-alpha)


@dataclasses.dataclass
class StalenessBuffer:
    """Arrived-but-unfolded updates with bounded staleness."""

    max_staleness: int | None = None  # None: unbounded
    updates: list[Update] = dataclasses.field(default_factory=list)
    dropped: int = 0  # evicted for exceeding the staleness bound

    def add(self, u: Update) -> None:
        self.updates.append(u)

    def __len__(self) -> int:
        return len(self.updates)

    def evict_stale(self, current_version: int) -> list[Update]:
        """Drop updates whose staleness at the *next* fold would exceed the
        bound; returns the evicted updates (their bits were still spent —
        the caller meters them with their terminal staleness)."""
        if self.max_staleness is None:
            return []
        keep, out = [], []
        for u in self.updates:
            if current_version - u.version > self.max_staleness:
                out.append(u)
            else:
                keep.append(u)
        self.updates = keep
        self.dropped += len(out)
        return out

    def take(self) -> list[Update]:
        """Drain every buffered update, oldest version first (ties by
        arrival, then client id — a total order, so folds are deterministic
        regardless of insertion order)."""
        out = sorted(self.updates, key=lambda u: (u.version, u.arrival, u.client))
        self.updates = []
        return out

    def take_arrived(self, now: float) -> list[Update]:
        """Drain only the updates that have fully arrived by `now`."""
        ready = [u for u in self.updates if u.arrival <= now]
        self.updates = [u for u in self.updates if u.arrival > now]
        return sorted(ready, key=lambda u: (u.version, u.arrival, u.client))
