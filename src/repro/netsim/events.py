"""Deterministic event-driven simulator: job DAGs + serial resources -> timestamps.

The adapters (repro/netsim/adapters.py) compile a training run's `CommEvent`
stream into `Job`s — compute jobs pinned to a node, transfer jobs pinned to a
directed link — wired by explicit dependencies that encode each algorithm's
barrier structure:

  * Fed-CHS     — interaction barriers inside the active cluster, then ONE
                  ES->ES transfer the whole next round depends on: the serial
                  chain emerges from the DAG, it is not special-cased.
  * FedAvg      — all clients' (download, compute, upload) chains share only
                  the per-round PS barrier: the round costs the max over
                  parallel clients, again purely from the DAG.
  * Hier-Local-QSGD — two barrier levels: per-cluster interaction barriers,
                  then the PS waits on every ES upload before broadcasting.
  * WRWGD       — a pure chain (compute, hop, compute, hop, ...).

Execution model (classic list scheduling):
  start(job)  = max(finish(dep) for dep in deps, availability(resource))
  finish(job) = start(job) + duration
Each resource (a node, or a directed link) carries one job at a time, FIFO in
ready order; ties broken by job id — so the timeline is a pure function of
the job list.  Durations come from `links.NetworkModel`, which is itself
deterministic given (seed, message) — the whole pipeline satisfies the
"identical event timelines for identical (seed, config)" contract.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Sequence

__all__ = ["Job", "JobTimes", "Timeline", "simulate"]


@dataclasses.dataclass(frozen=True)
class Job:
    """One unit of simulated work.

    `resource` serializes execution (node name for compute, "a->b" for a
    directed link, None for zero-cost barriers); `deps` are job ids that
    must finish first.  `tracked=False` marks work the protocol has
    abandoned (a deadline-dropped client's partial download/compute chain):
    it appears in the timeline for inspection but counts toward neither
    round completion nor the makespan (the adapters also give such jobs no
    resource, so abandoned work never queues ahead of live work).
    """

    job_id: int
    kind: str                      # "compute" | "transfer" | "barrier" | "deadline"
    duration: float
    resource: str | None = None
    deps: tuple[int, ...] = ()
    round: int = 0
    label: str = ""
    tracked: bool = True


class JobTimes(dict):
    """job_id -> (start, finish)."""


@dataclasses.dataclass
class Timeline:
    """Resolved wall-clock schedule of one simulated run.

    `dropped` / `dropped_bits` are filled by the adapters when a per-round
    reporting deadline is in force (see `adapters.timeline_for`): clients
    whose broadcast->compute->upload chain missed the deadline, and the
    uplink bits their never-sent uploads would have cost.
    """

    job_times: JobTimes
    round_end: dict[int, float]    # round -> completion time of its last job
    makespan: float
    dropped: dict[int, frozenset] = dataclasses.field(default_factory=dict)
    dropped_bits: int = 0

    def drop_counts(self) -> dict[int, int]:
        """Per-round deadline-dropped client counts (empty without a
        deadline) — the shape the timeline exporter and the summary tables
        consume."""
        return {r: len(c) for r, c in sorted(self.dropped.items()) if c}

    def round_duration(self, round_idx: int) -> float:
        """Wall-clock between the end of the previous round and this one."""
        prev = [r for r in self.round_end if r < round_idx]
        start = self.round_end[max(prev)] if prev else 0.0
        return self.round_end[round_idx] - start

    def time_until(self, round_idx: int) -> float:
        """Wall-clock at the first recorded round >= round_idx (the timing
        analogue of `CommLedger.bits_until`)."""
        for r in sorted(self.round_end):
            if r >= round_idx:
                return self.round_end[r]
        return self.makespan


def simulate(jobs: Sequence[Job]) -> Timeline:
    """Resolve a job DAG into start/finish timestamps.

    Deterministic: jobs become ready when all deps finished, run on their
    resource in (ready_time, job_id) order, and never preempt.
    """
    by_id = {j.job_id: j for j in jobs}
    assert len(by_id) == len(jobs), "duplicate job ids"
    children: dict[int, list[int]] = defaultdict(list)
    missing = defaultdict(int)
    for j in jobs:
        for d in j.deps:
            assert d in by_id, f"job {j.job_id} depends on unknown job {d}"
            children[d].append(j.job_id)
            missing[j.job_id] += 1

    ready_time = {j.job_id: 0.0 for j in jobs}
    heap = [(0.0, j.job_id) for j in jobs if missing[j.job_id] == 0]
    heapq.heapify(heap)
    resource_free: dict[str, float] = defaultdict(float)
    times = JobTimes()
    round_end: dict[int, float] = {}

    while heap:
        ready, jid = heapq.heappop(heap)
        job = by_id[jid]
        start = ready
        if job.resource is not None:
            start = max(start, resource_free[job.resource])
        finish = start + job.duration
        if job.resource is not None:
            resource_free[job.resource] = finish
        times[jid] = (start, finish)
        if job.tracked:
            round_end[job.round] = max(round_end.get(job.round, 0.0), finish)
        for child in children[jid]:
            ready_time[child] = max(ready_time[child], finish)
            missing[child] -= 1
            if missing[child] == 0:
                heapq.heappush(heap, (ready_time[child], child))

    assert len(times) == len(jobs), "dependency cycle: not all jobs ran"
    makespan = max((times[j.job_id][1] for j in jobs if j.tracked), default=0.0)
    return Timeline(times, round_end, makespan)
