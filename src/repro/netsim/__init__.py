# repro.netsim — event-driven network/time simulation over the bit ledger.
# Turns a recorded run's CommEvent stream into wall-clock time-to-accuracy:
# link + compute models (links.py), a deterministic DAG/resource event
# simulator (events.py), and per-algorithm adapters (adapters.py).
from repro.netsim.adapters import (
    build_jobs,
    replay_run,
    simulate_run,
    time_to_accuracy,
    timeline_for,
)
from repro.netsim.events import Job, Timeline, simulate
from repro.netsim.links import (
    ComputeModel,
    LinkModel,
    NetworkModel,
    edge_cloud_network,
    sgd_step_flops,
)

__all__ = [
    "Job",
    "Timeline",
    "simulate",
    "ComputeModel",
    "LinkModel",
    "NetworkModel",
    "edge_cloud_network",
    "sgd_step_flops",
    "build_jobs",
    "replay_run",
    "timeline_for",
    "simulate_run",
    "time_to_accuracy",
]
