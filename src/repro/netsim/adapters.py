"""Per-algorithm timing adapters: `CommEvent` streams -> job DAGs -> timelines.

A driver run already recorded *what* was sent (hop, bits, sender, receiver,
round, interaction phase) in its `CommLedger`; the adapter's job is to add
the *ordering semantics* the protocol implies and the *compute* the messages
bracket:

  * every in-cluster interaction is  broadcast -> E local steps -> upload,
    with an aggregation barrier before the next interaction;
  * Fed-CHS appends one ES->ES transfer per round that the entire next round
    depends on (the serial chain);
  * FedAvg's round is one interaction of E=K against the PS over the WAN,
    all clients in parallel;
  * Hier-Local-QSGD runs every cluster's interaction chain in parallel, then
    a two-level barrier: PS waits for all ES uploads, ESs wait for the PS
    broadcast;
  * WRWGD alternates compute and a client->client hop — a pure chain.

E is recovered from the stream itself (K total steps spread over the
observed number of interaction phases), so the adapter needs only what a
deployment would know statically: K, the batch size, and the model size.

The same recorded run can be re-timed under any number of `NetworkModel`s —
the straggler/bandwidth sweeps in benchmarks/fig_time_to_acc.py re-use one
training run per algorithm and only re-run this (cheap, host-side) replay.

Deadlines (`deadline_s`, per interaction): real aggregators do not wait
forever — a client whose broadcast -> compute -> upload chain exceeds the
reporting deadline is DROPPED: its upload never happens (those bits are
saved, tallied in `Timeline.dropped_bits`), but the aggregator still waits
out the full deadline before closing the phase (wall-clock wasted; the
client abandons its partial chain, which stays in the timeline untracked
and resource-free).  This is a timing-layer re-interpretation of a recorded
run — the training trajectory is unchanged, which keeps the replay cheap;
pair it with a `repro.part` sampler at training time when the dropouts
should also affect learning.  The drop decision evaluates chains at the
*attempted* fan-in (conservative under `shared_ingress`); surviving uploads
are then charged the post-drop fan-in.  Pass-through
rounds (a `repro.part` run whose active cluster was empty) carry no
wireless phases — the round is just its ES->ES model hop.  WRWGD's walk has
no aggregation phase, so deadlines don't apply to it (a pass-through walk
round is still charged its local compute: the event stream alone cannot
distinguish it).
"""
from __future__ import annotations

from collections import defaultdict

from repro.netsim.events import Job, Timeline, simulate
from repro.netsim.links import NetworkModel, sgd_step_flops

__all__ = ["build_jobs", "replay_run", "timeline_for", "simulate_run",
           "time_to_accuracy"]

_WIRELESS_UP = ("client_to_es", "client_to_ps")
_WIRELESS_DOWN = ("es_to_client", "ps_to_client")


class _Builder:
    def __init__(self, net: NetworkModel, deadline_s: float | None = None):
        self.net = net
        self.deadline_s = deadline_s
        self.jobs: list[Job] = []
        self.dropped: dict[int, set[str]] = defaultdict(set)
        self.dropped_bits: int = 0

    def transfer_duration(self, ev, fan_in=1) -> float:
        return self.net.transfer_time(ev.hop, ev.sender, ev.receiver, ev.n_bits,
                                      ev.round, ev.phase, fan_in)

    def transfer(self, ev, deps, label="", fan_in=1, duration=None) -> int:
        dur = self.transfer_duration(ev, fan_in) if duration is None else duration
        return self._add("transfer", dur, f"{ev.sender}->{ev.receiver}", deps,
                         ev.round, label or ev.hop)

    def compute(self, node, flops, round_idx, deps) -> int:
        dur = self.net.compute_time(node, flops, round_idx)
        return self._add("compute", dur, node, deps, round_idx, "local_sgd")

    def barrier(self, deps, round_idx) -> int:
        return self._add("barrier", 0.0, None, deps, round_idx, "barrier")

    def _add(self, kind, duration, resource, deps, round_idx, label,
             tracked=True) -> int:
        jid = len(self.jobs)
        self.jobs.append(Job(jid, kind, duration, resource, tuple(deps), round_idx,
                             label, tracked))
        return jid


def _phases(events):
    by_phase = defaultdict(list)
    for ev in events:
        by_phase[ev.phase].append(ev)
    return [by_phase[p] for p in sorted(by_phase)]


def _interaction(b: _Builder, phase_events, step_flops, entry_deps) -> list[int]:
    """One broadcast -> compute -> upload interaction for one server's
    clients; returns the upload job ids (the aggregation barrier inputs)."""
    down_events = [e for e in phase_events if e.hop in _WIRELESS_DOWN]
    up_events = [e for e in phase_events if e.hop in _WIRELESS_UP]
    downs = {e.receiver: e for e in down_events}
    ups = {e.sender: e for e in up_events}
    # one broadcast + one upload per client per interaction — duplicate
    # (sender, receiver) events (record(count>1) with metadata) would be
    # silently collapsed here, diverging time from bits
    assert len(downs) == len(down_events) and len(ups) == len(up_events), \
        "duplicate per-client messages in one interaction phase"
    assert downs.keys() == ups.keys(), "unpaired broadcast/upload in interaction"
    # pass 1 — deadline triage: a client whose chain would overrun the
    # reporting deadline is dropped.  The decision uses the *attempted*
    # fan-in (everyone starts uploading), which is conservative under
    # shared_ingress.
    dropped = set()
    if b.deadline_s is not None:
        for client, down in downs.items():
            chain = (b.transfer_duration(down)
                     + b.net.compute_time(client, step_flops, down.round)
                     + b.transfer_duration(ups[client], fan_in=len(ups)))
            if chain > b.deadline_s:
                dropped.add(client)
    # pass 2 — build jobs.  A dropped client abandons the round's work at the
    # deadline: its partial download/compute stay in the timeline (untracked,
    # for inspection) but hold NO resources — so the round closes at
    # max(kept uploads, deadline), and no later phase ever queues behind
    # abandoned work (which keeps pass 1's chains-start-at-phase-entry
    # arithmetic exact).  Surviving uploads split the aggregator's bandwidth
    # over the post-drop fan-in.
    kept_fan_in = len(ups) - len(dropped)
    up_jobs = []
    for client, down in sorted(downs.items()):
        if client in dropped:
            d = b._add("transfer", b.transfer_duration(down), None, entry_deps,
                       down.round, down.hop, tracked=False)
            b._add("compute", b.net.compute_time(client, step_flops, down.round),
                   None, [d], down.round, "local_sgd", tracked=False)
            # the upload never happens: bits saved, deadline waited out below
            b.dropped[down.round].add(client)
            b.dropped_bits += ups[client].n_bits
            continue
        d = b.transfer(down, entry_deps)
        c = b.compute(client, step_flops, down.round, [d])
        up_jobs.append(b.transfer(ups[client], [c], fan_in=kept_fan_in))
    if dropped:
        # the aggregator closes the phase no earlier than the full deadline
        up_jobs.append(b._add("deadline", b.deadline_s, None, entry_deps,
                              phase_events[0].round, "deadline"))
    return up_jobs


def _in_cluster_phases(events):
    """Split a round's events into wireless interaction phases vs the rest."""
    wireless, rest = [], []
    for ev in events:
        (wireless if ev.hop in _WIRELESS_UP + _WIRELESS_DOWN else rest).append(ev)
    return _phases(wireless), rest


def _steps_per_interaction(local_steps: int, n_phases: int) -> int:
    assert n_phases > 0 and local_steps % n_phases == 0, \
        f"K={local_steps} does not split over {n_phases} observed interactions"
    return local_steps // n_phases


def _compile(result, net: NetworkModel, *, local_steps: int, batch_size: int,
             num_params: int, deadline_s: float | None = None) -> _Builder:
    """Compile a run's event stream into the algorithm's job DAG; the
    returned builder also carries deadline-dropout bookkeeping."""
    builders = {
        "fed_chs": _build_sequential,
        "wrwgd": _build_walk,
        "fedavg": _build_star,
        "hier_local_qsgd": _build_hier,
    }
    events = result.ledger.round_events()
    assert events, "run has no structured events (ledger.track_events off?)"
    flops1 = sgd_step_flops(num_params, batch_size)
    if deadline_s is None:
        deadline_s = net.deadline_s
    b = _Builder(net, deadline_s)
    builders[result.name](b, events, local_steps, flops1)
    return b


def build_jobs(result, net: NetworkModel, *, local_steps: int, batch_size: int,
               num_params: int, deadline_s: float | None = None) -> list[Job]:
    """Compile a run's event stream into the algorithm's job DAG."""
    return _compile(result, net, local_steps=local_steps, batch_size=batch_size,
                    num_params=num_params, deadline_s=deadline_s).jobs


def _build_sequential(b, events, local_steps, flops1):
    """Fed-CHS: interaction barriers inside the active cluster, then the
    round's single ES->ES model pass gates everything that follows.  A
    pass-through round (whole cluster unavailable: no wireless phases in the
    stream) is just the forwarded-model hop."""
    prev: list[int] = []
    for t in sorted(events):
        phases, rest = _in_cluster_phases(events[t])
        if phases:
            step_flops = _steps_per_interaction(local_steps, len(phases)) * flops1
            for phase_events in phases:
                ups = _interaction(b, phase_events, step_flops, prev)
                prev = [b.barrier(ups, t)]
        (hop,) = [e for e in rest if e.hop == "es_to_es"]
        prev = [b.transfer(hop, prev)]
    return b.jobs


def _build_star(b, events, local_steps, flops1):
    """FedAvg: one E=K interaction against the PS, all clients parallel."""
    prev: list[int] = []
    for t in sorted(events):
        phases, rest = _in_cluster_phases(events[t])
        assert not rest, "FedAvg rounds are client<->PS only"
        step_flops = _steps_per_interaction(local_steps, len(phases)) * flops1
        for phase_events in phases:
            ups = _interaction(b, phase_events, step_flops, prev)
            prev = [b.barrier(ups, t)]
    return b.jobs


def _build_hier(b, events, local_steps, flops1):
    """Hier-Local-QSGD: per-cluster interaction chains in parallel, then the
    two-level ES->PS / PS->ES aggregation barrier."""
    prev: list[int] = []
    for t in sorted(events):
        phases, rest = _in_cluster_phases(events[t])
        step_flops = _steps_per_interaction(local_steps, len(phases)) * flops1
        # split each interaction phase by the aggregating ES
        cluster_prev: dict[str, list[int]] = defaultdict(lambda: list(prev))
        for phase_events in phases:
            per_es = defaultdict(list)
            for ev in phase_events:
                per_es[ev.sender if ev.hop == "es_to_client" else ev.receiver].append(ev)
            for es, evs in sorted(per_es.items()):
                ups = _interaction(b, evs, step_flops, cluster_prev[es])
                cluster_prev[es] = [b.barrier(ups, t)]
        es_up_events = sorted((e for e in rest if e.hop == "es_to_ps"),
                              key=lambda e: e.sender)
        es_ups = [b.transfer(ev, cluster_prev[ev.sender], fan_in=len(es_up_events))
                  for ev in es_up_events]
        ps_barrier = b.barrier(es_ups, t)
        downs = [b.transfer(ev, [ps_barrier])
                 for ev in sorted((e for e in rest if e.hop == "ps_to_es"),
                                  key=lambda e: e.receiver)]
        prev = [b.barrier(downs, t)]
    return b.jobs


def _build_walk(b, events, local_steps, flops1):
    """WRWGD: K local steps at the visited client, then one model hop."""
    prev: list[int] = []
    for t in sorted(events):
        (hop,) = events[t]
        c = b.compute(hop.sender, local_steps * flops1, t, prev)
        prev = [b.transfer(hop, [c])]
    return b.jobs


def replay_run(result, net: NetworkModel, *, local_steps: int, batch_size: int,
               num_params: int,
               deadline_s: float | None = None) -> tuple[list[Job], Timeline]:
    """Replay a recorded run through `net`: the job DAG AND its resolved
    timeline, from ONE compile.

    The pair is what consumers that need job-level detail (the merged
    Perfetto exporter in `repro.obs.export`, which matches each `CommEvent`
    to the transfer job that carried it) use; callers that only want
    wall-clock aggregates can keep calling `timeline_for`."""
    b = _compile(result, net, local_steps=local_steps, batch_size=batch_size,
                 num_params=num_params, deadline_s=deadline_s)
    tl = simulate(b.jobs)
    tl.dropped = {r: frozenset(s) for r, s in b.dropped.items()}
    tl.dropped_bits = b.dropped_bits
    return b.jobs, tl


def timeline_for(result, net: NetworkModel, *, local_steps: int, batch_size: int,
                 num_params: int, deadline_s: float | None = None) -> Timeline:
    """Wall-clock timeline of a recorded run under `net`.

    `deadline_s` (default: `net.deadline_s`) switches on deadline dropouts;
    the timeline then reports who was dropped when (`Timeline.dropped`) and
    the uplink bits saved (`Timeline.dropped_bits`)."""
    _, tl = replay_run(result, net, local_steps=local_steps,
                       batch_size=batch_size, num_params=num_params,
                       deadline_s=deadline_s)
    return tl


def simulate_run(task, result, net: NetworkModel, *, local_steps: int,
                 deadline_s: float | None = None) -> Timeline:
    """`timeline_for` with batch size / model size pulled from the task."""
    return timeline_for(result, net, local_steps=local_steps,
                        batch_size=task.batch_size, num_params=task.num_params(),
                        deadline_s=deadline_s)


def time_to_accuracy(result, timeline: Timeline, gamma: float) -> float | None:
    """Seconds of simulated wall-clock until test accuracy first reaches
    `gamma` (None if the run never got there) — the timing analogue of
    `RunResult.bits_to_accuracy`."""
    r = result.rounds_to_accuracy(gamma)
    return None if r is None else timeline.time_until(r)
