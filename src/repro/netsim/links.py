"""Per-hop link models and per-node compute models for the network simulator.

The paper's §3.2 overhead model counts information *bits* per hop type and is
deliberately silent about *time* — that is what lets Fed-CHS claim a win by
hop-count arithmetic alone.  This module supplies the missing physical layer
so `repro.netsim.events` can turn the bit ledger into wall-clock:

  * `LinkModel` — one hop class (wireless client<->ES, backhaul ES<->ES, WAN
    anything<->PS): a sustained `bandwidth_bps`, a fixed per-message
    `latency_s` (propagation + protocol), and bounded multiplicative jitter.
  * `ComputeModel` — effective local-SGD throughput (flops/s); per-node
    heterogeneity and stragglers are seeded multiplicative speed factors.
  * `NetworkModel` — the bundle: resolves (hop, sender, receiver, bits,
    round) -> seconds and (node, flops, round) -> seconds, deterministically
    given (seed, inputs).  All randomness (jitter draw, straggler
    assignment, per-pair backhaul spread) is derived from crc32-hashed
    stable keys, so two identical runs produce identical timelines and the
    model is replayable without storing any state.

Dynamic topologies (repro/core/dynamics.py) plug in via `dynamics`: an
ES->ES transfer over a link that is invisible this round (LEO node out of
window) or faded-but-repaired (IoV Gilbert drop) runs at
`degraded_frac * bandwidth` — a flaky link costs time, it does not lose the
bits §3.2 already counted.

Everything is classical simulation on the host (numpy only) — no JAX here;
the training computation this clocks was already done by the round engine.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable

import numpy as np

__all__ = [
    "HOP_LINK_CLASS",
    "LinkModel",
    "ComputeModel",
    "NetworkModel",
    "sgd_step_flops",
    "edge_cloud_network",
]

# hop type (repro.core.ledger.HOPS) -> link class
HOP_LINK_CLASS = {
    "client_to_es": "wireless",
    "es_to_client": "wireless",
    "client_to_client": "wireless",
    "es_to_es": "backhaul",
    "es_to_ps": "wan",
    "ps_to_es": "wan",
    "client_to_ps": "wan",
    "ps_to_client": "wan",
}


def _rng(*key) -> np.random.Generator:
    """Deterministic, platform-stable generator from a structured key."""
    return np.random.default_rng(zlib.crc32(repr(key).encode()))


def sgd_step_flops(num_params: int, batch_size: int) -> float:
    """Estimated flops of ONE local SGD step on a dense model.

    Forward + backward of a dense network is ~3x the forward's 2*d
    multiply-adds per sample (the standard 6*N*D rule), so one step over a
    batch of B samples costs ~6 * d * B flops.  Good to a small constant
    factor for the paper's MLP/LeNet — and the constant cancels in
    algorithm *comparisons*, which all share one model.
    """
    return 6.0 * float(num_params) * float(batch_size)


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One class of physical link."""

    bandwidth_bps: float          # sustained throughput
    latency_s: float = 0.0        # fixed per-message cost (propagation + protocol)
    jitter: float = 0.0           # max fractional uniform jitter on transfer time

    def base_time(self, n_bits: float) -> float:
        return self.latency_s + n_bits / self.bandwidth_bps


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """Effective local-training throughput of a baseline node."""

    flops_per_second: float = 5e9  # modest edge CPU/NPU


@dataclasses.dataclass
class NetworkModel:
    """Deterministic physical network: hops -> seconds, flops -> seconds.

    `heterogeneity` spreads per-node compute speed uniformly in
    [1 - h, 1 + h]; a seeded `straggler_frac` fraction of nodes is
    additionally `straggler_slowdown`x slower in BOTH compute and their
    wireless access link (the HiFlash-style device straggler).
    `backhaul_spread` gives each unordered ES pair a fixed multiplicative
    delay factor in [1, 1 + spread] — the per-edge diversity the
    `LatencyAwareScheduler` tie-break exploits.

    By default every directed link is dedicated: n parallel uploads into a
    server each run at full link speed, so a star round costs the *max* over
    clients (the contract pinned in tests/test_netsim.py, deliberately
    client-favorable — it makes Fed-CHS time wins conservative).
    `shared_ingress=True` instead splits a receiver's bandwidth across the
    `fan_in` concurrent senders of an aggregation phase (processor-sharing
    approximation), modeling the PS ingress bottleneck the paper's §1
    argues star topologies pay at scale.
    """

    wireless: LinkModel = LinkModel(bandwidth_bps=50e6, latency_s=2e-3, jitter=0.0)
    backhaul: LinkModel = LinkModel(bandwidth_bps=1e9, latency_s=5e-3, jitter=0.0)
    wan: LinkModel = LinkModel(bandwidth_bps=100e6, latency_s=25e-3, jitter=0.0)
    compute: ComputeModel = ComputeModel()
    seed: int = 0
    heterogeneity: float = 0.0
    straggler_frac: float = 0.0
    straggler_slowdown: float = 4.0
    backhaul_spread: float = 0.0
    shared_ingress: bool = False       # split receiver bandwidth across fan-in
    dynamics: Callable | None = None   # DynamicTopology (round -> Topology)
    degraded_frac: float = 0.1         # bandwidth multiplier on flaky ES links
    deadline_s: float | None = None    # per-interaction reporting deadline: a
                                       # client whose broadcast->compute->upload
                                       # chain exceeds it is dropped by the
                                       # aggregator (bits saved, wall-clock
                                       # wasted — see netsim/adapters.py)
    _node_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    # -- per-node models ---------------------------------------------------

    def is_straggler(self, node: str) -> bool:
        return self._node(node)[1]

    def node_speed(self, node: str) -> float:
        """Compute-speed multiplier of `node` (1.0 = baseline)."""
        return self._node(node)[0]

    def _node(self, node: str) -> tuple[float, bool]:
        cached = self._node_cache.get(node)
        if cached is None:
            g = _rng(self.seed, "node", node)
            speed = 1.0 + self.heterogeneity * (2.0 * g.random() - 1.0)
            straggler = g.random() < self.straggler_frac
            if straggler:
                speed /= self.straggler_slowdown
            cached = self._node_cache[node] = (speed, straggler)
        return cached

    def compute_time(self, node: str, flops: float, round_idx: int = 0) -> float:
        """Seconds for `node` to execute `flops` of local training."""
        del round_idx  # speeds are static per node; hook kept for extensions
        return flops / (self.compute.flops_per_second * self.node_speed(node))

    # -- per-link models ---------------------------------------------------

    def _link(self, hop: str) -> LinkModel:
        return getattr(self, HOP_LINK_CLASS[hop])

    def _pair_factor(self, a: str, b: str) -> float:
        """Fixed per-unordered-pair backhaul delay multiplier in [1, 1+spread]."""
        if self.backhaul_spread == 0.0:
            return 1.0
        lo, hi = sorted((a, b))
        return 1.0 + self.backhaul_spread * _rng(self.seed, "pair", lo, hi).random()

    def _es_degraded(self, sender: str, receiver: str, round_idx: int) -> bool:
        """Is this ES->ES link flaky this round (invisible or Gilbert-dropped)?"""
        if self.dynamics is None:
            return False
        a, b = int(sender.split(":")[1]), int(receiver.split(":")[1])
        topo = self.dynamics(round_idx)
        if b not in topo.neighbors(a):
            return True
        dropped = getattr(self.dynamics, "dropped", None)
        if dropped is not None and (min(a, b), max(a, b)) in dropped(round_idx):
            return True
        return False

    def transfer_time(
        self,
        hop: str,
        sender: str,
        receiver: str,
        n_bits: float,
        round_idx: int = 0,
        phase: int = 0,
        fan_in: int = 1,
    ) -> float:
        """Seconds to move one `n_bits` message over `hop` in (round, phase).

        `phase` only salts the jitter draw — without it, every message
        between the same pair within a round would share one draw, which
        correlates jitter across a multi-interaction round and biases
        multi-phase algorithms (Fed-CHS) against single-phase ones (FedAvg).
        `fan_in` is how many senders upload to this receiver concurrently in
        this phase; it divides bandwidth only under `shared_ingress`.
        """
        link = self._link(hop)
        bw = link.bandwidth_bps
        if self.shared_ingress and fan_in > 1:
            bw /= fan_in
        # a straggler's radio is as slow as its CPU
        for end in (sender, receiver):
            if end.startswith("client:") and self.is_straggler(end):
                bw /= self.straggler_slowdown
        factor = 1.0
        if hop == "es_to_es":
            factor = self._pair_factor(sender, receiver)
            if self._es_degraded(sender, receiver, round_idx):
                bw *= self.degraded_frac
        t = (link.latency_s + n_bits / bw) * factor
        if link.jitter:
            u = _rng(self.seed, "jitter", hop, sender, receiver, round_idx, phase).random()
            t *= 1.0 + link.jitter * u
        return t

    def nominal_chain_s(self, link_class: str, n_bits: float, flops: float) -> float:
        """A nominal (no-straggler, no-jitter, baseline-speed) client chain:
        broadcast -> `flops` of local compute -> upload, both transfers of
        `n_bits` over `link_class` ("wireless" / "wan" / "backhaul").  The
        reference point for setting reporting deadlines — heterogeneity stays
        within a small multiple of it, stragglers blow through it (see the
        deadline semantics in netsim/adapters.py)."""
        link: LinkModel = getattr(self, link_class)
        return 2 * link.base_time(n_bits) + flops / self.compute.flops_per_second

    def backhaul_delay(self, a: int, b: int, n_bits: float) -> float:
        """Expected ES->ES model-pass delay — the `LatencyAwareScheduler`
        tie-break cost (no jitter, no round-specific degradation: the
        scheduler ranks links by their *nominal* quality)."""
        return self.backhaul.base_time(n_bits) * self._pair_factor(f"es:{a}", f"es:{b}")

    def link_delay_fn(self, n_bits: float) -> Callable[[int, int], float]:
        """`backhaul_delay` bound to a message size — plug directly into
        `FedCHSConfig.link_delay`."""
        return lambda a, b: self.backhaul_delay(a, b, n_bits)


def edge_cloud_network(
    *,
    seed: int = 0,
    wireless_mbps: float = 50.0,
    backhaul_mbps: float = 1000.0,
    wan_mbps: float = 100.0,
    wan_latency_ms: float = 25.0,
    flops_per_second: float = 5e9,
    heterogeneity: float = 0.0,
    straggler_frac: float = 0.0,
    straggler_slowdown: float = 4.0,
    backhaul_spread: float = 0.0,
    jitter: float = 0.0,
    dynamics: Callable | None = None,
    deadline_s: float | None = None,
) -> NetworkModel:
    """The canonical deployment the paper sketches: clients on access
    wireless, ESs on a metro backhaul, the (baselines-only) PS across a WAN."""
    return NetworkModel(
        wireless=LinkModel(wireless_mbps * 1e6, latency_s=2e-3, jitter=jitter),
        backhaul=LinkModel(backhaul_mbps * 1e6, latency_s=5e-3, jitter=jitter),
        wan=LinkModel(wan_mbps * 1e6, latency_s=wan_latency_ms * 1e-3, jitter=jitter),
        compute=ComputeModel(flops_per_second),
        seed=seed,
        heterogeneity=heterogeneity,
        straggler_frac=straggler_frac,
        straggler_slowdown=straggler_slowdown,
        backhaul_spread=backhaul_spread,
        dynamics=dynamics,
        deadline_s=deadline_s,
    )
