"""Regenerate EXPERIMENTS.md §Dry-run and §Roofline from experiments/dryrun/*.json,
the §Benchmarks table from BENCH_core.json (written by `benchmarks/run.py
--json`), the hand-authored §Perf log from experiments/perf_log.md, the
§Participation table written by `benchmarks/fig_participation.py`
(experiments/participation.md), and §Telemetry from
experiments/obs/summary.json (written by `benchmarks/run.py --profile`).  Sections whose inputs are absent are
omitted rather than rendered empty, and a malformed/partial suite output
(e.g. an interrupted benchmark run) skips that section with a warning
instead of aborting the whole regeneration.

  PYTHONPATH=src:. python scripts/make_experiments_md.py
"""
from __future__ import annotations

import glob
import json
import os
import re
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRYRUN = os.path.join(ROOT, "experiments", "dryrun")
PERF_LOG = os.path.join(ROOT, "experiments", "perf_log.md")
PARTICIPATION = os.path.join(ROOT, "experiments", "participation.md")
OBS_SUMMARY = os.path.join(ROOT, "experiments", "obs", "summary.json")
BENCH_JSON = os.path.join(ROOT, "BENCH_core.json")
OUT = os.path.join(ROOT, "EXPERIMENTS.md")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def _warn(msg):
    print(f"warning: {msg}", file=sys.stderr)


def load():
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        try:
            with open(fn) as f:
                rec = json.load(f)
            rec["arch"], rec["shape"], rec["mesh"]  # required keys
        except (json.JSONDecodeError, KeyError, OSError) as e:
            _warn(f"skipping malformed dryrun record {os.path.basename(fn)}: {e!r}")
            continue
        recs.append(rec)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 99), r["mesh"],
                             str(r.get("variant"))))
    return recs


def gb(x):
    return f"{x / 1e9:.2f}"


def dryrun_section(recs):
    lines = [
        "## §Dry-run",
        "",
        "Every (architecture × input shape) lowered **and compiled** with "
        "`jax.jit(...).lower(...).compile()` on the production meshes "
        "(single pod 16×16 = 256 chips, multi-pod 2×16×16 = 512 chips). "
        "`train_4k` lowers one Fed-CHS round (variant `fedchs`; `hfl` = "
        "star-aggregation baseline); decode shapes lower `serve_step` "
        "(1 token vs a seq_len cache). long_500k runs for mamba2 / "
        "recurrentgemma / mistral-nemo (sliding-window variant) and is "
        "skipped for pure full-attention archs + whisper (DESIGN.md §4): "
        "33 combos × 2 meshes + 20 HFL-variant train lowerings + 20 `+opt` "
        "train lowerings + 6 `opt` serve lowerings = "
        f"{len(recs)} records, all compiled successfully.",
        "",
        "| arch | shape | mesh | variant | compile s | bytes/dev (peak) | "
        "collective bytes/dev | HLO dot GFLOPs/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('variant','-')} | "
            f"{r['compile_s']} | {gb(r['memory'].get('peak_bytes', 0))} GB | "
            f"{gb(r['collective_bytes_per_device'])} GB | "
            f"{r['dot_flops_per_device'] / 1e9:.1f} |"
        )
    return "\n".join(lines)


def roofline_section(recs):
    lines = [
        "## §Roofline",
        "",
        "Terms in seconds/step per chip (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, "
        "50 GB/s/link ICI). compute = trip-scaled HLO dot FLOPs / peak; memory = "
        "cost-analysis bytes (trip-scaled) / HBM bw; collective = HLO collective "
        "operand bytes (all-reduce 2×) / link bw. MODEL_FLOPS = 6·N·D (train, "
        "N=active params for MoE) or 2·N·D (serve); MF/HLO = MODEL_FLOPS / "
        "(Σdev HLO dot FLOPs) — the useful-compute fraction (values <1 mean "
        "HLO does extra work: remat, attention, MoE dispatch; values >1 mean "
        "the analytic model overestimates, e.g. decode where cache reads "
        "dominate and matmul work is tiny). Single-pod table = the 40-pair "
        "baseline grid (33 lowered + 7 structural skips).",
        "",
    ]
    for mesh in ("single", "multi"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if not sub:
            continue
        lines += [
            f"### {mesh} mesh ({sub[0]['chips']} chips)",
            "",
            "| arch | shape | var | bound | compute s | memory s | collective s "
            "| peak GB/dev | MF/HLO |",
            "|---|---|---|---|---|---|---|---|---|",
        ]
        for r in sub:
            mf = r.get("model_vs_hlo")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('variant','-')} | "
                f"**{r['bound']}** | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
                f"{r['collective_s']:.3e} | {gb(r['memory'].get('peak_bytes', 0))} | "
                f"{mf:.2f} |" if mf else
                f"| {r['arch']} | {r['shape']} | {r.get('variant','-')} | "
                f"**{r['bound']}** | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
                f"{r['collective_s']:.3e} | {gb(r['memory'].get('peak_bytes', 0))} | - |"
            )
        lines.append("")
        # per-record bottleneck notes
    return "\n".join(lines)


def bottleneck_notes(recs):
    lines = ["### Dominant-bottleneck notes (single-pod baselines)", ""]
    seen = set()
    for r in recs:
        if r["mesh"] != "single" or str(r.get("variant")) == "hfl":
            continue
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        note = {
            "compute": "matmul-limited; gains come from MXU-friendlier tiles or fewer recomputed dots",
            "memory": "HBM-stream-limited; gains come from tighter activation/cache sharding, "
                      "vocab padding to shardable sizes, or smaller temporaries",
            "collective": "ICI-limited; gains come from removing redundant all-gathers / "
                          "reshaping the layout so contractions stay shard-local",
        }[r["bound"]]
        lines.append(f"- **{r['arch']} × {r['shape']}** — bound: {r['bound']} "
                     f"(c={r['compute_s']:.2e}, m={r['memory_s']:.2e}, "
                     f"x={r['collective_s']:.2e}); {note}.")
    return "\n".join(lines)


def bench_section():
    """§Benchmarks from BENCH_core.json (benchmarks/run.py --json)."""
    if not os.path.exists(BENCH_JSON):
        return ""
    with open(BENCH_JSON) as f:
        payload = json.load(f)
    mode = "quick" if payload.get("quick", True) else "--full"
    lines = [
        "## §Benchmarks",
        "",
        f"Machine-readable results from `benchmarks/run.py --json` ({mode} "
        "mode, 2-core CPU container; BENCH_core.json is also uploaded as a "
        "CI artifact by the perf-smoke and kernels-smoke jobs, so the perf "
        "trajectory is tracked across PRs).  `scanned_*` rows are the "
        "whole-run `lax.scan` executor vs the looped driver / seed-style "
        "loop at 200 rounds, steady-state.  The `kernel/qsgd_encode_*` rows' "
        "`payload_B` is the byte size of the actual packed uint32 wire value "
        "(+ f32 norm sidecar) and equals `QSGDChannel.message_bits(n) / 8` "
        "exactly — the ledger charges what the wire weighs "
        "(tests/test_ledger.py); `round/fed_chs_packed_qsgd` vs the "
        "dense-code baseline is the gated round-throughput comparison.",
        "",
        "| suite | row | per-call | derived |",
        "|---|---|---|---|",
    ]
    for suite, data in payload.get("suites", {}).items():
        for row in data.get("rows", []):
            us = row.get("us_per_call", 0.0)
            per = f"{us / 1e3:.1f} ms" if us >= 1e3 else f"{us:.1f} µs"
            lines.append(f"| {suite} | {row.get('name', '?')} | {per} | "
                         f"{row.get('derived', '')} |")
    return "\n".join(lines)


def population_section():
    """§Population scaling from BENCH_core.json's population suite
    (benchmarks/run.py --only population --json under forced 8 host
    devices): the device-mesh sharded round's gated parity ratio and the
    per-device share of the staged client-axis batch stack."""
    if not os.path.exists(BENCH_JSON):
        return ""
    with open(BENCH_JSON) as f:
        payload = json.load(f)
    rows = payload.get("suites", {}).get("population", {}).get("rows", [])
    if not rows:
        return ""
    by_name = {r["name"]: r for r in rows}
    sharded = by_name.get("population/fedavg_round_sharded", {})
    if "fallback" in sharded.get("derived", ""):
        return ""  # single-device run: no scaling numbers to report
    ratio = sharded.get("derived", "?").split("x")[0]
    lines = [
        "## §Population scaling",
        "",
        "The device-mesh sharded round engine (`repro.sharding.fed`,"
        " README §Population-scale sharding) on a 2×4 ('clusters',"
        " 'clients') mesh of forced host devices, vs the identical"
        " single-device run.  Sharing one physical core, the gated claim is"
        f" **parity** — the sharded round ran at {ratio}x the unsharded one"
        " (gate: 0.9x, `benchmarks/run.py --json` + the CI sharding-smoke"
        " job) while staying bit-identical (tests/test_sharding_fed.py)."
        "  The scaling win is the memory column: each device holds 1/D of"
        " the staged client-axis batch stack — the population-proportional"
        " allocation — so the max simulable population grows with mesh"
        " size instead of capping at one device's memory.",
        "",
        "| row | per-call | derived |",
        "|---|---|---|",
    ]
    for r in rows:
        us = r.get("us_per_call", 0.0)
        per = f"{us / 1e3:.1f} ms" if us >= 1e3 else f"{us:.1f} µs"
        lines.append(f"| {r['name']} | {per} | {r.get('derived', '')} |")
    staged = [r for r in rows
              if r["name"].startswith("population/staged_batch_n")]
    if staged:
        m = re.search(r"per_device_B=(\d+)_of_(\d+)",
                      staged[-1].get("derived", ""))
        if m and int(m.group(1)):
            per_dev, tot = int(m.group(1)), int(m.group(2))
            lines += ["", f"Staged-batch headroom at the largest measured "
                          f"population: {per_dev / 1e6:.2f} MB/device of "
                          f"{tot / 1e6:.2f} MB global — "
                          f"{tot / per_dev:.1f}x on 8 devices."]
    return "\n".join(lines)


def asyncfl_section():
    """§Async federation from BENCH_core.json's asyncfl suite
    (benchmarks/run.py --only asyncfl --json): async event-driven Fed-CHS
    vs the synchronous barrier chain on simulated time-to-accuracy."""
    if not os.path.exists(BENCH_JSON):
        return ""
    with open(BENCH_JSON) as f:
        payload = json.load(f)
    rows = payload.get("suites", {}).get("asyncfl", {}).get("rows", [])
    if not rows:
        return ""
    headline = payload.get("asyncfl_headline", {})
    speedups = sorted(
        (h.get("speedup") for h in headline.values() if h.get("speedup")),
        reverse=True)
    best = f"{speedups[0]:.1f}x" if speedups else "?"
    lines = [
        "## §Async federation",
        "",
        "The event-driven federation service (`repro.async_fl`, README"
        " §Async federation service) vs the synchronous barrier chain on"
        " SIMULATED time-to-accuracy: per scenario, sync trains once and is"
        " re-timed through the scenario's `NetworkModel`"
        " (`repro.netsim.simulate_run`), while async actually executes under"
        " that network + an availability trace — arrival times drive its"
        " event loop, so waiting is something it *chooses* (quorum/deadline)"
        " rather than suffers.  Under hard stragglers/churn the async chain"
        f" reached the target accuracy up to **{best}** sooner in simulated"
        " wall-clock (gate: >1x in at least one scenario, `benchmarks/run.py"
        " --json` + the CI async-smoke job), while its full-quorum"
        " arithmetic stays bit-identical to the sync driver and a killed"
        " run resumes bit-identically from its continuous checkpoint"
        " (tests/test_async_fl.py, tests/test_resume_parity.py).",
        "",
        "| row | per-call | derived |",
        "|---|---|---|",
    ]
    for r in rows:
        us = r.get("us_per_call", 0.0)
        per = f"{us / 1e3:.1f} ms" if us >= 1e3 else f"{us:.1f} µs"
        lines.append(f"| {r['name']} | {per} | {r.get('derived', '')} |")
    return "\n".join(lines)


def telemetry_section():
    """§Telemetry from experiments/obs/summary.json (benchmarks/run.py
    --profile): per-round tap aggregates, span wall-clocks, and the netsim
    replay's deadline-drop totals for one instrumented run."""
    if not os.path.exists(OBS_SUMMARY):
        return ""
    with open(OBS_SUMMARY) as f:
        s = json.load(f)
    tele, net = s["telemetry"], s["netsim"]
    lines = [
        "## §Telemetry",
        "",
        f"One instrumented `{s['algo']}` run ({s['rounds']} rounds, final "
        f"acc {s['final_acc']}) from `benchmarks/run.py --profile`: in-graph "
        "training-health taps, host phase spans, and a straggler-network "
        "replay merged into `experiments/obs/trace.json` (open in "
        "ui.perfetto.dev; validated by CI's obs-smoke job).  "
        f"{s['trace_events']} trace events, of which {s['comm_events']} comm "
        "instants — exactly one per CommLedger event.  Simulated makespan "
        f"{net['makespan_s']} s; the reporting deadline dropped "
        f"{net['dropped_client_rounds']} client-rounds, saving "
        f"{net['dropped_mb']} MB of uplink.  Tapped runs stay bit-identical "
        "to untapped ones (tests/test_engine_parity.py) and under the 10% "
        "overhead gate (benchmarks/run.py --json).",
        "",
        "| tap (per-round, run aggregate) | mean | max |",
        "|---|---|---|",
    ]
    for k, v in sorted(tele["metrics"].items()):
        lines.append(f"| {k} | {v['mean']:.4g} | {v['max']:.4g} |")
    lines += ["", "| host span | total wall s |", "|---|---|"]
    for k, v in tele["spans"].items():
        lines.append(f"| {k} | {v:.3f} |")
    return "\n".join(lines)


def _read(path):
    if os.path.exists(path):
        with open(path) as f:
            return f.read().strip()
    return ""


def main():
    recs = load()
    sections = [
        "# EXPERIMENTS — Fed-CHS reproduction + multi-pod dry-run + roofline",
        "(generated by scripts/make_experiments_md.py from experiments/dryrun/*.json; "
        "§Benchmarks and §Population scaling from BENCH_core.json, written by "
        "`benchmarks/run.py --json`; "
        "§Perf from experiments/perf_log.md; §Participation from "
        "experiments/participation.md, written by `benchmarks/run.py --only "
        "participation`; §Telemetry from experiments/obs/summary.json, written "
        "by `benchmarks/run.py --profile`; paper-claims validation from "
        "benchmarks — see bench_output.txt)",
    ]
    # each section tolerates its own broken/partial input: a failed suite
    # must not block regenerating the rest of EXPERIMENTS.md
    builders = []
    if recs:
        builders += [lambda: dryrun_section(recs), lambda: roofline_section(recs),
                     lambda: bottleneck_notes(recs)]
    builders += [bench_section, population_section, asyncfl_section,
                 telemetry_section,
                 lambda: _read(PARTICIPATION), lambda: _read(PERF_LOG)]
    for build in builders:
        try:
            section = build()
        except Exception as e:  # noqa: BLE001 — skip, don't abort
            _warn(f"skipping section {getattr(build, '__name__', 'lambda')}: {e!r}")
            continue
        if section:
            sections.append(section)
    with open(OUT, "w") as f:
        f.write("\n\n".join(sections) + "\n")
    print(f"wrote {OUT} ({len(recs)} dryrun records)")


if __name__ == "__main__":
    main()
