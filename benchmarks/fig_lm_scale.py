"""LM-scale suite: the memory-lean mixed-precision engine at LM client scale.

What `client_microbatch` + `Precision` buy for federated LM pretraining,
measured on the REAL compiled round function (XLA memory analysis, not a
model of it):

  * lmscale/peak_bytes_{vmapped,mb1} — compiled peak-live bytes (argument +
    temp + output - donated) of one delta-mode Fed-CHS round at n_clients=8,
    all-clients-vmapped vs client_microbatch=1.  The mb1 row's derived field
    is the gated ratio (`run.py --json` fails below 2.0x): scanning clients
    through one training slot drops the per-client activation replicas from
    O(n) to O(microbatch), which is the knob that lands a 0.6B-param client
    on one host.
  * lmscale/tokens_per_s_{toy,scaled} — end-to-end training throughput of
    the memory-lean configuration (bf16 compute + f32 master + bf16 wire,
    remat on the scaled arm) at toy and scaled-up dims, through the full
    driver (staging, channel, ledger).  Informational: CPU tokens/s is not a
    TPU claim; the rows exist so the trajectory is tracked per PR.
  * lmscale/dense_wire_bf16 — the wire half of the policy: the bf16 dense
    uplink's exact `channel_wire_bits` vs the f32 dense message.  Gated to be
    EXACTLY 2.00x ("_exact" suffix): the ledger prices the true payload, so
    the ratio is arithmetic, not measurement.

Standalone usage (applies the gates itself, exits nonzero on regression —
the CI lm-scale-smoke job runs exactly this):

  PYTHONPATH=src:. python benchmarks/fig_lm_scale.py [--quick]
"""
from __future__ import annotations

import sys
import time

GATE_PEAK = 2.0  # mb=1 must at least halve compiled peak-live bytes (run.py)


def _lm_task(*, d_model: int, layers: int, vocab: int, seq: int, batch: int,
             clients: int, clusters: int = 1, remat: bool = False,
             seed: int = 0):
    from repro.configs.base import ArchConfig
    from repro.core.simulation import FLTask
    from repro.data.sources import TokenSource
    from repro.models.fed import LMFedModel

    cfg = ArchConfig(
        name=f"lmscale-d{d_model}l{layers}", family="dense",
        num_layers=layers, d_model=d_model,
        num_heads=max(d_model // 64, 1), num_kv_heads=max(d_model // 128, 1),
        d_ff=4 * d_model, vocab_size=vocab, dtype="float32",
    )
    model = LMFedModel(cfg, remat=remat)
    source = TokenSource(vocab, clients, batch, seq, topics=2, seed=seed)
    # clusters=1 puts every client in one round (the axis client_microbatch
    # folds — used for the direct-engine peak measurement); the driver-level
    # timed rows need >= 2 clusters for the ES topology
    members = [[i for i in range(clients) if i % clusters == m]
               for m in range(clusters)]
    task = FLTask.from_source(model, source, members, seed=seed)
    return task


def _compiled_round(task, microbatch, precision, *, local_steps=4, epochs=2):
    """Lower + compile one delta-mode round; return (compiled, seconds)."""
    import jax.numpy as jnp

    from repro.core.engine import RoundEngine, _delta_round_fn
    from repro.core.precision import dense_wire_channel

    channel = dense_wire_channel(precision)
    engine = RoundEngine(task.model, channel, client_microbatch=microbatch,
                         precision=precision)
    params = task.init_params()
    n = len(task.cluster_members[0])
    opt_state = engine.init_opt_state(params, n)
    batch = task.sample_round_batches(0, local_steps, epochs)
    gammas = jnp.asarray(task.cluster_weights(0))
    J = local_steps // epochs
    lrs = jnp.full((J, epochs), 0.05, jnp.float32)
    fn = _delta_round_fn(engine.model, channel, engine.local_opt, False,
                         microbatch, precision)
    t0 = time.time()
    compiled = fn.lower(params, opt_state, batch, gammas, lrs, None).compile()
    return compiled, time.time() - t0


def _peak_bytes(compiled) -> int:
    from repro.roofline.analysis import analyze_compiled

    return int(analyze_compiled(compiled)["memory"]["peak_bytes"])


def _round_us(task, cfg, reps: int = 2) -> float:
    """Best-of-reps steady-state round time through the full driver."""
    from repro.core import run_fed_chs

    run_fed_chs(task, cfg)  # compile + warm the engine caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        run_fed_chs(task, cfg)
        best = min(best, time.time() - t0)
    return best / cfg.rounds * 1e6


def run(quick: bool = True):
    from repro.comm.bits import dense_message_bits
    from repro.comm.channels import DenseChannel, channel_wire_bits
    from repro.core import FedCHSConfig
    from repro.core.precision import Precision

    rows = []
    prec = Precision()  # bf16 compute / f32 master / bf16 wire

    # -- peak-live bytes: vmapped vs microbatched, identical math ------------
    # dims chosen so per-client activations dominate params: the O(n) term
    # the microbatch knob removes is what the measurement isolates
    mem_task = _lm_task(d_model=256, layers=4, vocab=2048, seq=128,
                        batch=8 if quick else 16, clients=8)
    c0, s0 = _compiled_round(mem_task, None, prec)
    c1, s1 = _compiled_round(mem_task, 1, prec)
    p0, p1 = _peak_bytes(c0), _peak_bytes(c1)
    ratio = p0 / p1
    rows.append(("lmscale/peak_bytes_vmapped", s0 * 1e6,
                 f"peak_B={p0}_n=8_clients"))
    rows.append(("lmscale/peak_bytes_mb1", s1 * 1e6,
                 f"{ratio:.2f}x_peak_reduction_vs_vmapped"))
    print(f"  peak live bytes n=8: vmapped {p0 / 1e6:.1f} MB  mb=1 "
          f"{p1 / 1e6:.1f} MB  ({ratio:.2f}x reduction)")

    # -- tokens/s: toy vs scaled dims under the memory-lean configuration ----
    toy = _lm_task(d_model=64, layers=2, vocab=512, seq=64, batch=4,
                   clients=8, clusters=2)
    K, E = 4, 2
    cfg = FedCHSConfig(rounds=4 if quick else 12, local_steps=K,
                       local_epochs=E, eval_every=100, initial_cluster=0,
                       precision=prec, client_microbatch=2, seed=0)
    us = _round_us(toy, cfg)
    tokens = 4 * K * 4 * 64  # clients-per-cluster * steps * batch * seq
    rows.append(("lmscale/tokens_per_s_toy", us,
                 f"{tokens / (us / 1e6):.0f}_tok_s_d64_L2"))
    print(f"  toy d=64 L=2: {tokens / (us / 1e6):.0f} tok/s")

    scaled = _lm_task(d_model=256, layers=4, vocab=2048, seq=128, batch=4,
                      clients=8, clusters=2, remat=True)
    cfg_s = FedCHSConfig(rounds=2 if quick else 6, local_steps=2,
                         local_epochs=1, eval_every=100, initial_cluster=0,
                         precision=prec, client_microbatch=2, seed=0)
    us_s = _round_us(scaled, cfg_s)
    tokens_s = 4 * 2 * 4 * 128
    rows.append(("lmscale/tokens_per_s_scaled", us_s,
                 f"{tokens_s / (us_s / 1e6):.0f}_tok_s_d256_L4_remat"))
    print(f"  scaled d=256 L=4 (remat): {tokens_s / (us_s / 1e6):.0f} tok/s")

    # -- the wire half: bf16 dense uplink is EXACTLY half the f32 message ----
    d = mem_task.num_params()
    sizes = mem_task.param_leaf_sizes()
    half = channel_wire_bits(DenseChannel(wire_dtype=prec.wire), d, sizes)
    full = dense_message_bits(d)
    exact = "_exact" if full == 2 * half else "_INEXACT"
    rows.append(("lmscale/dense_wire_bf16", 0.0,
                 f"{full / half:.2f}x_vs_f32_dense{exact}"))
    print(f"  dense wire: bf16 {half} bits vs f32 {full} bits "
          f"({full / half:.2f}x{exact})")
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()

    rows = run(quick=args.quick)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    failures = []
    for name, _us, derived in rows:
        if name == "lmscale/peak_bytes_mb1":
            s = float(derived.split("x")[0])
            if s < GATE_PEAK:
                failures.append(f"{name}: {s:.2f}x < {GATE_PEAK:.2f}x peak "
                                "reduction vs vmapped")
        if name == "lmscale/dense_wire_bf16" and not derived.endswith("_exact"):
            failures.append(f"{name}: bf16 wire is not exactly half the f32 "
                            f"dense message ({derived})")
    if failures:
        print("PERF REGRESSION: " + "; ".join(failures), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
