"""Assemble the §Roofline table from the dry-run JSON records
(experiments/dryrun/*.json) — run `python -m repro.launch.dryrun` first."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def format_table(recs: list[dict], *, mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r.get("variant", "-")))
    out = [
        f"{'arch':20s} {'shape':12s} {'var':7s} {'bound':10s} "
        f"{'compute_s':>11s} {'memory_s':>11s} {'collect_s':>11s} "
        f"{'mem/dev GB':>10s} {'MF/HLO':>7s}"
    ]
    for r in rows:
        ratio = r.get("model_vs_hlo")
        out.append(
            f"{r['arch']:20s} {r['shape']:12s} {str(r.get('variant', '-')):7s} "
            f"{r['bound']:10s} {r['compute_s']:11.3e} {r['memory_s']:11.3e} "
            f"{r['collective_s']:11.3e} "
            f"{r['memory'].get('peak_bytes', 0) / 1e9:10.2f} "
            f"{ratio if ratio else float('nan'):7.2f}"
        )
    return "\n".join(out)


def run(quick: bool = True):
    recs = load_records()
    rows = []
    if not recs:
        print("  (no dry-run records; run `python -m repro.launch.dryrun` first)")
        return rows
    for mesh in ("single", "multi"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if not sub:
            continue
        print(f"\nRoofline table ({mesh} mesh, {sub[0]['chips']} chips):")
        print(format_table(recs, mesh=mesh))
    for r in recs:
        rows.append((
            f"roofline/{r['arch']}-{r['shape']}-{r['mesh']}-{r.get('variant', '-')}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"bound={r['bound']}",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
