"""Async vs sync Fed-CHS: simulated time-to-accuracy under churn/stragglers.

The synchronous chain is barrier-synchronous per activation: the ES waits
for EVERY cluster member before the model hops on, so one 16x straggler in
the active cluster stalls the whole sequential pass.  The async service
(`repro.async_fl`) fires at the quorum arrival (capped by a deadline) and
folds late updates staleness-discounted on the chain's next visit — it
trades a little statistical efficiency per fold for a lot of simulated
wall-clock.

Method, per scenario:
  * sync — train once with CommEvents on, replay through the scenario's
    `NetworkModel` (`repro.netsim.simulate_run`), read wall-clock-to-Γ;
  * async — actually EXECUTE under the same network + an availability trace
    (arrival times drive the event loop), read `sim_time_to_accuracy(Γ)`.

The async PS baselines (FedBuff FedAvg, two-tier Hier) run as context arms.
The derived field of each `asyncfl/<scenario>-fedchs_async` row carries
``<x>x_vs_sync_t2gamma``; `run.py --json` gates on async beating sync in at
least one scenario.
"""
from __future__ import annotations

import time

from benchmarks.common import BenchScale, build_task, run_algorithm
from repro.async_fl import (
    AsyncFedCHSConfig,
    AsyncPSConfig,
    run_async_fed_chs,
    run_async_fedavg,
    run_async_hier,
)
from repro.netsim import edge_cloud_network, simulate_run, time_to_accuracy
from repro.part import AlwaysOn, BernoulliTrace

GAMMA = 0.70  # below fig_time_to_acc's 0.80: partial-quorum folds give up a
              # little per-round progress, and the gate needs every arm to
              # cross the target at reduced scale

# scenario -> (network factory, availability trace factory, async knobs).
# Both regimes are ones where waiting for the full cohort is the bottleneck.
SCENARIOS = {
    # hard stragglers: a 16x-slow client stalls every sync visit to its
    # cluster; the async ES fires at the 70% quorum and folds the straggler's
    # update (discounted) when the chain comes back
    "straggler": dict(
        network=lambda: edge_cloud_network(seed=0, heterogeneity=0.4,
                                           straggler_frac=0.3,
                                           straggler_slowdown=16.0),
        trace=AlwaysOn,
        quorum_frac=0.7, deadline_s=None,
    ),
    # device churn + moderate stragglers: sync still waits for every member
    # it dispatched; async only dispatches the clients that are up and caps
    # its wait with a deadline
    "churn": dict(
        network=lambda: edge_cloud_network(seed=0, heterogeneity=0.3,
                                           straggler_frac=0.15,
                                           straggler_slowdown=8.0),
        trace=lambda: BernoulliTrace(p=0.8, seed=7),
        quorum_frac=0.8, deadline_s=5.0,
    ),
}


def _fmt(t):
    return "-" if t is None else f"{t:.2f}"


def run(quick: bool = True):
    scale = BenchScale() if quick else BenchScale.paper()
    task = build_task("mnist", "mlp", 0.6, scale)
    rows = []

    # one sync training run; CommEvents let every scenario re-time it host-side
    res_sync, wall = run_algorithm("fed_chs", task, scale, seed=0,
                                   track_events=True)
    rows.append(("asyncfl/train-fed_chs_sync", wall * 1e6 / scale.rounds,
                 f"final_acc={res_sync.final_acc():.3f}"))

    print(f"\nSimulated time-to-Γ (Γ={GAMMA}, seconds; '-' = not reached):")
    wins = 0
    for scen, spec in SCENARIOS.items():
        net = spec["network"]()
        tl = simulate_run(task, res_sync, net, local_steps=scale.local_steps)
        t_sync = time_to_accuracy(res_sync, tl, GAMMA)

        t0 = time.time()
        res_async = run_async_fed_chs(task, AsyncFedCHSConfig(
            rounds=scale.rounds, local_steps=scale.local_steps,
            eval_every=scale.eval_every, network=net, trace=spec["trace"](),
            quorum_frac=spec["quorum_frac"], deadline_s=spec["deadline_s"],
            seed=0))
        t_async = res_async.sim_time_to_accuracy(GAMMA)
        wall_async = time.time() - t0

        if t_sync is not None and t_async is not None and t_async < t_sync:
            wins += 1
            derived = f"{t_sync / t_async:.2f}x_vs_sync_t2gamma"
        elif t_sync is not None and t_async is not None:
            derived = f"{t_sync / t_async:.2f}x_vs_sync_t2gamma"
        else:
            derived = f"t2gamma_s={_fmt(t_async)}_sync={_fmt(t_sync)}"
        rows.append((f"asyncfl/{scen}-fedchs_sync", 0.0,
                     f"t2gamma_s={_fmt(t_sync)}"))
        rows.append((f"asyncfl/{scen}-fedchs_async",
                     wall_async * 1e6 / scale.rounds, derived))

        # async-PS context arms under the same physical network
        ps_cfg = AsyncPSConfig(rounds=scale.rounds, local_steps=scale.local_steps,
                               quorum_k=max(task.num_clients // 5, 2),
                               eval_every=scale.eval_every, network=net,
                               trace=spec["trace"](), seed=0)
        for arm, runner in (("fedavg_async", run_async_fedavg),
                            ("hier_async", run_async_hier)):
            r = runner(task, ps_cfg)
            rows.append((f"asyncfl/{scen}-{arm}", 0.0,
                         f"t2gamma_s={_fmt(r.sim_time_to_accuracy(GAMMA))}"))

        print(f"{scen:12s} sync={_fmt(t_sync):>8s}s  async={_fmt(t_async):>8s}s"
              f"  acc_async={res_async.final_acc():.3f}"
              f"  staleness={res_async.ledger.staleness_histogram()}")

    rows.append(("asyncfl/scenarios-won", float(wins),
                 f"async_beats_sync_in_{wins}_of_{len(SCENARIOS)}"))
    return rows
