"""One profiled run -> the merged observability artifact set.

Runs a short instrumented run of any of the four algorithms (telemetry taps
+ host spans on, CommEvent stream tracked), replays it through `repro.netsim`
on a straggler-heavy edge with a reporting deadline (so the trace also shows
deadline drops), and writes `experiments/obs/`:

  trace.json    — merged Chrome-trace/Perfetto timeline (host spans + comm
                  events + simulated deployment jobs; open in
                  ui.perfetto.dev or chrome://tracing)
  metrics.jsonl — one row per round of in-graph training-health taps
                  (update_norm, drift, comp_err, mass)
  summary.json  — per-metric aggregates, span wall-clocks, netsim makespan
                  and deadline-drop totals

The trace is validated (`repro.obs.validate_chrome_trace`) before writing:
monotonic per-track timestamps, matched B/E pairs, comm-instant count ==
ledger event count.  Entry point: ``python benchmarks/run.py --profile
[algo]`` (CI's obs-smoke job) or this module directly.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from benchmarks.common import ALGORITHMS, BenchScale, algorithm_config, build_task
from repro.core.ledger import dense_message_bits
from repro.netsim import edge_cloud_network, replay_run, sgd_step_flops
from repro.obs import (
    RunTelemetry,
    build_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
    write_metrics_jsonl,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "obs")

# deadline setup shared with fig_participation / fig_time_to_acc: 3x a
# nominal client chain — heterogeneity stays inside, stragglers get dropped
_STEPS_PER_PHASE = {"fed_chs": 1, "fedavg": None, "hier_local_qsgd": 5,
                    "wrwgd": None}
_ACCESS = {"fed_chs": "wireless", "fedavg": "wan",
           "hier_local_qsgd": "wireless"}


def _profile_scale(quick: bool) -> BenchScale:
    return (BenchScale(train_size=2000, test_size=400, num_clients=15,
                       num_clusters=5, rounds=16, local_steps=10, eval_every=4)
            if quick else BenchScale())


def run_profile(algo: str = "fed_chs", *, quick: bool = True,
                profiler: bool = False, out_dir: str = OUT_DIR) -> dict:
    """Produce, validate, and write the merged observability artifacts for
    one short instrumented `algo` run; returns the summary dict."""
    assert algo in ALGORITHMS, f"unknown algorithm {algo!r}"
    scale = _profile_scale(quick)
    task = build_task("mnist", "mlp", 0.6, scale)
    d = task.num_params()

    # sync_chunks: block on each chunk's tele transfer so the scan_chunk
    # spans in the exported timeline measure real device execution
    obs = RunTelemetry(profiler=profiler, sync_chunks=True)
    run, config = algorithm_config(algo, scale, seed=0, track_events=True,
                                   qsgd=16 if algo == "fed_chs" else None)
    config = dataclasses.replace(config, obs=obs)
    if algo == "fed_chs":
        # E=5 + QSGD puts the flagship artifact on the delta-mode path, so
        # the exported drift / comp_err taps are live signals (grad mode
        # zeroes both structurally — see repro.obs.taps.grad_taps)
        config = dataclasses.replace(config, local_epochs=5)
    t0 = time.time()
    res = run(task, config)
    wall = time.time() - t0
    assert res.telemetry is obs

    net = edge_cloud_network(seed=2, heterogeneity=0.3, straggler_frac=0.25,
                             straggler_slowdown=16.0)
    steps = _STEPS_PER_PHASE[algo]
    if steps is None and algo == "fedavg":
        steps = scale.local_steps
    deadline = None
    if steps is not None:  # WRWGD's walk has no aggregation phase
        flops = steps * sgd_step_flops(d, task.batch_size)
        deadline = 3.0 * net.nominal_chain_s(_ACCESS[algo],
                                             dense_message_bits(d), flops)
    jobs, timeline = replay_run(res, net, local_steps=config.local_steps,
                                batch_size=task.batch_size, num_params=d,
                                deadline_s=deadline)

    trace = build_chrome_trace(obs, res.ledger, jobs, timeline)
    problems = validate_chrome_trace(trace,
                                     expected_comm_events=len(res.ledger.events))
    if problems:
        raise SystemExit("invalid merged trace:\n  " + "\n  ".join(problems))

    os.makedirs(out_dir, exist_ok=True)
    write_chrome_trace(trace, os.path.join(out_dir, "trace.json"))
    n_rows = write_metrics_jsonl(obs, os.path.join(out_dir, "metrics.jsonl"))

    summary = {
        "algo": algo,
        "rounds": config.rounds,
        "train_wall_s": round(wall, 2),
        "final_acc": round(res.final_acc(), 4),
        "telemetry": obs.summary(),
        "trace_events": len(trace["traceEvents"]),
        "comm_events": len(res.ledger.events),
        "netsim": {
            "jobs": len(jobs),
            "makespan_s": round(timeline.makespan, 3),
            "deadline_s": None if deadline is None else round(deadline, 4),
            "dropped_client_rounds": sum(timeline.drop_counts().values()),
            "dropped_mb": round(timeline.dropped_bits / 8e6, 2),
        },
    }
    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)

    print(f"profiled {algo}: {config.rounds} rounds in {wall:.1f}s, "
          f"{n_rows} telemetry rows, {len(trace['traceEvents'])} trace events "
          f"({len(res.ledger.events)} comm), netsim makespan "
          f"{timeline.makespan:.2f}s, dropped "
          f"{summary['netsim']['dropped_client_rounds']} client-rounds "
          f"({summary['netsim']['dropped_mb']} MB saved)")
    print(f"wrote {os.path.normpath(out_dir)}/{{trace.json, metrics.jsonl, "
          "summary.json} — open trace.json in ui.perfetto.dev")
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("algo", nargs="?", default="fed_chs", choices=ALGORITHMS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--profiler", action="store_true",
                    help="also wrap spans in jax.profiler.TraceAnnotation")
    args = ap.parse_args()
    run_profile(args.algo, quick=not args.full, profiler=args.profiler)
