"""Time-to-accuracy: the wall-clock companion to Fig. 2's bits-to-accuracy.

The paper's §3.2 overhead model (and our fig2_comm.py) ranks algorithms by
information bits to reach test accuracy Γ.  Bits are network-independent;
*time* is not: Fed-CHS's ES->ES pass is strictly serial (one cluster trains
per round) while FedAvg trains every client in parallel each round and
Hier-Local-QSGD every cluster.  This benchmark trains each algorithm ONCE,
then replays the recorded `CommEvent` stream through `repro.netsim` under a
sweep of network scenarios — re-timing is host-side and cheap, so one
training run prices out arbitrarily many networks.

The point of the sweep: the bits-winner and the time-winner need not agree.
On a WAN-starved or straggler-heavy edge, Fed-CHS's PS-free serial pass wins
both; give every node a fat pipe and a slow CPU and FedAvg's full-parallel
rounds overtake it in wall-clock while Fed-CHS still wins the bit count.
"""
from __future__ import annotations

import time

from benchmarks.common import BenchScale, build_task, run_algorithm
from repro.core.ledger import dense_message_bits
from repro.netsim import (
    edge_cloud_network,
    replay_run,
    sgd_step_flops,
    simulate_run,
    time_to_accuracy,
)

GAMMA = 0.80  # below fig2's 0.90: at the reduced per-algorithm round budgets
              # every algorithm (incl. 5-round Hier-Local-QSGD) crosses it, so
              # the table has a time-to-Γ entry in every cell

# scenario name -> NetworkModel factory (seeded, deterministic)
SCENARIOS = {
    # the paper's sketched deployment: access wireless, metro backhaul,
    # PS across a WAN
    "edge_cloud": lambda: edge_cloud_network(seed=0),
    # starved WAN: every PS hop is 50x slower — the regime §1 argues for
    "wan_starved": lambda: edge_cloud_network(seed=0, wan_mbps=2.0,
                                              wan_latency_ms=80.0),
    # fat pipes, slow devices: communication is free, parallelism is king
    "compute_bound": lambda: edge_cloud_network(seed=0, wireless_mbps=1e4,
                                                backhaul_mbps=1e5, wan_mbps=1e4,
                                                wan_latency_ms=1.0,
                                                flops_per_second=5e8),
    # heterogeneous edge with hard stragglers: a parallel round waits for the
    # slowest of ALL clients, a sequential round only for its own cluster's
    "straggler": lambda: edge_cloud_network(seed=0, heterogeneity=0.4,
                                            straggler_frac=0.3,
                                            straggler_slowdown=16.0, jitter=0.1),
}


def run(quick: bool = True):
    scale = BenchScale()
    task = build_task("mnist", "mlp" if quick else "lenet", 0.6, scale)
    rows = []

    runs = {}
    for name in ("fed_chs", "fedavg", "wrwgd", "hier_local_qsgd"):
        res, wall = run_algorithm(name, task, scale, seed=0, track_events=True)
        runs[name] = res
        # rounds_log always ends with the last training round, so the CSV is
        # per *training* round regardless of each algorithm's eval cadence
        n_rounds = res.rounds[-1] + 1 if res.rounds else 1
        rows.append((f"timeacc/train-{name}", wall / n_rounds * 1e6,
                     f"final_acc={res.final_acc():.3f}"))

    bits = {n: r.bits_to_accuracy(GAMMA) for n, r in runs.items()}
    reached = {n for n, b in bits.items() if b is not None}
    bits_winner = min(reached, key=lambda n: bits[n]) if reached else None

    print(f"\nTime-to-Γ (Γ={GAMMA}, seconds of simulated wall-clock; "
          "'-' = never reached at this reduced scale):")
    print(f"{'scenario':14s} " + " ".join(f"{n:>16s}" for n in runs))
    divergences = []
    for scen, make_net in SCENARIOS.items():
        net = make_net()
        t2a = {}
        for name, res in runs.items():
            t0 = time.time()
            tl = simulate_run(task, res, net, local_steps=scale.local_steps)
            t2a[name] = time_to_accuracy(res, tl, GAMMA)
            rows.append((f"timeacc/{scen}-{name}", (time.time() - t0) * 1e6,
                         f"t2gamma_s={None if t2a[name] is None else round(t2a[name], 2)}"))
        def fmt(v):
            return f"{v:16.2f}" if v is not None else f"{'-':>16s}"
        print(f"{scen:14s} " + " ".join(fmt(t2a[n]) for n in runs))
        timed = {n for n, v in t2a.items() if v is not None}
        time_winner = min(timed, key=lambda n: t2a[n]) if timed else None
        if bits_winner and time_winner and time_winner != bits_winner:
            divergences.append((scen, time_winner))

    # --- deadline replay: who the straggler edge DROPS, and what it saves.
    # Same recorded runs, re-timed with a per-interaction reporting deadline
    # of 3x a nominal client chain (fig_participation's setting): ±het stays
    # inside it, 16x stragglers blow through and are dropped.  WRWGD's walk
    # has no aggregation phase, so deadlines don't apply to it. ------------
    net = SCENARIOS["straggler"]()
    d = task.num_params()
    steps_per_phase = {"fed_chs": 1, "fedavg": scale.local_steps,
                       "hier_local_qsgd": 5}
    access = {"fed_chs": "wireless", "fedavg": "wan",
              "hier_local_qsgd": "wireless"}
    print("\nDeadline replay (straggler edge, deadline = 3x nominal chain):")
    for name in ("fed_chs", "fedavg", "hier_local_qsgd"):
        flops = steps_per_phase[name] * sgd_step_flops(d, task.batch_size)
        deadline = 3.0 * net.nominal_chain_s(access[name],
                                             dense_message_bits(d), flops)
        jobs, tl = replay_run(runs[name], net, local_steps=scale.local_steps,
                              batch_size=task.batch_size, num_params=d,
                              deadline_s=deadline)
        drops = tl.drop_counts()
        n_drop = sum(drops.values())
        rows.append((f"timeacc/deadline-{name}", float(len(jobs)),
                     f"dropped={n_drop}_saved_mb={tl.dropped_bits / 8e6:.1f}"))
        print(f"{name:16s} {len(jobs):6d} jobs  "
              f"dropped {n_drop} client-rounds over {len(drops)} rounds  "
              f"saved {tl.dropped_bits / 8e6:.1f} MB uplink")

    mb = {n: (None if b is None else round(b / 8e6, 1)) for n, b in bits.items()}
    print(f"bits-to-Γ (MB): {mb}  ->  bits-winner: {bits_winner}")
    for scen, tw in divergences:
        print(f"winner flip: '{scen}' time-winner is {tw}, bits-winner is {bits_winner}")
    if not divergences:
        print("no winner flip at this scale (expected at reduced rounds: see "
              "tests/test_netsim.py::test_bits_winner_and_time_winner_can_differ)")
    rows.append(("timeacc/winner-flips", float(len(divergences)),
                 f"bits_winner={bits_winner}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
