# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (hours on CPU); default is reduced")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig2,fig3,fig4,kernels,roofline,"
                         "engine,timeacc,participation")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import engine_speedup, fig2_comm, fig3_hparams, fig4_partial_het
    from benchmarks import fig_participation, fig_time_to_acc, kernels_micro
    from benchmarks import roofline, table1_accuracy

    suites = {
        "table1": table1_accuracy.run,
        "fig2": fig2_comm.run,
        "fig3": fig3_hparams.run,
        "fig4": fig4_partial_het.run,
        "kernels": kernels_micro.run,
        "roofline": roofline.run,
        "engine": engine_speedup.run,
        "timeacc": fig_time_to_acc.run,  # netsim smoke: wall-clock time-to-Γ
        "participation": fig_participation.run,  # churn: bits + deadline replay
    }
    selected = args.only.split(",") if args.only else list(suites)

    all_rows = []
    for name in selected:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        all_rows.extend(suites[name](quick=quick))
        print(f"[{name} done in {time.time() - t0:.1f}s]", flush=True)

    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
