# One function per paper table. Print ``name,us_per_call,derived`` CSV and
# optionally (--json) write machine-readable results to BENCH_core.json so
# the perf trajectory is tracked across PRs.
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_core.json")


def _speedup(derived: str) -> float | None:
    """Parse the leading '<x>x_vs_<ref>' speedup factor from a derived field."""
    m = re.match(r"([\d.]+)x", derived)
    return float(m.group(1)) if m else None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (hours on CPU); default is reduced")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig2,fig3,fig4,kernels,roofline,"
                         "engine,timeacc,participation,population,asyncfl,"
                         "lmscale")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_core.json (suite, rows, wall-clock; for the "
                         "engine suite also the scanned-vs-looped speedups) and "
                         "fail if the scanned whole-run driver is slower than "
                         "the looped one or a packed-QSGD round is slower than "
                         "the dense-code baseline")
    ap.add_argument("--profile", nargs="?", const="fed_chs", default=None,
                    metavar="ALGO",
                    help="run one short instrumented run (telemetry taps + "
                         "spans + netsim replay) and write the merged "
                         "Perfetto trace / metrics / summary to "
                         "experiments/obs/ instead of the benchmark suites")
    args = ap.parse_args()
    quick = not args.full

    if args.profile is not None:
        from benchmarks import profile_obs

        profile_obs.run_profile(args.profile, quick=quick)
        return

    from benchmarks import engine_speedup, fig2_comm, fig3_hparams, fig4_partial_het
    from benchmarks import fig_async, fig_lm_scale, fig_participation, fig_population
    from benchmarks import fig_time_to_acc, kernels_micro, roofline, table1_accuracy

    suites = {
        "table1": table1_accuracy.run,
        "fig2": fig2_comm.run,
        "fig3": fig3_hparams.run,
        "fig4": fig4_partial_het.run,
        "kernels": kernels_micro.run,
        "roofline": roofline.run,
        "engine": engine_speedup.run,
        "timeacc": fig_time_to_acc.run,  # netsim smoke: wall-clock time-to-Γ
        "participation": fig_participation.run,  # churn: bits + deadline replay
        "population": fig_population.run,  # device-mesh sharded client axis
        "asyncfl": fig_async.run,  # async event-loop vs sync barrier chain
        "lmscale": fig_lm_scale.run,  # microbatch peak memory + bf16 wire
    }
    selected = args.only.split(",") if args.only else list(suites)

    all_rows = []
    suite_results = {}
    for name in selected:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        rows = suites[name](quick=quick)
        dt = time.time() - t0
        suite_results[name] = {
            "wall_s": round(dt, 1),
            "rows": [
                {"name": n, "us_per_call": round(us, 1), "derived": d}
                for n, us, d in rows
            ],
        }
        all_rows.extend(rows)
        print(f"[{name} done in {dt:.1f}s]", flush=True)

    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")

    if not args.json:
        return

    payload = {"quick": quick, "suites": suite_results}
    failures = []
    if "engine" in suite_results:
        headline = {}
        for row in suite_results["engine"]["rows"]:
            s = _speedup(row["derived"])
            if s is None:
                continue
            headline[row["name"]] = {"speedup": s, "ref": row["derived"]}
            # the perf gate: the scanned whole-run driver must not be slower
            # than the looped driver it replaces.  Only the HOST-BOUND arms
            # are gated (their structural speedup is ~1.2-1.4x, leaving real
            # margin above the 0.9 noise floor on shared 2-core runners);
            # compute-bound arms sit at ~1.0x by construction — the scan
            # cannot beat the FLOP floor — so gating them would only convert
            # timing noise into red CI.  They are still recorded in the JSON.
            gated = ("scanned_fed_chs_grad", "scanned_wrwgd")
            if row["name"] in gated and "vs_looped_driver" in row["derived"]:
                if s < 0.9:
                    failures.append(
                        f"{row['name']}: {s:.2f}x < 0.90x vs looped driver")
            # the telemetry gate: in-graph taps + host spans must cost < 10%
            # wall-clock vs the identical untapped scanned run (0.91x ~=
            # 1/1.10) — observability has to be cheap enough to leave on
            if (row["name"] == "scanned_fed_chs_telemetry"
                    and "vs_untapped" in row["derived"] and s < 0.91):
                failures.append(
                    f"{row['name']}: {s:.2f}x < 0.91x vs untapped "
                    "(taps cost >10% wall-clock)")
        payload["engine_headline"] = headline
    if "kernels" in suite_results:
        # the packed-wire gate: a Fed-CHS round on the packed QSGDChannel
        # must not regress below the dense-f32-code baseline.  0.8, not 1.0:
        # the structural claim is parity (packing arithmetic hides under the
        # training compute), and few-ms rounds on shared runners carry real
        # timing noise; the wire-size win itself is exact and ledger-pinned.
        for row in suite_results["kernels"]["rows"]:
            if row["name"] != "round/fed_chs_packed_qsgd":
                continue
            s = _speedup(row["derived"])
            payload["kernels_headline"] = {row["name"]: {
                "speedup": s, "ref": row["derived"]}}
            if s is not None and s < 0.8:
                failures.append(
                    f"{row['name']}: {s:.2f}x < 0.80x vs dense-code QSGD")
    if "population" in suite_results:
        # the sharding gate: the device-mesh sharded round must stay within
        # 10% of the unsharded run.  On forced host devices (one physical
        # core) the claim is structural parity — identical total FLOPs, the
        # mesh collectives must hide under the compute; the fleet-level win
        # is the per-device memory scaling recorded in the staged_batch rows.
        # Single-device fallback rows carry no '<x>x' prefix and gate nothing.
        for row in suite_results["population"]["rows"]:
            if row["name"] != "population/fedavg_round_sharded":
                continue
            s = _speedup(row["derived"])
            payload["population_headline"] = {row["name"]: {
                "speedup": s, "ref": row["derived"]}}
            if s is not None and s < fig_population.GATE:
                failures.append(
                    f"{row['name']}: {s:.2f}x < {fig_population.GATE:.2f}x "
                    "vs unsharded")
    if "asyncfl" in suite_results:
        # the async gate: the event-driven Fed-CHS service must reach the
        # target accuracy in less SIMULATED wall-clock than the synchronous
        # chain in at least one churn/straggler scenario — that is the whole
        # claim of the async service (the arithmetic itself is anchored
        # bit-exactly to sync in tests/test_async_fl.py, so this gate is
        # about the timing model, not correctness)
        headline = {}
        best = 0.0
        for row in suite_results["asyncfl"]["rows"]:
            if not row["name"].endswith("-fedchs_async"):
                continue
            s = _speedup(row["derived"])
            headline[row["name"]] = {"speedup": s, "ref": row["derived"]}
            if s is not None:
                best = max(best, s)
        payload["asyncfl_headline"] = headline
        if headline and best <= 1.0:
            failures.append(
                f"asyncfl: async Fed-CHS beat sync in no scenario "
                f"(best {best:.2f}x <= 1.00x simulated time-to-accuracy)")
    if "lmscale" in suite_results:
        # the memory gate: client_microbatch=1 must at least HALVE the
        # compiled peak-live bytes of the n=8 round vs the all-clients vmap —
        # XLA's own memory analysis, so the number is structural, not timing
        # noise.  The wire gate is exact arithmetic: the bf16 dense uplink is
        # half the f32 message bit-for-bit or the ledger is lying.
        headline = {}
        for row in suite_results["lmscale"]["rows"]:
            s = _speedup(row["derived"])
            if s is not None:
                headline[row["name"]] = {"ratio": s, "ref": row["derived"]}
            if row["name"] == "lmscale/peak_bytes_mb1" and s is not None:
                if s < fig_lm_scale.GATE_PEAK:
                    failures.append(
                        f"{row['name']}: {s:.2f}x < "
                        f"{fig_lm_scale.GATE_PEAK:.2f}x peak reduction "
                        "vs vmapped")
            if (row["name"] == "lmscale/dense_wire_bf16"
                    and not row["derived"].endswith("_exact")):
                failures.append(
                    f"{row['name']}: bf16 wire not exactly half the f32 "
                    f"dense message ({row['derived']})")
        payload["lmscale_headline"] = headline
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {os.path.normpath(BENCH_JSON)}")
    if failures:
        print("PERF REGRESSION: " + "; ".join(failures), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
