"""Shared benchmark scaffolding: task construction + timed algorithm runs.

Reduced-scale by default (CPU container): the paper's axes are preserved
(datasets, models, Dirichlet λ, 4 algorithms, 100-client/10-ES option) but
rounds and dataset sizes are scaled down; `--full` restores the paper's
T=4000 / 100-client setting (hours on CPU).
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import FedCHSConfig, FLTask, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    WRWGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
    run_wrwgd,
)
from repro.data import assign_clusters, dirichlet_partition, make_dataset
from repro.models.classifier import make_classifier


@dataclasses.dataclass
class BenchScale:
    train_size: int = 4000
    test_size: int = 1000
    num_clients: int = 20
    num_clusters: int = 5
    rounds: int = 30
    local_steps: int = 10
    eval_every: int = 5
    # quick mode shrinks LeNet widths (paper's 64/256-kernel LeNet is ~20 min
    # per algorithm run on this CPU); --full restores Appendix A exactly.
    lenet_width_scale: float = 0.25
    batch_size: int = 32

    @classmethod
    def paper(cls) -> "BenchScale":
        return cls(train_size=50_000, test_size=10_000, num_clients=100,
                   num_clusters=10, rounds=4000, local_steps=20, eval_every=100,
                   lenet_width_scale=1.0)

    @classmethod
    def edge(cls) -> "BenchScale":
        """Host-bound regime: tiny per-round device compute (small batches,
        short local phases), so the simulator's own per-round host work —
        staging, dispatch, scheduling, accounting — is a visible fraction of
        wall-clock.  This is the regime the whole-run scan executor targets
        (and the regime any fast accelerator is in for every model size)."""
        return cls(train_size=2000, test_size=400, num_clients=20, num_clusters=5,
                   rounds=200, local_steps=10, eval_every=5, batch_size=4)


def build_task(dataset: str, model: str, lam: float, scale: BenchScale, *,
               seed: int = 0) -> FLTask:
    ds = make_dataset(dataset, train_size=scale.train_size, test_size=scale.test_size,
                      seed=seed)
    clients = dirichlet_partition(ds.train_y, scale.num_clients, lam, seed=seed)
    clusters = assign_clusters(scale.num_clients, scale.num_clusters, seed=seed)
    clf = make_classifier(model, dataset, ds.spec.image_shape, ds.spec.num_classes,
                          width_scale=scale.lenet_width_scale)
    return FLTask(clf, ds, clients, clusters, batch_size=scale.batch_size, seed=seed)


ALGORITHMS = ("fed_chs", "fedavg", "wrwgd", "hier_local_qsgd")


def algorithm_config(name: str, scale: BenchScale, *, qsgd: int | None = None,
                     seed: int = 0, track_events: bool = False, sampler=None):
    """The benchmark-scale config + run function for one algorithm — shared
    by `run_algorithm` and the multi-seed `run_sweep` path so both run the
    exact same settings."""
    if name == "fed_chs":
        return run_fed_chs, FedCHSConfig(
            rounds=scale.rounds, local_steps=scale.local_steps,
            eval_every=scale.eval_every, qsgd_levels=qsgd, seed=seed,
            track_events=track_events, sampler=sampler)
    if name == "fedavg":
        return run_fedavg, FedAvgConfig(
            rounds=max(scale.rounds // 4, 4), local_steps=scale.local_steps,
            eval_every=max(scale.eval_every // 4, 1), qsgd_levels=qsgd, seed=seed,
            track_events=track_events, sampler=sampler)
    if name == "wrwgd":
        return run_wrwgd, WRWGDConfig(
            rounds=scale.rounds * 2, local_steps=scale.local_steps,
            eval_every=scale.eval_every * 2, seed=seed, track_events=track_events,
            sampler=sampler)
    if name == "hier_local_qsgd":
        return run_hier_local_qsgd, HierLocalQSGDConfig(
            rounds=max(scale.rounds // 6, 2), local_steps=scale.local_steps,
            local_epochs=5, eval_every=max(scale.eval_every // 6, 1),
            qsgd_levels=qsgd if qsgd is not None else 16, seed=seed,
            track_events=track_events, sampler=sampler)
    raise ValueError(name)


def run_algorithm(name: str, task: FLTask, scale: BenchScale, *, qsgd: int | None = None,
                  seed: int = 0, track_events: bool = False, sampler=None):
    """`track_events=False` (default) skips the per-message CommEvent stream —
    only the netsim time-to-accuracy suite replays events, and at --full
    scale the stream would be millions of tuples per run.  `sampler` is an
    optional `repro.part` participation sampler (None = full participation,
    the seed-parity path)."""
    t0 = time.time()
    run, config = algorithm_config(name, scale, qsgd=qsgd, seed=seed,
                                   track_events=track_events, sampler=sampler)
    res = run(task, config)
    return res, time.time() - t0
