"""Participation under churn: bits scale with who reports, time diverges by
protocol shape.

Every algorithm is trained twice — full participation vs an availability
-aware sampler over a seeded Bernoulli churn trace (`repro.part`) — and the
churn runs are then replayed through `repro.netsim` on a straggler-heavy
edge network with a per-interaction reporting deadline:

  * **bits**: the ledger's uplink total must scale *exactly* with the
    participating-client count — per round, `|participants| x interactions
    x bits_per_message` (printed as the closed-form check; the ratio to the
    full run approximates the trace's up-probability).
  * **time**: deadline dropouts save bits but waste wall-clock (the
    aggregator waits out the deadline), and churn hits the protocols
    differently: a Fed-CHS round whose whole cluster is dark degrades to a
    pass-through ES->ES hop (nearly free), while the PS-bound baselines
    still pay their barrier every round — so the churn-induced slowdown of
    Fed-CHS and the star/hierarchical baselines *diverges*.

Writes `experiments/participation.md` (deterministic simulated quantities
only) for `scripts/make_experiments_md.py` to splice into EXPERIMENTS.md.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import ALGORITHMS, BenchScale, build_task, run_algorithm
from repro.core.ledger import dense_message_bits, qsgd_message_bits
from repro.netsim import edge_cloud_network, sgd_step_flops, simulate_run, time_to_accuracy
from repro.part import AvailabilityAware, BernoulliTrace

GAMMA = 0.75
UP_HOPS = ("client_to_es", "client_to_ps")
MD_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "participation.md")


def _uplink_bits(res) -> int:
    return sum(res.ledger.bits[h] for h in UP_HOPS)


def _pass_through_rounds(res) -> int:
    """Rounds that carried protocol traffic but zero client uplinks (the
    Fed-CHS dark-cluster forwarded-model case).  0 for WRWGD, whose walk
    never has client uplinks to begin with."""
    if not any(res.ledger.bits[h] for h in UP_HOPS):
        return 0
    up = {}
    for h in UP_HOPS:
        for t, bits in res.ledger.round_bits(h).items():
            up[t] = up.get(t, 0) + bits
    rounds_seen = {e.round for e in res.ledger.events}
    return len([t for t in rounds_seen if up.get(t, 0) == 0])


def run(quick: bool = True):
    scale = (BenchScale(train_size=3000, test_size=800, num_clients=15,
                        num_clusters=5, rounds=24, local_steps=10, eval_every=4)
             if quick else BenchScale())
    task = build_task("mnist", "mlp" if quick else "lenet", 0.6, scale)
    d = task.num_params()
    sampler = AvailabilityAware(BernoulliTrace(p=0.5, seed=7))
    # deadline sits between a nominal and a straggler client chain, so only
    # stragglers get dropped; seeded -> the dropout set is deterministic
    net = edge_cloud_network(seed=2, heterogeneity=0.3, straggler_frac=0.25,
                             straggler_slowdown=16.0)

    rows, md = [], []
    md.append("## §Participation\n")
    md.append(
        "Availability-aware sampling over a Bernoulli(p=0.5) churn trace vs "
        "full participation, plus a netsim replay on a straggler-heavy edge "
        f"with a per-interaction reporting deadline (Γ={GAMMA}). Uplink bits "
        "scale exactly with the participating-client count; a dark Fed-CHS "
        "cluster degrades to a pass-through ES->ES hop while the PS-bound "
        "baselines pay their barrier every round.\n")
    md.append("| algorithm | uplink MB (full) | uplink MB (churn) | ratio | "
              "pass-through rounds | t2Γ full (s) | t2Γ churn+deadline (s) | "
              "churn slowdown | dropped-by-deadline MB |")
    md.append("|---|---|---|---|---|---|---|---|---|")

    # per-interaction reporting deadline: 3x a nominal (non-straggler) client's
    # broadcast -> E-steps -> upload chain — heterogeneity (±30%) stays inside
    # it, 16x stragglers blow through it and get dropped
    steps_per_phase = {"fed_chs": 1, "fedavg": scale.local_steps,
                       "hier_local_qsgd": 5, "wrwgd": None}
    access = {"fed_chs": "wireless", "fedavg": "wan",
              "hier_local_qsgd": "wireless"}

    def _deadline(name):
        if steps_per_phase[name] is None:
            return None  # WRWGD's walk has no aggregation phase
        flops = steps_per_phase[name] * sgd_step_flops(d, task.batch_size)
        return 3.0 * net.nominal_chain_s(access[name], dense_message_bits(d), flops)

    slowdowns = {}
    for name in ALGORITHMS:
        full_res, wall_f = run_algorithm(name, task, scale, seed=0,
                                         track_events=True)
        churn_res, wall_c = run_algorithm(name, task, scale, seed=0,
                                          track_events=True, sampler=sampler)
        fb, cb = _uplink_bits(full_res), _uplink_bits(churn_res)
        # WRWGD has no client uplinks — its one model hop per round is
        # participation-independent, so compare total bits instead
        ratio = cb / fb if fb else churn_res.ledger.total_bits() / full_res.ledger.total_bits()

        # closed-form: per-round uplink bits == |senders| * phases * msg bits
        msg_bits = (qsgd_message_bits(d, 16) if name == "hier_local_qsgd"
                    else dense_message_bits(d))
        up_hop = next((h for h in UP_HOPS if churn_res.ledger.bits[h]), None)
        if up_hop is not None:
            for t, bits in churn_res.ledger.round_bits(up_hop).items():
                senders = churn_res.ledger.round_senders(t, up_hop)
                phases = len({e.phase for e in churn_res.ledger.events
                              if e.round == t and e.hop == up_hop})
                assert bits == len(senders) * phases * msg_bits, \
                    f"{name} round {t}: ledger bits off the closed form"

        # netsim: same straggler network; churn replay adds the deadline
        tl_full = simulate_run(task, full_res, net,
                               local_steps=scale.local_steps)
        tl_churn = simulate_run(task, churn_res, net,
                                local_steps=scale.local_steps,
                                deadline_s=_deadline(name))
        t2_full = time_to_accuracy(full_res, tl_full, GAMMA)
        t2_churn = time_to_accuracy(churn_res, tl_churn, GAMMA)
        per_round_full = tl_full.makespan / len(tl_full.round_end)
        per_round_churn = tl_churn.makespan / len(tl_churn.round_end)
        slowdowns[name] = per_round_churn / per_round_full
        pt = _pass_through_rounds(churn_res)

        def fmt(v):
            return "-" if v is None else f"{v:.2f}"

        rows.append((f"participation/train-{name}", (wall_f + wall_c) * 1e6,
                     f"uplink_ratio={ratio:.2f}"))
        rows.append((f"participation/t2gamma-{name}",
                     0.0 if t2_churn is None else t2_churn * 1e6,
                     f"t2gamma_full_s={fmt(t2_full)}"))
        md.append(f"| {name} | {fb / 8e6:.1f} | {cb / 8e6:.1f} | {ratio:.2f} | "
                  f"{pt} | {fmt(t2_full)} | {fmt(t2_churn)} | "
                  f"{slowdowns[name]:.2f}x | {tl_churn.dropped_bits / 8e6:.1f} |")
        print(f"{name:16s} uplink {fb / 8e6:7.1f} -> {cb / 8e6:7.1f} MB "
              f"(x{ratio:.2f})  pass-through rounds: {pt}  "
              f"t2Γ {fmt(t2_full)} -> {fmt(t2_churn)} s  "
              f"slowdown x{slowdowns[name]:.2f}  "
              f"deadline-dropped {tl_churn.dropped_bits / 8e6:.1f} MB")

    ps_names = [n for n in ("fedavg", "hier_local_qsgd") if n in slowdowns]
    diverges = any(abs(slowdowns["fed_chs"] - slowdowns[n]) > 0.05
                   for n in ps_names)
    verdict = ("DIVERGES" if diverges else "no divergence at this scale")
    print(f"churn slowdown fed_chs x{slowdowns['fed_chs']:.2f} vs PS baselines "
          + ", ".join(f"{n} x{slowdowns[n]:.2f}" for n in ps_names)
          + f" -> {verdict}")
    md.append(f"\nChurn-induced per-round slowdown: Fed-CHS "
              f"x{slowdowns['fed_chs']:.2f} vs "
              + ", ".join(f"{n} x{slowdowns[n]:.2f}" for n in ps_names)
              + f" — {verdict}.\n")
    rows.append(("participation/divergence", float(diverges),
                 f"fed_chs_slowdown={slowdowns['fed_chs']:.2f}"))

    os.makedirs(os.path.dirname(MD_PATH), exist_ok=True)
    with open(MD_PATH, "w") as f:
        f.write("\n".join(md) + "\n")
    return rows


if __name__ == "__main__":
    t0 = time.time()
    for r in run():
        print(",".join(map(str, r)))
    print(f"[{time.time() - t0:.1f}s]")
