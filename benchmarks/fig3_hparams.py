"""Paper Fig. 3: Fed-CHS sensitivity to K (local rounds), λ (heterogeneity)
and M (number of ESs)."""
from __future__ import annotations

from benchmarks.common import BenchScale, build_task, run_algorithm


def run(quick: bool = True):
    rows = []
    base = BenchScale()
    print("\nFig. 3a (K sweep, mnist/mlp λ=0.6):")
    for K in (5, 10, 20):
        scale = BenchScale(local_steps=K)
        task = build_task("mnist", "mlp", 0.6, scale)
        res, wall = run_algorithm("fed_chs", task, scale)
        print(f"  K={K:3d}  acc={res.final_acc():.4f}")
        rows.append((f"fig3/K{K}", wall / base.rounds * 1e6, f"acc={res.final_acc():.4f}"))

    print("Fig. 3b (λ sweep):")
    for lam in (0.1, 0.3, 0.6, 10.0):
        task = build_task("mnist", "mlp", lam, base)
        res, wall = run_algorithm("fed_chs", task, base)
        print(f"  λ={lam:5.1f}  acc={res.final_acc():.4f}")
        rows.append((f"fig3/lam{lam}", wall / base.rounds * 1e6, f"acc={res.final_acc():.4f}"))

    print("Fig. 3c (M sweep — too many ESs hurt, paper B.2):")
    for M in (2, 5, 10):
        scale = BenchScale(num_clusters=M)
        task = build_task("mnist", "mlp", 0.6, scale)
        res, wall = run_algorithm("fed_chs", task, scale)
        print(f"  M={M:3d}  acc={res.final_acc():.4f}")
        rows.append((f"fig3/M{M}", wall / base.rounds * 1e6, f"acc={res.final_acc():.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
